"""Continuous-batching serving benchmark -> BENCH_serve.json.

Replays closed-loop request traces (every request queued at t=0) at
increasing pressure levels against the continuous batcher, once with
dense weights and once with the same weights packed 2:4 — the serve-time
payoff the paper motivates (memory conservation -> decode throughput).

Headline numbers are **modeled TPU decode-roofline throughput**: the
scheduler run on CPU yields exact step counts, slot occupancy and
per-step context sizes (all deterministic for a greedy closed-loop
trace), and each decode step is costed at its HBM traffic
``(weight_bytes + kv_bytes) / bw`` — weights are read once per step
regardless of how many slots are active, which is precisely why
continuous batching multiplies decode throughput and why the 0.625x
packed weight traffic lifts it further at every pressure level.

Alongside the model, every mode row carries MEASURED per-step wall time
(``measured_step_us``: each level/mode is run ``MEASURE_REPEATS`` times
with dense and packed repeats interleaved, the compile tick is dropped
from each run's per-tick walls, and the minimum of the per-run medians
is reported — the scheduler is deterministic, so repeats only re-sample
CPU wall noise) and the steady-state throughput it implies
(``measured_tok_s``).  These are CPU numbers, not TPU predictions — but
they are exactly what caught the packed-slower-than-dense regression:
packed serving used to interpret the spmm24 Pallas kernel inside the
jitted per-token step.  ``serve/packed.decode_view`` now unpacks once
at construction, so the packed row's measured ratio vs dense
(``measured_packed_vs_dense``, dense step time / packed step time) must
sit at ~1.0 on CPU rather than ~0.5.

Gates vs the committed ``benchmarks/serve_baseline.json``: packed
modeled throughput within ``tolerance`` (5%) at every pressure level
(the benchmark also asserts modeled packed >= dense everywhere), and
the measured packed-vs-dense ratio within ``measured_tolerance`` (15%,
generous — CPU wall noise) of the baselined ratio — at the HIGH
pressure level only (low/mid are reported informationally: a low
pressure run decodes for ~19 steps, so its median step wall is a
handful of samples of pure CPU noise; see ``measured_gate_note`` in
the baseline).

Two serving-feature rows ride along (this PR's radix prefix cache +
chunked prefill), both MEASURED wall-clock, not modeled:

* ``bench_prefix_cache``: a shared-prefix Poisson trace replayed with
  the radix cache off then on (both chunked, so the numerics are
  identical and the decoded tokens are asserted bitwise-equal).  TTFT
  is per-request ``first_token - arrival``; ITL comes from
  ``RequestResult.token_times`` diffs.  Gates: TTFT p50 speedup
  (cache-on vs cache-off) >= ``prefix_ttft_min_speedup`` (2x), and the
  cached-vs-cold throughput ratio within ``measured_tolerance`` of the
  baselined ratio.
* ``bench_chunked_itl``: long prompts interleaved with in-flight
  decoders, eager one-shot prefill vs chunked.  Chunked prefill bounds
  the inter-token stall a decode slot sees while a neighbor prefills,
  so pooled ITL p99 (chunked / eager) is gated at
  ``chunked_itl_p99_max_ratio``.

One extra row measures the observability tax (``bench_obs_overhead``):
the high-pressure packed run repeated bare vs with ``repro.obs``
recording on, gated at ``obs_overhead_max_ratio`` (1.02 — recording is
a guarded attribute access + a bisect per tick, so the instrumented
step must stay within 2% of bare) and pinned token-identical.  The
instrumented run's spans are exported as a Perfetto trace for the CI
artifact (``TRACE_PATH``).
"""
from __future__ import annotations

import dataclasses
import json
import sys
import time
from typing import Dict, List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro import obs
from repro.core.sparsity import round_tree_nm
from repro.models.registry import model_def
from repro.serve import (BatchConfig, ContinuousBatcher, Request,
                         synthetic_trace)

OUT_PATH = "BENCH_serve.json"
BASELINE_PATH = "benchmarks/serve_baseline.json"

HBM_BW = 819e9                      # v5e, as kernel_bench/quality_bench

#: serving shape of the benchmark (fixed so rows are comparable PR-to-PR)
BATCH = BatchConfig(slots=4, block_size=16, max_blocks_per_request=2,
                    num_blocks=24, seed=0)
PROMPT_LEN, MAX_NEW = (8, 14), 16
PRESSURES = {"low": 4, "mid": 8, "high": 16}     # requests per trace

#: model-parallel degree of the extra TP roofline row: params shard per
#: the Megatron column/row rules and the paged KV pool heads-shards
#: (distributed/executor.py), so each device reads weight_bytes/TP and
#: kv_bytes/TP per step — the per-step roofline divides by TP.  The TP
#: scheduler behavior (steps, occupancy, tokens) is identical to the
#: single-device packed run: TP decode is pinned token-identical in
#: tests/distributed_cases.py::case_batcher_tp_parity.
TP_DEGREE = 4

#: shared-prefix workload: every prompt is one 96-token system prefix
#: plus a short per-request tail, arriving Poisson at PREFIX_RATE req/s
#: (slow enough that the first request's prefill usually completes —
#: and inserts the prefix into the radix cache — before the next
#: arrival, so nearly every later request hits)
PREFIX_BATCH = BatchConfig(slots=4, block_size=16, max_blocks_per_request=8,
                           num_blocks=64, seed=0, prefill_chunk=16)
PREFIX_LEN, PREFIX_TAIL = 96, (4, 12)
PREFIX_REQS, PREFIX_RATE, PREFIX_MAX_NEW = 10, 25.0, 8

#: ITL workload: two long-decode requests in flight while four
#: 112-token prompts prefill behind them — eager one-shot prefill
#: stalls the decoders for a full forward; chunked bounds each stall
#: at one 16-token chunk
ITL_BATCH = BatchConfig(slots=4, block_size=16, max_blocks_per_request=8,
                        num_blocks=64, seed=0)
ITL_SHORT_P, ITL_SHORT_NEW = 8, 32
ITL_LONG_P, ITL_LONG_NEW, ITL_LONG_REQS = 112, 4, 4

#: repeats for the measured serving-feature rows (each replays the
#: Poisson trace in wall time, so repeats are seconds, not ms)
FEATURE_REPEATS = 3


def _sparse_model() -> Tuple[object, object]:
    """Tiny opt-family model with every linear rounded to exact 2:4 —
    serve throughput doesn't depend on weight values, so no training."""
    cfg = common.opt_family_config()
    model = model_def(cfg)
    return model, round_tree_nm(model.init(jax.random.PRNGKey(0)))


def _tree_bytes(params) -> int:
    return int(sum(l.size * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree_util.tree_leaves(params)))


def _kv_token_bytes(cfg) -> int:
    """HBM bytes of one cached token across all layers (K + V)."""
    from repro.models.common import dtype_of
    itemsize = jnp.dtype(dtype_of(cfg.compute_dtype)).itemsize
    return 2 * cfg.num_layers * cfg.num_kv_heads * cfg.resolved_head_dim() * itemsize


def _modeled(st: Dict, results, weight_bytes: int, tok_kv: int,
             tp: int = 1) -> Dict:
    """Roofline numbers from the measured scheduler counters.  ``tp``
    divides the per-device weight and KV traffic (Megatron col/row
    sharding + heads-sharded paged pool): each model shard reads 1/tp of
    the weights and of the cached tokens per step."""
    wb, kb = weight_bytes / tp, tok_kv / tp
    step_s = (wb + kb * st["context_tokens"] / max(st["steps"], 1)) / HBM_BW
    prefill_s = (st["prefills"] * wb + st["prefill_tokens"] * kb) / HBM_BW
    modeled_total = st["steps"] * step_s + prefill_s
    tokens = int(sum(len(r.tokens) for r in results))
    # latency is modeled from *arrival* (t=0 in the closed-loop trace), so
    # queueing delay — the thing pressure buys — is included: a request
    # admitted late finishes at a later step and pays for it here
    lat = np.asarray([r.finished_step * step_s + (wb + r.prompt_len * kb) / HBM_BW
                      for r in results])
    return {
        "modeled_step_us": step_s * 1e6,
        "modeled_tok_s": tokens / max(modeled_total, 1e-12),
        "modeled_p50_ms": float(np.percentile(lat, 50)) * 1e3,
        "modeled_p99_ms": float(np.percentile(lat, 99)) * 1e3,
    }


#: measured-step repeats: the scheduler is deterministic, so re-running a
#: level only re-samples CPU wall noise — dense and packed alternate
#: within each repeat (both modes sample the same noise epochs; they run
#: bitwise-identical compute via decode_view, so any measured gap is
#: pure wall noise) and min-of-medians over the repeats is the
#: steady-state step time (the first tick's jit compile is dropped from
#: each repeat's median)
MEASURE_REPEATS = 5


def _one_run(model, params, sparse: str, n_requests: int):
    trace = synthetic_trace(n_requests, rate=0.0, vocab=model.cfg.vocab,
                            prompt_len=PROMPT_LEN, max_new_tokens=MAX_NEW,
                            seed=7)
    b = ContinuousBatcher(model, params,
                          dataclasses.replace(BATCH, sparse=sparse))
    t0 = time.perf_counter()
    res = b.run(trace)
    return b, res, time.perf_counter() - t0


def _median_step(batcher) -> float:
    walls = batcher.stats["step_walls"]
    return float(np.median(np.asarray(walls[1:] or walls)))


def _run_level_modes(model, params, n_requests: int) -> Dict[str, Dict]:
    """One pressure level, both modes, interleaved measured repeats."""
    first, meds = {}, {"dense": [], "packed": []}
    for rep in range(MEASURE_REPEATS):
        for sparse in ("dense", "packed"):
            b, res, wall = _one_run(model, params, sparse, n_requests)
            if rep == 0:
                first[sparse] = (b, res, wall)
            meds[sparse].append(_median_step(b))
    out = {}
    for sparse in ("dense", "packed"):
        batcher, results, wall = first[sparse]
        step_s = min(meds[sparse])
        st = batcher.stats
        tokens = int(sum(len(r.tokens) for r in results))
        weight_bytes = _tree_bytes(batcher.params)
        tok_kv = _kv_token_bytes(model.cfg)
        out[sparse] = {
            "mode": batcher.sparse_stats["mode"], "requests": n_requests,
            "tokens": tokens, "steps": st["steps"],
            "mean_occupancy": st["active_slot_steps"] / max(st["steps"], 1),
            "weight_bytes": weight_bytes,
            "cpu_wall_s": wall, "cpu_tok_s": tokens / max(wall, 1e-9),
            "measured_step_us": step_s * 1e6,
            "measured_tok_s": tokens / max(st["steps"] * step_s, 1e-12),
            **_modeled(st, results, weight_bytes, tok_kv),
            "token_ids": [r.tokens.tolist() for r in results],
            "_counters": (dict(st), results, weight_bytes, tok_kv),
        }
    return out


def bench_serve_matrix() -> List[Dict]:
    model, params = _sparse_model()
    rows = []
    for level, n in PRESSURES.items():
        per_mode = {}
        level_rows = _run_level_modes(model, params, n)
        for sparse in ("dense", "packed"):
            row = level_rows[sparse]
            st, results, weight_bytes, tok_kv = row.pop("_counters")
            toks = row.pop("token_ids")
            row["pressure"] = level
            per_mode[row["mode"]] = (row, toks)
            rows.append(row)
            if row["mode"] == "packed":
                # the regression this PR fixes: packed per-step wall must
                # not lag dense (same schedule, so step time IS
                # throughput).  Reported at 2 decimals — the run-to-run
                # spread of the underlying CPU walls is several percent,
                # so more digits would be noise printed as signal.
                row["measured_packed_vs_dense"] = round(
                    per_mode["dense"][0]["measured_step_us"]
                    / max(row["measured_step_us"], 1e-9), 2)
            print(f"{level:>5} {row['mode']:>6}: modeled "
                  f"{row['modeled_tok_s']:9.0f} tok/s "
                  f"(p50 {row['modeled_p50_ms']:.3f} ms, "
                  f"p99 {row['modeled_p99_ms']:.3f} ms, occupancy "
                  f"{row['mean_occupancy']:.2f}); measured "
                  f"{row['measured_step_us']:.0f} us/step, "
                  f"{row['measured_tok_s']:.1f} tok/s")
            if sparse == "packed":
                # TP row: same measured schedule (TP decode is pinned
                # token-identical), per-device traffic divided by the
                # model-parallel degree.  Only schedule-derived and
                # modeled fields appear — no cpu_wall/cpu_tok_s, since
                # no TP run was executed here, and weight_bytes is the
                # PER-DEVICE read the roofline actually charges.
                tp_row = dict(
                    mode=f"packed-tp{TP_DEGREE}", tp=TP_DEGREE,
                    requests=row["requests"], tokens=row["tokens"],
                    steps=row["steps"],
                    mean_occupancy=row["mean_occupancy"],
                    weight_bytes=weight_bytes // TP_DEGREE,
                    pressure=level,
                    **_modeled(st, results, weight_bytes, tok_kv,
                               tp=TP_DEGREE))
                rows.append(tp_row)
                print(f"{level:>5} {tp_row['mode']:>6}: modeled "
                      f"{tp_row['modeled_tok_s']:9.0f} tok/s "
                      f"(p50 {tp_row['modeled_p50_ms']:.3f} ms, "
                      f"p99 {tp_row['modeled_p99_ms']:.3f} ms)")
                assert tp_row["modeled_tok_s"] >= row["modeled_tok_s"], \
                    f"TP roofline regressed below packed at {level}"
        # packed serving is bitwise token-identical to dense, so both modes
        # schedule identically and the modeled comparison is apples-to-apples
        assert per_mode["packed"][1] == per_mode["dense"][1], \
            f"packed tokens diverged from dense at pressure {level}"
    return rows


def _latency_stats(results) -> Dict[str, float]:
    """Measured TTFT / pooled-ITL percentiles from one batcher run."""
    ttft = np.asarray([r.first_token - r.arrival for r in results])
    diffs = [np.diff(r.token_times) for r in results
             if r.token_times is not None and len(r.token_times) > 1]
    itl = np.concatenate(diffs) if diffs else np.asarray([0.0])
    return {"ttft_p50_ms": float(np.percentile(ttft, 50)) * 1e3,
            "ttft_p99_ms": float(np.percentile(ttft, 99)) * 1e3,
            "itl_p50_ms": float(np.percentile(itl, 50)) * 1e3,
            "itl_p99_ms": float(np.percentile(itl, 99)) * 1e3}


def _min_stats(per_repeat: List[Dict[str, float]]) -> Dict[str, float]:
    """min over repeats, field-wise — the deterministic scheduler means
    repeats only re-sample CPU wall noise (same convention as
    ``measured_step_us``)."""
    return {k: min(d[k] for d in per_repeat) for k in per_repeat[0]}


def _replay(batcher, trace) -> Tuple[List, Dict[str, float], Dict[str, int]]:
    """One wall-timed replay of ``trace`` on a (reused) batcher.  Request
    ids are offset per replay (the batcher retains results by id), and
    only this replay's results are returned, in trace order."""
    before = dict(batcher.stats)
    offset = getattr(batcher, "_bench_id_offset", 0)
    batcher._bench_id_offset = offset + 1000
    t0 = time.perf_counter()
    res = batcher.run([dataclasses.replace(r, id=r.id + offset)
                       for r in trace])
    wall = time.perf_counter() - t0
    res = sorted((r for r in res if offset <= r.id < offset + 1000),
                 key=lambda r: r.id)
    lat = _latency_stats(res)
    lat["wall_s"] = wall
    deltas = {k: batcher.stats[k] - before[k]
              for k in ("prefill_chunks", "prefills", "preemptions")}
    return res, lat, deltas


def bench_prefix_cache(model, params) -> List[Dict]:
    """Shared-prefix Poisson trace, radix cache off vs on.

    Each mode reuses ONE batcher across ``FEATURE_REPEATS`` timed
    replays after a warmup replay: the decode/chunk executables are
    per-batcher closures, so a fresh batcher per repeat would put a
    multi-hundred-ms jit compile inside the first requests' latency
    windows and swamp the percentiles.  For the cache-on mode the
    warmup also populates the radix cache — the timed replays measure
    steady-state serving, where even the trace's first request hits.
    The decoded tokens of the WARM cache-on replay are asserted
    bitwise-equal to the cache-off replay: that is the cache-identity
    anchor (a hit replays cached K/V, never approximates it)."""
    trace = synthetic_trace(PREFIX_REQS, rate=PREFIX_RATE,
                            vocab=model.cfg.vocab, prompt_len=PREFIX_TAIL,
                            max_new_tokens=PREFIX_MAX_NEW, seed=11,
                            shared_prefix_len=PREFIX_LEN)
    rows = []
    first: Dict[bool, List] = {}
    for cached in (False, True):
        cfg = dataclasses.replace(PREFIX_BATCH, sparse="packed",
                                  prefix_cache=cached)
        b = ContinuousBatcher(model, params, cfg)
        _replay(b, trace)                       # warmup: compiles (+ cache)
        stats, res, deltas = [], None, None
        for _ in range(FEATURE_REPEATS):
            res, lat, deltas = _replay(b, trace)
            stats.append(lat)
        first[cached] = res
        best = _min_stats(stats)
        tokens = int(sum(len(r.tokens) for r in res))
        prompt_tokens = int(sum(r.prompt_len for r in res))
        hit_tokens = int(sum(r.prefix_hit_tokens for r in res))
        rows.append({
            "mode": "prefix-cache-on" if cached else "prefix-cache-off",
            "pressure": "prefix", "requests": len(res), "tokens": tokens,
            "prefill_chunks": deltas["prefill_chunks"],
            "prefix_hit_rate": hit_tokens / max(prompt_tokens, 1),
            "measured_tok_s": tokens / max(best["wall_s"], 1e-9),
            **best})
    off, on = rows
    on["ttft_speedup"] = round(
        off["ttft_p50_ms"] / max(on["ttft_p50_ms"], 1e-9), 2)
    on["throughput_ratio"] = round(
        on["measured_tok_s"] / max(off["measured_tok_s"], 1e-9), 2)
    # the cache-hit path must be BITWISE the cold chunked path
    assert [r.tokens.tolist() for r in first[True]] == \
           [r.tokens.tolist() for r in first[False]], \
        "prefix-cache tokens diverged from cold chunked prefill"
    for row in rows:
        print(f"prefix {row['mode']:>16}: ttft p50 {row['ttft_p50_ms']:.1f} "
              f"ms / p99 {row['ttft_p99_ms']:.1f} ms, itl p99 "
              f"{row['itl_p99_ms']:.2f} ms, hit rate "
              f"{row['prefix_hit_rate']:.2f}, {row['prefill_chunks']} chunks")
    print(f"prefix ttft speedup {on['ttft_speedup']:.2f}x, throughput "
          f"ratio {on['throughput_ratio']:.2f}x (cache-on / cache-off)")
    return rows


def _itl_trace(vocab: int) -> List[Request]:
    rng = np.random.default_rng(13)
    def prompt(p):
        return rng.integers(0, vocab, size=p).astype(np.int32)
    reqs = [Request(id=i, prompt=prompt(ITL_SHORT_P),
                    max_new_tokens=ITL_SHORT_NEW) for i in range(2)]
    reqs += [Request(id=2 + i, prompt=prompt(ITL_LONG_P),
                     max_new_tokens=ITL_LONG_NEW)
             for i in range(ITL_LONG_REQS)]
    return reqs


def bench_chunked_itl(model, params) -> List[Dict]:
    """Long prompts behind live decoders: eager vs chunked prefill.
    Same warmup-replay discipline as ``bench_prefix_cache`` — the
    per-batcher jit compiles must not masquerade as prefill stalls."""
    trace = _itl_trace(model.cfg.vocab)
    rows = []
    for mode in ("eager", "chunked"):
        cfg = dataclasses.replace(
            ITL_BATCH, sparse="packed",
            prefill_chunk=None if mode == "eager" else 16)
        b = ContinuousBatcher(model, params, cfg)
        _replay(b, trace)                       # warmup: compiles
        stats, res, deltas = [], None, None
        for _ in range(FEATURE_REPEATS):
            res, lat, deltas = _replay(b, trace)
            stats.append(lat)
        best = _min_stats(stats)
        rows.append({"mode": f"prefill-{mode}", "pressure": "itl",
                     "requests": len(trace),
                     "tokens": int(sum(len(r.tokens) for r in res)),
                     "prefill_chunks": deltas["prefill_chunks"], **best})
    eager, chunked = rows
    chunked["itl_p99_ratio"] = round(
        chunked["itl_p99_ms"] / max(eager["itl_p99_ms"], 1e-9), 2)
    for row in rows:
        print(f"   itl {row['mode']:>16}: itl p50 {row['itl_p50_ms']:.2f} "
              f"ms / p99 {row['itl_p99_ms']:.2f} ms, ttft p99 "
              f"{row['ttft_p99_ms']:.1f} ms")
    print(f"   itl p99 ratio {chunked['itl_p99_ratio']:.2f} "
          f"(chunked / eager; <1 means chunking bounds the stall)")
    return rows


#: where the instrumented run's Perfetto trace lands (uploaded by CI)
TRACE_PATH = "experiments/bench/serve_trace.json"


def bench_obs_overhead(model, params) -> Dict:
    """The observability tax row: the 'high'-pressure packed run, once
    bare and once with ``repro.obs`` recording (spans + the batcher's SLO
    instruments), paired within each of ``MEASURE_REPEATS`` repeats.

    The GATED number is ``obs_overhead_ratio`` = 1 + (measured per-tick
    recording cost / bare median step time), where the recording cost
    times the batcher's own ``_record_tick_obs`` — the exact sequence the
    decode loop runs per tick.  Raw step-wall ratios cannot carry the 2%
    gate: recording happens *between* the measured step windows (OBS001
    keeps it out of the jitted step), so the off/on wall ratio is pure
    CPU noise at +-3-5% per session — it is still reported
    (``paired_wall_ratio``, median of per-repeat paired ratios) as a
    cross-check that nothing structural crept into the step.  The decoded
    tokens are asserted identical with recording on, and the instrumented
    run's spans are exported as a Perfetto trace (``TRACE_PATH``)."""
    n = PRESSURES["high"]
    meds: Dict[str, List[float]] = {"off": [], "on": []}
    first = {}
    for rep in range(MEASURE_REPEATS):
        for mode in ("off", "on"):
            # enable() resets recorder+registry, so each instrumented
            # repeat pays the same (fresh-instrument) recording cost
            obs.enable() if mode == "on" else obs.disable()
            b, res, _ = _one_run(model, params, "packed", n)
            if rep == 0:
                first[mode] = res
            meds[mode].append(_median_step(b))
    # time the real per-tick recording path on the last instrumented
    # batcher (its instruments and pool state are live)
    reps = 2000
    t0 = time.perf_counter()
    for _ in range(reps):
        b._record_tick_obs(BATCH.slots)
    rec_s = (time.perf_counter() - t0) / reps
    from repro.obs import spans as spans_lib
    spans_lib.export_perfetto(obs.recorder().spans(), TRACE_PATH)
    obs.disable()
    assert [r.tokens.tolist() for r in first["on"]] == \
           [r.tokens.tolist() for r in first["off"]], \
        "obs recording changed the decoded tokens"
    wall_ratios = [on / max(off, 1e-12)
                   for off, on in zip(meds["off"], meds["on"])]
    off, on = min(meds["off"]), min(meds["on"])
    row = {"mode": "packed-obs", "pressure": "high", "requests": n,
           "step_us_off": off * 1e6, "step_us_on": on * 1e6,
           "recording_us_per_tick": rec_s * 1e6,
           "paired_wall_ratio": round(float(np.median(wall_ratios)), 3),
           "obs_overhead_ratio": round(1.0 + rec_s / max(off, 1e-12), 4)}
    print(f" high packed-obs: recording {row['recording_us_per_tick']:.2f} "
          f"us/tick on a {row['step_us_off']:.0f} us bare step "
          f"(overhead ratio {row['obs_overhead_ratio']:.4f}; paired wall "
          f"ratio {row['paired_wall_ratio']:.3f}); trace -> {TRACE_PATH}")
    return row


def check_regression(rows: List[Dict], baseline_path: str = BASELINE_PATH
                     ) -> Tuple[bool, str]:
    """Gate: packed modeled throughput within ``tolerance`` of the
    committed baseline at every pressure level, and the MEASURED
    packed-vs-dense step-time ratio within ``measured_tolerance``
    (generous; CPU wall noise) of the baselined ratio.  Missing or
    protocol-mismatched baseline => informational pass."""
    try:
        with open(baseline_path) as f:
            base = json.load(f)
    except FileNotFoundError:
        return True, f"no baseline at {baseline_path} (gate skipped)"
    if base.get("protocol") != _protocol():
        return True, "baseline protocol differs (gate skipped; not comparable)"
    tol = float(base.get("tolerance", 0.05))
    mtol = float(base.get("measured_tolerance", 0.15))
    mbase = base.get("measured_packed_vs_dense", {})
    gate_level = base.get("measured_gate_pressure", "high")
    msgs, ok = [], True
    for level in PRESSURES:
        row = next(r for r in rows
                   if r["pressure"] == level and r["mode"] == "packed")
        limit = float(base["levels"][level]) * (1.0 - tol)
        good = row["modeled_tok_s"] >= limit
        ok &= good
        msgs.append(f"{level} {row['modeled_tok_s']:.0f}>= {limit:.0f} "
                    f"{'PASS' if good else 'FAIL'}")
        if level in mbase:
            # the ratio is ~1.0 by construction (decode_view makes both
            # modes run the same compute on CPU); cap the reference at
            # 1.0 so a lucky-fast baseline run can't tighten the gate.
            # Only the HIGH-pressure ratio is gated: a low-pressure trace
            # decodes for ~19 steps, so its median step wall is a
            # handful of CPU-noise samples (a 0.94 reading there is
            # indistinguishable from 1.0) — low/mid stay informational.
            mlimit = min(float(mbase[level]), 1.0) * (1.0 - mtol)
            mgood = row["measured_packed_vs_dense"] >= mlimit
            if level == gate_level:
                ok &= mgood
                msgs.append(f"{level} measured-ratio "
                            f"{row['measured_packed_vs_dense']:.2f}>= "
                            f"{mlimit:.2f} {'PASS' if mgood else 'FAIL'}")
            else:
                msgs.append(f"{level} measured-ratio "
                            f"{row['measured_packed_vs_dense']:.2f} (info)")
    pbase = base.get("prefix", {})
    prow = next((r for r in rows if r.get("mode") == "prefix-cache-on"), None)
    if pbase and prow is not None:
        floor = float(pbase.get("ttft_min_speedup", 2.0))
        sgood = prow["ttft_speedup"] >= floor
        ok &= sgood
        msgs.append(f"prefix ttft-speedup {prow['ttft_speedup']:.2f}>= "
                    f"{floor:.1f} {'PASS' if sgood else 'FAIL'}")
        if "throughput_ratio" in pbase:
            tlimit = float(pbase["throughput_ratio"]) * (1.0 - mtol)
            tgood = prow["throughput_ratio"] >= tlimit
            ok &= tgood
            msgs.append(f"prefix throughput-ratio "
                        f"{prow['throughput_ratio']:.2f}>= {tlimit:.2f} "
                        f"{'PASS' if tgood else 'FAIL'}")
    icap = base.get("chunked_itl_p99_max_ratio")
    irow = next((r for r in rows if r.get("mode") == "prefill-chunked"), None)
    if icap is not None and irow is not None:
        igood = irow["itl_p99_ratio"] <= float(icap)
        ok &= igood
        msgs.append(f"chunked itl-p99-ratio {irow['itl_p99_ratio']:.2f}<= "
                    f"{float(icap):.2f} {'PASS' if igood else 'FAIL'}")
    cap = base.get("obs_overhead_max_ratio")
    orow = next((r for r in rows if r.get("mode") == "packed-obs"), None)
    if cap is not None and orow is not None:
        ogood = orow["obs_overhead_ratio"] <= float(cap)
        ok &= ogood
        msgs.append(f"obs-overhead {orow['obs_overhead_ratio']:.3f}<= "
                    f"{float(cap):.2f} {'PASS' if ogood else 'FAIL'}")
    return ok, (f"packed vs baseline (modeled -{tol:.0%}, measured ratio "
                f"-{mtol:.0%}): " + "; ".join(msgs))


def _protocol() -> Dict:
    return {"batch": dataclasses.asdict(BATCH), "prompt_len": list(PROMPT_LEN),
            "max_new": MAX_NEW, "pressures": dict(PRESSURES),
            "prefix": {"batch": dataclasses.asdict(PREFIX_BATCH),
                       "prefix_len": PREFIX_LEN, "tail": list(PREFIX_TAIL),
                       "requests": PREFIX_REQS, "rate": PREFIX_RATE,
                       "max_new": PREFIX_MAX_NEW},
            "itl": {"batch": dataclasses.asdict(ITL_BATCH),
                    "short": [ITL_SHORT_P, ITL_SHORT_NEW],
                    "long": [ITL_LONG_P, ITL_LONG_NEW, ITL_LONG_REQS]}}


def write_baseline(rows: List[Dict], path: str = BASELINE_PATH,
                   tolerance: float = 0.05,
                   measured_tolerance: float = 0.15,
                   obs_overhead_max_ratio: float = 1.02,
                   prefix_ttft_min_speedup: float = 2.0,
                   chunked_itl_p99_max_ratio: float = 1.0) -> None:
    packed = [r for r in rows if r["mode"] == "packed"]
    prow = next((r for r in rows if r.get("mode") == "prefix-cache-on"), None)
    base = {"levels": {r["pressure"]: r["modeled_tok_s"] for r in packed},
            "tolerance": tolerance,
            "measured_packed_vs_dense":
                {r["pressure"]: r["measured_packed_vs_dense"]
                 for r in packed},
            "measured_tolerance": measured_tolerance,
            # dense and packed run BITWISE-identical compute on CPU
            # (packed.decode_view unpacks once at construction), so the
            # measured ratio is pure wall noise; only the high-pressure
            # level decodes long enough (~4x the steps of 'low') for its
            # median step wall to carry signal.  A 0.94 at 'low' is ~19
            # steps of CPU jitter, not a packed regression — hence the
            # gate applies at 'high' only and low/mid print as (info).
            "measured_gate_pressure": "high",
            "measured_gate_note":
                "dense/packed run bitwise-identical compute on CPU "
                "(decode_view), so the measured ratio is wall noise; "
                "'low' decodes ~19 steps and 'mid' ~35, too few for a "
                "stable median — the 15% measured_tolerance gate "
                "applies at 'high' only, low/mid are informational",
            # a FIXED cap, not baselined-run-relative: recording is
            # a few guarded attribute accesses + bisects per tick,
            # so instrumented/bare step time must stay within 2%
            "obs_overhead_max_ratio": obs_overhead_max_ratio,
            "protocol": _protocol()}
    if prow is not None:
        # ttft_min_speedup is a FIXED floor (the feature's contract:
        # cache hits must at least halve time-to-first-token on the
        # shared-prefix trace); the throughput ratio is baselined
        # run-relative like the other measured numbers
        base["prefix"] = {"ttft_min_speedup": prefix_ttft_min_speedup,
                          "throughput_ratio": prow["throughput_ratio"]}
    if any(r.get("mode") == "prefill-chunked" for r in rows):
        # FIXED cap: chunked prefill must never make tail inter-token
        # latency WORSE than eager one-shot prefill (measured ratios sit
        # well below 1 — each stall is one chunk, not a full prompt)
        base["chunked_itl_p99_max_ratio"] = chunked_itl_p99_max_ratio
    with open(path, "w") as f:
        json.dump(base, f, indent=1)
        f.write("\n")


def run_all(out_path: str = OUT_PATH, baseline_path: str = BASELINE_PATH,
            update_baseline: bool = False) -> Dict:
    print("\n== Continuous-batching serve (modeled TPU roofline, "
          "dense vs packed 2:4) ==")
    rows = bench_serve_matrix()
    model, params = _sparse_model()
    rows.append(bench_obs_overhead(model, params))
    print("\n== Serving features (measured wall): radix prefix cache, "
          "chunked prefill ==")
    rows += bench_prefix_cache(model, params)
    rows += bench_chunked_itl(model, params)
    packed_ge_dense = all(
        next(r for r in rows if r["pressure"] == lv and r["mode"] == "packed")
        ["modeled_tok_s"] >=
        next(r for r in rows if r["pressure"] == lv and r["mode"] == "dense")
        ["modeled_tok_s"] for lv in PRESSURES)
    # measured at the HIGH pressure level only — shorter runs' step
    # medians are CPU noise (see measured_gate_note in the baseline)
    packed_ge_dense_measured = next(
        r for r in rows if r["pressure"] == "high" and r["mode"] == "packed"
    )["measured_packed_vs_dense"] >= 1.0
    ok, msg = check_regression(rows, baseline_path)
    payload = {"rows": rows, "protocol": _protocol(), "hbm_bw": HBM_BW,
               "packed_ge_dense": packed_ge_dense,
               "packed_ge_dense_measured": packed_ge_dense_measured,
               "gate_ok": ok and packed_ge_dense, "regression_gate": msg,
               "backend": jax.default_backend()}
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    common.write_result("serve_bench", payload)
    if update_baseline:
        write_baseline(rows, baseline_path)
        print(f"baseline updated: {baseline_path}")
    print(f"\nwrote {out_path}; packed>=dense modeled: {packed_ge_dense}, "
          f"measured: {packed_ge_dense_measured}; {msg}")
    return payload


if __name__ == "__main__":
    payload = run_all(update_baseline="--update-baseline" in sys.argv)
    sys.exit(0 if payload["gate_ok"] else 1)
