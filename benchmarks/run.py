"""Benchmark orchestrator: one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run             # fast protocol
    PYTHONPATH=src python -m benchmarks.run --full      # full protocol
    PYTHONPATH=src python -m benchmarks.run --only table1,fig3

Every benchmark prints its table and writes experiments/bench/<name>.json.
``--only prune`` additionally writes BENCH_prune.json at the repo root:
FISTA outer-loop impl rows plus the per-solver matrix (one row per
registered solver — fista, admm, wanda, sparsegpt — per sparsity).
``--only quality`` writes BENCH_quality.json (held-out perplexity / KL
per solver per sparsity + the sparse-serving decode row) and enforces
the committed 2:4-fista perplexity regression gate
(benchmarks/quality_baseline.json).
``--only serve`` writes BENCH_serve.json (continuous-batching modeled
throughput + latency percentiles, dense vs packed 2:4 per pressure
level) and enforces the committed packed-throughput regression gate
(benchmarks/serve_baseline.json, 5%).
The headline assertion of the suite (the paper's claim) is checked at the
end: FISTAPruner ppl <= Wanda and SparseGPT at 50% and 2:4 on both
families.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="more training steps + wider sweeps")
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table2,table3,ptbc4,fig3,fig4a,"
                         "fig4b,seeds,kernels,prune,quality,serve")
    args = ap.parse_args()

    steps = 500 if args.full else 300
    from benchmarks import (figures, kernel_bench, prune_bench, quality_bench,
                            serve_bench, tables)

    registry = {
        "table1": lambda: tables.table1_opt_family(steps),
        "table2": lambda: tables.table2_llama_family(steps),
        "table3": lambda: tables.table3_zeroshot(steps),
        "ptbc4": lambda: tables.tables_ptb_c4(steps),
        "fig3": lambda: figures.fig3_sparsity_sweep(
            steps, ratios=(0.2, 0.35, 0.5, 0.65, 0.8) if args.full
            else (0.2, 0.5, 0.7)),
        "fig4a": lambda: figures.fig4a_error_correction(steps),
        "fig4b": lambda: figures.fig4b_calibration(
            steps, counts=(2, 4, 8, 16, 32) if args.full else (2, 8, 32)),
        "seeds": lambda: figures.seed_sensitivity(
            steps, seeds=(0, 1, 2, 3, 4) if args.full else (0, 1, 2)),
        "kernels": kernel_bench.run_all,
        "prune": prune_bench.run_all,
        "quality": lambda: quality_bench.run_all(steps),
        "serve": serve_bench.run_all,
    }
    names = args.only.split(",") if args.only else list(registry)

    results = {}
    t0 = time.perf_counter()
    for name in names:
        print(f"\n########## {name} ##########")
        t1 = time.perf_counter()
        results[name] = registry[name]()
        print(f"[{name} done in {time.perf_counter()-t1:.1f}s]")

    # regression gates (checked at the end so a drift never aborts the
    # remaining benchmarks mid-suite)
    ok = True
    for gate_name in ("quality", "serve"):
        g = results.get(gate_name)
        if isinstance(g, dict) and not g.get("gate_ok", True):
            ok = False
            print(f"{gate_name.upper()} GATE: {g.get('regression_gate')}")

    # headline claim check (paper Tables 1-2 ordering)
    for tbl in ("table1", "table2"):
        if tbl not in results:
            continue
        rows = results[tbl]
        for sp in ("50%", "2:4"):
            get = lambda m: next((r["ppl"] for r in rows
                                  if r["method"] == m and r["sparsity"] == sp),
                                 None)
            f, w, s = get("fista"), get("wanda"), get("sparsegpt")
            if f is None:
                continue
            verdict = f <= w * 1.02 and f <= s * 1.02
            ok &= verdict
            print(f"CLAIM {tbl}@{sp}: fista={f:.3f} wanda={w:.3f} "
                  f"sparsegpt={s:.3f} -> {'PASS' if verdict else 'FAIL'}")
    print(f"\nbenchmarks completed in {time.perf_counter()-t0:.1f}s; "
          f"verdict (headline ordering + quality gate): "
          f"{'PASS' if ok else 'FAIL'}")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
