"""Paper-figure benchmarks: Fig. 3 sparsity sweep, Fig. 4a error-correction
ablation, Fig. 4b calibration-count ablation, Sec. 4.4 seed sensitivity."""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.sparsity import SparsitySpec
from repro.data import CalibConfig

from benchmarks import common


def fig3_sparsity_sweep(steps: int = 300,
                        ratios=(0.2, 0.35, 0.5, 0.65, 0.8)) -> List[Dict]:
    """Fig. 3 analog: ppl vs unstructured sparsity per method.  The paper's
    low-sparsity claim (20% can beat dense) is checked on this curve."""
    t = common.train_family("opt", steps=steps)
    rows = [{"method": "dense", "ratio": 0.0, "ppl": t.dense_ppl}]
    for ratio in ratios:
        spec = SparsitySpec(ratio=ratio)
        for method in ("magnitude", "wanda", "sparsegpt", "fista"):
            res = common.prune_and_eval(t, method, spec)
            rows.append({"method": method, "ratio": ratio, "ppl": res["ppl"]})
    common.print_table("Fig. 3 analog — sparsity vs ppl",
                       rows, ["method", "ratio", "ppl"])
    common.write_result("fig3_sparsity_sweep", rows)
    return rows


def fig4a_error_correction(steps: int = 300) -> List[Dict]:
    """Fig. 4a analog: FISTAPruner with vs without intra-layer correction,
    plus the beyond-paper 'full' inter-layer mode."""
    t = common.train_family("opt", steps=steps)
    rows = []
    for ratio in (0.5, 0.6, 0.7):
        spec = SparsitySpec(ratio=ratio)
        for mode in ("intra", "none", "full"):
            res = common.prune_and_eval(t, "fista", spec, correction=mode)
            rows.append({"mode": mode, "ratio": ratio, "ppl": res["ppl"],
                         "mean_rel_err": res["mean_rel_err"]})
    common.print_table("Fig. 4a analog — intra-layer error correction",
                       rows, ["mode", "ratio", "ppl", "mean_rel_err"])
    common.write_result("fig4a_error_correction", rows)
    return rows


def fig4b_calibration(steps: int = 300, counts=(2, 4, 8, 16, 32)) -> List[Dict]:
    """Fig. 4b analog: ppl vs number of calibration sequences (powers of 2);
    the curve should flatten."""
    t = common.train_family("opt", steps=steps)
    rows = []
    for n in counts:
        calib = CalibConfig(num_sequences=n, seq_len=64,
                            batch_size=min(8, n), seed=1234)
        for method in ("wanda", "sparsegpt", "fista"):
            res = common.prune_and_eval(t, method, SparsitySpec(ratio=0.5),
                                        calib=calib)
            rows.append({"method": method, "n_calib": n, "ppl": res["ppl"]})
    common.print_table("Fig. 4b analog — calibration-sample count",
                       rows, ["method", "n_calib", "ppl"])
    common.write_result("fig4b_calibration", rows)
    return rows


def seed_sensitivity(steps: int = 300, seeds=(0, 1, 2, 3, 4)) -> Dict:
    """Sec. 4.4 analog: ppl across calibration-sampling seeds (mean ± std)."""
    t = common.train_family("opt", steps=steps)
    ppls = []
    for s in seeds:
        calib = CalibConfig(num_sequences=16, seq_len=64, batch_size=8,
                            seed=1000 + 17 * s)
        res = common.prune_and_eval(t, "fista", SparsitySpec(ratio=0.5),
                                    calib=calib)
        ppls.append(res["ppl"])
    out = {"seeds": list(seeds), "ppls": ppls,
           "mean": float(np.mean(ppls)), "std": float(np.std(ppls)),
           "rel_std": float(np.std(ppls) / np.mean(ppls))}
    print(f"\n== Seed sensitivity == ppl {out['mean']:.3f} ± {out['std']:.3f} "
          f"(rel {out['rel_std']:.3%})")
    common.write_result("seed_sensitivity", out)
    return out
