"""Kernel microbenchmarks + derived roofline accounting.

CPU wall times of the interpret-mode Pallas kernels are NOT TPU
predictions; the meaningful numbers here are the DERIVED columns —
bytes moved / FLOPs per call and the v5e-bound microseconds they imply
(the kernels' roofline positions), plus the fused-vs-unfused HBM-traffic
ratio the fista_step kernel is designed around.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ref

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _time(fn, *args, iters=3) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def bench_fista_step(m=512, n=512) -> Dict:
    rng = np.random.default_rng(0)
    y = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    a = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32) * 0.2)
    G = a @ a.T
    B = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    wall = _time(jax.jit(lambda y, G, B: ref.fista_prox_step(y, G, B, 0.01, 0.005)),
                 y, G, B)
    flops = 2.0 * m * n * n
    fused_bytes = 4.0 * (2 * m * n + n * n)        # read Y,B,G; write out
    unfused_bytes = 4.0 * (5 * m * n + n * n)      # + YG, P round-trips
    return {"name": "fista_step", "m": m, "n": n,
            "us_per_call_cpu": wall * 1e6,
            "flops": flops, "bytes_fused": fused_bytes,
            "tpu_compute_us": flops / PEAK_FLOPS * 1e6,
            "tpu_memory_us": fused_bytes / HBM_BW * 1e6,
            "fusion_traffic_ratio": fused_bytes / unfused_bytes}


def bench_fista_step_batched(k=3, m=512, n=512) -> Dict:
    """vmap-batched FISTA step (the prune_group path): k same-shape
    operators with per-operator G/B/step-size in one dispatch."""
    rng = np.random.default_rng(3)
    y = jnp.asarray(rng.normal(size=(k, m, n)).astype(np.float32))
    a = rng.normal(size=(k, n, n)).astype(np.float32) * 0.2
    G = jnp.asarray(np.einsum("kij,klj->kil", a, a))
    B = jnp.asarray(rng.normal(size=(k, m, n)).astype(np.float32))
    inv_l = jnp.asarray(rng.uniform(0.01, 0.03, size=(k,)).astype(np.float32))
    thresh = jnp.asarray(rng.uniform(0.003, 0.006, size=(k,)).astype(np.float32))
    batched = jax.jit(jax.vmap(ref.fista_prox_step))
    wall = _time(batched, y, G, B, inv_l, thresh)
    # sequential baseline: k SEPARATE dispatches of the per-operator step —
    # the per-dispatch overhead is exactly what the batched path removes
    one = jax.jit(ref.fista_prox_step)
    seq = lambda y, G, B, i, t: [one(y[j], G[j], B[j], i[j], t[j])
                                 for j in range(k)]
    wall_seq = _time(seq, y, G, B, inv_l, thresh)
    flops = 2.0 * k * m * n * n
    fused_bytes = 4.0 * k * (2 * m * n + n * n)
    return {"name": "fista_step_batched", "k": k, "m": m, "n": n,
            "us_per_call_cpu": wall * 1e6,
            "us_per_call_cpu_sequential": wall_seq * 1e6,
            "batch_speedup_cpu": wall_seq / max(wall, 1e-12),
            "flops": flops,
            "tpu_compute_us": flops / PEAK_FLOPS * 1e6,
            "tpu_memory_us": fused_bytes / HBM_BW * 1e6}


def bench_round24(m=1024, n=4096) -> Dict:
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    wall = _time(jax.jit(ref.round24), w)
    bytes_ = 2.0 * 4 * m * n
    return {"name": "round24", "m": m, "n": n, "us_per_call_cpu": wall * 1e6,
            "bytes": bytes_, "tpu_memory_us": bytes_ / HBM_BW * 1e6}


def bench_spmm24(B=8, m=1024, n=4096) -> Dict:
    rng = np.random.default_rng(2)
    w = ref.round24(jnp.asarray(rng.normal(size=(m, n)).astype(np.float32)))
    vals, meta = ref.pack24(w.astype(jnp.bfloat16))
    x = jnp.asarray(rng.normal(size=(B, n)).astype(np.float32)).astype(jnp.bfloat16)
    wall = _time(jax.jit(lambda x, v, mt: ref.spmm24(x, v, mt, n)), x, vals, meta)
    dense_bytes = 2.0 * m * n
    packed_bytes = 2.0 * vals.size + meta.size
    return {"name": "spmm24", "B": B, "m": m, "n": n,
            "us_per_call_cpu": wall * 1e6,
            "weight_bytes_dense": dense_bytes,
            "weight_bytes_packed": packed_bytes,
            "traffic_ratio": packed_bytes / dense_bytes,
            "tpu_decode_bound_dense_us": dense_bytes / HBM_BW * 1e6,
            "tpu_decode_bound_packed_us": packed_bytes / HBM_BW * 1e6}


def bench_paged_attention(S=4, nq=32, nkv=8, hd=128, ctx=2048,
                          block_size=16) -> Dict:
    """Block-table flash decode (kernels/paged_attention.py) vs the
    reference gather path, at a serving-sized decode step.

    The derived columns are the point: the reference path materializes
    the position-ordered ``(S, W, nkv, hd)`` K/V gather in HBM (one
    write + one re-read of the whole context, per layer, per step); the
    kernel walks the block table via scalar prefetch and streams each
    pool block through VMEM exactly once.  The packed o_proj epilogue
    additionally drops the separate projection dispatch: 0.625x wo
    traffic and no attention-output round-trip.  CPU wall is the jnp
    oracle (informational; interpret-mode parity is covered by the
    ``kernels_interpret`` tests, not timed here).
    """
    rng = np.random.default_rng(4)
    dt = 2                                     # bf16 serving dtype
    num_blocks = S * ctx // block_size + 1     # + trash block
    T = num_blocks * block_size
    q = jnp.asarray(rng.standard_normal((S, nq, hd)), jnp.float32)
    k_pool = jnp.asarray(rng.standard_normal((T, nkv, hd)), jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal((T, nkv, hd)), jnp.float32)
    tables = jnp.asarray(
        1 + np.arange(S * (ctx // block_size)).reshape(S, -1), jnp.int32)
    pos = jnp.full((S,), ctx - 1, jnp.int32)
    active = jnp.ones((S,), bool)
    wall = _time(jax.jit(lambda *a: ref.paged_attention(
        *a, block_size=block_size)), q, k_pool, v_pool, tables, pos, active)

    kv_bytes = 2.0 * S * ctx * nkv * hd * dt         # context K+V read
    qo_bytes = 2.0 * S * nq * hd * dt                # q in, attn out
    fused_bytes = kv_bytes + qo_bytes
    gather_bytes = fused_bytes + 2.0 * kv_bytes      # write + re-read gather
    d_model = nq * hd
    wo_dense = float(d_model * nq * hd * dt)         # o_proj weight read
    # epilogue: packed wo (0.625x) and the attn output never leaves VMEM
    epilogue_saved = wo_dense * (1 - 0.625) + 2.0 * S * nq * hd * dt
    return {"name": "paged_attention", "S": S, "nq": nq, "nkv": nkv,
            "hd": hd, "ctx": ctx, "block_size": block_size,
            "us_per_call_cpu": wall * 1e6,
            "bytes_fused": fused_bytes, "bytes_gather": gather_bytes,
            "gather_traffic_ratio": fused_bytes / gather_bytes,
            "tpu_memory_us_fused": fused_bytes / HBM_BW * 1e6,
            "tpu_memory_us_gather": gather_bytes / HBM_BW * 1e6,
            "o_proj_epilogue_bytes_saved": epilogue_saved}


def run_all() -> List[Dict]:
    rows = [bench_fista_step(), bench_fista_step_batched(), bench_round24(),
            bench_spmm24(), bench_paged_attention()]
    print("\n== Kernel microbench (derived TPU-v5e roofline positions) ==")
    for r in rows:
        extras = {k: v for k, v in r.items()
                  if k not in ("name",) and isinstance(v, float)}
        print(f"{r['name']}: " + "  ".join(f"{k}={v:.3g}" for k, v in extras.items()))
    from benchmarks import common
    common.write_result("kernel_bench", rows)
    return rows
