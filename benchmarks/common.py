"""Shared benchmark infrastructure.

Every table/figure benchmark runs the same protocol the paper uses,
shrunk to CPU scale (see DESIGN.md §6): train a small member of the
relevant model family on the synthetic corpus until converged-ish, then
prune with each method at each sparsity and measure held-out perplexity.
The dense model + calibration batches are trained once per family and
cached on disk so the whole suite stays fast.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Optional, Tuple

import numpy as np
import jax

from repro.checkpoint import store
from repro.core import solvers as solvers_lib
from repro.core.driver import parallel_prune
from repro.core.pruner import PrunerConfig
from repro.core.scheduler import SchedulerConfig
from repro.core.sequential import SequentialConfig, prune_model
from repro.core.sparsity import SparsitySpec
from repro.data import CalibConfig, CorpusConfig, MarkovCorpus, calibration_batches
from repro.models.registry import ModelDef, model_def
from repro.train import AdamWConfig, TrainConfig, Trainer, evaluate_ppl

CACHE_DIR = os.environ.get("REPRO_BENCH_CACHE", "experiments/bench_cache")

# paper protocol constants (scaled): Sec 4.1 uses 128 x max-seq calibration
CALIB = CalibConfig(num_sequences=32, seq_len=64, batch_size=8, seed=1234)
EVAL_BATCHES = 6
EVAL_BATCH, EVAL_SEQ = 8, 64


def opt_family_config():
    """OPT-125M family member (LayerNorm + GELU), trainable on CPU."""
    from repro.configs.opt125m_proxy import tiny_config
    return tiny_config()


def llama_family_config():
    """LLaMA family member (RMSNorm + SwiGLU + GQA), trainable on CPU."""
    from repro.configs.opt125m_proxy import tiny_config
    return tiny_config().replace(arch="llama-proxy", norm="rmsnorm", act="silu",
                                 num_kv_heads=2, qkv_bias=False)


@dataclasses.dataclass
class Trained:
    model: ModelDef
    params: dict
    corpus: MarkovCorpus
    dense_ppl: float
    family: str = "opt"


def family_pruner(family: str) -> PrunerConfig:
    """Paper Sec. 4.1: OPT warm-starts from SparseGPT with eps=1e-6;
    LLaMA warm-starts from Wanda with eps=1e-3.  K=20, T=3."""
    if family == "opt":
        return PrunerConfig(warm_start="sparsegpt", fista_iters=20,
                            eps=1e-6, patience=3, max_outer=12)
    return PrunerConfig(warm_start="wanda", fista_iters=20,
                        eps=1e-3, patience=3, max_outer=12)


def train_family(name: str, cfg=None, steps: int = 300, seed: int = 0,
                 corpus_seed: int = 11) -> Trained:
    """Train (or load from cache) the family's dense model."""
    cfg = cfg or (opt_family_config() if name == "opt" else llama_family_config())
    model = model_def(cfg)
    corpus = MarkovCorpus(CorpusConfig(vocab=cfg.vocab, seed=corpus_seed))
    cache_name = f"dense_{name}_{steps}_{seed}_{corpus_seed}"
    params0 = model.init(jax.random.PRNGKey(seed))
    if store.exists(CACHE_DIR, cache_name):
        params, extra = store.load(CACHE_DIR, cache_name, like=params0)
        return Trained(model, params, corpus, extra["dense_ppl"], family=name)
    tr = Trainer(model, corpus, TrainConfig(
        steps=steps, batch=16, seq=EVAL_SEQ, log_every=50, seed=seed,
        optim=AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=steps)))
    tr.run()
    ppl = evaluate_ppl(model, tr.params, corpus, EVAL_BATCH, EVAL_SEQ, EVAL_BATCHES)
    store.save(CACHE_DIR, cache_name, tr.params, extra={"dense_ppl": ppl})
    return Trained(model, tr.params, corpus, ppl, family=name)


FAST_PRUNER = PrunerConfig(fista_iters=12, max_outer=8, patience=2, eps=1e-4)


def prune_and_eval(t: Trained, method: str, spec: SparsitySpec,
                   correction: str = "intra", calib: Optional[CalibConfig] = None,
                   pruner: Optional[PrunerConfig] = None) -> Dict[str, float]:
    calib_batches = calibration_batches(t.corpus, calib or CALIB)
    pr = pruner or family_pruner(t.family)
    cfg = SequentialConfig(spec=spec, pruner=pr, method=method,
                           error_correction=correction,
                           solver=solvers_lib.from_legacy(method, pr))
    t0 = time.perf_counter()
    pruned, reports = prune_model(t.model, t.params, calib_batches, cfg)
    dt = time.perf_counter() - t0
    ppl = evaluate_ppl(t.model, pruned, t.corpus, EVAL_BATCH, EVAL_SEQ, EVAL_BATCHES)
    rel = float(np.mean([r.rel_error for r in reports])) if reports else 0.0
    return {"ppl": ppl, "mean_rel_err": rel, "prune_seconds": dt,
            "params": pruned}


def zero_shot_metrics(t: Trained, params) -> Dict[str, float]:
    """Zero-shot proxies (Table 3 analog): next-token top-1/top-5 accuracy
    on the held-out split + mean NLL."""
    import jax.numpy as jnp
    it = t.corpus.batches(EVAL_BATCH, EVAL_SEQ, split="valid")
    top1 = top5 = count = 0
    nll = 0.0

    @jax.jit
    def logits_of(p, tokens):
        return t.model.forward_logits(p, {"tokens": tokens})

    for _ in range(4):
        _, toks = next(it)
        tokens = jnp.asarray(toks[:, :-1])
        labels = toks[:, 1:]
        lg = np.asarray(logits_of(params, tokens), np.float32)
        pred = lg.argsort(axis=-1)
        top1 += int((pred[..., -1] == labels).sum())
        top5 += int((pred[..., -5:] == labels[..., None]).sum())
        lse = np.log(np.exp(lg - lg.max(-1, keepdims=True)).sum(-1)) + lg.max(-1)
        ll = np.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
        nll += float((lse - ll).sum())
        count += labels.size
    return {"top1": top1 / count, "top5": top5 / count, "nll": nll / count}


def write_result(name: str, payload) -> str:
    os.makedirs("experiments/bench", exist_ok=True)
    path = f"experiments/bench/{name}.json"
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def print_table(title: str, rows: List[Dict], cols: List[str]) -> None:
    print(f"\n== {title} ==")
    print(" | ".join(f"{c:>12}" for c in cols))
    for r in rows:
        print(" | ".join(f"{r.get(c, ''):>12.4f}" if isinstance(r.get(c), float)
                         else f"{str(r.get(c, '')):>12}" for c in cols))
