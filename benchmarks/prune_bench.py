"""Solver-throughput benchmark: FISTA outer-loop variants + the full
per-solver matrix of the registry.

Two sections, both over one transformer pruning unit (all four operator
groups of a decoder layer), both configured through ``PruneRecipe``:

* ``rows`` — Algorithm 1 under its three outer-loop implementations
  (``host`` reference / ``fused`` device-resident / ``fused-group``
  vmap-batched), the PR-1 speedup trajectory;
* ``solver_matrix`` — one row per registered solver (fista, admm,
  frankwolfe, wanda, sparsegpt) per sparsity: wall-clock, mean relative
  error, batched-op share.  This is the extensibility surface made
  measurable — a newly registered solver shows up here by adding its
  name to ``MATRIX``.

Unlike the kernel microbenchmarks, wall-clock is meaningful here on any
backend: the fused paths remove host<->device round trips, which cost on
CPU exactly as they do on TPU.  Each variant is run once to compile and
then timed, so the numbers compare steady-state solves.

Writes ``BENCH_prune.json`` at the repo root (and a copy under
``experiments/bench/``) so the perf trajectory is tracked from PR to PR.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Dict, List

import jax

from repro.api import PruneRecipe
from repro.core.sequential import prune_model
from repro.data import CalibConfig, CorpusConfig, MarkovCorpus, calibration_batches
from repro.models.registry import model_def

OUT_PATH = "BENCH_prune.json"
MESH_OUT_PATH = "BENCH_prune_mesh.json"

SPARSITIES = ("50%", "2:4")
MATRIX = ("fista", "admm", "frankwolfe", "wanda", "sparsegpt")

# paper-default solver depth (K=20), deep enough that the solve dominates
# the unit wall-clock; shared by every fista-family recipe below
_FISTA_KW = {"fista_iters": 20, "max_outer": 12, "patience": 3, "eps": 1e-6}


def _unit_problem(d_model: int = 64, d_ff: int = 128, seed: int = 0):
    from repro.configs.opt125m_proxy import tiny_config
    cfg = tiny_config().replace(num_layers=1, d_model=d_model, d_ff=d_ff,
                                num_heads=4, num_kv_heads=4, vocab=128)
    model = model_def(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    corpus = MarkovCorpus(CorpusConfig(vocab=cfg.vocab, seed=7))
    calib = calibration_batches(corpus, CalibConfig(num_sequences=8, seq_len=32,
                                                    batch_size=4))
    return model, params, calib


def _impl_recipes(sparsity: str) -> Dict[str, PruneRecipe]:
    return {
        "host": PruneRecipe(method="fista", sparsity=sparsity,
                            solver=dict(_FISTA_KW, outer_impl="host")),
        "fused": PruneRecipe(method="fista", sparsity=sparsity,
                             solver=dict(_FISTA_KW, outer_impl="fused",
                                         group_batch=False)),
        "fused-group": PruneRecipe(method="fista", sparsity=sparsity,
                                   solver=dict(_FISTA_KW, outer_impl="fused",
                                               group_batch=True)),
    }


def _timed_prune(model, params, calib, recipe: PruneRecipe,
                 repeats: int) -> Dict:
    cfg = recipe.sequential_config()
    prune_model(model, params, calib, cfg)          # compile
    times, solver_times, reports = [], [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        _, reports = prune_model(model, params, calib, cfg)
        times.append(time.perf_counter() - t0)
        solver_times.append(sum(r.seconds for r in reports))
    return {
        "unit_seconds": min(times),
        "solver_seconds": min(solver_times),
        "operators": len(reports),
        "batched_operators": sum(1 for r in reports if r.group_size > 1),
        "mean_rel_err": (sum(r.rel_error for r in reports)
                         / max(len(reports), 1)),
    }


def bench_prune_impls(d_model: int = 64, d_ff: int = 128,
                      repeats: int = 5) -> List[Dict]:
    """FISTA outer-loop implementation comparison (host/fused/fused-group)."""
    model, params, calib = _unit_problem(d_model, d_ff)
    rows: List[Dict] = []
    for sparsity in SPARSITIES:
        for name, recipe in _impl_recipes(sparsity).items():
            row = dict(impl=name, sparsity=sparsity, d_model=d_model,
                       d_ff=d_ff,
                       **_timed_prune(model, params, calib, recipe, repeats))
            rows.append(row)
            print(f"{name:>12} {sparsity}: unit {row['unit_seconds']*1e3:8.1f} ms  "
                  f"solver {row['solver_seconds']*1e3:8.1f} ms  "
                  f"({row['batched_operators']}/{row['operators']} batched)")
    return rows


def bench_solver_matrix(d_model: int = 64, d_ff: int = 128,
                        repeats: int = 3) -> List[Dict]:
    """One row per registered solver per sparsity — the pluggable-API
    surface under benchmark.  New solvers: add the name to MATRIX."""
    model, params, calib = _unit_problem(d_model, d_ff)
    rows: List[Dict] = []
    for sparsity in SPARSITIES:
        for method in MATRIX:
            solver_kw = dict(_FISTA_KW) if method == "fista" else {}
            recipe = PruneRecipe(method=method, sparsity=sparsity,
                                 solver=solver_kw)
            # solvers that don't read the pruned-path Gram report the
            # dense-path error ||YX - WX||; tag each row so rel_err
            # columns are not compared across different metrics
            error_stats = ("pruned-path" if recipe.build_solver().wants_pruned_gram
                           else "dense-path")
            row = dict(solver=method, sparsity=sparsity, d_model=d_model,
                       d_ff=d_ff, error_stats=error_stats,
                       **_timed_prune(model, params, calib, recipe, repeats))
            rows.append(row)
            print(f"{method:>12} {sparsity}: unit {row['unit_seconds']*1e3:8.1f} ms  "
                  f"rel_err {row['mean_rel_err']:.4f} ({error_stats})  "
                  f"({row['batched_operators']}/{row['operators']} batched)")
    print("   (rel_err is ||YX*-WX|| for pruned-path rows, ||YX-WX|| for"
          " dense-path rows — compare within a mode, or by table ppl)")
    return rows


def _summarize(rows: List[Dict]) -> Dict[str, float]:
    """Host-loop time / variant time (>1 means the variant wins), averaged
    over sparsities, for both unit wall-clock and the solver phase."""
    out: Dict[str, float] = {}
    for impl in ("fused", "fused-group"):
        for metric in ("unit_seconds", "solver_seconds"):
            ratios = []
            for row in rows:
                if row["impl"] != impl:
                    continue
                host = next(r for r in rows if r["impl"] == "host"
                            and r["sparsity"] == row["sparsity"])
                ratios.append(host[metric] / max(row[metric], 1e-12))
            key = f"{impl}_{metric.removesuffix('_seconds')}"
            out[key] = sum(ratios) / max(len(ratios), 1)
    return out


# ---------------------------------------------------------------------------
# mesh-native Gram accumulation: 1-device vs 8-fake-device dispatch row
# ---------------------------------------------------------------------------
def _mesh_gram_child(devices: int) -> Dict:
    """Runs INSIDE a subprocess whose XLA_FLAGS already forces ``devices``
    fake host devices: prune one unit with the calibration batches
    data-sharded over the mesh and count Gram-accumulation dispatches."""
    from repro.core import sequential as seq_lib

    model, params, _ = _unit_problem()
    # 8 calibration micro-batches so every probed mesh divides them (one
    # batch per shard at 8 devices — the bitwise-parity regime)
    corpus = MarkovCorpus(CorpusConfig(vocab=model.cfg.vocab, seed=7))
    calib = calibration_batches(corpus, CalibConfig(num_sequences=32,
                                                    seq_len=32, batch_size=4))
    counts = {"dispatches": 0, "stacked_batches": 0}
    orig = seq_lib._group_stats_scan

    def counting(init, current, ws, caps, ps, **kw):
        counts["dispatches"] += 1
        counts["stacked_batches"] += int(
            jax.tree_util.tree_leaves(caps)[0].shape[0])
        return orig(init, current, ws, caps, ps, **kw)

    seq_lib._group_stats_scan = counting
    try:
        mesh = ({"devices": devices, "data_parallel": devices,
                 "model_parallel": 1} if devices > 1 else {})
        recipe = PruneRecipe(sparsity="2:4", mesh=mesh,
                             solver=dict(_FISTA_KW, max_outer=4,
                                         fista_iters=5))
        from repro import api
        t0 = time.perf_counter()
        _, reports, _ = api.prune(model, params, calib, recipe)
        wall = time.perf_counter() - t0
    finally:
        seq_lib._group_stats_scan = orig
    # under the mesh the counting wrapper runs inside shard_map, so the
    # stacked length it sees is already the per-device slice
    per_device = counts["stacked_batches"] // max(counts["dispatches"], 1)
    return {
        "devices": devices,
        "data_parallel": devices,
        "gram_dispatches": counts["dispatches"],
        "calib_batches": len(calib),
        # scan trip count each device executes per dispatch — the thing
        # data parallelism divides (the dispatch count itself is mesh-
        # independent: one sharded scan replaces one serial scan)
        "scan_steps_per_device": per_device,
        "operators": len(reports),
        "wall_s": wall,
    }


def bench_mesh_gram(device_counts=(1, 8)) -> Dict:
    """Parent-side: spawn one child per device count (XLA fake-device
    flags must be set before jax initializes, hence subprocesses) and
    assemble the comparison row for BENCH_prune.json."""
    from repro.utils.compat import force_host_devices_flags

    rows = []
    for n in device_counts:
        env = dict(os.environ)
        # replace (not prepend to) any inherited device-count flag — the
        # last duplicated XLA flag wins, so an exported =8 would
        # override the child's count
        env["XLA_FLAGS"] = force_host_devices_flags(n)
        env.setdefault("JAX_PLATFORMS", "cpu")
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.prune_bench",
             "--mesh-gram-child", str(n)],
            env=env, capture_output=True, text=True, timeout=900)
        if out.returncode != 0:
            raise RuntimeError(f"mesh-gram child ({n} devices) failed:\n"
                               f"{out.stdout}\n{out.stderr}")
        row = json.loads(out.stdout.splitlines()[-1])
        rows.append(row)
        print(f"{n:>2} device(s): {row['gram_dispatches']} Gram dispatches, "
              f"{row['scan_steps_per_device']} scan step(s)/device "
              f"({row['calib_batches']} calib batches)")
    base = rows[0]
    return {"rows": rows,
            "scan_step_ratio": base["scan_steps_per_device"]
            / max(rows[-1]["scan_steps_per_device"], 1)}


def run_all(out_path: str = OUT_PATH) -> List[Dict]:
    print("\n== Prune solver bench (host vs fused vs group-batched) ==")
    rows = bench_prune_impls()
    print("\n== Per-solver matrix (fista / admm / frankwolfe / wanda /"
          " sparsegpt) ==")
    matrix = bench_solver_matrix()
    print("\n== Mesh-native Gram accumulation (1 vs 8 fake devices) ==")
    mesh_gram = bench_mesh_gram()
    summary = _summarize(rows)
    payload = {"rows": rows, "solver_matrix": matrix, "summary": summary,
               "mesh_gram": mesh_gram, "backend": jax.default_backend()}
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    from benchmarks import common
    common.write_result("prune_bench", payload)
    print(f"\nwrote {out_path}; speedup vs host-loop: "
          + "  ".join(f"{k}={v:.2f}x" for k, v in sorted(summary.items())))
    return rows


def main(argv: List[str]) -> int:
    if "--mesh-gram-child" in argv:
        n = int(argv[argv.index("--mesh-gram-child") + 1])
        print(json.dumps(_mesh_gram_child(n)))
        return 0
    if "--mesh-only" in argv:
        # the CI distributed job's cheap entry: just the mesh comparison
        payload = bench_mesh_gram()
        with open(MESH_OUT_PATH, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {MESH_OUT_PATH}")
        return 0
    run_all()
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
