"""Solver-throughput benchmark: host-loop vs fused vs group-batched.

Times Algorithm 1 over one transformer pruning unit (all four operator
groups of a decoder layer) under the three outer-loop implementations:

* ``host``        — the seed's host-Python outer loop (one device sync
                    per outer iteration per operator);
* ``fused``       — device-resident ``lax.while_loop`` (one dispatch per
                    operator);
* ``fused-group`` — fused + vmap over same-shape group peers (one
                    dispatch per shape-subgroup).

Unlike the kernel microbenchmarks, wall-clock is meaningful here on any
backend: the fused path removes host<->device round trips, which cost on
CPU exactly as they do on TPU.  Each variant is run once to compile and
then timed, so the numbers compare steady-state solves.

Writes ``BENCH_prune.json`` at the repo root (and a copy under
``experiments/bench/``) so the perf trajectory is tracked from PR to PR.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import jax

from repro.core.pruner import PrunerConfig
from repro.core.sequential import SequentialConfig, prune_model
from repro.core.sparsity import SparsitySpec
from repro.data import CalibConfig, CorpusConfig, MarkovCorpus, calibration_batches
from repro.models.registry import model_def

OUT_PATH = "BENCH_prune.json"


def _unit_problem(d_model: int = 64, d_ff: int = 128, seed: int = 0):
    from repro.configs.opt125m_proxy import tiny_config
    cfg = tiny_config().replace(num_layers=1, d_model=d_model, d_ff=d_ff,
                                num_heads=4, num_kv_heads=4, vocab=128)
    model = model_def(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    corpus = MarkovCorpus(CorpusConfig(vocab=cfg.vocab, seed=7))
    calib = calibration_batches(corpus, CalibConfig(num_sequences=8, seq_len=32,
                                                    batch_size=4))
    return model, params, calib


def _variants(base: PrunerConfig) -> Dict[str, PrunerConfig]:
    import dataclasses
    return {
        "host": dataclasses.replace(base, outer_impl="host"),
        "fused": dataclasses.replace(base, outer_impl="fused",
                                     group_batch=False),
        "fused-group": dataclasses.replace(base, outer_impl="fused",
                                           group_batch=True),
    }


def bench_prune_impls(d_model: int = 64, d_ff: int = 128, repeats: int = 5,
                      out_path: str = OUT_PATH) -> List[Dict]:
    model, params, calib = _unit_problem(d_model, d_ff)
    # paper-default solver depth (K=20), deep enough that the solve — the
    # phase this PR moves on-device — dominates the unit wall-clock
    base = PrunerConfig(fista_iters=20, max_outer=12, patience=3, eps=1e-6)
    rows: List[Dict] = []
    for spec in (SparsitySpec(ratio=0.5), SparsitySpec(kind="nm", n=2, m=4)):
        for name, pruner in _variants(base).items():
            cfg = SequentialConfig(spec=spec, pruner=pruner, method="fista")
            prune_model(model, params, calib, cfg)          # compile
            times, solver_times, reports = [], [], []
            for _ in range(repeats):
                t0 = time.perf_counter()
                _, reports = prune_model(model, params, calib, cfg)
                times.append(time.perf_counter() - t0)
                solver_times.append(sum(r.seconds for r in reports))
            rows.append({
                "impl": name, "sparsity": str(spec),
                "d_model": d_model, "d_ff": d_ff,
                "unit_seconds": min(times),
                "solver_seconds": min(solver_times),
                "operators": len(reports),
                "batched_operators": sum(1 for r in reports
                                         if r.solver == "fused-group"),
                "mean_rel_err": (sum(r.rel_error for r in reports)
                                 / max(len(reports), 1)),
            })
            print(f"{name:>12} {spec}: unit {min(times)*1e3:8.1f} ms  "
                  f"solver {min(solver_times)*1e3:8.1f} ms  "
                  f"({rows[-1]['batched_operators']}/{len(reports)} batched)")

    summary = _summarize(rows)
    payload = {"rows": rows, "summary": summary,
               "backend": jax.default_backend()}
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    from benchmarks import common
    common.write_result("prune_bench", payload)
    print(f"\nwrote {out_path}; speedup vs host-loop: "
          + "  ".join(f"{k}={v:.2f}x" for k, v in sorted(summary.items())))
    return rows


def _summarize(rows: List[Dict]) -> Dict[str, float]:
    """Host-loop time / variant time (>1 means the variant wins), averaged
    over sparsities, for both unit wall-clock and the solver phase."""
    out: Dict[str, float] = {}
    for impl in ("fused", "fused-group"):
        for metric in ("unit_seconds", "solver_seconds"):
            ratios = []
            for row in rows:
                if row["impl"] != impl:
                    continue
                host = next(r for r in rows if r["impl"] == "host"
                            and r["sparsity"] == row["sparsity"])
                ratios.append(host[metric] / max(row[metric], 1e-12))
            key = f"{impl}_{metric.removesuffix('_seconds')}"
            out[key] = sum(ratios) / max(len(ratios), 1)
    return out


def run_all() -> List[Dict]:
    print("\n== Prune solver bench (host vs fused vs group-batched) ==")
    return bench_prune_impls()
