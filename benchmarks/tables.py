"""Paper-table benchmarks (Tables 1/2/3 + PTB/C4 appendix analogs).

Each function reproduces one table's PROTOCOL at CPU scale: the claim
under test is the METHOD ORDERING (FISTAPruner <= SparseGPT, Wanda at
matched sparsity), not absolute perplexities.  Three corpora stand in
for WikiText/PTB/C4 via different corpus seeds (same distribution
family, disjoint chains).
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.sparsity import SparsitySpec

from benchmarks import common

METHODS = ["dense", "magnitude", "wanda", "sparsegpt", "fista"]
SPARSITIES = {"50%": SparsitySpec(ratio=0.5), "2:4": SparsitySpec(kind="nm", n=2, m=4)}


def _family_table(family: str, steps: int, corpus_seed: int = 11) -> List[Dict]:
    t = common.train_family(family, steps=steps, corpus_seed=corpus_seed)
    rows = []
    for sp_name, spec in SPARSITIES.items():
        for method in METHODS:
            if method == "dense":
                if sp_name == "50%":
                    rows.append({"method": "dense", "sparsity": "0%",
                                 "ppl": t.dense_ppl, "mean_rel_err": 0.0})
                continue
            res = common.prune_and_eval(t, method, spec)
            rows.append({"method": method, "sparsity": sp_name,
                         "ppl": res["ppl"], "mean_rel_err": res["mean_rel_err"],
                         "prune_seconds": res["prune_seconds"]})
    return rows


def table1_opt_family(steps: int = 300) -> List[Dict]:
    """Table 1 analog: OPT family (LayerNorm+GELU), WikiText stand-in."""
    rows = _family_table("opt", steps)
    common.print_table("Table 1 analog — OPT-family, WikiText-analog ppl",
                       rows, ["method", "sparsity", "ppl", "mean_rel_err"])
    common.write_result("table1_opt_family", rows)
    return rows


def table2_llama_family(steps: int = 300) -> List[Dict]:
    """Table 2 analog: LLaMA family (RMSNorm+SwiGLU+GQA)."""
    rows = _family_table("llama", steps)
    common.print_table("Table 2 analog — LLaMA-family, WikiText-analog ppl",
                       rows, ["method", "sparsity", "ppl", "mean_rel_err"])
    common.write_result("table2_llama_family", rows)
    return rows


def tables_ptb_c4(steps: int = 300) -> List[Dict]:
    """Appendix C.1/C.2 analog: two more corpora (different chain seeds)."""
    rows = []
    for corpus_name, seed in (("ptb-analog", 23), ("c4-analog", 37)):
        t = common.train_family("opt", steps=steps, corpus_seed=seed)
        rows.append({"corpus": corpus_name, "method": "dense", "ppl": t.dense_ppl})
        for method in ("wanda", "sparsegpt", "fista"):
            res = common.prune_and_eval(t, method, SPARSITIES["50%"])
            rows.append({"corpus": corpus_name, "method": method, "ppl": res["ppl"]})
    common.print_table("Tables 4/6 analog — PTB/C4 stand-ins (50%)",
                       rows, ["corpus", "method", "ppl"])
    common.write_result("tables_ptb_c4", rows)
    return rows


def table3_zeroshot(steps: int = 300) -> List[Dict]:
    """Table 3 analog: zero-shot next-token accuracy of pruned models."""
    t = common.train_family("opt", steps=steps)
    rows = [{"method": "dense", "sparsity": "0%",
             **common.zero_shot_metrics(t, t.params)}]
    for sp_name, spec in SPARSITIES.items():
        for method in ("wanda", "sparsegpt", "fista"):
            res = common.prune_and_eval(t, method, spec)
            rows.append({"method": method, "sparsity": sp_name,
                         **common.zero_shot_metrics(t, res["params"])})
    common.print_table("Table 3 analog — zero-shot accuracy",
                       rows, ["method", "sparsity", "top1", "top5", "nll"])
    common.write_result("table3_zeroshot", rows)
    return rows
