"""Model-level quality benchmark: perplexity x solver x sparsity + the
sparse-serving decode row.

Where ``prune_bench`` scores solvers by layer-wise reconstruction error,
this benchmark scores them by what the paper actually claims — held-out
perplexity of the pruned model (plus KL-from-dense and the error-budget
audit) on the opt125m proxy family, for every registered solver at 50%
unstructured and 2:4 semi-structured sparsity.  It also times the serve
engine's decode step with dense vs. packed-2:4 weights and reports the
modeled TPU decode-roofline positions (CPU wall-clock of the packed path
includes the interpret-mode unpack and is NOT a TPU prediction; the
roofline columns are the meaningful ones — DESIGN.md §2/§6).

Beyond the per-solver matrix (every row uses the paper's intra-unit
error correction), one extra row measures cross-unit correction —
``fista`` at 2:4 with ``correction="cross"``, where downstream units
calibrate their Gram statistics from the REALIZED pruned activations of
upstream units — and reports its perplexity delta vs. the matching
intra row.

Writes ``BENCH_quality.json`` at the repo root (and a copy under
``experiments/bench/``).  When ``benchmarks/quality_baseline.json``
exists, the committed regression gate runs: neither the opt-proxy 2:4
fista perplexity (intra) nor the cross-unit variant's perplexity may
degrade more than ``tolerance`` (2%) vs. the pinned baseline — CI fails
otherwise.
"""
from __future__ import annotations

import dataclasses
import json
import sys
import time
from typing import Dict, List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.api import PruneRecipe
from repro.core.sequential import prune_model
from repro.data import calibration_batches
from repro.eval import EvalConfig, evaluate_perplexity, quality_report
from repro.serve import Engine, ServeConfig, pack_tree

OUT_PATH = "BENCH_quality.json"
BASELINE_PATH = "benchmarks/quality_baseline.json"

SPARSITIES = ("50%", "2:4")
MATRIX = ("fista", "admm", "frankwolfe", "wanda", "sparsegpt")
GATE_METHOD, GATE_SPARSITY = "fista", "2:4"

#: eval protocol of the benchmark (fixed so rows are comparable PR-to-PR)
EVAL = EvalConfig(num_batches=6, batch_size=8, seq_len=64,
                  kl_batches=3, budget_batches=2)

# solver depth matched to the opt family's paper settings, shallow enough
# for the CI budget (same spirit as common.FAST_PRUNER)
_FISTA_KW = {"fista_iters": 12, "max_outer": 8, "patience": 2, "eps": 1e-4,
             "warm_start": "sparsegpt"}


def _recipe(method: str, sparsity: str,
            correction: str = "intra") -> PruneRecipe:
    return PruneRecipe(method=method, sparsity=sparsity,
                       correction=correction,
                       solver=dict(_FISTA_KW) if method == "fista" else {})


def _prune(t: common.Trained, recipe: PruneRecipe):
    calib = calibration_batches(t.corpus, common.CALIB)
    t0 = time.perf_counter()
    pruned, reports = prune_model(t.model, t.params, calib,
                                  recipe.sequential_config())
    return pruned, reports, time.perf_counter() - t0


def bench_quality_matrix(steps: int = 300
                         ) -> Tuple[List[Dict], Dict[str, jnp.ndarray]]:
    """One row per (solver, sparsity): ppl, KL, budget audit.  Returns the
    rows and the 2:4 fista params (reused by the decode bench)."""
    t = common.train_family("opt", steps=steps)
    # one dense reference pass shared by every matrix row
    dense_eval = evaluate_perplexity(t.model, t.params, t.corpus, EVAL)
    rows: List[Dict] = []
    gate_params = None
    for sparsity in SPARSITIES:
        for method in MATRIX:
            recipe = _recipe(method, sparsity)
            pruned, reports, dt = _prune(t, recipe)
            q = quality_report(t.model, pruned, t.corpus, EVAL,
                               dense_params=t.params, reports=reports,
                               dense_eval=dense_eval)
            # rel_err metric differs per solver (relay ||YX*-WX|| vs dense
            # ||YX-WX||) — tag it like prune_bench so the column is never
            # compared across modes (ppl/kl are the cross-method metrics)
            error_stats = ("pruned-path" if recipe.build_solver().wants_pruned_gram
                           else "dense-path")
            row = {"method": method, "sparsity": sparsity,
                   "correction": "intra", "ppl": q.ppl,
                   "dense_ppl": q.dense_ppl, "ppl_ratio": q.ppl_ratio,
                   "kl": q.kl, "top1_agreement": q.top1_agreement,
                   "budget_ok": q.budget_ok,
                   "mean_rel_err": float(np.mean([r.rel_error
                                                  for r in reports])),
                   "error_stats": error_stats,
                   "prune_seconds": dt}
            rows.append(row)
            print(f"{method:>10} {sparsity:>4}: ppl {q.ppl:8.3f} "
                  f"(dense {q.dense_ppl:7.3f}, x{q.ppl_ratio:.3f})  "
                  f"kl {q.kl:.4f}  agree {q.top1_agreement:.3f}  "
                  f"budget_ok {q.budget_ok}")
            if method == GATE_METHOD and sparsity == GATE_SPARSITY:
                gate_params = pruned
    rows.append(bench_cross_unit(t, rows, dense_eval))
    return rows, gate_params


def bench_cross_unit(t: common.Trained, rows: List[Dict],
                     dense_eval) -> Dict:
    """The cross-unit correction row: the gate recipe re-run with
    ``correction="cross"`` (downstream Gram stats calibrated from
    realized pruned activations), reported as a ppl delta against the
    matching intra row from the matrix."""
    recipe = _recipe(GATE_METHOD, GATE_SPARSITY, correction="cross")
    pruned, reports, dt = _prune(t, recipe)
    q = quality_report(t.model, pruned, t.corpus, EVAL,
                       dense_params=t.params, reports=reports,
                       dense_eval=dense_eval)
    intra = next(r for r in rows if r["method"] == GATE_METHOD
                 and r["sparsity"] == GATE_SPARSITY
                 and r["correction"] == "intra")
    row = {"method": GATE_METHOD, "sparsity": GATE_SPARSITY,
           "correction": "cross", "ppl": q.ppl,
           "dense_ppl": q.dense_ppl, "ppl_ratio": q.ppl_ratio,
           "kl": q.kl, "top1_agreement": q.top1_agreement,
           "budget_ok": q.budget_ok,
           "mean_rel_err": float(np.mean([r.rel_error for r in reports])),
           "error_stats": "pruned-path",
           "ppl_delta_vs_intra": q.ppl - intra["ppl"],
           "prune_seconds": dt}
    print(f"{GATE_METHOD:>10} {GATE_SPARSITY:>4} (cross-unit): "
          f"ppl {q.ppl:8.3f}  delta vs intra "
          f"{row['ppl_delta_vs_intra']:+.3f}  kl {q.kl:.4f}")
    return row


def bench_decode(model, pruned_params, batch: int = 1,
                 new_tokens: int = 32) -> Dict:
    """Timed decode step: dense matmuls vs. the packed-2:4 spmm24 path on
    the same masked weights, plus the modeled TPU decode-roofline bound."""
    packed_params, stats = pack_tree(pruned_params, dtype=None)
    scfg = ServeConfig(max_new_tokens=new_tokens, cache_len=64)
    eng_dense = Engine(model, pruned_params,
                       dataclasses.replace(scfg, sparse="dense"))
    eng_packed = Engine(model, packed_params,
                        dataclasses.replace(scfg, sparse="packed"))
    prompt = jnp.zeros((batch, 8), jnp.int32)

    def steady(engine) -> float:
        engine.generate(prompt)                       # compile
        t0 = time.perf_counter()
        out = engine.generate(prompt)
        return (time.perf_counter() - t0) / out.shape[1]

    ms_dense = steady(eng_dense) * 1e3
    ms_packed = steady(eng_packed) * 1e3
    hbm_bw = 819e9                                    # v5e, as kernel_bench
    row = {"name": "serve_decode_24", "batch": batch,
           "new_tokens": new_tokens,
           "packed_ops": stats["packed_ops"],
           "ms_per_token_dense_cpu": ms_dense,
           "ms_per_token_packed_cpu": ms_packed,
           "weight_bytes_dense": stats["dense_bytes"],
           "weight_bytes_packed": stats["packed_bytes"],
           "weight_traffic_ratio": stats["packed_bytes"] / stats["dense_bytes"],
           "tpu_decode_bound_dense_us": stats["dense_bytes"] / hbm_bw * 1e6,
           "tpu_decode_bound_packed_us": stats["packed_bytes"] / hbm_bw * 1e6}
    print(f"decode: dense {ms_dense:.2f} ms/tok cpu, packed {ms_packed:.2f} "
          f"ms/tok cpu (interpret-mode unpack); weight traffic "
          f"{row['weight_traffic_ratio']:.3f}x -> TPU decode bound "
          f"{row['tpu_decode_bound_dense_us']:.1f} -> "
          f"{row['tpu_decode_bound_packed_us']:.1f} us")
    return row


def _gate_row(rows: List[Dict], correction: str):
    return next((r for r in rows if r["method"] == GATE_METHOD
                 and r["sparsity"] == GATE_SPARSITY
                 and r.get("correction", "intra") == correction), None)


def check_regression(rows: List[Dict], baseline_path: str = BASELINE_PATH,
                     steps: int = 300) -> Tuple[bool, str]:
    """Gate: the opt-proxy 2:4 fista ppl (intra) — and, when the baseline
    pins one, the cross-unit variant's ppl — within tolerance of the
    committed baseline.  Missing baseline, or a baseline recorded under a
    different training protocol (e.g. a --full 500-step run vs. the
    committed 300-step baseline) => informational pass, never a spurious
    failure."""
    try:
        with open(baseline_path) as f:
            base = json.load(f)
    except FileNotFoundError:
        return True, f"no baseline at {baseline_path} (gate skipped)"
    base_steps = base.get("protocol", {}).get("steps")
    if base_steps is not None and base_steps != steps:
        return True, (f"baseline protocol steps={base_steps} != run "
                      f"steps={steps} (gate skipped; not comparable)")
    tol = float(base.get("tolerance", 0.02))
    gates = [("intra", "ppl", base.get("ppl"))]
    if base.get("cross_ppl") is not None:
        gates.append(("cross", "cross_ppl", base["cross_ppl"]))
    ok, parts = True, []
    for correction, label, pinned in gates:
        row = _gate_row(rows, correction)
        if row is None:
            return False, (f"gate row {GATE_METHOD}@{GATE_SPARSITY} "
                           f"({correction}) missing")
        limit = float(pinned) * (1.0 + tol)
        good = row["ppl"] <= limit
        ok = ok and good
        parts.append(f"{GATE_METHOD}@{GATE_SPARSITY}/{correction} ppl "
                     f"{row['ppl']:.3f} vs baseline {float(pinned):.3f} "
                     f"(+{tol:.0%} limit {limit:.3f}) -> "
                     f"{'PASS' if good else 'FAIL'}")
    return ok, "; ".join(parts)


def write_baseline(rows: List[Dict], path: str = BASELINE_PATH,
                   tolerance: float = 0.02, steps: int = 300) -> None:
    row = _gate_row(rows, "intra")
    cross = _gate_row(rows, "cross")
    with open(path, "w") as f:
        json.dump({"method": GATE_METHOD, "sparsity": GATE_SPARSITY,
                   "ppl": row["ppl"], "dense_ppl": row["dense_ppl"],
                   "cross_ppl": None if cross is None else cross["ppl"],
                   "tolerance": tolerance,
                   "protocol": {"steps": steps,
                                "eval": dataclasses.asdict(EVAL)}},
                  f, indent=1)
        f.write("\n")


def run_all(steps: int = 300, out_path: str = OUT_PATH,
            baseline_path: str = BASELINE_PATH,
            update_baseline: bool = False) -> Dict:
    """Returns the full payload incl. ``gate_ok`` — callers (benchmarks/
    run.py, __main__) decide the exit code, so a gate failure never
    aborts the other benchmarks of a suite run mid-way."""
    print("\n== Quality matrix (held-out ppl x solver x sparsity) ==")
    rows, gate_params = bench_quality_matrix(steps)
    print("\n== Sparse serving decode step (2:4 fista checkpoint) ==")
    t = common.train_family("opt", steps=steps)   # cache hit
    decode = bench_decode(t.model, gate_params)
    ok, msg = check_regression(rows, baseline_path, steps=steps)
    payload = {"rows": rows, "decode": decode,
               "eval": dataclasses.asdict(EVAL), "steps": steps,
               "gate_ok": ok, "regression_gate": msg,
               "backend": jax.default_backend()}
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    common.write_result("quality_bench", payload)
    if update_baseline:
        write_baseline(rows, baseline_path, steps=steps)
        print(f"baseline updated: {baseline_path}")
    print(f"\nwrote {out_path}; {msg}")
    return payload


if __name__ == "__main__":
    payload = run_all(update_baseline="--update-baseline" in sys.argv)
    sys.exit(0 if payload["gate_ok"] else 1)
