"""End-to-end CLI driver tests (subprocess): launch.train and launch.prune."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(module, *args, timeout=900, env_extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get("PYTHONPATH", "")
    env.update(env_extra or {})
    return subprocess.run([sys.executable, "-m", module, *args], env=env,
                          capture_output=True, text=True, timeout=timeout,
                          cwd=ROOT)


def test_train_cli_smoke():
    out = _run("repro.launch.train", "--arch", "opt125m-proxy",
               "--steps", "20", "--batch", "4", "--seq", "32")
    assert out.returncode == 0, out.stdout + out.stderr
    assert "valid_ppl=" in out.stdout


def test_train_cli_resume(tmp_path):
    args = ["--arch", "opt125m-proxy", "--steps", "16", "--batch", "4",
            "--seq", "32", "--ckpt-dir", str(tmp_path)]
    out = _run("repro.launch.train", *args)
    assert out.returncode == 0, out.stderr
    out = _run("repro.launch.train", *args, "--resume")
    assert out.returncode == 0, out.stderr
    assert "steps=16" in out.stdout  # restored at final step, no retraining


def test_prune_cli_end_to_end(tmp_path):
    report = tmp_path / "report.json"
    out = _run("repro.launch.prune", "--arch", "opt125m-proxy",
               "--method", "fista", "--sparsity", "2:4",
               "--train-steps", "40", "--calib-sequences", "8",
               "--calib-seq-len", "32", "--workers", "2",
               "--out", str(report))
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.loads(report.read_text())
    assert rec["method"] == "fista" and rec["sparsity"] == "2:4"
    assert rec["pruned_ppl"] > 0 and rec["dense_ppl"] > 0
    assert rec["mean_rel_err"] < 1.0


def test_prune_then_evaluate_cli(tmp_path):
    """The quality loop of the README: prune --ckpt-dir, then evaluate the
    run's pruned checkpoint against its dense reference."""
    run_dir = tmp_path / "run"
    out = _run("repro.launch.prune", "--arch", "opt125m-proxy",
               "--method", "fista", "--sparsity", "2:4",
               "--train-steps", "30", "--calib-sequences", "8",
               "--calib-seq-len", "32", "--workers", "2",
               "--ckpt-dir", str(run_dir))
    assert out.returncode == 0, out.stdout + out.stderr
    assert (run_dir / "pruned_model" / "MANIFEST.json").exists()

    # the prune driver records observability artifacts alongside the
    # checkpoints: spans + metrics + Perfetto trace + scheduler summary
    obs_dir = run_dir / "obs"
    for fname in ("spans.jsonl", "metrics.jsonl", "trace.json"):
        assert (obs_dir / fname).exists(), fname
    trace = json.loads((obs_dir / "trace.json").read_text())
    assert any(e.get("name") == "prune.unit"
               for e in trace["traceEvents"])
    summary = json.loads((run_dir / "run_summary.json").read_text())
    assert summary["completed"] > 0 and summary["slowest_unit"]
    assert summary["total_solver_seconds"] > 0
    rep = _run("repro.obs", "report", str(run_dir))
    assert rep.returncode == 0, rep.stdout + rep.stderr
    assert "prune.solve" in rep.stdout and "scheduler run summary" in rep.stdout

    report = tmp_path / "quality.json"
    out = _run("repro.launch.evaluate", "--checkpoint", str(run_dir),
               "--against-dense", "--out", str(report))
    assert out.returncode == 0, out.stdout + out.stderr
    assert "ppl=" in out.stdout and "kl=" in out.stdout
    rec = json.loads(report.read_text())
    assert rec["ppl"] > 0 and rec["dense_ppl"] > 0
    assert rec["kl"] >= 0 and 0 <= rec["top1_agreement"] <= 1
    assert rec["meta"]["sparsity"] == "2:4"
    assert rec["error_budget"] and rec["budget_ok"] is not None


def test_evaluate_cli_rejects_bad_eval_recipe(tmp_path):
    """Unknown `eval` keys in a recipe must fail at load time (exit != 0),
    matching the strictness of every other recipe section."""
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"method": "fista",
                               "eval": {"num_batch": 4}}))   # typo'd key
    out = _run("repro.launch.evaluate", "--checkpoint", str(tmp_path),
               "--recipe", str(bad))
    assert out.returncode != 0
    assert "eval" in (out.stderr + out.stdout)


def test_evaluate_cli_missing_run_errors(tmp_path):
    out = _run("repro.launch.evaluate", "--checkpoint",
               str(tmp_path / "nowhere"))
    assert out.returncode == 2
    assert "not found" in out.stderr


def test_serve_cli_smoke(tmp_path):
    """Continuous-batching serve driver over a Poisson trace (random-init
    smoke model): must report throughput/latency and write the JSON."""
    report = tmp_path / "serve.json"
    metrics = tmp_path / "metrics.jsonl"
    trace = tmp_path / "trace.json"
    out = _run("repro.launch.serve", "--arch", "opt125m-proxy", "--smoke",
               "--requests", "5", "--rate", "16", "--max-new-tokens", "6",
               "--slots", "2", "--out", str(report),
               "--metrics-out", str(metrics), "--trace-out", str(trace))
    assert out.returncode == 0, out.stdout + out.stderr
    assert "tok/s" in out.stdout and "latency" in out.stdout
    rec = json.loads(report.read_text())
    assert rec["requests"] == 5 and rec["tokens"] == 30
    assert rec["steps"] > 0 and rec["latency_p99_s"] >= rec["latency_p50_s"]
    # SLO observability rides the same run: TTFT/inter-token histograms
    # in the metrics JSONL, spans in a Perfetto-loadable trace
    assert "SLO: ttft p50" in out.stdout
    names = {json.loads(line)["name"]
             for line in metrics.read_text().splitlines() if line.strip()}
    assert {"serve.ttft_s", "serve.inter_token_s", "serve.step_s",
            "serve.pool_occupancy", "serve.decode_steps"} <= names
    events = json.loads(trace.read_text())["traceEvents"]
    assert any(e.get("name") == "serve.run" for e in events)


def test_serve_cli_rejects_oversized_trace():
    """Prompt lengths that cannot fit the serving context must die with a
    clear error instead of wrapping the KV pool."""
    out = _run("repro.launch.serve", "--arch", "opt125m-proxy", "--smoke",
               "--requests", "2", "--prompt-len-min", "60",
               "--prompt-len-max", "64", "--max-new-tokens", "16",
               "--max-blocks-per-request", "4", "--block-size", "16")
    assert out.returncode == 2
    assert "context" in out.stderr


def test_serve_cli_rejects_bad_mesh():
    """--mesh must parse as DATAxMODEL and fit the visible devices; a bad
    spec (or a mesh this machine cannot build) exits 2 with the error."""
    out = _run("repro.launch.serve", "--arch", "opt125m-proxy", "--smoke",
               "--requests", "2", "--mesh", "4y2")
    assert out.returncode == 2
    assert "mesh" in out.stderr.lower()


def test_prune_cli_rejects_bad_mesh():
    """A bad --mesh must die with a clean error/exit 2 BEFORE any
    training happens — same contract as the evaluate/serve CLIs."""
    out = _run("repro.launch.prune", "--arch", "opt125m-proxy",
               "--train-steps", "9999", "--mesh", "4y2", timeout=120)
    assert out.returncode == 2, out.stdout + out.stderr
    assert "mesh" in out.stderr.lower()
    assert "Traceback" not in out.stderr


def test_evaluate_cli_mesh_unavailable_degrades(tmp_path):
    """A checkpoint whose recipe RECORDS a mesh must still evaluate on a
    machine without those devices (single-device fallback), while an
    EXPLICIT --mesh that cannot be built fails loudly."""
    from repro.utils.compat import force_host_devices_flags

    run_dir = tmp_path / "run"
    # prune under 8 fake host devices with --mesh 8x1 so the stored
    # recipe actually records the mesh this machine won't have
    fake8 = {"XLA_FLAGS": force_host_devices_flags(8)}
    out = _run("repro.launch.prune", "--arch", "opt125m-proxy",
               "--method", "wanda", "--sparsity", "2:4",
               "--train-steps", "6", "--calib-sequences", "8",
               "--calib-seq-len", "32", "--workers", "1", "--mesh", "8x1",
               "--ckpt-dir", str(run_dir), env_extra=fake8)
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.loads((run_dir / "pruned_model" / "MANIFEST.json").read_text())
    assert rec["extra"]["recipe"]["mesh"]["devices"] == 8  # mesh recorded
    # strip any inherited fake-device flag: these two runs must really
    # see fewer than 8 devices
    bare = {"XLA_FLAGS": force_host_devices_flags(1)}
    # explicit --mesh on this 1-device process must fail loudly
    out = _run("repro.launch.evaluate", "--checkpoint", str(run_dir),
               "--mesh", "8x1", env_extra=bare)
    assert out.returncode == 2 and "devices" in out.stderr
    # without --mesh the recorded mesh degrades to the single-device
    # (bitwise-identical) eval path instead of failing
    out = _run("repro.launch.evaluate", "--checkpoint", str(run_dir),
               env_extra=bare)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "ppl=" in out.stdout
