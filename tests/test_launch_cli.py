"""End-to-end CLI driver tests (subprocess): launch.train and launch.prune."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(module, *args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-m", module, *args], env=env,
                          capture_output=True, text=True, timeout=timeout,
                          cwd=ROOT)


def test_train_cli_smoke():
    out = _run("repro.launch.train", "--arch", "opt125m-proxy",
               "--steps", "20", "--batch", "4", "--seq", "32")
    assert out.returncode == 0, out.stdout + out.stderr
    assert "valid_ppl=" in out.stdout


def test_train_cli_resume(tmp_path):
    args = ["--arch", "opt125m-proxy", "--steps", "16", "--batch", "4",
            "--seq", "32", "--ckpt-dir", str(tmp_path)]
    out = _run("repro.launch.train", *args)
    assert out.returncode == 0, out.stderr
    out = _run("repro.launch.train", *args, "--resume")
    assert out.returncode == 0, out.stderr
    assert "steps=16" in out.stdout  # restored at final step, no retraining


def test_prune_cli_end_to_end(tmp_path):
    report = tmp_path / "report.json"
    out = _run("repro.launch.prune", "--arch", "opt125m-proxy",
               "--method", "fista", "--sparsity", "2:4",
               "--train-steps", "40", "--calib-sequences", "8",
               "--calib-seq-len", "32", "--workers", "2",
               "--out", str(report))
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.loads(report.read_text())
    assert rec["method"] == "fista" and rec["sparsity"] == "2:4"
    assert rec["pruned_ppl"] > 0 and rec["dense_ppl"] > 0
    assert rec["mean_rel_err"] < 1.0
