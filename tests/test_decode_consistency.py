"""Decode-vs-teacher-forcing consistency for the recurrent/stateful
families (the transformer family is covered in test_substrate.py).

For each arch: feed a short prompt token-by-token through serve_step and
check each step's next-token logits match the full-sequence forward at
that position — the strictest functional test of the cache/state
plumbing (ring buffers, conv windows, SSM states, cross-attention).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.registry import load_arch


def _stepwise_logits(d, params, tokens, extras=None, cache_len=32):
    B, S = tokens.shape
    state = d.init_serve_state(params, B, cache_len, extras)
    outs = []
    for t in range(S):
        logits, state = d.serve_step(params, state, tokens[:, t:t + 1],
                                     jnp.int32(t))
        outs.append(np.asarray(logits[:, -1, :], np.float32))
    return np.stack(outs, axis=1)  # (B, S, V)


@pytest.mark.parametrize("arch", ["mamba2-780m", "recurrentgemma-9b"])
def test_recurrent_decode_matches_forward(arch):
    d = load_arch(arch, smoke=True)
    params = d.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                d.cfg.vocab, jnp.int32)
    got = _stepwise_logits(d, params, tokens)
    want = np.asarray(d.forward_logits(params, {"tokens": tokens}), np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_whisper_decode_matches_forward():
    d = load_arch("whisper-base", smoke=True)
    params = d.init(jax.random.PRNGKey(0))
    batch = d.make_batch(jax.random.PRNGKey(1), 2, 10)
    tokens = batch["tokens"]
    got = _stepwise_logits(d, params, tokens, {"frames": batch["frames"]})
    want = np.asarray(d.forward_logits(params, batch), np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_windowed_attention_ring_buffer():
    """mixtral's SWA ring cache: decode past the window must equal the
    windowed full forward (positions beyond the window are evicted)."""
    d = load_arch("mixtral-8x7b", smoke=True)   # window=16 in smoke config
    params = d.init(jax.random.PRNGKey(0))
    S = 24  # > window
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, S), 0,
                                d.cfg.vocab, jnp.int32)
    got = _stepwise_logits(d, params, tokens, cache_len=d.cfg.window)
    want = np.asarray(d.forward_logits(params, {"tokens": tokens}), np.float32)
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)


def test_windowed_attention_wide_cache():
    """A cache *wider* than the window must still mask attention to the
    window: decode == windowed full forward.  (The non-ring decode branch
    used to skip the window cut and attend to everything <= pos.)"""
    d = load_arch("mixtral-8x7b", smoke=True)   # window=16 in smoke config
    params = d.init(jax.random.PRNGKey(0))
    S = 24  # > window
    tokens = jax.random.randint(jax.random.PRNGKey(4), (1, S), 0,
                                d.cfg.vocab, jnp.int32)
    got = _stepwise_logits(d, params, tokens, cache_len=2 * d.cfg.window)
    want = np.asarray(d.forward_logits(params, {"tokens": tokens}), np.float32)
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)


def test_window_mask_helper_shared_by_decode_paths():
    """``common.decode_window_mask`` is the single source of the decode
    length + sliding-window cut.  Pin (a) its truth table against the
    two formulas it replaced (contiguous non-ring branch; paged gather
    branch), and (b) that the contiguous and paged decode paths agree
    bitwise through it on a window narrower than the cache."""
    from repro.models import common
    from repro.configs.opt125m_proxy import tiny_config

    # (a) truth table, scalar and broadcast pos, window None / narrow
    idx = jnp.arange(16, dtype=jnp.int32)
    for pos in (0, 5, 15):
        for window in (None, 4, 16):
            got = np.asarray(common.decode_window_mask(idx, jnp.int32(pos),
                                                       window))
            want = (np.arange(16) <= pos)
            if window is not None:
                want &= np.arange(16) > pos - window
            np.testing.assert_array_equal(got, want, err_msg=f"{pos},{window}")
    posb = jnp.asarray([[3], [9]], jnp.int32)
    got = np.asarray(common.decode_window_mask(idx[None, :], posb, 4))
    want = (np.arange(16)[None, :] <= np.asarray(posb)) \
        & (np.arange(16)[None, :] > np.asarray(posb) - 4)
    np.testing.assert_array_equal(got, want)

    # (b) contiguous mha_decode == paged mha_decode_paged, windowed,
    # cache wider than the window (both paths route through the helper)
    cfg = tiny_config().replace(num_layers=1, d_model=16, num_heads=2,
                                num_kv_heads=2, vocab=32, window=6)
    p = common.attn_init(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    S, W, nkv, hd = 2, 16, 2, cfg.resolved_head_dim()
    x = jnp.asarray(rng.standard_normal((S, 1, cfg.d_model)), jnp.float32)
    ck = jnp.asarray(rng.standard_normal((S, W, nkv, hd)), jnp.float32)
    cv = jnp.asarray(rng.standard_normal((S, W, nkv, hd)), jnp.float32)
    pos = np.asarray([9, 14], np.int32)
    # identity paging: slot b's context lives at flat slots b*W + [0, W)
    flat = {"k": ck.reshape(S * W, nkv, hd), "v": cv.reshape(S * W, nkv, hd)}
    gather = jnp.asarray(np.arange(S * W).reshape(S, W))
    paged, _ = common.mha_decode_paged(
        cfg, p, x, jnp.asarray(pos), flat,
        jnp.asarray(np.arange(S) * W + pos), gather, jnp.ones((S,), bool),
        cfg.window)
    for b in range(S):
        solo, _ = common.mha_decode(cfg, p, x[b:b + 1], jnp.int32(pos[b]),
                                    {"k": ck[b:b + 1], "v": cv[b:b + 1]},
                                    window=cfg.window)
        np.testing.assert_array_equal(np.asarray(paged[b:b + 1]),
                                      np.asarray(solo))


def test_flash_attention_matches_xla_forward():
    """attn_impl='flash' == 'xla' on the same params (S >= 128 kernel path)."""
    from repro.models.registry import model_def
    d_xla = load_arch("stablelm-1.6b", smoke=True)
    cfg = d_xla.cfg.replace(max_seq=256, attn_impl="xla")
    d_xla = model_def(cfg)
    d_fla = model_def(cfg.replace(attn_impl="flash"))
    params = d_xla.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 192), 0,
                                cfg.vocab, jnp.int32)
    a = np.asarray(d_xla.forward_logits(params, {"tokens": tokens}), np.float32)
    b = np.asarray(d_fla.forward_logits(params, {"tokens": tokens}), np.float32)
    np.testing.assert_allclose(b, a, rtol=5e-3, atol=5e-3)


def test_flash_attention_train_grads_match():
    from repro.models.registry import model_def
    base = load_arch("stablelm-1.6b", smoke=True).cfg.replace(max_seq=256)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 160), 0,
                                base.vocab, jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    grads = {}
    for impl in ("xla", "flash"):
        d = model_def(base.replace(attn_impl=impl))
        params = d.init(jax.random.PRNGKey(0))
        g = jax.grad(lambda p: d.loss(p, batch)[0])(params)
        grads[impl] = g
    ga = np.asarray(grads["xla"]["layers"]["attn"]["wq"], np.float32)
    gb = np.asarray(grads["flash"]["layers"]["attn"]["wq"], np.float32)
    np.testing.assert_allclose(gb, ga, rtol=2e-2, atol=1e-4)
