"""Decode-vs-teacher-forcing consistency for the recurrent/stateful
families (the transformer family is covered in test_substrate.py).

For each arch: feed a short prompt token-by-token through serve_step and
check each step's next-token logits match the full-sequence forward at
that position — the strictest functional test of the cache/state
plumbing (ring buffers, conv windows, SSM states, cross-attention).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.registry import load_arch


def _stepwise_logits(d, params, tokens, extras=None, cache_len=32):
    B, S = tokens.shape
    state = d.init_serve_state(params, B, cache_len, extras)
    outs = []
    for t in range(S):
        logits, state = d.serve_step(params, state, tokens[:, t:t + 1],
                                     jnp.int32(t))
        outs.append(np.asarray(logits[:, -1, :], np.float32))
    return np.stack(outs, axis=1)  # (B, S, V)


@pytest.mark.parametrize("arch", ["mamba2-780m", "recurrentgemma-9b"])
def test_recurrent_decode_matches_forward(arch):
    d = load_arch(arch, smoke=True)
    params = d.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                d.cfg.vocab, jnp.int32)
    got = _stepwise_logits(d, params, tokens)
    want = np.asarray(d.forward_logits(params, {"tokens": tokens}), np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_whisper_decode_matches_forward():
    d = load_arch("whisper-base", smoke=True)
    params = d.init(jax.random.PRNGKey(0))
    batch = d.make_batch(jax.random.PRNGKey(1), 2, 10)
    tokens = batch["tokens"]
    got = _stepwise_logits(d, params, tokens, {"frames": batch["frames"]})
    want = np.asarray(d.forward_logits(params, batch), np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_windowed_attention_ring_buffer():
    """mixtral's SWA ring cache: decode past the window must equal the
    windowed full forward (positions beyond the window are evicted)."""
    d = load_arch("mixtral-8x7b", smoke=True)   # window=16 in smoke config
    params = d.init(jax.random.PRNGKey(0))
    S = 24  # > window
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, S), 0,
                                d.cfg.vocab, jnp.int32)
    got = _stepwise_logits(d, params, tokens, cache_len=d.cfg.window)
    want = np.asarray(d.forward_logits(params, {"tokens": tokens}), np.float32)
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)


def test_windowed_attention_wide_cache():
    """A cache *wider* than the window must still mask attention to the
    window: decode == windowed full forward.  (The non-ring decode branch
    used to skip the window cut and attend to everything <= pos.)"""
    d = load_arch("mixtral-8x7b", smoke=True)   # window=16 in smoke config
    params = d.init(jax.random.PRNGKey(0))
    S = 24  # > window
    tokens = jax.random.randint(jax.random.PRNGKey(4), (1, S), 0,
                                d.cfg.vocab, jnp.int32)
    got = _stepwise_logits(d, params, tokens, cache_len=2 * d.cfg.window)
    want = np.asarray(d.forward_logits(params, {"tokens": tokens}), np.float32)
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)


def test_flash_attention_matches_xla_forward():
    """attn_impl='flash' == 'xla' on the same params (S >= 128 kernel path)."""
    from repro.models.registry import model_def
    d_xla = load_arch("stablelm-1.6b", smoke=True)
    cfg = d_xla.cfg.replace(max_seq=256, attn_impl="xla")
    d_xla = model_def(cfg)
    d_fla = model_def(cfg.replace(attn_impl="flash"))
    params = d_xla.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 192), 0,
                                cfg.vocab, jnp.int32)
    a = np.asarray(d_xla.forward_logits(params, {"tokens": tokens}), np.float32)
    b = np.asarray(d_fla.forward_logits(params, {"tokens": tokens}), np.float32)
    np.testing.assert_allclose(b, a, rtol=5e-3, atol=5e-3)


def test_flash_attention_train_grads_match():
    from repro.models.registry import model_def
    base = load_arch("stablelm-1.6b", smoke=True).cfg.replace(max_seq=256)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 160), 0,
                                base.vocab, jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    grads = {}
    for impl in ("xla", "flash"):
        d = model_def(base.replace(attn_impl=impl))
        params = d.init(jax.random.PRNGKey(0))
        g = jax.grad(lambda p: d.loss(p, batch)[0])(params)
        grads[impl] = g
    ga = np.asarray(grads["xla"]["layers"]["attn"]["wq"], np.float32)
    gb = np.asarray(grads["flash"]["layers"]["attn"]["wq"], np.float32)
    np.testing.assert_allclose(gb, ga, rtol=2e-2, atol=1e-4)
