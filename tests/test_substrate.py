"""Substrate tests: data pipeline, checkpoint store, trainer, serving."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import store
from repro.data import CalibConfig, CorpusConfig, MarkovCorpus, calibration_batches
from repro.data import tokenizer
from repro.data.corpus import batch_to_model_inputs
from repro.models.registry import load_arch, model_def
from repro.serve import Engine, ServeConfig, pack_tree, unpack_tree
from repro.train import AdamWConfig, TrainConfig, Trainer, evaluate_ppl
from repro.train.optim import schedule_fn


class TestCorpus:
    def test_deterministic_stream(self):
        c = MarkovCorpus(CorpusConfig(vocab=128, seed=3))
        a = list(zip(*[next(c.batches(2, 16)) for _ in range(3)]))
        b = list(zip(*[next(c.batches(2, 16)) for _ in range(3)]))
        for x, y in zip(a[1], b[1]):
            np.testing.assert_array_equal(x, y)

    def test_cursor_resume(self):
        c = MarkovCorpus(CorpusConfig(vocab=64))
        it = c.batches(2, 8)
        [next(it) for _ in range(5)]
        step, want = next(it)
        it2 = c.batches(2, 8, start_step=step)
        step2, got = next(it2)
        assert step2 == step
        np.testing.assert_array_equal(got, want)

    def test_splits_disjoint_streams(self):
        c = MarkovCorpus(CorpusConfig(vocab=64))
        _, tr = next(c.batches(2, 32, split="train"))
        _, va = next(c.batches(2, 32, split="valid"))
        assert not np.array_equal(tr, va)

    def test_entropy_floor_positive(self):
        c = MarkovCorpus(CorpusConfig(vocab=128))
        assert 0.1 < c.entropy_per_token < np.log(128)

    def test_labels_are_shifted_tokens(self):
        c = MarkovCorpus(CorpusConfig(vocab=64))
        _, toks = next(c.batches(2, 8))
        b = batch_to_model_inputs(toks)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_calibration_count(self):
        c = MarkovCorpus(CorpusConfig(vocab=64))
        batches = calibration_batches(c, CalibConfig(num_sequences=10, seq_len=16,
                                                     batch_size=4))
        assert sum(b["tokens"].shape[0] for b in batches) == 10


class TestTokenizer:
    def test_roundtrip(self):
        for text in ["hello world", "üñïçødé ✓", ""]:
            assert tokenizer.decode(tokenizer.encode(text)) == text

    def test_specials(self):
        ids = tokenizer.encode("a")
        assert ids[0] == tokenizer.BOS and ids[-1] == tokenizer.EOS


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
                "b": jnp.ones((4,), jnp.bfloat16)}
        store.save(str(tmp_path), "step_00000001", tree, extra={"step": 1})
        got, extra = store.load(str(tmp_path), "step_00000001", like=tree)
        assert extra["step"] == 1
        np.testing.assert_array_equal(np.asarray(got["a"]["w"]), np.asarray(tree["a"]["w"]))
        assert got["b"].dtype == jnp.bfloat16

    def test_corruption_detected(self, tmp_path):
        tree = {"w": jnp.ones((8,), jnp.float32)}
        path = store.save(str(tmp_path), "step_00000001", tree)
        npz = os.path.join(path, "arrays.npz")
        # corrupt: rewrite with different data, keep manifest
        np.savez(npz, w=np.zeros((8,), np.float32))
        with pytest.raises(store.CheckpointCorrupt):
            store.load(str(tmp_path), "step_00000001", like=tree)

    def test_incomplete_invisible(self, tmp_path):
        os.makedirs(tmp_path / "step_00000009")
        assert store.latest_step(str(tmp_path)) is None

    def test_prune_old(self, tmp_path):
        tree = {"w": jnp.zeros((2,))}
        for s in range(5):
            store.save(str(tmp_path), store.step_name(s), tree)
        store.prune_old(str(tmp_path), keep=2)
        assert store.list_steps(str(tmp_path)) == [3, 4]


class TestOptim:
    def test_schedules(self):
        for sched in ("cosine", "wsd", "const"):
            cfg = AdamWConfig(lr=1.0, schedule=sched, warmup_steps=10, total_steps=100)
            fn = schedule_fn(cfg)
            assert float(fn(jnp.int32(0))) == 0.0
            assert float(fn(jnp.int32(10))) == pytest.approx(1.0, abs=0.11)
            if sched != "const":
                assert float(fn(jnp.int32(100))) < 0.2

    def test_wsd_stable_phase(self):
        cfg = AdamWConfig(lr=1.0, schedule="wsd", warmup_steps=5,
                          total_steps=100, decay_frac=0.2)
        fn = schedule_fn(cfg)
        assert float(fn(jnp.int32(50))) == pytest.approx(1.0)
        assert float(fn(jnp.int32(100))) == pytest.approx(cfg.min_lr_frac, abs=1e-5)


@pytest.fixture(scope="module")
def tiny_setup():
    from repro.configs.opt125m_proxy import tiny_config
    cfg = tiny_config().replace(num_layers=2, d_model=64, d_ff=128,
                                num_heads=4, num_kv_heads=4, vocab=128)
    model = model_def(cfg)
    corpus = MarkovCorpus(CorpusConfig(vocab=cfg.vocab, seed=7))
    return model, corpus


class TestTrainer:
    def test_loss_decreases(self, tiny_setup):
        model, corpus = tiny_setup
        tr = Trainer(model, corpus, TrainConfig(
            steps=30, batch=8, seq=32, log_every=5,
            optim=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=30)))
        out = tr.run()
        first = out["history"][0]["loss"]
        last = out["history"][-1]["loss"]
        assert last < first - 0.2, (first, last)

    def test_resume_bit_exact(self, tiny_setup, tmp_path):
        model, corpus = tiny_setup
        mk = lambda d: TrainConfig(steps=12, batch=4, seq=16, ckpt_every=6,
                                   ckpt_dir=str(d), log_every=3,
                                   optim=AdamWConfig(lr=1e-3, warmup_steps=2,
                                                     total_steps=12))
        t1 = Trainer(model, corpus, mk(tmp_path / "a"))
        t1.run()
        # crash-and-restart: new trainer, restore at step 6, continue to 12
        t2 = Trainer(model, corpus, mk(tmp_path / "b"))
        t2.cfg = mk(tmp_path / "a")
        t2.run  # same corpus stream
        t3 = Trainer(model, corpus, mk(tmp_path / "a"))
        # wipe the final checkpoint so restore() picks step 6
        import shutil
        shutil.rmtree(tmp_path / "a" / store.step_name(12))
        assert t3.restore() and t3.step == 6
        t3.run()
        from repro.utils.tree import tree_allclose
        assert tree_allclose(t1.params, t3.params, rtol=1e-5, atol=1e-6)

    def test_grad_accum_matches_big_batch(self, tiny_setup):
        model, corpus = tiny_setup
        cfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=4, grad_clip=0.0)
        a = Trainer(model, corpus, TrainConfig(steps=2, batch=8, seq=16,
                                               grad_accum=1, log_every=1, optim=cfg))
        a.run()
        b = Trainer(model, corpus, TrainConfig(steps=2, batch=4, seq=16,
                                               grad_accum=2, log_every=1, optim=cfg))
        b.run()
        # same total tokens; streams differ per-microbatch so require only
        # both-finite + same order of magnitude (consistency smoke)
        assert np.isfinite(a.history[-1]["loss"]) and np.isfinite(b.history[-1]["loss"])

    def test_evaluate_ppl(self, tiny_setup):
        model, corpus = tiny_setup
        params = model.init(jax.random.PRNGKey(0))
        ppl = evaluate_ppl(model, params, corpus, batch=4, seq=32, n_batches=2)
        assert 1.0 < ppl < model.cfg.vocab * 4  # random init ~ uniform


class TestServe:
    def test_generate_greedy_deterministic(self, tiny_setup):
        model, corpus = tiny_setup
        params = model.init(jax.random.PRNGKey(0))
        eng = Engine(model, params, ServeConfig(max_new_tokens=8))
        prompt = jnp.asarray(next(corpus.batches(2, 8))[1][:, :8], jnp.int32)
        a = eng.generate(prompt)
        b = eng.generate(prompt)
        assert a.shape == (2, 8)
        np.testing.assert_array_equal(a, b)

    def test_decode_matches_teacher_forcing(self, tiny_setup):
        """Greedy decode == argmax of full-forward logits at each position."""
        model, corpus = tiny_setup
        params = model.init(jax.random.PRNGKey(3))
        prompt = jnp.asarray(next(corpus.batches(1, 8))[1][:, :8], jnp.int32)
        eng = Engine(model, params, ServeConfig(max_new_tokens=4))
        gen = eng.generate(prompt)
        seq = jnp.concatenate([prompt, jnp.asarray(gen)], axis=1)
        logits = model.forward_logits(params, {"tokens": seq})
        want = np.asarray(jnp.argmax(logits[:, 7:-1].astype(jnp.float32), axis=-1))
        np.testing.assert_array_equal(np.asarray(gen), want)

    def test_pack_unpack_roundtrip(self, tiny_setup):
        from repro.core.sparsity import round_nm
        model, corpus = tiny_setup
        params = model.init(jax.random.PRNGKey(0))
        # make every attn/mlp weight exactly 2:4 in paper layout
        from repro.utils.tree import tree_map_with_path
        def prune(path, w):
            if w.ndim == 2 and "embed" not in path and w.shape[0] % 4 == 0 \
                    and "pos" not in path:
                return round_nm(w.T.astype(jnp.float32), 2, 4).T.astype(w.dtype)
            return w
        sparse = tree_map_with_path(prune, params)
        packed, stats = pack_tree(sparse)
        assert stats["packed_ops"] > 0
        assert stats["packed_bytes"] / max(stats["dense_bytes"], 1) == pytest.approx(0.625)
        back = unpack_tree(packed)
        from repro.utils.tree import get_path
        w0 = np.asarray(get_path(sparse, "layers/attn/wq")[0], np.float32)
        w1 = np.asarray(get_path(back, "layers/attn/wq")[0], np.float32)
        np.testing.assert_allclose(w0, w1, atol=2e-2)  # bf16 packing

    def test_packed_serving_matches_dense(self, tiny_setup):
        from repro.core.sparsity import round_nm
        from repro.utils.tree import tree_map_with_path
        model, corpus = tiny_setup
        params = model.init(jax.random.PRNGKey(1))
        def prune(path, w):
            if w.ndim == 2 and "embed" not in path and w.shape[0] % 4 == 0:
                return round_nm(w.T.astype(jnp.float32), 2, 4).T.astype(w.dtype)
            return w
        sparse = tree_map_with_path(prune, params)
        packed, _ = pack_tree(jax.tree_util.tree_map(
            lambda x: x.astype(jnp.float32), sparse))
        prompt = jnp.asarray(next(corpus.batches(1, 8))[1][:, :8], jnp.int32)
        dense_gen = Engine(model, sparse, ServeConfig(max_new_tokens=4)).generate(prompt)
        packed_gen = Engine(model, packed, ServeConfig(max_new_tokens=4)).generate(prompt)
        np.testing.assert_array_equal(dense_gen, packed_gen)
