"""Hypothesis property tests on the system's core invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional test dep (pip install '.[test]') — see pyproject.toml")
from hypothesis import given, settings, strategies as st
import hypothesis.extra.numpy as hnp

from repro.core import fista as fista_lib
from repro.core import frankwolfe as fw_lib
from repro.core import gram as gram_lib
from repro.core.sparsity import (SparsitySpec, mask_by_score, round_nm,
                                 round_to, round_unstructured, satisfies)
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.utils import tree as tree_lib

F32 = st.floats(-10, 10, width=32, allow_nan=False, allow_infinity=False)


def arr(shape):
    return hnp.arrays(np.float32, shape, elements=F32)


class TestSparsityProps:
    @given(arr((6, 16)), st.sampled_from([0.0, 0.25, 0.5, 0.75]))
    @settings(max_examples=30, deadline=None)
    def test_unstructured_exact_and_subset(self, w, ratio):
        out = np.asarray(round_unstructured(jnp.asarray(w), ratio))
        assert int((out == 0).sum()) >= round(ratio * w.size)
        nz = out != 0
        assert np.array_equal(out[nz], w[nz])  # surviving values unchanged

    @given(arr((4, 24)), st.sampled_from([(1, 4), (2, 4), (4, 8), (2, 8)]))
    @settings(max_examples=30, deadline=None)
    def test_nm_invariants(self, w, nm):
        n, m = nm
        out = np.asarray(round_nm(jnp.asarray(w), n, m))
        spec = SparsitySpec(kind="nm", n=n, m=m)
        assert satisfies(out, spec)
        # kept magnitude per group >= any dropped magnitude
        g = out.reshape(4, -1, m)
        gw = w.reshape(4, -1, m)
        kept_min = np.where(g != 0, np.abs(gw), np.inf).min(axis=-1)
        dropped_max = np.where(g == 0, np.abs(gw), -np.inf).max(axis=-1)
        assert (kept_min >= dropped_max - 1e-6).all()

    @given(arr((5, 12)), st.sampled_from([0.25, 0.5]))
    @settings(max_examples=20, deadline=None)
    def test_mask_scores_keep_largest(self, score, ratio):
        score = np.abs(score)
        mask = np.asarray(mask_by_score(jnp.asarray(score), SparsitySpec(ratio=ratio)))
        if mask.all() or (~mask).all():
            return
        assert score[mask].min() >= score[~mask].max() - 1e-6


class TestShrinkageProps:
    @given(arr((8, 8)), st.floats(0, 5, width=32))
    @settings(max_examples=30, deadline=None)
    def test_shrinkage_properties(self, x, rho):
        out = np.asarray(fista_lib.soft_shrinkage(jnp.asarray(x), rho))
        # nonexpansive, sign-preserving, kills |x|<=rho
        assert (np.abs(out) <= np.abs(x) + 1e-6).all()
        assert (out * x >= -1e-6).all()
        assert (out[np.abs(x) <= rho] == 0).all()
        # exact prox of rho*|.|: distance property
        assert np.allclose(out, np.sign(x) * np.maximum(np.abs(x) - rho, 0), atol=1e-6)


class TestGramProps:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_error_identity_random(self, seed):
        rng = np.random.default_rng(seed)
        m, n, p = 6, 10, 40
        w = rng.normal(size=(m, n)).astype(np.float32)
        x = rng.normal(size=(n, p)).astype(np.float32)
        xs = x + 0.1 * rng.normal(size=(n, p)).astype(np.float32)
        y = rng.normal(size=(m, n)).astype(np.float32)
        stats = gram_lib.init_stats(n)
        stats = gram_lib.accumulate(stats, x.T, xs.T, (w @ x).T)
        b = gram_lib.target_correlation(stats, jnp.asarray(w))
        direct = np.linalg.norm(y @ xs - w @ x) ** 2
        via = float(gram_lib.frob_error_sq(stats, jnp.asarray(y), b))
        assert np.isclose(direct, via, rtol=2e-3, atol=1e-3)

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_merge_equals_joint(self, seed):
        rng = np.random.default_rng(seed)
        n, p = 8, 32
        xa = rng.normal(size=(n, p)).astype(np.float32)
        xb = rng.normal(size=(n, p)).astype(np.float32)
        w = rng.normal(size=(4, n)).astype(np.float32)
        sa = gram_lib.accumulate(gram_lib.init_stats(n), xa.T, xa.T, (w @ xa).T)
        sb = gram_lib.accumulate(gram_lib.init_stats(n), xb.T, xb.T, (w @ xb).T)
        joint = gram_lib.accumulate(sa, xb.T, xb.T, (w @ xb).T)
        merged = gram_lib.merge(sa, sb)
        np.testing.assert_allclose(np.asarray(merged.G), np.asarray(joint.G), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(float(merged.h), float(joint.h), rtol=1e-4)


FW_SPECS = [SparsitySpec(ratio=0.5), SparsitySpec(ratio=0.25),
            SparsitySpec(kind="nm", n=2, m=4), SparsitySpec(kind="nm", n=1, m=4)]


def _fw_problem(seed, m=8, n=16, p=64):
    """Random well-posed Gram problem (G PSD by construction)."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(m, n)).astype(np.float32)
    x = rng.normal(size=(n, p)).astype(np.float32)
    xs = x + 0.1 * rng.normal(size=(n, p)).astype(np.float32)
    stats = gram_lib.accumulate(gram_lib.init_stats(n), jnp.asarray(x.T),
                                jnp.asarray(xs.T), jnp.asarray((w @ x).T))
    b = gram_lib.target_correlation(stats, jnp.asarray(w))
    return jnp.asarray(w), stats, b


class TestFrankWolfeProps:
    """Invariants of the projection-free Frank-Wolfe solver
    (core/frankwolfe.py)."""

    @given(st.integers(0, 2**31 - 1), st.sampled_from(range(len(FW_SPECS))))
    @settings(max_examples=20, deadline=None)
    def test_lmo_atom_support_within_budget(self, seed, spec_i):
        """The LMO's atom is spec-pattern k-sparse: support <= keep budget,
        n:m pattern exact, and it is a descent atom (<grad, s> <= 0)."""
        spec = FW_SPECS[spec_i]
        rng = np.random.default_rng(seed)
        grad = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
        atom = np.asarray(fw_lib.lmo_atom(grad, spec, jnp.float32(1.0)))
        assert int(np.count_nonzero(atom)) <= fw_lib.keep_count(atom.shape, spec)
        assert satisfies(atom, spec)
        assert float(np.sum(np.asarray(grad) * atom)) <= 1e-6
        # atom lives on the tau-radius ball (radius 1 here)
        assert float(np.linalg.norm(atom)) <= 1.0 + 1e-5

    @given(st.integers(0, 2**31 - 1), st.sampled_from(range(len(FW_SPECS))))
    @settings(max_examples=15, deadline=None)
    def test_objective_monotone_nonincreasing(self, seed, spec_i):
        """Exact line search on the quadratic: f never increases along the
        FW iterates, and the dual gap stays nonnegative in the hull."""
        spec = FW_SPECS[spec_i]
        w, stats, b = _fw_problem(seed)
        y = round_to(w.astype(jnp.float32), spec)
        tau = 1.25 * jnp.linalg.norm(y) + 1e-8
        f = lambda z: 0.5 * float(gram_lib.frob_error_sq_gh(stats.G, stats.h,
                                                            z, b))
        prev = f(y)
        for _ in range(6):
            y, gap = fw_lib.fw_step(y, stats.G, b, spec, tau)
            cur = f(y)
            assert float(gap) >= -1e-3 * (prev + 1.0)
            assert cur <= prev + 1e-3 * (prev + 1.0)
            prev = cur

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_resolve_already_feasible_is_noop(self, seed):
        """Solving a problem whose weight is already feasible AND already
        exact (X* = X, target = W X) returns the input bitwise — strict
        best-tracking never replaces a zero-error candidate.

        Small-integer data keeps every Gram matmul exact in fp32, so the
        input's measured error is exactly 0 (float data would bury the
        true 0 under catastrophic cancellation in <YG,Y> - 2<Y,B> + h)."""
        rng = np.random.default_rng(seed)
        spec = SparsitySpec(kind="nm", n=2, m=4)
        w = np.asarray(round_to(jnp.asarray(
            rng.integers(-3, 4, size=(6, 16)).astype(np.float32)), spec))
        x = rng.integers(-2, 3, size=(16, 48)).astype(np.float32)
        stats = gram_lib.accumulate(gram_lib.init_stats(16), jnp.asarray(x.T),
                                    jnp.asarray(x.T), jnp.asarray((w @ x).T))
        res = fw_lib.prune_operator_fw(jnp.asarray(w), stats, spec)
        assert res.error == 0.0
        assert np.array_equal(np.asarray(res.weight), w)


class TestCrossUnitStatsProps:
    @given(st.integers(0, 2**31 - 1), st.integers(1, 4))
    @settings(max_examples=10, deadline=None)
    def test_realized_accumulation_conserves_psd(self, seed, nbatches):
        """Cross-unit provisioning feeds REALIZED (pruned-relay) activations
        into both Gram paths; accumulated G and H must stay PSD however the
        realized inputs drift, including across shard merges."""
        rng = np.random.default_rng(seed)
        n, p = 10, 24
        w = rng.normal(size=(4, n)).astype(np.float32)
        stats = gram_lib.init_stats(n)
        shards = []
        for _ in range(nbatches):
            xr = rng.normal(size=(n, p)).astype(np.float32)       # realized X~
            xs = xr + rng.normal(size=(n, p)).astype(np.float32)  # intra relay
            stats = gram_lib.accumulate(stats, jnp.asarray(xr.T),
                                        jnp.asarray(xs.T),
                                        jnp.asarray((w @ xr).T))
            shards.append(gram_lib.accumulate(
                gram_lib.init_stats(n), jnp.asarray(xr.T), jnp.asarray(xs.T),
                jnp.asarray((w @ xr).T)))
        merged = shards[0]
        for s in shards[1:]:
            merged = gram_lib.merge(merged, s)
        for st_ in (stats, merged):
            for mat in (st_.G, st_.H):
                eig = np.linalg.eigvalsh(np.asarray(mat, np.float64))
                assert eig.min() >= -1e-3 * max(1.0, eig.max())
            assert float(st_.h) >= 0.0
        np.testing.assert_allclose(np.asarray(merged.G), np.asarray(stats.G),
                                   rtol=1e-4, atol=1e-4)


class TestTwoFourProps:
    """Sparsity invariants of the 2:4 pipeline (round -> pack -> spmm)."""

    @given(st.integers(1, 40), st.integers(1, 24), st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_round24_kernel_matches_ref_and_invariants(self, m, ngroups, seed):
        """kernels.round24 == ref on random shapes (incl. ragged tails not
        aligned to the kernel's 8x128 blocks); every 4-group keeps <= 2."""
        n = 4 * ngroups
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
        out = np.asarray(kops.round24(w))        # kernel or oracle dispatch
        np.testing.assert_array_equal(out, np.asarray(kref.round24(w)))
        groups = out.reshape(m, ngroups, 4)
        assert ((groups != 0).sum(axis=-1) <= 2).all()
        # surviving values are a subset of the input, untouched
        nz = out != 0
        np.testing.assert_array_equal(out[nz], np.asarray(w)[nz])

    @given(st.integers(1, 16), st.integers(1, 16), st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_round24_idempotent(self, m, ngroups, seed):
        """Masks are a fixed point: re-rounding a 2:4 matrix is identity."""
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.normal(size=(m, 4 * ngroups)).astype(np.float32))
        once = kops.round24(w)
        np.testing.assert_array_equal(np.asarray(kops.round24(once)),
                                      np.asarray(once))

    @given(st.integers(1, 16), st.integers(1, 16), st.integers(0, 2**31 - 1),
           st.sampled_from([0.0, 0.5, 0.9]))
    @settings(max_examples=15, deadline=None)
    def test_pack_roundtrip_any_sparsity(self, m, ngroups, seed, extra_zero):
        """pack24/unpack24 round-trip exactly, including groups with fewer
        than 2 nonzeros (zero-padded slots)."""
        rng = np.random.default_rng(seed)
        n = 4 * ngroups
        w = rng.normal(size=(m, n)).astype(np.float32)
        w[rng.random(size=w.shape) < extra_zero] = 0.0
        w24 = kref.round24(jnp.asarray(w))
        vals, meta = kref.pack24(w24)
        np.testing.assert_array_equal(np.asarray(kref.unpack24(vals, meta, n)),
                                      np.asarray(w24))


class TestTreeProps:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_set_get_roundtrip(self, seed):
        rng = np.random.default_rng(seed)
        tree = {"a": {"b": jnp.zeros((2,)), "c": jnp.ones((3,))}, "d": jnp.zeros(())}
        val = jnp.asarray(rng.normal(size=(2,)).astype(np.float32))
        new = tree_lib.set_path(tree, "a/b", val)
        np.testing.assert_array_equal(np.asarray(tree_lib.get_path(new, "a/b")), np.asarray(val))
        # untouched leaves shared
        assert new["a"]["c"] is tree["a"]["c"]
        assert new["d"] is tree["d"]

    def test_stack_unstack(self):
        trees = [{"w": jnp.full((2, 2), i)} for i in range(3)]
        stacked = tree_lib.tree_stack(trees)
        assert stacked["w"].shape == (3, 2, 2)
        back = tree_lib.tree_unstack(stacked, 3)
        for i in range(3):
            np.testing.assert_array_equal(np.asarray(back[i]["w"]), np.asarray(trees[i]["w"]))

    def test_flatten_deterministic(self):
        tree = {"b": jnp.zeros((1,)), "a": {"z": jnp.ones((1,)), "y": jnp.zeros((2,))}}
        paths = [p for p, _ in tree_lib.flatten_with_paths(tree)]
        assert paths == sorted(paths)
