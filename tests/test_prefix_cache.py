"""Invariants for the radix prompt-prefix cache (serve/prefix_cache.py).

The cache shares KV blocks across requests via pool refcounts, so the
properties that matter are allocator-level: refcounts are conserved
(every block's count equals its sharers plus its trie node), shared
blocks never alias into the free list, and a pool defrag moves cached
contents with their renamed ids.  The hypothesis sweeps replay random
serve histories (acquire -> alloc -> insert -> free / evict / defrag)
against those invariants; the deterministic pins below them cover the
exact-match semantics, the >= 1-token-recomputed cap, LRU leaf-only
eviction, and capacity behavior — and run even without hypothesis
(optional test dep, pip install '.[test]').
"""
import numpy as np
import pytest

from repro.serve.kv_cache import (TRASH_BLOCK, BlockPool, PoolExhausted,
                                  apply_defrag)
from repro.serve.prefix_cache import PrefixCache

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

NB, BS = 17, 4          # 16 allocatable blocks of 4 token slots


def _serve_one(pool: BlockPool, cache: PrefixCache, rid: int,
               prompt: np.ndarray):
    """The batcher's admission accounting in miniature: adopt matched
    blocks, allocate the rest (always >= 1 — the tail chunk writes K/V
    into the request's own blocks), cache the full prompt blocks."""
    blocks, matched = cache.acquire(rid, prompt)
    P = len(prompt)
    n_own = max(1, -(-P // pool.block_size)) - len(blocks)
    try:
        own = pool.alloc(rid, n_own)
    except PoolExhausted:
        pool.free_request(rid)                 # roll back the share
        raise
    table = blocks + own
    cache.insert(prompt, table[:P // pool.block_size])
    return table, matched


def _trie_nodes(cache: PrefixCache):
    out, stack = [], list(cache._root.children.values())
    while stack:
        node = stack.pop()
        out.append(node)
        stack.extend(node.children.values())
    return out


def _check_invariants(pool: BlockPool, cache: PrefixCache) -> None:
    nodes = _trie_nodes(cache)
    assert len(nodes) == cache.num_blocks
    # refcount conservation: each block's count is its owners (share
    # appends to the owned list once per sharer) plus its trie node
    expected: dict = {}
    for bl in pool._owned.values():
        for b in bl:
            expected[b] = expected.get(b, 0) + 1
    for node in nodes:
        expected[node.block] = expected.get(node.block, 0) + 1
    assert pool._ref == expected
    # live/free partition the allocatable ids; trash is neither
    live, free = set(pool._ref), set(pool._free)
    assert not live & free
    assert live | free == set(range(1, pool.num_blocks))
    assert TRASH_BLOCK not in live
    assert pool.num_live == len(live)
    # every cached block is still live (never aliased into the free list)
    for node in nodes:
        assert pool.refcount(node.block) >= 1


if HAVE_HYPOTHESIS:
    PROMPT = st.lists(st.integers(0, 2), min_size=1, max_size=14)
    OPS = st.lists(st.one_of(
        st.tuples(st.just("req"), st.integers(0, 3), PROMPT),
        st.tuples(st.just("free"), st.integers(0, 3)),
        st.tuples(st.just("evict"), st.integers(1, 4)),
        st.tuples(st.just("defrag")),
    ), min_size=1, max_size=30)

    class TestPrefixCacheProps:
        @given(OPS)
        @settings(max_examples=60, deadline=None)
        def test_refcounts_conserved_over_serve_histories(self, ops):
            pool = BlockPool(NB, BS)
            cache = PrefixCache(pool)
            active = set()
            for op in ops:
                if op[0] == "req":
                    _, rid, prompt = op
                    if rid in active:
                        continue
                    try:
                        _serve_one(pool, cache, rid,
                                   np.asarray(prompt, np.int32))
                        active.add(rid)
                    except PoolExhausted:
                        pass
                elif op[0] == "free":
                    pool.free_request(op[1])
                    active.discard(op[1])
                elif op[0] == "evict":
                    cache.evict(op[1])
                else:
                    cache.apply_defrag(pool.defrag())
                _check_invariants(pool, cache)
            # drain: free every request, evict everything evictable —
            # the pool must come all the way back
            for rid in list(active):
                pool.free_request(rid)
            cache.evict(cache.num_blocks)
            _check_invariants(pool, cache)
            assert cache.num_blocks == 0
            assert pool.num_live == 0

        @given(OPS)
        @settings(max_examples=30, deadline=None)
        def test_defrag_preserves_shared_contents(self, ops):
            """Blocks are stamped with the tokens they cover; after any
            defrag the trie's renamed block ids must still read back the
            exact prompt bytes (the serving pool moves K/V rows with
            the same remap — kv_cache.apply_defrag)."""
            pool = BlockPool(NB, BS)
            cache = PrefixCache(pool)
            state = {"k": np.zeros((1, NB * BS, 1, 1), np.int32)}
            stamped = []
            active = set()
            for op in ops:
                if op[0] == "req":
                    _, rid, prompt = op
                    prompt = np.asarray(prompt, np.int32)
                    if rid in active:
                        continue
                    try:
                        table, matched = _serve_one(pool, cache, rid, prompt)
                    except PoolExhausted:
                        continue
                    active.add(rid)
                    # the prefill writes each full block's tokens into
                    # its rows (matched blocks were written by the
                    # original insert — bitwise equal by construction)
                    for i in range(matched // BS, len(prompt) // BS):
                        b = table[i]
                        state["k"][0, b * BS:(b + 1) * BS, 0, 0] = \
                            prompt[i * BS:(i + 1) * BS]
                elif op[0] == "free":
                    pool.free_request(op[1])
                    active.discard(op[1])
                elif op[0] == "evict":
                    cache.evict(op[1])
                else:
                    remap = pool.defrag()
                    cache.apply_defrag(remap)
                    state = apply_defrag(state, remap, NB, BS)
                    stamped.append(len(remap))
            # every surviving trie path still reads back its tokens
            def walk(node, tokens):
                for child in node.children.values():
                    toks = tokens + list(np.frombuffer(child.key, np.int32))
                    got = state["k"][0, child.block * BS:
                                     (child.block + 1) * BS, 0, 0]
                    assert got.tolist() == toks[-BS:]
                    walk(child, toks)
            walk(cache._root, [])


class TestPrefixCachePins:
    """Deterministic pins — run without hypothesis."""

    def _mk(self, capacity=None):
        pool = BlockPool(NB, BS)
        return pool, PrefixCache(pool, capacity)

    def test_exact_match_and_one_token_recomputed_cap(self):
        pool, cache = self._mk()
        prompt = np.arange(10, dtype=np.int32)          # 2 full blocks + 2
        _serve_one(pool, cache, 0, prompt)
        assert cache.num_blocks == 2
        # identical prompt: both full blocks match... but the cap keeps
        # >= 1 token uncached, so a 8-token prompt matches only 1 block
        assert cache.match_tokens(prompt) == 8
        assert cache.match_tokens(prompt[:8]) == 4
        # partial-block tails and near-miss tokens never match
        assert cache.match_tokens(prompt[:7]) == 4
        wrong = prompt.copy()
        wrong[2] = 99
        assert cache.match_tokens(wrong) == 0
        # matching is per-block prefix: diverge in block 2 -> 1 block
        wrong2 = prompt.copy()
        wrong2[6] = 99
        assert cache.match_tokens(wrong2) == 4

    def test_hit_shares_blocks_and_skips_recompute_region(self):
        pool, cache = self._mk()
        prompt = np.arange(12, dtype=np.int32)
        table0, _ = _serve_one(pool, cache, 0, prompt)
        table1, matched = _serve_one(pool, cache, 1, prompt)
        assert matched == 8                      # (12-1)//4 = 2 blocks
        assert table1[:2] == table0[:2]          # adopted, not copied
        assert table1[2] not in table0           # own tail block
        assert cache.hits == 1 and cache.misses == 1
        # both sharers + the cache hold the shared blocks
        for b in table1[:2]:
            assert pool.refcount(b) == 3
        _check_invariants(pool, cache)

    def test_free_then_evict_returns_blocks(self):
        pool, cache = self._mk()
        prompt = np.arange(12, dtype=np.int32)
        _serve_one(pool, cache, 0, prompt)
        pool.free_request(0)
        # cache retains all 3 cached blocks; the tail own-block frees
        assert pool.num_live == 3
        assert cache.evict(99) == 3              # cascades leaf -> root
        assert pool.num_live == 0 and cache.num_blocks == 0
        _check_invariants(pool, cache)

    def test_eviction_is_lru_and_skips_shared_blocks(self):
        pool, cache = self._mk()
        a = np.arange(12, dtype=np.int32)
        b = np.arange(100, 112, dtype=np.int32)
        _serve_one(pool, cache, 0, a)
        _serve_one(pool, cache, 1, b)
        pool.free_request(0)
        pool.free_request(1)
        # touch a's matchable path (the cap bumps only 2 of a's 3
        # nodes) -> a's third block is the LRU leaf
        cache.acquire(2, a)
        pool.free_request(2)
        a3 = cache._root.children[a[:4].tobytes()].children[
            a[4:8].tobytes()].children[a[8:12].tobytes()].block
        assert cache.evict(1) == 1
        assert all(n.block != a3 for n in _trie_nodes(cache))
        assert cache.num_blocks == 5
        # a sharer pins its blocks: a's remaining path survives a full
        # eviction sweep while request 3 holds it; b's chain cascades out
        cache.acquire(3, a)
        freed = cache.evict(99)
        assert freed == 3                        # b3 -> b2 -> b1
        assert cache.num_blocks == 2             # a's two shared blocks stay
        _check_invariants(pool, cache)

    def test_capacity_evicts_then_skips_when_pinned(self):
        pool, cache = self._mk(capacity=2)
        a = np.arange(12, dtype=np.int32)
        _serve_one(pool, cache, 0, a)
        assert cache.num_blocks == 2
        pool.free_request(0)
        b = np.arange(100, 112, dtype=np.int32)
        _serve_one(pool, cache, 1, b)            # evicts a's LRU leaves
        assert cache.num_blocks == 2
        pool.free_request(1)
        _check_invariants(pool, cache)

    def test_insert_needs_enough_blocks(self):
        pool, cache = self._mk()
        with pytest.raises(ValueError):
            cache.insert(np.arange(8, dtype=np.int32), [1])

    def test_first_writer_wins_on_reinsert(self):
        pool, cache = self._mk()
        prompt = np.arange(8, dtype=np.int32)
        table0, _ = _serve_one(pool, cache, 0, prompt)
        table1, _ = _serve_one(pool, cache, 1, prompt)
        node = cache._root.children[prompt[:4].tobytes()]
        assert node.block == table0[0]
        assert cache.inserted_blocks == 2        # block 1 + block 2, once
        pool.free_request(0)
        pool.free_request(1)
        _check_invariants(pool, cache)
