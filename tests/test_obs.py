"""Observability subsystem (repro.obs): spans, metrics, persistence,
serve/prune instrumentation.

The load-bearing pins: the span ring retains exactly the last
``capacity`` spans with nesting/parenting intact; histogram bucket
edges follow Prometheus upper-edge semantics; spans and metrics
round-trip through JSONL and the Perfetto export is Chrome-trace
loadable; the batcher records SLO metrics under defrag and EOS retire
without changing a single emitted token; and the fused solver's
convergence trace matches the host oracle's.
"""
import json
import os
import subprocess
import sys
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import obs
from repro.obs import metrics as metrics_lib
from repro.obs import report as report_lib
from repro.obs import spans as spans_lib
from repro.configs.opt125m_proxy import tiny_config
from repro.core import gram as gram_lib
from repro.core.pruner import PrunerConfig, prune_operator
from repro.core.sparsity import SparsitySpec
from repro.models.registry import model_def
from repro.serve import BatchConfig, ContinuousBatcher, Request


@pytest.fixture(autouse=True)
def _obs_clean():
    """Global obs state must never leak between tests (or into the rest
    of the suite — batcher/solver tests assume uninstrumented runs)."""
    obs.disable()
    yield
    obs.disable()


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------
class TestSpanRecorder:
    def test_nesting_parent_and_depth(self):
        rec = spans_lib.SpanRecorder(capacity=16)
        with rec.span("outer", unit="u0"):
            with rec.span("inner"):
                pass
            with rec.span("inner"):
                pass
        sps = rec.spans()
        # children finish before the parent, so they precede it in the ring
        assert [s.name for s in sps] == ["inner", "inner", "outer"]
        outer = sps[2]
        assert outer.depth == 0 and outer.parent == -1
        assert outer.attrs == {"unit": "u0"}
        for child in sps[:2]:
            assert child.depth == 1 and child.parent == outer.index
        assert all(s.dur >= 0 for s in sps)

    def test_ring_wraparound_keeps_last_capacity(self):
        rec = spans_lib.SpanRecorder(capacity=4)
        for i in range(8):
            with rec.span(f"s{i}"):
                pass
        assert rec.total == 8
        kept = rec.spans()
        assert [s.name for s in kept] == ["s4", "s5", "s6", "s7"]
        # allocation indices keep climbing across the overwrite
        assert [s.index for s in kept] == [4, 5, 6, 7]

    def test_threads_get_independent_stacks(self):
        rec = spans_lib.SpanRecorder(capacity=32)
        barrier = threading.Barrier(2)

        def work(tag):
            with rec.span("worker", tag=tag):
                barrier.wait()    # both spans live at once...
                with rec.span("step", tag=tag):
                    pass

        threads = [threading.Thread(target=work, args=(t,)) for t in "ab"]
        with rec.span("main"):
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        by_name = {}
        for s in rec.spans():
            by_name.setdefault(s.name, []).append(s)
        # ...yet neither nests under the other: each thread's "step" has
        # its own thread's "worker" as parent, and "worker" is top-level
        assert all(w.depth == 0 for w in by_name["worker"])
        workers = {w.tid: w.index for w in by_name["worker"]}
        for st in by_name["step"]:
            assert st.parent == workers[st.tid] and st.depth == 1
        assert len({s.tid for s in rec.spans()}) == 3

    def test_exception_annotates_and_propagates(self):
        rec = spans_lib.SpanRecorder(capacity=4)
        with pytest.raises(ValueError):
            with rec.span("boom", unit="u1"):
                raise ValueError("nope")
        (sp,) = rec.spans()
        assert sp.attrs == {"unit": "u1", "error": "ValueError"}

    def test_capacity_validation(self):
        with pytest.raises(ValueError, match="capacity"):
            spans_lib.SpanRecorder(capacity=0)


class TestGlobalToggle:
    def test_disabled_span_is_shared_noop(self):
        assert not obs.enabled()
        assert obs.span("x", a=1) is spans_lib.NULL_SPAN
        with obs.span("x"):
            pass
        assert obs.recorder().total == 0

    def test_enable_resets_state(self):
        obs.enable(capacity=8)
        with obs.span("first"):
            pass
        obs.registry().counter("c").inc()
        obs.enable(capacity=8)          # reset=True default
        assert obs.recorder().total == 0
        assert len(obs.registry()) == 0
        obs.registry().counter("c").inc(3)
        obs.enable(capacity=8, reset=False)
        assert obs.registry().counter("c").value == 3

    def test_save_run_dir_empty_returns_none(self, tmp_path):
        obs.enable()
        assert obs.save_run_dir(str(tmp_path)) is None
        assert not os.path.exists(tmp_path / obs.OBS_SUBDIR)

    def test_save_run_dir_writes_all_artifacts(self, tmp_path):
        obs.enable()
        with obs.span("phase", unit="u0"):
            pass
        obs.registry().counter("ops").inc(2)
        out = obs.save_run_dir(str(tmp_path))
        assert out == str(tmp_path / obs.OBS_SUBDIR)
        for fname in ("spans.jsonl", "metrics.jsonl", "trace.json"):
            assert os.path.exists(os.path.join(out, fname)), fname


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------
class TestHistogram:
    def test_upper_edge_bucketing(self):
        h = metrics_lib.Histogram("h", buckets=(1, 2, 4))
        for v in (0.5, 1.0, 1.5, 2.0, 4.0, 5.0):
            h.observe(v)
        # <=1, (1,2], (2,4], >4 — values ON an edge land in that edge
        assert h.counts == [2, 2, 1, 1]
        assert h.total == 6 and h.vmin == 0.5 and h.vmax == 5.0
        assert h.quantile(0.5) == 2.0          # rank 3 of 6 -> edge 2
        assert h.quantile(1.0) == 5.0          # overflow resolves to max

    def test_empty_histogram(self):
        h = metrics_lib.Histogram("h", buckets=(1, 2))
        assert h.mean is None and h.quantile(0.5) is None

    def test_rejects_unsorted_buckets(self):
        with pytest.raises(ValueError, match="ascending"):
            metrics_lib.Histogram("h", buckets=(2, 1))

    def test_registry_kind_conflict(self):
        reg = metrics_lib.MetricsRegistry()
        reg.counter("x")
        with pytest.raises(ValueError, match="already registered"):
            reg.histogram("x")

    def test_get_or_create_is_idempotent(self):
        reg = metrics_lib.MetricsRegistry()
        assert reg.counter("c") is reg.counter("c")
        reg.counter("c").inc(5)
        assert reg.get("c").value == 5
        assert reg.get("missing") is None


class TestRoundTrips:
    def test_spans_jsonl_round_trip(self, tmp_path):
        rec = spans_lib.SpanRecorder(capacity=8)
        with rec.span("a", unit="u0", ops=3):
            with rec.span("b"):
                pass
        path = str(tmp_path / "deep" / "spans.jsonl")
        spans_lib.dump_jsonl(rec.spans(), path)   # makedirs the parent
        assert spans_lib.load_jsonl(path) == rec.spans()

    def test_metrics_jsonl_round_trip(self, tmp_path):
        reg = metrics_lib.MetricsRegistry()
        reg.counter("c").inc(7)
        reg.gauge("g").set(1.5)
        h = reg.histogram("h_s", buckets=(0.1, 1.0))
        h.observe(0.05)
        h.observe(2.0)
        reg.series("s").append({"unit": "u0", "e_total": [1.0, 0.5]})
        path = str(tmp_path / "metrics.jsonl")
        reg.dump_jsonl(path)
        back = metrics_lib.MetricsRegistry.load_jsonl(path)
        assert back.snapshot() == reg.snapshot()
        assert back.get("h_s").quantile(0.5) == 0.1

    def test_perfetto_export_structure(self, tmp_path):
        rec = spans_lib.SpanRecorder(capacity=8)
        with rec.span("prune.unit", unit="u0"):
            with rec.span("prune.solve", op="wq"):
                pass
        path = str(tmp_path / "trace.json")
        spans_lib.export_perfetto(rec.spans(), path, pid=1)
        with open(path) as f:
            doc = json.load(f)
        events = doc["traceEvents"]
        xs = [e for e in events if e["ph"] == "X"]
        metas = [e for e in events if e["ph"] == "M"]
        assert {e["name"] for e in xs} == {"prune.unit", "prune.solve"}
        assert all(e["cat"] == "prune" for e in xs)
        assert metas and metas[0]["name"] == "thread_name"
        # complete events carry microsecond ts/dur and JSON-safe args
        solve = next(e for e in xs if e["name"] == "prune.solve")
        assert solve["dur"] >= 0 and solve["args"] == {"op": "wq"}
        # the nested span is contained within its parent's window
        unit = next(e for e in xs if e["name"] == "prune.unit")
        assert unit["ts"] <= solve["ts"]
        assert solve["ts"] + solve["dur"] <= unit["ts"] + unit["dur"] + 1e-3


# ---------------------------------------------------------------------------
# serve instrumentation
# ---------------------------------------------------------------------------
#: tight pool (forces defrag-relevant churn) shared by the batcher tests
BC = BatchConfig(slots=3, block_size=8, max_blocks_per_request=4,
                 num_blocks=16)


@pytest.fixture(scope="module")
def tiny():
    cfg = tiny_config().replace(num_layers=2, d_model=64, d_ff=128,
                                num_heads=4, num_kv_heads=4, vocab=128)
    model = model_def(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _requests(vocab, n=5, eos_id=None):
    rng = np.random.default_rng(7)
    spec = [(5, 6), (9, 4), (3, 8), (12, 5), (7, 7)][:n]
    return [Request(id=i, prompt=rng.integers(0, vocab, size=p).astype(np.int32),
                    max_new_tokens=m, eos_id=eos_id)
            for i, (p, m) in enumerate(spec)]


class TestBatcherMetrics:
    def test_slo_metrics_recorded(self, tiny):
        model, params = tiny
        obs.enable()
        batcher = ContinuousBatcher(model, params, BC)
        results = batcher.run(_requests(model.cfg.vocab))
        reg = obs.registry()
        n_tokens = sum(len(r.tokens) for r in results)
        assert reg.get("serve.prefills").value == 5
        assert reg.get("serve.ttft_s").total == 5
        assert reg.get("serve.admission_wait_s").total == 5
        # every request decoded >1 token, so each lands one ITL sample
        assert reg.get("serve.inter_token_s").total == 5
        steps = reg.get("serve.decode_steps").value
        assert reg.get("serve.step_s").total == steps
        assert reg.get("serve.queue_depth").total == steps
        # first token comes from prefill, the rest from decode ticks
        assert reg.get("serve.prefill_tokens").value == \
            sum(len(r.prompt) for r in _requests(model.cfg.vocab))
        assert reg.get("serve.decode_tokens").value == n_tokens - 5
        occ = reg.get("serve.pool_occupancy")
        assert occ.total == steps and 0.0 <= occ.vmax <= 1.0

    def test_defrag_and_eos_paths(self, tiny):
        model, params = tiny
        # pick an EOS the model actually emits so retire-on-EOS fires
        probe = ContinuousBatcher(model, params, BC)
        solo = probe.run(_requests(model.cfg.vocab, n=1))[0].tokens
        eos = int(solo[2])

        obs.enable()
        batcher = ContinuousBatcher(model, params, BC)
        results = batcher.run(_requests(model.cfg.vocab, eos_id=eos))
        batcher.defrag()
        reg = obs.registry()
        assert any(r.reason == "eos" for r in results)
        assert reg.get("serve.defrags").value == 1
        assert reg.get("serve.defrag_blocks_moved").value >= 0
        # retired-early requests with a single token never record an ITL
        itl = reg.get("serve.inter_token_s")
        assert itl.total == sum(1 for r in results if len(r.tokens) > 1)

    def test_tokens_bitwise_identical_with_obs(self, tiny):
        """The whole point of the overhead gate: instrumentation must be
        observationally invisible to the decode path."""
        model, params = tiny
        obs.disable()
        bare = ContinuousBatcher(model, params, BC).run(
            _requests(model.cfg.vocab))
        obs.enable()
        instrumented = ContinuousBatcher(model, params, BC).run(
            _requests(model.cfg.vocab))
        for b, i in zip(bare, instrumented):
            np.testing.assert_array_equal(b.tokens, i.tokens)
            assert b.reason == i.reason
        assert obs.registry().get("serve.decode_steps").value > 0


# ---------------------------------------------------------------------------
# solver convergence traces
# ---------------------------------------------------------------------------
class TestSolverTrace:
    def _problem(self, seed=0, n=32, m=24, p=256):
        rng = np.random.default_rng(seed)
        w = rng.normal(size=(m, n)).astype(np.float32)
        x = rng.normal(size=(n, p)).astype(np.float32)
        stats = gram_lib.init_stats(n)
        stats = gram_lib.accumulate(stats, x.T, x.T, (w @ x).T)
        return jnp.asarray(w), stats

    def test_fused_trace_matches_host(self):
        w, stats = self._problem()
        spec = SparsitySpec(ratio=0.5)
        tl = 6
        host = prune_operator(w, stats, spec,
                              PrunerConfig(outer_impl="host", trace_len=tl))
        fused = prune_operator(w, stats, spec,
                               PrunerConfig(outer_impl="fused", trace_len=tl))
        assert host.trace is not None and fused.trace is not None
        n = min(host.outer_iters, tl)
        for key in ("e_total", "lam"):
            assert len(fused.trace[key]) == n
            np.testing.assert_allclose(fused.trace[key], host.trace[key],
                                       rtol=1e-4, atol=1e-6)

    def test_trace_disabled_by_default(self):
        w, stats = self._problem(seed=1)
        res = prune_operator(w, stats, SparsitySpec(ratio=0.5),
                             PrunerConfig(outer_impl="fused"))
        assert res.trace is None

    def test_trace_is_host_numpy(self):
        w, stats = self._problem(seed=2)
        res = prune_operator(w, stats, SparsitySpec(ratio=0.5),
                             PrunerConfig(outer_impl="fused", trace_len=4))
        assert isinstance(res.trace["e_total"], np.ndarray)
        assert res.trace["e_total"].dtype == np.float32


# ---------------------------------------------------------------------------
# report CLI
# ---------------------------------------------------------------------------
class TestReport:
    def _fake_run(self, tmp_path):
        obs.enable()
        with obs.span("prune.unit", unit="u0"):
            pass
        reg = obs.registry()
        reg.histogram("prune.solve_s").observe(0.2)
        reg.histogram("prune.outer_iters", obs.COUNT_BUCKETS).observe(12)
        reg.counter("prune.operators").inc(4)
        obs.save_run_dir(str(tmp_path))
        with open(tmp_path / "run_summary.json", "w") as f:
            json.dump({"total_solver_seconds": 1.5,
                       "attempts_histogram": {"1": 2},
                       "slowest_unit": {"unit": "u0", "seconds": 1.0},
                       "completed": 2, "resumed": 0, "duplicated": []}, f)
        return str(tmp_path)

    def test_summarize_and_render(self, tmp_path):
        run = self._fake_run(tmp_path)
        summary = report_lib.summarize_run(run)
        assert summary["num_spans"] == 1
        assert summary["spans"]["prune.unit"]["count"] == 1
        assert summary["metrics"]["prune.operators"]["value"] == 4
        text = report_lib.render_text(summary)
        assert "total solver seconds: 1.50" in text
        assert "slowest unit: u0" in text
        # count histograms render as plain numbers, latency ones as time
        assert "prune.outer_iters" in text and "12s" not in text
        assert "200.0ms" in text

    def test_render_empty_dir(self, tmp_path):
        text = report_lib.render_text(
            report_lib.summarize_run(str(tmp_path)))
        assert "no observability artifacts" in text

    def test_cli_report_subprocess(self, tmp_path):
        run = self._fake_run(tmp_path)
        env = dict(os.environ, PYTHONPATH="src")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.obs", "report", run],
            capture_output=True, text=True, env=env, cwd="/root/repo")
        assert proc.returncode == 0, proc.stderr
        assert "prune.unit" in proc.stdout
