"""Intra-layer error correction + whole-model pruning pipeline tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import sequential as seq_lib
from repro.core.driver import parallel_prune
from repro.core.pruner import PrunerConfig
from repro.core.scheduler import SchedulerConfig
from repro.core.sequential import SequentialConfig, prune_model, unit_output_error
from repro.core.sparsity import SparsitySpec, satisfies
from repro.data import CalibConfig, CorpusConfig, MarkovCorpus, calibration_batches
from repro.models.registry import load_arch, model_def
from repro.utils.tree import flatten_with_paths, get_path


def tiny_model(seed=0):
    from repro.configs.opt125m_proxy import tiny_config
    cfg = tiny_config().replace(num_layers=2, d_model=64, d_ff=128,
                                num_heads=4, num_kv_heads=4, vocab=128)
    model = model_def(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    corpus = MarkovCorpus(CorpusConfig(vocab=cfg.vocab, seed=5))
    calib = calibration_batches(corpus, CalibConfig(num_sequences=8, seq_len=32,
                                                    batch_size=4))
    return model, params, corpus, calib


FAST = PrunerConfig(fista_iters=8, max_outer=6, patience=2, eps=1e-4)


def _check_sparsity(model, params, spec):
    """Every prunable operator satisfies the spec."""
    for u in model.units():
        up = seq_lib._unit_params_of(params, u)
        for group in u.groups:
            for key in group:
                w = seq_lib.get_weight(up, key)
                assert satisfies(np.asarray(w, np.float32).T, spec), (u.name, key)


class TestPruneModel:
    @pytest.mark.parametrize("spec", [SparsitySpec(ratio=0.5),
                                      SparsitySpec(kind="nm", n=2, m=4)])
    def test_fista_pipeline(self, spec):
        model, params, corpus, calib = tiny_model()
        cfg = SequentialConfig(spec=spec, pruner=FAST, method="fista")
        new_params, reports = prune_model(model, params, calib, cfg)
        _check_sparsity(model, new_params, spec)
        assert len(reports) == len(model.units()) * sum(
            len(g) for g in model.units()[0].groups)
        assert all(np.isfinite(r.error) for r in reports)
        # embeddings / norms untouched (paper excludes them)
        np.testing.assert_array_equal(np.asarray(new_params["embed"]),
                                      np.asarray(params["embed"]))

    def test_baseline_methods(self):
        model, params, corpus, calib = tiny_model()
        for method in ("magnitude", "wanda", "sparsegpt"):
            cfg = SequentialConfig(spec=SparsitySpec(ratio=0.5), method=method)
            new_params, reports = prune_model(model, params, calib, cfg)
            _check_sparsity(model, new_params, SparsitySpec(ratio=0.5))

    def test_error_correction_helps(self):
        """Fig. 4a analog at operator level: mean relative output error of
        pruned operators is lower WITH intra-layer correction."""
        model, params, corpus, calib = tiny_model()
        spec = SparsitySpec(ratio=0.6)
        errs = {}
        for mode in ("intra", "none"):
            cfg = SequentialConfig(spec=spec, pruner=FAST, method="fista",
                                   error_correction=mode)
            pruned, _ = prune_model(model, params, calib, cfg)
            # end metric: unit output error of the LAST unit wrt dense
            u = model.units()[-1]
            states = [model.embed(params, b) for b in calib]
            # relay both to the last unit input on the dense path
            for spec_u in model.units()[:-1]:
                fwd = seq_lib._capture_forward(model, spec_u)
                du = seq_lib._unit_params_of(params, spec_u)
                states = [fwd(du, s)[0] for s in states]
            errs[mode] = unit_output_error(
                model, u, seq_lib._unit_params_of(params, u),
                seq_lib._unit_params_of(pruned, u), states)
        assert errs["intra"] <= errs["none"] * 1.05, errs

    def test_full_correction_mode_runs(self):
        model, params, corpus, calib = tiny_model()
        cfg = SequentialConfig(spec=SparsitySpec(ratio=0.5), pruner=FAST,
                               method="fista", error_correction="full")
        new_params, reports = prune_model(model, params, calib, cfg)
        _check_sparsity(model, new_params, SparsitySpec(ratio=0.5))

    def test_moe_units(self):
        d = load_arch("qwen2-moe-a2.7b", smoke=True)
        params = d.init(jax.random.PRNGKey(0))
        corpus = MarkovCorpus(CorpusConfig(vocab=d.cfg.vocab, seed=2))
        calib = calibration_batches(corpus, CalibConfig(num_sequences=4,
                                                        seq_len=16, batch_size=2))
        cfg = SequentialConfig(spec=SparsitySpec(ratio=0.5), pruner=FAST,
                               method="wanda")
        units = d.units()[:1]
        new_params, reports = prune_model(d, params, calib, cfg, units=units)
        keys = {r.key for r in reports}
        assert any("expert" in k for k in keys)
        assert any("shared" in k for k in keys)
        # router stays dense (excluded like embeddings)
        r0 = get_path(new_params, "layers/moe/router")[0]
        assert float((np.asarray(r0) == 0).mean()) < 0.4


class TestParallelDriver:
    def test_parallel_equals_serial(self):
        model, params, corpus, calib = tiny_model()
        cfg = SequentialConfig(spec=SparsitySpec(ratio=0.5), pruner=FAST,
                               method="wanda")
        serial, _ = prune_model(model, params, calib, cfg)
        par, _, stats = parallel_prune(model, params, calib, cfg,
                                       SchedulerConfig(workers=3))
        for (pa, a), (pb, b) in zip(flatten_with_paths(serial),
                                    flatten_with_paths(par)):
            assert pa == pb
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=1e-5,
                                       err_msg=pa)

    def test_resume_from_unit_checkpoints(self, tmp_path):
        model, params, corpus, calib = tiny_model()
        cfg = SequentialConfig(spec=SparsitySpec(ratio=0.5), pruner=FAST,
                               method="wanda")
        sched = SchedulerConfig(workers=2, checkpoint_dir=str(tmp_path))
        a, _, _ = parallel_prune(model, params, calib, cfg, sched)
        # second run must resume all units (0 fresh computations)
        calls = []
        import repro.core.driver as drv
        orig = seq_lib.prune_unit

        def counting(*args, **kw):
            calls.append(1)
            return orig(*args, **kw)

        seq_lib.prune_unit, b = counting, None
        try:
            b, _, _ = parallel_prune(model, params, calib, cfg, sched)
        finally:
            seq_lib.prune_unit = orig
        assert not calls, "expected full resume from unit checkpoints"
        for (pa, x), (pb, y) in zip(flatten_with_paths(a), flatten_with_paths(b)):
            np.testing.assert_allclose(np.asarray(x, np.float32),
                                       np.asarray(y, np.float32), atol=1e-6)
