"""Multi-device distribution tests (the sharded-parity suite).

Each case runs in a subprocess with XLA_FLAGS forcing fake host devices —
the main pytest process keeps the single-device view (smoke tests and
benches must see 1 device, per the dry-run contract).  All tests carry
the ``distributed`` marker so CI can run exactly this suite under the
8-fake-device job (``pytest -m distributed``); a case exits 42 when the
backend refuses the forced device count (e.g. a real-GPU platform) and
the wrapper turns that into a clean skip.

The ``*_parity`` cases pin the mesh-native substrate's acceptance
invariants (DESIGN.md §10): sharded-vs-single-device bitwise equality
for one pruning unit's Gram+solve and for held-out perplexity/KL, and
token identity for a multi-request continuous-batcher run (dense and
packed-2:4, greedy and temperature) plus Engine.generate.
"""
import os
import subprocess
import sys

import pytest

#: (case name, forced fake-device count)
CASES = [
    ("rowfista", 8),
    ("gram_psum", 8),
    ("sharded_train", 8),
    ("pipeline", 8),
    ("compression", 8),
    ("ef_convergence", 8),
    ("moe_sharded", 8),
    # mesh-native substrate (PR 5)
    ("debug_mesh", 8),
    ("debug_mesh", 6),          # non-power-of-two factorization, device-backed
    ("prune_unit_parity", 8),
    ("gram_init_seeding", 8),
    ("rowfista_solver_parity", 8),
    ("eval_parity", 8),
    ("batcher_tp_parity", 8),
    ("batcher_chunked_prefix_tp_parity", 8),
    ("engine_tp_parity", 8),
    # fused decode fast path (block-table flash attention shard_map)
    ("paged_attn_shardmap", 8),
]

SCRIPT = os.path.join(os.path.dirname(__file__), "distributed_cases.py")


@pytest.mark.distributed
@pytest.mark.parametrize("case,devices", CASES,
                         ids=[f"{c}-{d}dev" for c, d in CASES])
def test_distributed_case(case, devices):
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, SCRIPT, case, str(devices)], env=env,
                         capture_output=True, text=True, timeout=600)
    if out.returncode == 42:
        pytest.skip(f"{case}: {devices} fake devices unavailable on this "
                    f"backend\n{out.stdout}")
    assert out.returncode == 0, f"{case} failed:\n{out.stdout}\n{out.stderr}"
    assert f"CASE_OK {case}" in out.stdout
