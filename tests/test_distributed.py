"""Multi-device distribution tests.

Each case runs in a subprocess with XLA_FLAGS forcing 8 host devices —
the main pytest process keeps the single-device view (smoke tests and
benches must see 1 device, per the dry-run contract).
"""
import os
import subprocess
import sys

import pytest

CASES = ["rowfista", "gram_psum", "sharded_train", "pipeline",
         "compression", "ef_convergence", "moe_sharded"]

SCRIPT = os.path.join(os.path.dirname(__file__), "distributed_cases.py")


@pytest.mark.parametrize("case", CASES)
def test_distributed_case(case):
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, SCRIPT, case], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"{case} failed:\n{out.stdout}\n{out.stderr}"
    assert f"CASE_OK {case}" in out.stdout
