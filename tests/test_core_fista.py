"""Core math tests: Gram identities, FISTA convergence/KKT, rounding,
Algorithm-1 behaviour, baseline correctness."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import gram as gram_lib
from repro.core import fista as fista_lib
from repro.core import baselines
from repro.core.pruner import PrunerConfig, prune_operator, prune_with_method
from repro.core.sparsity import (SparsitySpec, round_nm, round_unstructured,
                                 round_to, satisfies, sparsity)


def make_problem(m=24, n=32, p=256, seed=0, pruned_shift=0.05):
    """Random operator + calibration activations (dense and pruned paths)."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(m, n)).astype(np.float32)
    x = rng.normal(size=(n, p)).astype(np.float32)
    xs = x + pruned_shift * rng.normal(size=(n, p)).astype(np.float32)
    stats = gram_lib.init_stats(n)
    # accumulate in two batches to exercise streaming
    for sl in (slice(0, p // 2), slice(p // 2, p)):
        stats = gram_lib.accumulate(
            stats, x[:, sl].T, xs[:, sl].T, (w @ x[:, sl]).T)
    return w, x, xs, stats


class TestGram:
    def test_error_identity(self):
        """Gram-form error == direct Frobenius error (the key restructuring)."""
        w, x, xs, stats = make_problem()
        y = np.random.default_rng(1).normal(size=w.shape).astype(np.float32)
        b = gram_lib.target_correlation(stats, jnp.asarray(w))
        direct = np.linalg.norm(y @ xs - w @ x)
        via_gram = float(gram_lib.frob_error(stats, jnp.asarray(y), b))
        assert np.isclose(direct, via_gram, rtol=1e-4)

    def test_streaming_matches_batch(self):
        w, x, xs, stats = make_problem()
        one = gram_lib.init_stats(x.shape[0])
        one = gram_lib.accumulate(one, x.T, xs.T, (w @ x).T)
        np.testing.assert_allclose(np.asarray(stats.G), np.asarray(one.G), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(stats.C), np.asarray(one.C), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(float(stats.h), float(one.h), rtol=1e-5)

    def test_max_eigval_power_iteration(self):
        rng = np.random.default_rng(0)
        a = rng.normal(size=(40, 40)).astype(np.float32)
        G = a @ a.T
        got = float(gram_lib.max_eigval(jnp.asarray(G)))
        want = float(np.linalg.eigvalsh(G).max())
        assert np.isclose(got, want, rtol=1e-3)

    def test_hdiag(self):
        w, x, xs, stats = make_problem()
        np.testing.assert_allclose(
            np.asarray(stats.hdiag), (x ** 2).sum(axis=1), rtol=1e-4)


class TestFista:
    def test_kkt_optimality(self):
        """FISTA solution satisfies LASSO KKT conditions (paper's guarantee)."""
        w, x, xs, stats = make_problem(m=16, n=24, p=128)
        b = gram_lib.target_correlation(stats, jnp.asarray(w))
        lam = 5.0
        y, k = fista_lib.solve(stats.G, b, jnp.asarray(w), lam,
                               max_iters=4000, tol=1e-9)
        res = float(fista_lib.kkt_residual(stats.G, b, y, lam))
        scale = float(jnp.max(jnp.abs(b)))
        assert res < 1e-2 * scale, f"KKT residual {res} too large (scale {scale})"

    def test_objective_monotone_descent_envelope(self):
        """Objective at the prox points decreases vs the warm start."""
        w, x, xs, stats = make_problem()
        b = gram_lib.target_correlation(stats, jnp.asarray(w))
        lam = 10.0
        y0 = jnp.zeros_like(jnp.asarray(w))
        f0 = float(fista_lib.objective(stats.G, b, stats.h, y0, lam))
        y, _ = fista_lib.solve(stats.G, b, y0, lam, max_iters=200)
        f1 = float(fista_lib.objective(stats.G, b, stats.h, y, lam))
        assert f1 < f0

    def test_lam_zero_recovers_least_squares(self):
        """lam=0 => unregularized LS; with X* = X the optimum is W itself."""
        w, x, xs, stats = make_problem(pruned_shift=0.0)
        b = gram_lib.target_correlation(stats, jnp.asarray(w))
        y, _ = fista_lib.solve(stats.G, b, jnp.zeros_like(jnp.asarray(w)),
                               0.0, max_iters=3000, tol=1e-10)
        err = float(gram_lib.frob_error(stats, y, b))
        wx = np.linalg.norm(w @ x)
        assert err / wx < 1e-2

    def test_soft_shrinkage(self):
        x = jnp.asarray([-2.0, -0.5, 0.0, 0.5, 2.0])
        out = np.asarray(fista_lib.soft_shrinkage(x, 1.0))
        np.testing.assert_allclose(out, [-1.0, 0.0, 0.0, 0.0, 1.0])

    def test_large_lam_kills_everything(self):
        w, x, xs, stats = make_problem()
        b = gram_lib.target_correlation(stats, jnp.asarray(w))
        lam = float(jnp.max(jnp.abs(b))) * 10
        y, _ = fista_lib.solve(stats.G, b, jnp.asarray(w), lam, max_iters=500)
        assert float(sparsity(y)) > 0.99

    def test_paper_momentum_variant_converges(self):
        w, x, xs, stats = make_problem()
        b = gram_lib.target_correlation(stats, jnp.asarray(w))
        y, _ = fista_lib.solve(stats.G, b, jnp.asarray(w), 1.0,
                               max_iters=500, momentum="paper")
        assert np.isfinite(np.asarray(y)).all()

    def test_stopping_criterion(self):
        """Solver stops early when the iterate change drops below tol."""
        w, x, xs, stats = make_problem(m=8, n=12, p=64)
        b = gram_lib.target_correlation(stats, jnp.asarray(w))
        _, k = fista_lib.solve(stats.G, b, jnp.asarray(w), 1e-3,
                               max_iters=5000, tol=1e-4)
        assert int(k) < 5000


class TestRounding:
    def test_unstructured_exact_count(self):
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
        for ratio in (0.2, 0.5, 0.9):
            out = round_unstructured(w, ratio)
            k = round(ratio * w.size)  # exact count semantics
            assert int((np.asarray(out) == 0).sum()) == k

    def test_unstructured_keeps_largest(self):
        w = jnp.asarray(np.arange(1, 101, dtype=np.float32).reshape(10, 10))
        out = np.asarray(round_unstructured(w, 0.5))
        assert (out[w >= 51] != 0).all() and (out[np.asarray(w) <= 50] == 0).all()

    def test_nm_pattern(self):
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
        out = round_nm(w, 2, 4)
        assert satisfies(out, SparsitySpec(kind="nm", n=2, m=4))
        g = np.asarray(out).reshape(8, 8, 4)
        assert ((g != 0).sum(axis=-1) == 2).all()

    def test_nm_keeps_group_largest(self):
        w = jnp.asarray([[1.0, 3.0, 2.0, 4.0, -5.0, 0.1, 0.2, -6.0]])
        out = np.asarray(round_nm(w, 2, 4))
        np.testing.assert_allclose(out, [[0, 3, 0, 4, -5, 0, 0, -6]])

    def test_nm_ties_deterministic(self):
        w = jnp.asarray([[1.0, 1.0, 1.0, 1.0]])
        out = np.asarray(round_nm(w, 2, 4))
        np.testing.assert_allclose(out, [[1, 1, 0, 0]])  # lower index wins

    def test_round_idempotent(self):
        rng = np.random.default_rng(3)
        w = jnp.asarray(rng.normal(size=(12, 24)).astype(np.float32))
        for spec in (SparsitySpec(ratio=0.5), SparsitySpec(kind="nm", n=2, m=4)):
            once = round_to(w, spec)
            twice = round_to(once, spec)
            np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))

    def test_spec_parse(self):
        assert SparsitySpec.parse("2:4").kind == "nm"
        assert SparsitySpec.parse("50%").ratio == 0.5
        assert SparsitySpec.parse("0.3").ratio == 0.3
        assert np.isclose(SparsitySpec.parse("2:4").target_density, 0.5)


class TestBaselines:
    @pytest.mark.parametrize("spec", [SparsitySpec(ratio=0.5),
                                      SparsitySpec(kind="nm", n=2, m=4)])
    def test_all_hit_target(self, spec):
        w, x, xs, stats = make_problem(m=16, n=32)
        for method in ("magnitude", "wanda", "sparsegpt"):
            y, err = prune_with_method(method, jnp.asarray(w), stats, spec)
            assert satisfies(y, spec), method
            assert err > 0

    def test_wanda_equals_magnitude_when_isotropic(self):
        """With identical column norms Wanda reduces to per-row magnitude."""
        rng = np.random.default_rng(0)
        m, n, p = 8, 16, 512
        w = rng.normal(size=(m, n)).astype(np.float32)
        x = rng.normal(size=(n, p)).astype(np.float32)
        x = x / np.linalg.norm(x, axis=1, keepdims=True)  # unit rows
        stats = gram_lib.init_stats(n)
        stats = gram_lib.accumulate(stats, x.T, x.T, (w @ x).T)
        got = np.asarray(baselines.wanda(jnp.asarray(w), stats, SparsitySpec(ratio=0.5)))
        # per-row magnitude
        keep = np.abs(w).argsort(axis=1)[:, n // 2:]
        want = np.zeros_like(w)
        for i in range(m):
            want[i, keep[i]] = w[i, keep[i]]
        np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_sparsegpt_beats_magnitude(self):
        """OBS compensation should beat plain magnitude on correlated data."""
        w, x, xs, stats = make_problem(m=32, n=48, p=512, pruned_shift=0.0)
        spec = SparsitySpec(ratio=0.5)
        _, e_mag = prune_with_method("magnitude", jnp.asarray(w), stats, spec)
        _, e_sgpt = prune_with_method("sparsegpt", jnp.asarray(w), stats, spec)
        assert e_sgpt < e_mag

    def test_sparsegpt_multiblock(self):
        """Cross-block compensation path (n > blocksize)."""
        w, x, xs, stats = make_problem(m=8, n=96, p=256, pruned_shift=0.0)
        spec = SparsitySpec(ratio=0.5)
        y = baselines.sparsegpt(jnp.asarray(w), stats, spec, blocksize=32)
        assert satisfies(y, spec)
        b = gram_lib.target_correlation(stats, jnp.asarray(w))
        e = float(gram_lib.frob_error(stats, y, b))
        _, e_mag = prune_with_method("magnitude", jnp.asarray(w), stats, spec)
        assert e < e_mag


class TestAlgorithm1:
    @pytest.mark.parametrize("spec", [SparsitySpec(ratio=0.5),
                                      SparsitySpec(kind="nm", n=2, m=4)])
    def test_improves_on_warm_start(self, spec):
        w, x, xs, stats = make_problem(m=24, n=32, p=512)
        res = prune_operator(jnp.asarray(w), stats, spec,
                             PrunerConfig(warm_start="wanda"))
        assert satisfies(res.weight, spec)
        assert res.error <= res.warm_error + 1e-6
        assert res.outer_iters >= 1

    def test_beats_baselines(self):
        """The paper's headline ordering: fista < sparsegpt, wanda (output err)."""
        w, x, xs, stats = make_problem(m=32, n=48, p=768, pruned_shift=0.0)
        spec = SparsitySpec(ratio=0.5)
        errs = {}
        for method in ("magnitude", "wanda", "sparsegpt", "fista"):
            _, errs[method] = prune_with_method(
                method, jnp.asarray(w), stats, spec,
                PrunerConfig(warm_start="wanda", eps=1e-6, max_outer=24))
        # relative tolerance: the error norms are ~1e2, where an absolute
        # 1e-5 margin is below fp32 resolution and scores ties as losses
        # (benchmarks/run.py's headline check is relative for the same reason)
        assert errs["fista"] <= errs["wanda"] * (1 + 1e-4)
        assert errs["fista"] <= errs["magnitude"] * (1 + 1e-4)

    def test_sparsegpt_warm_start(self):
        w, x, xs, stats = make_problem(m=16, n=24)
        res = prune_operator(jnp.asarray(w), stats, SparsitySpec(ratio=0.5),
                             PrunerConfig(warm_start="sparsegpt"))
        assert satisfies(res.weight, SparsitySpec(ratio=0.5))

    def test_terminates_within_bound(self):
        w, x, xs, stats = make_problem()
        cfg = PrunerConfig(max_outer=6, patience=2)
        res = prune_operator(jnp.asarray(w), stats, SparsitySpec(ratio=0.5), cfg)
        assert res.outer_iters <= 6

    def test_zero_sparsity_noop(self):
        w, x, xs, stats = make_problem(pruned_shift=0.0)
        res = prune_operator(jnp.asarray(w), stats, SparsitySpec(ratio=0.0),
                             PrunerConfig(warm_start="dense", max_outer=2))
        assert res.error <= 1e-3 * np.linalg.norm(w @ x)
