"""PruneRecipe serialization + the repro.api.prune entry point.

Pins the ISSUE-2 acceptance criteria: JSON round-trip, fista-recipe
bitwise equivalence with the pre-redesign SequentialConfig path, and an
admm recipe running end-to-end on the opt125m proxy family."""
import numpy as np
import jax
import pytest

from repro import api
from repro.core.pruner import PrunerConfig
from repro.core.scheduler import SchedulerConfig
from repro.core.sequential import SequentialConfig
from repro.core.driver import parallel_prune
from repro.core.sparsity import SparsitySpec, satisfies
from repro.data import CalibConfig, CorpusConfig, MarkovCorpus, calibration_batches
from repro.models.registry import model_def
from repro.utils.tree import flatten_with_paths


def tiny_setup(seed=0, layers=2):
    from repro.configs.opt125m_proxy import tiny_config
    cfg = tiny_config().replace(num_layers=layers, d_model=32, d_ff=64,
                                num_heads=4, num_kv_heads=4, vocab=128)
    model = model_def(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    corpus = MarkovCorpus(CorpusConfig(vocab=cfg.vocab, seed=5))
    calib = calibration_batches(corpus, CalibConfig(num_sequences=4, seq_len=16,
                                                    batch_size=2))
    return model, params, calib


FAST_KW = {"fista_iters": 8, "max_outer": 6, "patience": 2, "eps": 1e-4}


class TestRecipeSerialization:
    def test_json_round_trip(self, tmp_path):
        recipe = api.PruneRecipe(
            arch="opt125m-proxy", method="admm", sparsity="2:4",
            correction="none", solver={"rho_rel": 0.2, "max_iters": 32},
            calibration={"num_sequences": 8, "seq_len": 32},
            scheduler={"workers": 3})
        back = api.PruneRecipe.from_json(recipe.to_json())
        assert back == recipe
        path = tmp_path / "recipe.json"
        recipe.to_json(str(path))
        assert api.PruneRecipe.from_json(str(path)) == recipe

    def test_builders(self):
        recipe = api.PruneRecipe(method="fista", sparsity="2:4",
                                 solver=FAST_KW, scheduler={"workers": 2})
        cfg = recipe.sequential_config()
        assert isinstance(cfg, SequentialConfig)
        assert cfg.solver is not None and cfg.solver.name == "fista"
        assert cfg.pruner == PrunerConfig(**FAST_KW)   # mirrored legacy field
        assert cfg.spec == SparsitySpec(kind="nm", n=2, m=4)
        assert recipe.scheduler_config() == SchedulerConfig(workers=2)
        assert recipe.calib_config() == CalibConfig()

    def test_rejects_unknown_keys(self):
        with pytest.raises(ValueError, match="calibration"):
            api.PruneRecipe(calibration={"num_sequence": 8})   # typo'd key
        with pytest.raises(ValueError, match="scheduler"):
            api.PruneRecipe(scheduler={"worker_count": 2})
        with pytest.raises(ValueError, match="PruneRecipe"):
            api.PruneRecipe.from_dict({"method": "fista", "sparsityy": "50%"})
        with pytest.raises(ValueError):
            api.PruneRecipe(correction="sideways")

    def test_eval_section_round_trip(self):
        from repro.eval import EvalConfig
        recipe = api.PruneRecipe(eval={"num_batches": 3, "seq_len": 32,
                                       "split": "valid", "kl_batches": 0})
        assert api.PruneRecipe.from_json(recipe.to_json()) == recipe
        cfg = recipe.eval_config()
        assert cfg == EvalConfig(num_batches=3, seq_len=32, split="valid",
                                 kl_batches=0)
        assert api.PruneRecipe().eval_config() == EvalConfig()

    def test_eval_section_rejects_unknown_keys(self):
        """Unknown eval keys fail at recipe load time (PR-2 strictness)."""
        with pytest.raises(ValueError, match="eval"):
            api.PruneRecipe(eval={"num_batch": 4})             # typo'd key
        with pytest.raises(ValueError, match="split"):
            api.PruneRecipe(eval={"split": "tset"})
        with pytest.raises(ValueError, match="eval"):
            api.PruneRecipe.from_json(
                '{"method": "fista", "eval": {"bogus": 1}}')

    def test_unknown_method_lists_solvers_at_construction(self):
        """A typo'd recipe must die at load time, before any training."""
        with pytest.raises(KeyError, match="registered solvers"):
            api.PruneRecipe(method="no-such")

    def test_bad_solver_kwargs_fail_at_construction(self):
        with pytest.raises(ValueError, match="fista_iter"):
            api.PruneRecipe(method="fista", solver={"fista_iter": 8})  # typo
        with pytest.raises(ValueError, match="admm"):
            api.PruneRecipe(method="admm", solver={"rho": 0.1})


class TestPruneEntryPoint:
    def test_fista_recipe_bitwise_matches_legacy_path(self):
        """Acceptance: the fista recipe is bitwise-identical to the
        pre-redesign SequentialConfig(method='fista') path."""
        model, params, calib = tiny_setup()
        recipe = api.PruneRecipe(method="fista", sparsity="50%",
                                 solver=FAST_KW, scheduler={"workers": 1})
        new, new_reports, _ = api.prune(model, params, calib, recipe)

        legacy_cfg = SequentialConfig(spec=SparsitySpec(ratio=0.5),
                                      pruner=PrunerConfig(**FAST_KW),
                                      method="fista")
        with pytest.warns(DeprecationWarning):
            old, old_reports, _ = parallel_prune(
                model, params, calib, legacy_cfg, SchedulerConfig(workers=1))

        for (pa, a), (pb, b) in zip(flatten_with_paths(old),
                                    flatten_with_paths(new)):
            assert pa == pb
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=pa)
        assert [r.key for r in old_reports] == [r.key for r in new_reports]

    @pytest.mark.parametrize("method,solver_kw", [
        ("fista", FAST_KW),
        ("admm", {"max_iters": 16, "polish_iters": 4}),
    ])
    def test_recipes_run_end_to_end_on_opt_proxy(self, method, solver_kw):
        """Acceptance: {"method": "fista"} and {"method": "admm"} recipes
        both run end-to-end on the opt125m proxy family."""
        model, params, calib = tiny_setup(layers=1)
        recipe = api.PruneRecipe(arch="opt125m-proxy", method=method,
                                 sparsity="2:4", solver=solver_kw,
                                 scheduler={"workers": 2})
        pruned, reports, stats = api.prune(model, params, calib, recipe)
        spec = SparsitySpec(kind="nm", n=2, m=4)
        from repro.core import sequential as seq_lib
        for u in model.units():
            up = seq_lib._unit_params_of(pruned, u)
            for group in u.groups:
                for key in group:
                    w = seq_lib.get_weight(up, key)
                    assert satisfies(np.asarray(w, np.float32).T, spec)
        assert all(np.isfinite(r.error) for r in reports)
        assert stats.get("completed") == len(model.units())

    def test_load_model_rejects_unknown_arch(self):
        with pytest.raises(ValueError, match="unknown arch"):
            api.load_model("opt350m")


class TestCorrectionModes:
    # sha256 over (path, fp32 bytes) of every pruned leaf of the fixed-seed
    # opt-proxy run below, captured on the commit BEFORE the declared-stats
    # refactor (ISSUE 8).  The default correction="intra" path must stay
    # bitwise-identical; regenerate only on a deliberate solver change.
    INTRA_SHA256 = \
        "c3f4cfdc5f90860a9307991835c7304f8810f12186270852725620483e03bd45"
    INTRA_MEAN_REL = 0.18754995871333588

    def _digest(self, tree):
        import hashlib
        h = hashlib.sha256()
        for p, leaf in flatten_with_paths(tree):
            h.update(p.encode())
            h.update(np.ascontiguousarray(
                np.asarray(leaf, np.float32)).tobytes())
        return h.hexdigest()

    def test_intra_bitwise_identical_to_pre_pr_output(self):
        """Regression anchor: the default intra-correction pruning path is
        end-to-end bitwise-identical to the pre-ISSUE-8 output."""
        model, params, calib = tiny_setup()
        recipe = api.PruneRecipe(method="fista", sparsity="2:4",
                                 solver=FAST_KW, scheduler={"workers": 1})
        assert recipe.correction == "intra"        # the default
        pruned, reports, _ = api.prune(model, params, calib, recipe)
        assert float(np.mean([r.rel_error for r in reports])) == \
            pytest.approx(self.INTRA_MEAN_REL, rel=1e-6)
        assert self._digest(pruned) == self.INTRA_SHA256

    def test_cross_recipe_round_trips_and_runs_serial(self):
        model, params, calib = tiny_setup()
        recipe = api.PruneRecipe(method="fista", sparsity="2:4",
                                 solver=FAST_KW, correction="cross",
                                 scheduler={"workers": 4})
        assert api.PruneRecipe.from_json(recipe.to_json()) == recipe
        pruned, reports, stats = api.prune(model, params, calib, recipe)
        assert stats["mode"] == "serial-cross"     # cross-unit => serial
        spec = SparsitySpec(kind="nm", n=2, m=4)
        from repro.core import sequential as seq_lib
        for u in model.units():
            up = seq_lib._unit_params_of(pruned, u)
            for group in u.groups:
                for key in group:
                    w = seq_lib.get_weight(up, key)
                    assert satisfies(np.asarray(w, np.float32).T, spec)
        assert all(np.isfinite(r.error) for r in reports)
        # realized calibration differs from the paper path beyond unit 0
        intra, _, _ = api.prune(model, params, calib,
                                api.PruneRecipe(method="fista", sparsity="2:4",
                                                solver=FAST_KW,
                                                scheduler={"workers": 1}))
        same = all(np.array_equal(np.asarray(a), np.asarray(b))
                   for (_, a), (_, b) in zip(flatten_with_paths(pruned),
                                             flatten_with_paths(intra)))
        assert not same

    def test_frankwolfe_recipe_end_to_end(self):
        model, params, calib = tiny_setup(layers=1)
        recipe = api.PruneRecipe(method="frankwolfe", sparsity="2:4",
                                 solver={"max_iters": 24, "polish_iters": 8},
                                 scheduler={"workers": 1})
        pruned, reports, _ = api.prune(model, params, calib, recipe)
        assert any(r.solver == "frankwolfe-group" for r in reports)
        assert all(np.isfinite(r.error) for r in reports)
