"""Block-table flash-decode fast path (kernels/paged_attention.py).

Deterministic pins for the fused decode path, layered the same way the
code is:

* oracle vs. a handwritten numpy softmax over the gathered context —
  ragged per-slot lengths, a window narrower than the context, softcap,
  block tables with holes and trash-block-0 tails;
* Pallas kernels vs. the oracle under ``interpret=True`` (the
  ``kernels_interpret`` marker; compiled-mode parity needs a TPU),
  including the packed o_proj epilogue and the fused MLP;
* the serving contract: ``impl="fused"`` is BITWISE the reference
  gather path on this backend (DESIGN.md §11), at the attention level
  and through a full multi-step ``paged_serve_step`` drive — dense and
  packed-2:4, windowed and not, with an inactive slot in the batch.

The hypothesis sweeps over random scenarios live in
tests/test_paged_attention_props.py (optional dep, skips without it).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.opt125m_proxy import tiny_config
from repro.core.sparsity import round_tree_nm
from repro.kernels import ops as kops
from repro.kernels import paged_attention as pk
from repro.kernels import ref
from repro.models import common, transformer
from repro.serve.packed import pack_tree

TRASH = 0       # serve/kv_cache.py reserves block 0 as the trash block
NB, BS = 10, 4  # pool blocks / block size for the scenarios here


def build_scenario(seed, lengths, nkv=2, g=2, hd=8, trash_fill=37.0):
    """Random pool + block tables for ragged per-slot contexts.

    Each slot's blocks come from one permutation of 1..NB-1, so
    consecutive table columns are non-contiguous pool blocks (holes);
    table tails pad with the trash block, and the trash block is filled
    with large garbage so an unmasked read shows up loudly.  Returns
    numpy (q, k_pool, v_pool, tables, pos); pos = lengths - 1.
    """
    rng = np.random.default_rng(seed)
    S = len(lengths)
    MB = max(-(-int(l) // BS) for l in lengths) + 1   # >= 1 trash tail col
    perm = rng.permutation(np.arange(1, NB))
    tables = np.full((S, MB), TRASH, np.int32)
    used = 0
    for s, L in enumerate(lengths):
        nb = -(-int(L) // BS)
        tables[s, :nb] = perm[used:used + nb]
        used += nb
    assert used <= NB - 1, "scenario too large for the pool"
    T = NB * BS
    k_pool = rng.standard_normal((T, nkv, hd)).astype(np.float32)
    v_pool = rng.standard_normal((T, nkv, hd)).astype(np.float32)
    k_pool[:BS] = trash_fill
    v_pool[:BS] = trash_fill
    q = rng.standard_normal((S, nkv * g, hd)).astype(np.float32)
    pos = np.asarray(lengths, np.int32) - 1
    return q, k_pool, v_pool, tables, pos


def naive_paged_attention(q, k_pool, v_pool, tables, pos, active,
                          window=0, softcap=0.0):
    """Per-slot, per-head loop-and-softmax in float64 — the independent
    check the oracle (and through it the kernel) is pinned against.
    Inactive slots return zeros (their serving output is discarded)."""
    S, nq, hd = q.shape
    nkv = k_pool.shape[1]
    g = nq // nkv
    out = np.zeros_like(q)
    for s in range(S):
        if not active[s]:
            continue
        lo = max(0, pos[s] - window + 1) if window else 0
        flat = [tables[s, t // BS] * BS + t % BS
                for t in range(lo, pos[s] + 1)]
        k, v = k_pool[flat].astype(np.float64), v_pool[flat].astype(np.float64)
        for h in range(nkv):
            for gg in range(g):
                sc = k[:, h] @ q[s, h * g + gg].astype(np.float64)
                sc /= np.sqrt(hd)
                if softcap > 0:
                    sc = np.tanh(sc / softcap) * softcap
                p = np.exp(sc - sc.max())
                p /= p.sum()
                out[s, h * g + gg] = p @ v[:, h]
    return out


def pack_random_24(rng, m, n, scale=1.0):
    """A random exactly-2:4 (m, n) matrix (groups along n) and its packed
    form — two random survivors per 4-group."""
    w = rng.standard_normal((m, n)).astype(np.float32) * scale
    keep = rng.random((m, n // 4, 4)).argsort(axis=-1) < 2
    w = w * keep.reshape(m, n)
    vals, meta = kops.pack24(jnp.asarray(w))
    return w, vals, meta


class TestOracle:
    """ref.paged_attention vs. the handwritten numpy reduction."""

    @pytest.mark.parametrize("window,softcap", [(0, 0.0), (3, 0.0),
                                                (0, 5.0), (5, 2.0)])
    def test_matches_naive(self, window, softcap):
        q, k, v, tables, pos = build_scenario(0, lengths=[1, 7, 8])
        active = np.ones(3, bool)
        got = ref.paged_attention(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            jnp.asarray(tables), jnp.asarray(pos), jnp.asarray(active),
            block_size=BS, window=window, softcap=softcap)
        want = naive_paged_attention(q, k, v, tables, pos, active,
                                     window=window, softcap=softcap)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5,
                                   atol=1e-6)

    def test_trash_block_never_leaks(self):
        """Changing the trash block's contents must not move a single bit
        of any slot's output — the tail columns of every table row alias
        positions past ``pos`` and mask out."""
        outs = []
        for fill in (37.0, -1e4):
            q, k, v, tables, pos = build_scenario(1, lengths=[5, 2],
                                                  trash_fill=fill)
            outs.append(np.asarray(ref.paged_attention(
                jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                jnp.asarray(tables), jnp.asarray(pos),
                jnp.ones((2,), bool), block_size=BS)))
        np.testing.assert_array_equal(outs[0], outs[1])

    def test_inactive_slot_isolated(self):
        """Flipping one slot inactive leaves the other slots' outputs
        bitwise unchanged (retirement can't perturb neighbours)."""
        q, k, v, tables, pos = build_scenario(2, lengths=[6, 3, 8])
        args = (jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                jnp.asarray(tables), jnp.asarray(pos))
        all_on = np.asarray(ref.paged_attention(
            *args, jnp.ones((3,), bool), block_size=BS))
        one_off = np.asarray(ref.paged_attention(
            *args, jnp.asarray([True, False, True]), block_size=BS))
        np.testing.assert_array_equal(one_off[[0, 2]], all_on[[0, 2]])


@pytest.mark.kernels_interpret
class TestKernelInterpret:
    """Pallas kernels vs. the jnp oracles under ``interpret=True``."""

    @pytest.mark.parametrize("window,softcap", [(0, 0.0), (3, 0.0),
                                                (5, 2.0)])
    def test_attention_matches_oracle(self, window, softcap):
        q, k, v, tables, pos = build_scenario(3, lengths=[1, 6, 8])
        args = (jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                jnp.asarray(tables), jnp.asarray(pos),
                jnp.ones((3,), bool))
        got = pk.paged_decode_attn(*args, block_size=BS, window=window,
                                   softcap=softcap, interpret=True)
        want = ref.paged_attention(*args, block_size=BS, window=window,
                                   softcap=softcap)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)

    def test_attention_inactive_and_holes(self):
        q, k, v, tables, pos = build_scenario(4, lengths=[7, 2])
        active = jnp.asarray([True, False])
        args = (jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                jnp.asarray(tables), jnp.asarray(pos), active)
        got = pk.paged_decode_attn(*args, block_size=BS, interpret=True)
        want = ref.paged_attention(*args, block_size=BS)
        np.testing.assert_allclose(np.asarray(got)[:1], np.asarray(want)[:1],
                                   rtol=1e-5, atol=1e-6)

    def test_fused_o_epilogue_matches_oracle(self):
        """Packed o_proj accumulated across kv heads inside the kernel ==
        oracle attention -> oracle spmm24, fp32."""
        rng = np.random.default_rng(5)
        q, k, v, tables, pos = build_scenario(5, lengths=[5, 8, 3])
        nq, hd = q.shape[1], q.shape[2]
        d = 16
        _, wo_vals, wo_meta = pack_random_24(rng, d, nq * hd)
        args = (jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                jnp.asarray(tables), jnp.asarray(pos),
                jnp.ones((3,), bool))
        got = pk.paged_decode_attn(*args, block_size=BS, window=3,
                                   wo_vals=wo_vals, wo_meta=wo_meta,
                                   interpret=True)
        attn = ref.paged_attention(*args, block_size=BS, window=3)
        want = ref.spmm24(attn.reshape(3, nq * hd).astype(jnp.float32),
                          wo_vals, wo_meta, nq * hd)
        assert got.shape == (3, d) and got.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("gated,f,bf", [(True, 16, 16), (True, 12, 8),
                                            (False, 12, 8)])
    def test_fused_mlp_matches_oracle(self, gated, f, bf):
        """One-dispatch MLP vs. the unpack-and-matmul oracle; f % bf != 0
        exercises the d_ff tile padding."""
        rng = np.random.default_rng(6)
        B, d = 3, 8
        x = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
        _, w1v, w1m = pack_random_24(rng, f, d)
        _, w2v, w2m = pack_random_24(rng, d, f)
        if gated:
            _, upv, upm = pack_random_24(rng, f, d)
            b1 = b2 = None
            act = "silu"
        else:
            upv = upm = None
            b1 = jnp.asarray(rng.standard_normal((f,)), jnp.float32)
            b2 = jnp.asarray(rng.standard_normal((d,)), jnp.float32)
            act = "gelu"
        got = pk.fused_mlp24(x, w1v, w1m, b1, upv, upm, w2v, w2m, b2,
                             act=act, bf=bf, interpret=True)
        want = ref.fused_mlp24(x, w1v, w1m, b1, upv, upm, w2v, w2m, b2,
                               act=act)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


def _gather_from_tables(tables, block_size):
    S, MB = tables.shape
    j = np.arange(MB * block_size)
    return tables[:, j // block_size] * block_size + j % block_size


class TestFusedEqualsReference:
    """The serving contract: on this backend the fused impl routes to an
    oracle that repeats the reference gather math element-for-element,
    so impl="fused" == impl="reference" BITWISE (DESIGN.md §11)."""

    @pytest.mark.parametrize("window,packed_wo", [(None, False), (3, False),
                                                  (None, True)])
    def test_mha_decode_paged(self, window, packed_wo):
        cfg = tiny_config().replace(num_layers=1, d_model=16, num_heads=2,
                                    num_kv_heads=2, vocab=32)
        p = common.attn_init(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(7)
        hd, nq = cfg.resolved_head_dim(), cfg.num_heads
        if packed_wo:
            wo, vals, meta = pack_random_24(rng, cfg.d_model, nq * hd, 0.2)
            p = dict(p, wo={"vals": vals, "meta": meta})
        _, k, v, tables, pos = build_scenario(7, lengths=[6, 2, 8], nkv=2,
                                              g=1, hd=hd)
        x = jnp.asarray(rng.standard_normal((3, 1, cfg.d_model)), jnp.float32)
        cache = {"k": jnp.asarray(k), "v": jnp.asarray(v)}
        write_idx = jnp.asarray(
            tables[np.arange(3), pos // BS] * BS + pos % BS)
        gather = jnp.asarray(_gather_from_tables(tables, BS))
        active = jnp.asarray([True, True, False])
        out_ref_, cache_ref = common.mha_decode_paged(
            cfg, p, x, jnp.asarray(pos), cache, write_idx, gather, active,
            window, impl="reference")
        out_fused, cache_fused = common.mha_decode_paged(
            cfg, p, x, jnp.asarray(pos), cache, write_idx, None, active,
            window, tables=jnp.asarray(tables), block_size=BS, impl="fused")
        np.testing.assert_array_equal(np.asarray(out_fused),
                                      np.asarray(out_ref_))
        for key in ("k", "v"):
            np.testing.assert_array_equal(np.asarray(cache_fused[key]),
                                          np.asarray(cache_ref[key]))

    @pytest.mark.parametrize("window", [None, 6])
    @pytest.mark.parametrize("packed", [False, True])
    def test_paged_serve_step_multi_step(self, window, packed):
        """Full decode steps (attention + MLP + head) driven for several
        ticks at ragged positions: logits and pools bitwise-identical
        between the impls, dense and packed-2:4."""
        cfg = tiny_config().replace(num_layers=2, d_model=32, d_ff=64,
                                    num_heads=4, num_kv_heads=2, vocab=64,
                                    window=window)
        params = transformer.init(cfg, jax.random.PRNGKey(1))
        if packed:
            params = pack_tree(round_tree_nm(params), dtype=None)[0]
        rng = np.random.default_rng(8)
        S, MB = 3, 4                        # 3 ctx blocks + trash tail
        perm = rng.permutation(np.arange(1, S * 3 + 1))
        tables = np.full((S, MB), TRASH, np.int32)
        tables[:, :3] = perm.reshape(S, 3)
        tables = jnp.asarray(tables)
        pool_r = pool_f = transformer.init_paged_caches(cfg, S * 3 + 1, BS)
        pos0 = np.asarray([0, 3, 5], np.int32)
        active = jnp.asarray([True, True, False])
        for t in range(4):
            token = jnp.asarray(rng.integers(0, cfg.vocab, (S, 1)), jnp.int32)
            pos = jnp.asarray(pos0 + t)
            lr, pool_r = transformer.paged_serve_step(
                cfg, params, pool_r, tables, token, pos, active, BS,
                impl="reference")
            lf, pool_f = transformer.paged_serve_step(
                cfg, params, pool_f, tables, token, pos, active, BS,
                impl="fused")
            np.testing.assert_array_equal(np.asarray(lf), np.asarray(lr),
                                          err_msg=f"step {t} logits diverged")
            for key in ("k", "v"):
                np.testing.assert_array_equal(np.asarray(pool_f[key]),
                                              np.asarray(pool_r[key]))


class TestDispatchRouting:
    """ops.py routing contracts the serving paths rely on."""

    def test_cpu_routes_to_oracle(self):
        if jax.default_backend() == "tpu":
            pytest.skip("TPU backend compiles the kernel instead")
        assert not kops.use_decode_kernel(128, 16)
        assert not kops.use_fused_mlp(4096, 11008)

    def test_kernel_shape_gates(self):
        # independent of backend: misaligned shapes always fall back
        assert not kops.use_decode_kernel(64, 16)   # head_dim < lane width
        assert not kops.use_decode_kernel(128, 6)   # block_size % 8 != 0
        assert not kops.use_fused_mlp(64, 11008)
        assert not kops.use_fused_mlp(4096, 128)

    def test_ops_paged_decode_attn_is_oracle_off_tpu(self):
        q, k, v, tables, pos = build_scenario(9, lengths=[4, 7])
        args = (jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                jnp.asarray(tables), jnp.asarray(pos),
                jnp.ones((2,), bool))
        got = kops.paged_decode_attn(*args, block_size=BS, window=3)
        want = ref.paged_attention(*args, block_size=BS, window=3)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
