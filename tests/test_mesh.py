"""Mesh factorization + MeshConfig parsing (pure — no devices needed).

Pins the debug-mesh factorization for non-power-of-two device counts
(6, 12) and the degenerate counts the seed implementation mishandled
(1 -> a (0, 2) shape, 2*odd under multi_pod -> wrong product).  The
device-backed construction of the same meshes runs in the distributed
suite (tests/distributed_cases.py::case_debug_mesh at 6 and 8 devices).
"""
import pytest

from repro.distributed.executor import MeshConfig
from repro.launch.mesh import factor_debug_mesh


@pytest.mark.parametrize("devices,expected", [
    (1, (1, 1)),
    (2, (2, 1)),
    (3, (3, 1)),       # odd: no model axis
    (4, (2, 2)),
    (6, (3, 2)),       # non-power-of-two: model takes the 2
    (8, (4, 2)),
    (12, (6, 2)),      # 4 divides 12 but 4^2 > 12 -> model stays 2
    (16, (4, 4)),
    (48, (12, 4)),
    (256, (16, 16)),
])
def test_factor_single_pod(devices, expected):
    shape, axes = factor_debug_mesh(devices)
    assert axes == ("data", "model")
    assert shape == expected
    assert shape[0] * shape[1] == devices
    assert shape[0] >= shape[1] >= 1     # model never dominates data


@pytest.mark.parametrize("devices,expected", [
    (2, (2, 1, 1)),
    (6, (2, 3, 1)),    # 2*odd: seed code produced a product-4 "6-device" mesh
    (12, (2, 3, 2)),
    (16, (2, 4, 2)),
    (32, (2, 4, 4)),
])
def test_factor_multi_pod(devices, expected):
    shape, axes = factor_debug_mesh(devices, multi_pod=True)
    assert axes == ("pod", "data", "model")
    assert shape == expected
    assert shape[0] * shape[1] * shape[2] == devices


def test_factor_rejects_bad_counts():
    with pytest.raises(ValueError):
        factor_debug_mesh(0)
    with pytest.raises(ValueError):
        factor_debug_mesh(3, multi_pod=True)   # odd count has no pod axis


def test_factor_every_count_builds():
    """No count up to 64 may produce a zero/degenerate axis (the seed bug
    class): product exact, every axis >= 1."""
    for n in range(1, 65):
        shape, _ = factor_debug_mesh(n)
        assert all(s >= 1 for s in shape) and shape[0] * shape[1] == n
        if n % 2 == 0:
            shape, _ = factor_debug_mesh(n, multi_pod=True)
            p = shape[0] * shape[1] * shape[2]
            assert all(s >= 1 for s in shape) and p == n


# ---------------------------------------------------------------------------
# MeshConfig (the strict recipe `mesh` section / --mesh flag)
# ---------------------------------------------------------------------------
def test_mesh_config_parse_dxm():
    cfg = MeshConfig.parse("4x2")
    assert (cfg.devices, cfg.data_parallel, cfg.model_parallel) == (8, 4, 2)
    assert cfg.resolve(available=8) == (4, 2)
    assert not cfg.is_single


def test_mesh_config_parse_bare_count():
    cfg = MeshConfig.parse("8")
    assert cfg.resolve(available=8) == (8, 1)


def test_mesh_config_single_device_forms():
    assert MeshConfig().is_single
    assert MeshConfig.parse("1x1").is_single
    assert not MeshConfig.parse("1x2").is_single   # pure TP is a real mesh


def test_mesh_config_rejects_garbage():
    for bad in ("4y2", "x", "", "2x2x2", "-1x2"):
        with pytest.raises(ValueError):
            MeshConfig.parse(bad)
    with pytest.raises(ValueError):
        MeshConfig(devices=8, data_parallel=3, model_parallel=2).resolve(8)
    with pytest.raises(ValueError):
        MeshConfig(devices=8, data_parallel=8).resolve(available=1)


def test_mesh_config_round_trip():
    cfg = MeshConfig.parse("4x2")
    assert MeshConfig(**cfg.to_dict()) == cfg


def test_recipe_mesh_section_strict():
    """Unknown mesh keys die at recipe-load time like every section."""
    from repro.api import PruneRecipe

    with pytest.raises(ValueError, match="mesh"):
        PruneRecipe(mesh={"devicez": 8})
    r = PruneRecipe(mesh={"devices": 8, "data_parallel": 4,
                          "model_parallel": 2})
    assert r.mesh_config().model_parallel == 2
    rt = PruneRecipe.from_json(r.to_json())
    assert rt.mesh_config() == r.mesh_config()


def test_recipe_default_mesh_builds_no_executor():
    from repro.api import PruneRecipe

    assert PruneRecipe().build_executor() is None
