"""LayerSolver protocol + registry: registration, capability flags, the
ADMM backend's parity with FISTA, group-batched baselines, and the
legacy-API deprecation shims."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import gram as gram_lib
from repro.core import solvers as solvers_lib
from repro.core.pruner import PrunerConfig, prune_operator, prune_with_method
from repro.core.solvers import (LayerSolver, get_solver, register_solver,
                                registered_solvers, unregister_solver)
from repro.core.sequential import SequentialConfig, prune_model
from repro.core.sparsity import SparsitySpec, satisfies
from repro.data import CalibConfig, CorpusConfig, MarkovCorpus, calibration_batches
from repro.models.registry import model_def

SPECS = [SparsitySpec(ratio=0.5), SparsitySpec(kind="nm", n=2, m=4)]


def make_problem(m=24, n=32, p=256, seed=0, pruned_shift=0.05):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(m, n)).astype(np.float32)
    x = rng.normal(size=(n, p)).astype(np.float32)
    xs = x + pruned_shift * rng.normal(size=(n, p)).astype(np.float32)
    stats = gram_lib.init_stats(n)
    stats = gram_lib.accumulate(stats, x.T, xs.T, (w @ x).T)
    return jnp.asarray(w), stats


def tiny_model(seed=0, layers=1):
    from repro.configs.opt125m_proxy import tiny_config
    cfg = tiny_config().replace(num_layers=layers, d_model=32, d_ff=64,
                                num_heads=4, num_kv_heads=4, vocab=128)
    model = model_def(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    corpus = MarkovCorpus(CorpusConfig(vocab=cfg.vocab, seed=5))
    calib = calibration_batches(corpus, CalibConfig(num_sequences=4, seq_len=16,
                                                    batch_size=2))
    return model, params, calib


FAST = PrunerConfig(fista_iters=8, max_outer=6, patience=2, eps=1e-4)


class TestRegistry:
    def test_builtins_registered(self):
        names = registered_solvers()
        for name in ("fista", "admm", "wanda", "sparsegpt", "magnitude",
                     "dense"):
            assert name in names

    def test_unknown_name_lists_registered_solvers(self):
        with pytest.raises(KeyError) as exc:
            get_solver("no-such-solver")
        msg = str(exc.value)
        assert "no-such-solver" in msg
        for name in registered_solvers():
            assert name in msg

    def test_solver_kwargs_flow_through(self):
        s = get_solver("fista", fista_iters=3, outer_impl="host")
        assert s.cfg.fista_iters == 3
        assert not s.supports_group_batch      # host impl can't vmap
        s2 = get_solver("sparsegpt", use_pruned_gram=True)
        assert s2.wants_pruned_gram and get_solver("sparsegpt").wants_pruned_gram is False

    def test_toy_solver_needs_no_sequential_edits(self):
        """Registering a brand-new solver class makes it reachable from the
        full pipeline by name alone — the acceptance criterion of ISSUE 2."""

        @register_solver("toy-topk")
        class ToyTopK(LayerSolver):
            wants_pruned_gram = False

            def solve(self, w, stats, spec):
                from repro.core.pruner import _make_result
                from repro.core.sparsity import round_to
                y = round_to(jnp.asarray(w, jnp.float32), spec)
                b = gram_lib.target_correlation(stats, w)
                e = float(gram_lib.frob_error(stats, y, b))
                return _make_result(y, e, 0.0, 0, 0, e, float(stats.h))

        try:
            model, params, calib = tiny_model()
            cfg = SequentialConfig(spec=SparsitySpec(ratio=0.5),
                                   solver=get_solver("toy-topk"))
            pruned, reports = prune_model(model, params, calib, cfg)
            assert reports and all(r.solver == "toy-topk" for r in reports)
            assert all(np.isfinite(r.error) for r in reports)
        finally:
            unregister_solver("toy-topk")
        with pytest.raises(KeyError):
            get_solver("toy-topk")


class TestDeclaredStats:
    """The declared stats-dependency contract (ISSUE 8): solvers name the
    calibration statistics they read; core/sequential.py provisions them
    generically — zero per-solver edits."""

    def test_builtin_declarations(self):
        assert get_solver("fista").wants_pruned_gram
        assert get_solver("admm").wants_pruned_gram
        assert get_solver("frankwolfe").wants_pruned_gram
        for name in ("wanda", "magnitude", "dense"):
            s = get_solver(name)
            assert s.stats_required() == (solvers_lib.DENSE_GRAM,)
            assert not s.wants_pruned_gram

    def test_undeclared_stat_raises_listing_known_stats(self):
        class Bad(LayerSolver):
            stat_deps = (solvers_lib.DENSE_GRAM, "no-such-stat")

            def solve(self, w, stats, spec):   # pragma: no cover
                raise AssertionError

        with pytest.raises(KeyError) as exc:
            Bad().stats_required()
        msg = str(exc.value)
        assert "no-such-stat" in msg
        for known in (solvers_lib.DENSE_GRAM, solvers_lib.PRUNED_GRAM):
            assert known in msg

    def test_toy_solver_with_novel_stat_needs_no_sequential_edits(self):
        """A solver declaring a brand-new registered stat gets it
        accumulated into GramStats.extras by the generic provisioning —
        verified against the closed form diag(G) = sum_p X*_p^2."""
        solvers_lib.register_stat(solvers_lib.StatSpec(
            "pruned_sqnorms", needs_pruned_path=True,
            init=lambda n: jnp.zeros((n,), jnp.float32),
            update=lambda acc, xd, xp, wx: acc + jnp.sum(xp * xp, axis=0)))
        seen = []

        @register_solver("toy-novel-stat")
        class ToyNovel(LayerSolver):
            stat_deps = (solvers_lib.DENSE_GRAM, solvers_lib.PRUNED_GRAM,
                         "pruned_sqnorms")

            def solve(self, w, stats, spec):
                from repro.core.pruner import _make_result
                from repro.core.sparsity import round_to
                seen.append((np.asarray(stats.extras["pruned_sqnorms"]),
                             np.asarray(jnp.diag(stats.G))))
                y = round_to(jnp.asarray(w, jnp.float32), spec)
                b = gram_lib.target_correlation(stats, w)
                e = float(gram_lib.frob_error(stats, y, b))
                return _make_result(y, e, 0.0, 0, 0, e, float(stats.h))

        try:
            model, params, calib = tiny_model()
            cfg = SequentialConfig(spec=SparsitySpec(ratio=0.5),
                                   solver=get_solver("toy-novel-stat"))
            _, reports = prune_model(model, params, calib, cfg)
            assert reports and seen
            for sq, diag_g in seen:
                assert sq.shape == diag_g.shape
                np.testing.assert_allclose(sq, diag_g, rtol=1e-4, atol=1e-4)
        finally:
            unregister_solver("toy-novel-stat")
            solvers_lib.unregister_stat("pruned_sqnorms")

    def test_builtin_stats_cannot_be_unregistered(self):
        with pytest.raises(ValueError):
            solvers_lib.unregister_stat(solvers_lib.PRUNED_GRAM)

    def test_dense_stats_solver_skips_pruned_capture_on_moe(self, monkeypatch):
        """The wants_pruned_gram asymmetry fix: a dense-stats-only baseline
        must not trigger the pruned-path capture forwards on a grouped MoE
        unit — the dispatch count is pinned at exactly one capture per
        calibration micro-batch (pre-fix it was 2x: a wasted per-expert
        relay pass).  Cross-unit modes still relay (2x)."""
        from repro.core import sequential as seq_lib
        from repro.models.registry import load_arch

        model = load_arch("mixtral-8x7b", smoke=True)
        params = model.init(jax.random.PRNGKey(0))
        batches = [model.make_batch(jax.random.PRNGKey(i + 1), 2, 16)
                   for i in range(3)]
        states = [model.embed(params, b) for b in batches]
        spec = list(model.units())[0]
        dense_unit = seq_lib._unit_params_of(params, spec)
        assert any("/expert" in k for g in spec.groups for k in g)

        calls = {"n": 0}
        orig = seq_lib._capture_forward

        def counting(model_, uspec):
            fwd = orig(model_, uspec)

            def wrapped(unit_params, state):
                calls["n"] += 1
                return fwd(unit_params, state)

            return wrapped

        monkeypatch.setattr(seq_lib, "_capture_forward", counting)
        cfg = SequentialConfig(spec=SparsitySpec(kind="nm", n=2, m=4),
                               solver=get_solver("wanda"))
        _, reports, pruned_next = seq_lib.prune_unit(
            model, spec, dense_unit, states, [dict(s) for s in states], cfg)
        assert reports
        assert pruned_next == []
        assert calls["n"] == len(batches)          # dense captures ONLY

        calls["n"] = 0
        cfg_full = dataclasses.replace(cfg, error_correction="full")
        _, _, nxt = seq_lib.prune_unit(
            model, spec, dense_unit, states, [dict(s) for s in states],
            cfg_full)
        assert len(nxt) == len(batches)
        assert calls["n"] == 2 * len(batches)      # captures + pruned relay


class TestAdmm:
    @pytest.mark.parametrize("spec", SPECS, ids=str)
    @pytest.mark.parametrize("seed", [0, 1])
    def test_parity_with_fista(self, spec, seed):
        """Same objective, different solver: the ADMM error must land in
        FISTA's ballpark, beat its own warm start, and hit the sparsity
        pattern exactly."""
        w, stats = make_problem(seed=seed)
        fista = get_solver("fista").solve(w, stats, spec)
        admm = get_solver("admm").solve(w, stats, spec)
        assert satisfies(admm.weight, spec)
        assert admm.error <= admm.warm_error + 1e-5
        assert admm.error <= fista.error * 1.25, (admm.error, fista.error)

    def test_group_matches_solo(self):
        spec = SparsitySpec(ratio=0.5)
        ws, sts = zip(*[make_problem(seed=30 + s) for s in range(3)])
        solver = get_solver("admm")
        assert solver.supports_group_batch
        group = solver.solve_group(list(ws), list(sts), spec)
        for i, res in enumerate(group):
            solo = solver.solve(ws[i], sts[i], spec)
            np.testing.assert_allclose(np.asarray(res.weight),
                                       np.asarray(solo.weight), atol=1e-5)
            assert np.isclose(res.error, solo.error, rtol=1e-4)

    def test_pipeline_end_to_end(self):
        model, params, calib = tiny_model()
        cfg = SequentialConfig(spec=SparsitySpec(kind="nm", n=2, m=4),
                               solver=get_solver("admm", max_iters=16,
                                                 polish_iters=4))
        pruned, reports = prune_model(model, params, calib, cfg)
        assert any(r.solver == "admm-group" for r in reports)
        assert all(np.isfinite(r.error) for r in reports)


class TestGroupBatchedBaselines:
    @pytest.mark.parametrize("name", ["wanda", "sparsegpt", "magnitude"])
    @pytest.mark.parametrize("spec", SPECS, ids=str)
    def test_group_matches_per_operator(self, name, spec):
        ws, sts = zip(*[make_problem(seed=40 + s) for s in range(3)])
        solver = get_solver(name)
        assert solver.supports_group_batch
        group = solver.solve_group(list(ws), list(sts), spec)
        for i, res in enumerate(group):
            solo = solver.solve(ws[i], sts[i], spec)
            assert satisfies(res.weight, spec)
            np.testing.assert_allclose(np.asarray(res.weight),
                                       np.asarray(solo.weight), atol=1e-5)
            assert np.isclose(res.error, solo.error, rtol=1e-4)


class TestDeprecationShims:
    def test_prune_with_method_warns_and_matches_solver(self):
        w, stats = make_problem(seed=7)
        spec = SparsitySpec(ratio=0.5)
        with pytest.warns(DeprecationWarning):
            y, err = prune_with_method("wanda", w, stats, spec)
        res = get_solver("wanda").solve(w, stats, spec)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(res.weight))
        assert np.isclose(err, res.error, rtol=1e-6)

    def test_prune_with_method_fista_matches_prune_operator(self):
        w, stats = make_problem(seed=8)
        spec = SparsitySpec(kind="nm", n=2, m=4)
        with pytest.warns(DeprecationWarning):
            y, err = prune_with_method("fista", w, stats, spec, FAST)
        res = prune_operator(w, stats, spec, FAST)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(res.weight))

    def test_prune_with_method_unknown_raises_valueerror(self):
        w, stats = make_problem(seed=9)
        with pytest.warns(DeprecationWarning):
            with pytest.raises(ValueError, match="registered solvers"):
                prune_with_method("nope", w, stats, SparsitySpec(ratio=0.5))

    def test_legacy_sequential_config_warns_and_matches_new_api(self):
        """SequentialConfig(method=...) without a solver still works — and
        produces weights identical to the explicit-solver path."""
        from repro.utils.tree import flatten_with_paths

        model, params, calib = tiny_model()
        legacy = SequentialConfig(spec=SparsitySpec(ratio=0.5), pruner=FAST,
                                  method="fista")
        with pytest.warns(DeprecationWarning):
            old, _ = prune_model(model, params, calib, legacy)
        new_cfg = SequentialConfig(spec=SparsitySpec(ratio=0.5),
                                   solver=get_solver("fista", cfg=FAST))
        new, _ = prune_model(model, params, calib, new_cfg)
        for (pa, a), (pb, b) in zip(flatten_with_paths(old),
                                    flatten_with_paths(new)):
            assert pa == pb
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=pa)
