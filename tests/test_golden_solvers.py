"""Golden regression tests: pinned solver quality on fixed-seed problems.

The fista, admm and frankwolfe backends are the repo's quality-bearing
solvers; a
refactor that silently degrades their solutions would pass every
equivalence/invariant test and only show up (noisily) in benchmark
perplexity.  These tests pin the exact ``PruneResult`` quality — relative
reconstruction error within a committed tolerance band, and the EXACT
nonzero count — on fixed-seed Gram problems, so any quality regression
fails deterministically in tier-1.

The bands (RTOL) absorb fp32 accumulation-order drift across jax/XLA
versions; a real solver change moves rel-err by orders of magnitude more.
Regenerate the constants with the snippet in this file's git history
only when a deliberate solver-quality change is being made — and say so
in the PR.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import gram as gram_lib
from repro.core.solvers import get_solver
from repro.core.sparsity import SparsitySpec, satisfies

M, N, P = 24, 32, 256          # operator (out, in) and calibration tokens
RTOL = 2e-3                    # committed tolerance band on rel_error

FISTA_KW = dict(fista_iters=20, max_outer=12, patience=3, eps=1e-6)

#: per-method constructor kwargs used for every golden solve
SOLVER_KW = {"fista": FISTA_KW, "admm": {}, "frankwolfe": {}}

# (seed, method, sparsity) -> (rel_error, exact nnz).  m*n = 768 weights:
# both 50% and 2:4 keep exactly 384.
GOLDEN = {
    (0, "fista", "50%"): (0.282221, 384),
    (0, "admm", "50%"): (0.273067, 384),
    (0, "frankwolfe", "50%"): (0.272393, 384),
    (0, "fista", "2:4"): (0.379089, 384),
    (0, "admm", "2:4"): (0.367955, 384),
    (0, "frankwolfe", "2:4"): (0.365348, 384),
    (1, "fista", "50%"): (0.275195, 384),
    (1, "admm", "50%"): (0.267110, 384),
    (1, "frankwolfe", "50%"): (0.267403, 384),
    (1, "fista", "2:4"): (0.361894, 384),
    (1, "admm", "2:4"): (0.351150, 384),
    (1, "frankwolfe", "2:4"): (0.349776, 384),
}


def golden_problem(seed: int, drift: float = 0.1):
    """Fixed-seed operator + Gram stats with a realistic X/X* gap."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(M, N)).astype(np.float32)
    x = rng.normal(size=(N, P)).astype(np.float32)
    xs = (x + drift * rng.normal(size=(N, P))).astype(np.float32)
    stats = gram_lib.accumulate(gram_lib.init_stats(N),
                                jnp.asarray(x.T), jnp.asarray(xs.T),
                                jnp.asarray((w @ x).T))
    return jnp.asarray(w), stats


@pytest.mark.parametrize("seed,method,sparsity", sorted(GOLDEN))
def test_pinned_quality(seed, method, sparsity):
    want_rel, want_nnz = GOLDEN[(seed, method, sparsity)]
    w, stats = golden_problem(seed)
    solver = get_solver(method, **SOLVER_KW[method])
    res = solver.solve(w, stats, SparsitySpec.parse(sparsity))

    weight = np.asarray(res.weight, np.float32)
    assert int(np.count_nonzero(weight)) == want_nnz
    assert satisfies(weight, SparsitySpec.parse(sparsity))
    assert res.rel_error == pytest.approx(want_rel, rel=RTOL), \
        f"solver quality drifted: {res.rel_error:.6f} vs pinned {want_rel}"
    # internal consistency: rel_error is error / ||W X||_F
    assert res.error == pytest.approx(res.rel_error * np.sqrt(float(stats.h)),
                                      rel=1e-4)


@pytest.mark.parametrize("sparsity", ["50%", "2:4"])
def test_group_solve_matches_golden(sparsity):
    """The vmap-batched group path must hit the same pinned quality —
    group batching is a dispatch optimization, not a math change."""
    problems = [golden_problem(s) for s in (0, 1)]
    for method, kw in sorted(SOLVER_KW.items()):
        solver = get_solver(method, **kw)
        results = solver.solve_group([w for w, _ in problems],
                                     [st for _, st in problems],
                                     SparsitySpec.parse(sparsity))
        for seed, res in zip((0, 1), results):
            want_rel, want_nnz = GOLDEN[(seed, method, sparsity)]
            assert int(np.count_nonzero(np.asarray(res.weight))) == want_nnz
            assert res.rel_error == pytest.approx(want_rel, rel=RTOL)
