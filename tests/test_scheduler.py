"""Scheduler fault-tolerance tests: retries, permanent failure, stragglers,
checkpoint resume, worker elasticity."""
import threading
import time

import pytest

from repro.checkpoint import store
from repro.core.scheduler import PruneScheduler, SchedulerConfig, UnitFailed


def test_basic_completion():
    done = []
    s = PruneScheduler([f"u{i}" for i in range(8)],
                       lambda u: done.append(u) or u.upper(),
                       SchedulerConfig(workers=4))
    res = s.run()
    assert len(res) == 8 and res["u3"].payload == "U3"


def test_retry_then_success():
    attempts = {}
    lock = threading.Lock()

    def flaky(u):
        with lock:
            attempts[u] = attempts.get(u, 0) + 1
            if u == "u1" and attempts[u] < 3:
                raise RuntimeError("transient")
        return u

    s = PruneScheduler(["u0", "u1", "u2"], flaky,
                       SchedulerConfig(workers=2, max_retries=3,
                                       retry_backoff=0.01))
    res = s.run()
    assert len(res) == 3 and attempts["u1"] == 3
    assert res["u1"].attempts == 3


def test_permanent_failure_raises():
    def bad(u):
        if u == "u1":
            raise RuntimeError("node died")
        return u

    s = PruneScheduler(["u0", "u1"], bad,
                       SchedulerConfig(workers=2, max_retries=1,
                                       retry_backoff=0.01))
    with pytest.raises(UnitFailed):
        s.run()


def test_straggler_duplication():
    """A unit stuck far beyond the median gets speculatively re-dispatched;
    the duplicate finishes first."""
    state = {"first_call": True}
    lock = threading.Lock()

    def work(u):
        if u == "slow":
            with lock:
                first = state["first_call"]
                state["first_call"] = False
            if first:
                time.sleep(30)       # the straggler (daemon thread; abandoned)
                return "straggler"
            return "duplicate"
        time.sleep(0.02)
        return "fast"

    s = PruneScheduler(["a", "b", "c", "d", "slow"], work,
                       SchedulerConfig(workers=3, straggler_factor=2.0,
                                       straggler_min_wait=0.2))
    t0 = time.perf_counter()
    res = s.run()
    assert time.perf_counter() - t0 < 20
    assert res["slow"].payload == "duplicate"
    assert "slow" in s.stats["duplicated"]


def test_checkpoint_resume(tmp_path):
    ran = []

    def save(u, payload):
        store.save(str(tmp_path), f"unit_{u}", {"x": payload})

    def load(u):
        import jax.numpy as jnp
        tree, _ = store.load(str(tmp_path), f"unit_{u}",
                             {"x": jnp.zeros((2,), jnp.float32)})
        return tree["x"]

    import jax.numpy as jnp

    def work(u):
        ran.append(u)
        return jnp.ones((2,), jnp.float32) * int(u[1:])

    # disable speculative duplication: an abandoned duplicate thread could
    # append to `ran` after clear() under heavy CPU load
    cfg = SchedulerConfig(workers=2, checkpoint_dir=str(tmp_path),
                          straggler_min_wait=300.0)
    PruneScheduler(["u0", "u1", "u2"], work, cfg, save, load).run()
    assert sorted(ran) == ["u0", "u1", "u2"]

    ran.clear()
    res = PruneScheduler(["u0", "u1", "u2", "u3"], work, cfg, save, load).run()
    assert ran == ["u3"], "only the new unit should run"
    assert float(res["u2"].payload[0]) == 2.0


def _payload_of(u):
    """Deterministic per-unit payload (pure function of the unit name)."""
    import numpy as np
    import jax.numpy as jnp
    rng = np.random.default_rng(abs(hash(u)) % (2 ** 31))
    return jnp.asarray(rng.normal(size=(4,)).astype(np.float32))


def _store_io(tmp_path):
    import jax.numpy as jnp

    def save(u, payload):
        store.save(str(tmp_path), f"unit_{u}", {"x": payload})

    def load(u):
        tree, _ = store.load(str(tmp_path), f"unit_{u}",
                             {"x": jnp.zeros((4,), jnp.float32)})
        return tree["x"]

    return save, load


def test_kill_mid_run_then_restart_skips_completed(tmp_path):
    """Fault injection: the run dies mid-unit (simulated worker crash after
    some units already checkpointed); a restart against the same checkpoint
    dir must skip every completed unit and finish only the rest."""
    import numpy as np

    units = [f"u{i}" for i in range(5)]
    save, load = _store_io(tmp_path)

    def crashy(u):
        if u in ("u3", "u4"):
            raise RuntimeError(f"simulated kill while running {u}")
        return _payload_of(u)

    cfg = SchedulerConfig(workers=1, max_retries=0, retry_backoff=0.01,
                          checkpoint_dir=str(tmp_path),
                          straggler_min_wait=300.0)
    with pytest.raises(UnitFailed):
        PruneScheduler(units, crashy, cfg, save, load).run()
    # the crash left a partial run: u0-u2 checkpointed, u3/u4 not
    assert [store.exists(str(tmp_path), f"unit_{u}") for u in units] == \
        [True, True, True, False, False]

    ran = []

    def healthy(u):
        ran.append(u)
        return _payload_of(u)

    res = PruneScheduler(units, healthy, cfg, save, load).run()
    assert sorted(ran) == ["u3", "u4"], "completed units must be skipped"
    assert len(res) == 5
    for u in units:   # resumed and fresh payloads are the same pure function
        np.testing.assert_array_equal(np.asarray(res[u].payload),
                                      np.asarray(_payload_of(u)))


def test_straggler_redispatch_idempotent_payload(tmp_path):
    """Speculative duplicates are pure recomputations: whichever copy wins,
    the persisted payload is bitwise-identical, and a follow-up restart
    resumes from it without recomputing anything."""
    import numpy as np

    state = {"first": True}
    lock = threading.Lock()

    def work(u):
        payload = _payload_of(u)       # compute BEFORE stalling: both the
        if u == "slow":                # straggler and its duplicate produce
            with lock:                 # finished results; first wins
                first, state["first"] = state["first"], False
            if first:
                time.sleep(10)
        else:
            time.sleep(0.02)
        return payload

    save, load = _store_io(tmp_path)
    cfg = SchedulerConfig(workers=3, straggler_factor=2.0,
                          straggler_min_wait=0.2,
                          checkpoint_dir=str(tmp_path))
    s = PruneScheduler(["a", "b", "c", "slow"], work, cfg, save, load)
    res = s.run()
    assert "slow" in s.stats["duplicated"]
    np.testing.assert_array_equal(np.asarray(res["slow"].payload),
                                  np.asarray(_payload_of("slow")))
    # the winning copy's checkpoint is bitwise-equal to the pure payload
    np.testing.assert_array_equal(np.asarray(load("slow")),
                                  np.asarray(_payload_of("slow")))

    def must_not_run(u):
        raise AssertionError(f"unit {u} recomputed after clean completion")

    res2 = PruneScheduler(["a", "b", "c", "slow"], must_not_run, cfg,
                          save, load).run()
    np.testing.assert_array_equal(np.asarray(res2["slow"].payload),
                                  np.asarray(_payload_of("slow")))


def test_persisted_telemetry_attributes_workers(tmp_path):
    """A 3-arg save_payload receives per-unit telemetry (worker id,
    wall-clock, attempts) with the checkpointed payload, so a
    multi-worker run is attributable post-hoc from the run dir alone."""
    import json
    import os

    import jax.numpy as jnp

    persisted = {}
    lock = threading.Lock()

    def save(u, payload, meta):
        store.save(str(tmp_path), f"unit_{u}", {"x": payload},
                   extra={"telemetry": meta})
        with lock:
            persisted[u] = meta

    def load(u):
        tree, _ = store.load(str(tmp_path), f"unit_{u}",
                             {"x": jnp.zeros((4,), jnp.float32)})
        return tree["x"]

    units = [f"u{i}" for i in range(6)]
    cfg = SchedulerConfig(workers=3, checkpoint_dir=str(tmp_path),
                          straggler_min_wait=300.0)
    s = PruneScheduler(units, _payload_of, cfg, save, load)
    res = s.run()

    assert sorted(persisted) == units
    for u in units:
        meta = persisted[u]
        assert meta["worker"] == res[u].worker >= 0
        assert meta["seconds"] == res[u].seconds > 0
        assert meta["attempts"] == res[u].attempts == 1
        # ... and the telemetry is in the on-disk manifest, not just memory
        with open(os.path.join(str(tmp_path), f"unit_{u}",
                               "MANIFEST.json")) as f:
            extra = json.load(f)["extra"]
        assert extra["telemetry"]["worker"] == res[u].worker
    # run-level stats expose the same assignment map
    assert s.stats["workers"] == {u: res[u].worker for u in units}


def test_two_arg_save_payload_still_works(tmp_path):
    """Legacy 2-arg save_payload callbacks keep working (no meta)."""
    import jax.numpy as jnp

    calls = []

    def save(u, payload):
        calls.append(u)
        store.save(str(tmp_path), f"unit_{u}", {"x": payload})

    def load(u):
        tree, _ = store.load(str(tmp_path), f"unit_{u}",
                             {"x": jnp.zeros((4,), jnp.float32)})
        return tree["x"]

    cfg = SchedulerConfig(workers=2, checkpoint_dir=str(tmp_path),
                          straggler_min_wait=300.0)
    res = PruneScheduler(["u0", "u1"], _payload_of, cfg, save, load).run()
    assert sorted(calls) == ["u0", "u1"] and len(res) == 2


def test_elastic_worker_counts_agree():
    def work(u):
        return hash(u) % 97

    for workers in (1, 2, 5):
        res = PruneScheduler([f"u{i}" for i in range(6)], work,
                             SchedulerConfig(workers=workers)).run()
        assert {k: v.payload for k, v in res.items()} == \
               {f"u{i}": hash(f"u{i}") % 97 for i in range(6)}


def test_run_summary_totals_and_slowest():
    """run_summary feeds `repro.obs report`: solver seconds, the attempts
    histogram and the slowest unit must reflect the actual run."""
    def work(u):
        time.sleep(0.12 if u == "u1" else 0.01)
        return u

    s = PruneScheduler(["u0", "u1", "u2"], work,
                       SchedulerConfig(workers=2, straggler_min_wait=300.0))
    s.run()
    rs = s.run_summary
    assert rs["completed"] == 3 and rs["resumed"] == 0
    assert rs["slowest_unit"]["unit"] == "u1"
    assert rs["total_solver_seconds"] >= rs["slowest_unit"]["seconds"] > 0.1
    assert rs["attempts_histogram"] == {"1": 3}
    assert rs["duplicated"] == []


def test_run_summary_counts_retries_and_resumes(tmp_path):
    attempts = {}
    lock = threading.Lock()

    def flaky(u):
        with lock:
            attempts[u] = attempts.get(u, 0) + 1
            if u == "u1" and attempts[u] < 2:
                raise RuntimeError("transient")
        return _payload_of(u)

    save, load = _store_io(tmp_path)
    cfg = SchedulerConfig(workers=2, max_retries=3, retry_backoff=0.01,
                          checkpoint_dir=str(tmp_path),
                          straggler_min_wait=300.0)
    first = PruneScheduler(["u0", "u1"], flaky, cfg, save, load)
    first.run()
    assert first.run_summary["attempts_histogram"] == {"1": 1, "2": 1}

    # a restart resumes both units from checkpoint: zero fresh seconds
    second = PruneScheduler(["u0", "u1", "u2"], flaky, cfg, save, load)
    second.run()
    rs = second.run_summary
    assert rs["completed"] == 3 and rs["resumed"] == 2
    assert rs["slowest_unit"]["unit"] == "u2"
