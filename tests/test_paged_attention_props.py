"""Hypothesis sweeps for the block-table flash-decode path.

Randomized companions to the deterministic pins in
tests/test_paged_attention.py (same scenario builder): ragged per-slot
lengths, windows narrower than the context, block tables with holes and
trash-block-0 tails.  Skips wholesale without hypothesis (optional test
dep), like tests/test_kv_pool.py.
"""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="optional test dep "
                    "(pip install '.[test]') — see pyproject.toml")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import paged_attention as pk  # noqa: E402
from repro.kernels import ref  # noqa: E402
from test_paged_attention import (BS, build_scenario,  # noqa: E402
                                  naive_paged_attention)

# <= 3 slots x <= 2 blocks each always fits the 9 allocatable blocks
scenarios = st.fixed_dictionaries({
    "seed": st.integers(0, 2**31 - 1),
    "lengths": st.lists(st.integers(1, 2 * BS), min_size=1, max_size=3),
    "window": st.sampled_from([0, 2, 3, BS + 1]),
    "softcap": st.sampled_from([0.0, 5.0]),
    "inactive": st.integers(-1, 2),     # slot to deactivate (-1: none)
})


def _materialize(sc):
    q, k, v, tables, pos = build_scenario(sc["seed"], sc["lengths"])
    active = np.ones(len(sc["lengths"]), bool)
    if 0 <= sc["inactive"] < active.size:
        active[sc["inactive"]] = False
    args = (jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            jnp.asarray(tables), jnp.asarray(pos), jnp.asarray(active))
    return (q, k, v, tables, pos, active), args


@given(sc=scenarios)
@settings(max_examples=40, deadline=None)
def test_oracle_matches_naive(sc):
    (q, k, v, tables, pos, active), args = _materialize(sc)
    got = np.asarray(ref.paged_attention(
        *args, block_size=BS, window=sc["window"], softcap=sc["softcap"]))
    want = naive_paged_attention(q, k, v, tables, pos, active,
                                 window=sc["window"], softcap=sc["softcap"])
    np.testing.assert_allclose(got[active], want[active], rtol=1e-5,
                               atol=1e-6)


@pytest.mark.kernels_interpret
@given(sc=scenarios)
@settings(max_examples=10, deadline=None)   # interpret mode is slow
def test_kernel_matches_oracle(sc):
    (_, _, _, _, _, active), args = _materialize(sc)
    got = np.asarray(pk.paged_decode_attn(
        *args, block_size=BS, window=sc["window"], softcap=sc["softcap"],
        interpret=True))
    want = np.asarray(ref.paged_attention(
        *args, block_size=BS, window=sc["window"], softcap=sc["softcap"]))
    np.testing.assert_allclose(got[active], want[active], rtol=1e-5,
                               atol=1e-6)
