"""Equivalence of the device-resident (fused) Algorithm 1 with the host-loop
reference, and of the vmap-batched group solve with per-operator solves.

The fused path re-expresses the outer loop (FISTA solve -> round ->
error eval -> patience/eps stop -> lambda bisection) as one
``lax.while_loop``; these tests pin it to the host oracle: same W_best,
same E_best, same lambda trajectory within fp32 tolerance, and no KKT
regression of the final FISTA solve.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import fista as fista_lib
from repro.core import gram as gram_lib
from repro.core.pruner import (PrunerConfig, prune_group, prune_operator)
from repro.core.sparsity import SparsitySpec, satisfies

SPECS = [SparsitySpec(ratio=0.5), SparsitySpec(kind="nm", n=2, m=4)]


def make_problem(m=24, n=32, p=256, seed=0, pruned_shift=0.05):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(m, n)).astype(np.float32)
    x = rng.normal(size=(n, p)).astype(np.float32)
    xs = x + pruned_shift * rng.normal(size=(n, p)).astype(np.float32)
    stats = gram_lib.init_stats(n)
    stats = gram_lib.accumulate(stats, x.T, xs.T, (w @ x).T)
    return jnp.asarray(w), stats


HOST = PrunerConfig(outer_impl="host")
FUSED = PrunerConfig(outer_impl="fused")


class TestFusedEquivalence:
    @pytest.mark.parametrize("spec", SPECS, ids=str)
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_matches_host_loop(self, spec, seed):
        w, stats = make_problem(seed=seed)
        host = prune_operator(w, stats, spec, HOST)
        fused = prune_operator(w, stats, spec, FUSED)
        assert satisfies(fused.weight, spec)
        np.testing.assert_allclose(np.asarray(fused.weight),
                                   np.asarray(host.weight), atol=1e-5)
        assert np.isclose(fused.error, host.error, rtol=1e-4)
        assert np.isclose(fused.warm_error, host.warm_error, rtol=1e-4)
        # same trajectory: identical trip counts and final bracket midpoint
        assert fused.outer_iters == host.outer_iters
        assert fused.fista_iters == host.fista_iters
        assert np.isclose(fused.lam, host.lam, rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("warm", ["wanda", "sparsegpt", "magnitude", "dense"])
    def test_all_warm_starts(self, warm):
        w, stats = make_problem(seed=3)
        spec = SparsitySpec(ratio=0.5)
        host = prune_operator(w, stats, spec,
                              PrunerConfig(outer_impl="host", warm_start=warm))
        fused = prune_operator(w, stats, spec,
                               PrunerConfig(outer_impl="fused", warm_start=warm))
        np.testing.assert_allclose(np.asarray(fused.weight),
                                   np.asarray(host.weight), atol=1e-5)
        assert np.isclose(fused.error, host.error, rtol=1e-4)

    def test_array_warm_start(self):
        w, stats = make_problem(seed=4)
        spec = SparsitySpec(ratio=0.5)
        w0 = np.asarray(w) * (np.random.default_rng(0).random(w.shape) > 0.3)
        host = prune_operator(w, stats, spec, HOST, warm=jnp.asarray(w0))
        fused = prune_operator(w, stats, spec, FUSED, warm=jnp.asarray(w0))
        np.testing.assert_allclose(np.asarray(fused.weight),
                                   np.asarray(host.weight), atol=1e-5)

    def test_respects_max_outer_and_patience(self):
        w, stats = make_problem(seed=5)
        cfg = PrunerConfig(outer_impl="fused", max_outer=6, patience=2)
        res = prune_operator(w, stats, SparsitySpec(ratio=0.5), cfg)
        assert 1 <= res.outer_iters <= 6

    def test_kkt_residual_no_regression(self):
        """The FISTA solve at the fused path's final lambda must satisfy the
        LASSO KKT conditions as well as at the host path's final lambda."""
        w, stats = make_problem(m=16, n=24, p=128, seed=6)
        spec = SparsitySpec(ratio=0.5)
        b = gram_lib.target_correlation(stats, w)
        residual = {}
        for name, cfg in (("host", HOST), ("fused", FUSED)):
            res = prune_operator(w, stats, spec, cfg)
            y, _ = fista_lib.solve(stats.G, b, jnp.asarray(res.weight),
                                   res.lam, max_iters=2000, tol=1e-9)
            residual[name] = float(fista_lib.kkt_residual(stats.G, b, y, res.lam))
        scale = float(jnp.max(jnp.abs(b)))
        assert residual["fused"] < 1e-2 * scale, residual
        assert residual["fused"] <= residual["host"] * 1.5 + 1e-4 * scale, residual


class TestGroupBatched:
    @pytest.mark.parametrize("spec", SPECS, ids=str)
    def test_matches_per_operator(self, spec):
        ws, sts = zip(*[make_problem(seed=s) for s in range(3)])
        results = prune_group(list(ws), list(sts), spec, FUSED)
        assert len(results) == 3
        for i, res in enumerate(results):
            solo = prune_operator(ws[i], sts[i], spec, FUSED)
            assert satisfies(res.weight, spec)
            np.testing.assert_allclose(np.asarray(res.weight),
                                       np.asarray(solo.weight), atol=1e-5)
            assert np.isclose(res.error, solo.error, rtol=1e-4)
            assert res.outer_iters == solo.outer_iters

    def test_matches_host_loop(self):
        spec = SparsitySpec(kind="nm", n=2, m=4)
        ws, sts = zip(*[make_problem(seed=10 + s) for s in range(2)])
        batched = prune_group(list(ws), list(sts), spec, FUSED)
        host = prune_group(list(ws), list(sts), spec, HOST)
        for b, h in zip(batched, host):
            np.testing.assert_allclose(np.asarray(b.weight),
                                       np.asarray(h.weight), atol=1e-5)
            assert np.isclose(b.error, h.error, rtol=1e-4)

    def test_stacked_array_input(self):
        spec = SparsitySpec(ratio=0.5)
        ws, sts = zip(*[make_problem(seed=20 + s) for s in range(2)])
        stacked_w = jnp.stack(list(ws))
        stacked_stats = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *sts)
        a = prune_group(stacked_w, stacked_stats, spec, FUSED)
        b = prune_group(list(ws), list(sts), spec, FUSED)
        for ra, rb in zip(a, b):
            np.testing.assert_array_equal(np.asarray(ra.weight),
                                          np.asarray(rb.weight))

    def test_rejects_mixed_shapes(self):
        w1, s1 = make_problem(m=8, n=16, seed=0)
        w2, s2 = make_problem(m=8, n=32, seed=0)
        with pytest.raises(ValueError):
            prune_group([w1, w2], [s1, s2], SparsitySpec(ratio=0.5), FUSED)


class TestKernelVmap:
    def test_fista_step_survives_vmap(self):
        """kernels/fista_step must be vmap-able (the batched group solver
        maps over it); check both the ref fallback and the Pallas tile path
        against the per-slice oracle."""
        from repro.kernels import ops as kops
        from repro.kernels import ref

        rng = np.random.default_rng(0)
        for m, n in ((32, 48), (128, 128)):   # ref path, pallas path
            y = jnp.asarray(rng.normal(size=(3, m, n)).astype(np.float32))
            a = rng.normal(size=(3, n, n)).astype(np.float32) * 0.2
            G = jnp.asarray(np.einsum("kij,klj->kil", a, a))
            B = jnp.asarray(rng.normal(size=(3, m, n)).astype(np.float32))
            inv_l = jnp.asarray([0.01, 0.02, 0.03], jnp.float32)
            thresh = jnp.asarray([0.005, 0.004, 0.003], jnp.float32)
            got = jax.vmap(kops.fista_prox_step)(y, G, B, inv_l, thresh)
            want = jnp.stack([ref.fista_prox_step(y[i], G[i], B[i],
                                                  inv_l[i], thresh[i])
                              for i in range(3)])
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=1e-5)

    def test_solve_survives_vmap_with_pallas_step(self):
        """End-to-end: the FISTA solver under vmap with step_impl=pallas."""
        rng = np.random.default_rng(1)
        m = n = 128
        y0 = jnp.asarray(rng.normal(size=(2, m, n)).astype(np.float32))
        a = rng.normal(size=(2, n, n)).astype(np.float32) * 0.2
        G = jnp.asarray(np.einsum("kij,klj->kil", a, a))
        B = jnp.asarray(rng.normal(size=(2, m, n)).astype(np.float32))
        lam = jnp.asarray([0.5, 1.0], jnp.float32)

        def solve(step_impl, i=None):
            fn = lambda G_, B_, y_, l_: fista_lib.solve(
                G_, B_, y_, l_, max_iters=5, step_impl=step_impl)[0]
            if i is None:
                return jax.vmap(fn)(G, B, y0, lam)
            return fn(G[i], B[i], y0[i], lam[i])

        got = solve("pallas")
        for i in range(2):
            np.testing.assert_allclose(np.asarray(got[i]),
                                       np.asarray(solve("jnp", i)), atol=1e-4)


class TestPipelineFused:
    def test_ragged_calibration_batches(self):
        """A truncated final calibration batch (num_sequences % batch_size
        != 0) must work: the group-stats scan buckets micro-batches by
        shape instead of stacking ragged arrays."""
        import dataclasses
        from repro.configs.opt125m_proxy import tiny_config
        from repro.core.sequential import SequentialConfig, prune_model
        from repro.data import (CalibConfig, CorpusConfig, MarkovCorpus,
                                calibration_batches)
        from repro.models.registry import model_def
        from repro.utils.tree import flatten_with_paths

        cfg = tiny_config().replace(num_layers=1, d_model=32, d_ff=64,
                                    num_heads=4, num_kv_heads=4, vocab=128)
        model = model_def(cfg)
        params = model.init(jax.random.PRNGKey(0))
        corpus = MarkovCorpus(CorpusConfig(vocab=cfg.vocab, seed=5))
        calib = calibration_batches(corpus, CalibConfig(num_sequences=10,
                                                        seq_len=16,
                                                        batch_size=4))
        assert len({b["tokens"].shape for b in calib}) > 1  # really ragged
        fast = PrunerConfig(fista_iters=4, max_outer=3, patience=2, eps=1e-4)
        outs = {}
        for impl in ("host", "fused"):
            scfg = SequentialConfig(
                spec=SparsitySpec(ratio=0.5), method="fista",
                pruner=dataclasses.replace(fast, outer_impl=impl))
            outs[impl], reports = prune_model(model, params, calib, scfg)
            assert all(np.isfinite(r.error) for r in reports)
        for (pa, a), (pb, b) in zip(flatten_with_paths(outs["host"]),
                                    flatten_with_paths(outs["fused"])):
            assert pa == pb
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=2e-4, err_msg=pa)


    def test_prune_unit_group_batching_matches_unbatched(self):
        """Whole-pipeline equivalence: fused+group_batch == fused without
        batching == host loop, on a real transformer unit."""
        from repro.configs.opt125m_proxy import tiny_config
        from repro.core.sequential import SequentialConfig, prune_model
        from repro.data import (CalibConfig, CorpusConfig, MarkovCorpus,
                                calibration_batches)
        from repro.models.registry import model_def
        from repro.utils.tree import flatten_with_paths

        cfg = tiny_config().replace(num_layers=1, d_model=64, d_ff=128,
                                    num_heads=4, num_kv_heads=4, vocab=128)
        model = model_def(cfg)
        params = model.init(jax.random.PRNGKey(0))
        corpus = MarkovCorpus(CorpusConfig(vocab=cfg.vocab, seed=5))
        calib = calibration_batches(corpus, CalibConfig(num_sequences=8,
                                                        seq_len=32,
                                                        batch_size=4))
        outs = {}
        reports = {}
        for name, pruner in (
                ("host", PrunerConfig(fista_iters=8, max_outer=4, patience=2,
                                      eps=1e-4, outer_impl="host")),
                ("fused", PrunerConfig(fista_iters=8, max_outer=4, patience=2,
                                       eps=1e-4, group_batch=False)),
                ("group", PrunerConfig(fista_iters=8, max_outer=4, patience=2,
                                       eps=1e-4, group_batch=True))):
            scfg = SequentialConfig(spec=SparsitySpec(ratio=0.5),
                                    pruner=pruner, method="fista")
            outs[name], reports[name] = prune_model(model, params, calib, scfg)
        assert any(r.solver == "fused-group" for r in reports["group"])
        assert all(r.solver == "host" for r in reports["host"])
        for variant in ("fused", "group"):
            for (pa, a), (pb, b) in zip(flatten_with_paths(outs["host"]),
                                        flatten_with_paths(outs[variant])):
                assert pa == pb
                np.testing.assert_allclose(
                    np.asarray(a, np.float32), np.asarray(b, np.float32),
                    atol=2e-4, err_msg=f"{variant}:{pa}")
