"""Shared test-session hygiene.

The tier-1 suite compiles a few thousand distinct XLA programs in one
process.  On single-core CPU runners, jaxlib 0.4.37's compiler
eventually segfaults partway through the later modules (observed
repeatedly around test ~315/369, in a *different* test each run, with
RSS under 6 GB — accumulated compiler/executable state, not memory
pressure).  Dropping the jit caches at module boundaries keeps the
live-executable population bounded; each module recompiles what it
actually uses, which costs a little wall time and changes no results.
"""
import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    yield
    jax.clear_caches()
