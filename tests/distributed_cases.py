"""Multi-device test cases, run in a subprocess with forced host devices.

Invoked by tests/test_distributed.py as
    python distributed_cases.py <case> [devices]
which forces ``devices`` (default 8) fake host devices via XLA_FLAGS
before jax initializes.  Prints "CASE_OK <case>" on success; exits 42
("CASE_SKIP") when the requested device count is not available — the
pytest wrapper turns that into a clean skip.

The ``*_parity`` cases are the sharded-vs-single-device acceptance
anchors of the mesh-native substrate (DESIGN.md §10): one pruning unit's
Gram+solve, held-out perplexity/KL, and a multi-request continuous-batcher
run must be bitwise / token-identical between a 1-device run and the
8-fake-device mesh.
"""
import os
import sys

_DEVICES = int(sys.argv[2]) if len(sys.argv) > 2 else 8
# replace (not prepend to) any inherited device-count flag — the CI
# distributed job exports =8 globally, and a duplicated flag would let
# the job's value override a case asking for a different count (6)
_flags = [f for f in os.environ.get("XLA_FLAGS", "").split()
          if not f.startswith("--xla_force_host_platform_device_count")]
os.environ["XLA_FLAGS"] = " ".join(
    [f"--xla_force_host_platform_device_count={_DEVICES}"] + _flags)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

if jax.device_count() < _DEVICES:
    # the backend ignored the fake-device flag (e.g. a GPU platform):
    # only 1 device is visible — skip cleanly instead of failing
    print(f"CASE_SKIP need {_DEVICES} devices, have {jax.device_count()}")
    sys.exit(42)


def _tiny_model(seed: int = 0):
    from repro.configs.opt125m_proxy import tiny_config
    from repro.models.registry import model_def

    cfg = tiny_config().replace(num_layers=2, d_model=64, d_ff=128,
                                num_heads=4, num_kv_heads=4, vocab=128)
    model = model_def(cfg)
    return model, model.init(jax.random.PRNGKey(seed))


def case_rowfista():
    from repro.core import fista as fista_lib
    from repro.core import gram as gram_lib
    from repro.distributed.rowfista import sharded_solve

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    m, n = 32, 48
    a = rng.normal(size=(n, n)).astype(np.float32) * 0.3
    G = jnp.asarray(a @ a.T)
    B = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    y0 = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    L = gram_lib.max_eigval(G) * 1.01
    want, _ = fista_lib.solve(G, B, y0, 0.5, L=L, max_iters=50)
    got = sharded_solve(mesh, G, B, y0, 0.5, L, max_iters=50)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-5)


def case_gram_psum():
    from repro.core import gram as gram_lib
    from repro.distributed.rowfista import sharded_accumulate

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(1)
    p, n, m = 64, 16, 8
    xd = rng.normal(size=(p, n)).astype(np.float32)
    xp = xd + 0.1 * rng.normal(size=(p, n)).astype(np.float32)
    w = rng.normal(size=(m, n)).astype(np.float32)
    wx = xd @ w.T
    serial = gram_lib.accumulate(gram_lib.init_stats(n), xd, xp, wx)
    sharded = sharded_accumulate(mesh, gram_lib.init_stats(n),
                                 jnp.asarray(xd), jnp.asarray(xp), jnp.asarray(wx))
    np.testing.assert_allclose(np.asarray(sharded.G), np.asarray(serial.G),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(sharded.h), float(serial.h), rtol=1e-5)


def case_sharded_train():
    from repro.configs.opt125m_proxy import tiny_config
    from repro.distributed.train import make_train_step
    from repro.models.registry import model_def
    from repro.train import optim

    cfg = tiny_config().replace(num_layers=2, d_model=64, d_ff=128,
                                num_heads=4, num_kv_heads=4, vocab=128)
    model = model_def(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = optim.init(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 128, jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}

    ocfg = optim.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=4)
    # unsharded reference
    def step(params, opt_state, batch):
        def loss_fn(p):
            return model.loss(p, batch)
        (l, m), g = jax.value_and_grad(lambda p: loss_fn(p)[0], has_aux=False) \
            (params), None
        return l
    def ref_step(params, opt_state, batch):
        (l, m), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(params)
        p2, o2, om = optim.update(ocfg, grads, opt_state, params)
        return p2, o2, l

    p_ref, o_ref, l_ref = jax.jit(ref_step)(params, opt, batch)

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    build = make_train_step(model, mesh, ocfg, donate=False)
    fn, _ = build(params, opt, batch)
    p_sh, o_sh, metrics = fn(params, opt, batch)
    assert np.isclose(float(metrics["loss"]), float(l_ref), rtol=1e-4), \
        (float(metrics["loss"]), float(l_ref))
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(p_ref),
            jax.tree_util.tree_leaves_with_path(p_sh)):
        np.testing.assert_allclose(np.asarray(b, np.float32),
                                   np.asarray(a, np.float32),
                                   rtol=5e-3, atol=5e-4)


def case_pipeline():
    from repro.distributed.pipeline import (pipeline_apply, split_microbatches,
                                            merge_microbatches, stack_to_stages)

    mesh = jax.make_mesh((4, 2), ("pod", "data"))
    rng = np.random.default_rng(2)
    L, D = 8, 16
    ws = jnp.asarray(rng.normal(size=(L, D, D)).astype(np.float32) * 0.2)

    def layer(w, x):
        return jnp.tanh(x @ w)

    def plain(x):
        for i in range(L):
            x = layer(ws[i], x)
        return x

    def stage_fn(stage_params, x):
        def body(h, w):
            return layer(w, h), None
        out, _ = jax.lax.scan(body, x, stage_params)
        return out

    x = jnp.asarray(rng.normal(size=(12, D)).astype(np.float32))
    xs = split_microbatches(x, 6)
    stages = stack_to_stages(ws, 4)
    got = merge_microbatches(pipeline_apply(mesh, stage_fn, stages, xs))
    np.testing.assert_allclose(np.asarray(got), np.asarray(plain(x)),
                               rtol=1e-4, atol=1e-5)


def case_compression():
    from repro.distributed.compression import (compressed_allreduce,
                                               ef_compress, init_residuals)

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(3)
    D = 8
    grads = {"w": jnp.asarray(rng.normal(size=(D, 16, 8)).astype(np.float32))}
    residuals = init_residuals(grads)
    mean, new_r = compressed_allreduce(mesh, grads, residuals)
    want = np.asarray(grads["w"]).mean(axis=0)
    got = np.asarray(mean["w"][0])
    # int8 quantization error bounded by sum of per-shard scales / 127
    scale_bound = np.abs(np.asarray(grads["w"])).max(axis=(1, 2)).sum() / 127 / D
    assert np.abs(got - want).max() <= scale_bound * 1.5 + 1e-6
    # error feedback: residual equals what quantization dropped
    q, s, r = ef_compress(grads["w"][0], residuals["w"][0])
    np.testing.assert_allclose(
        np.asarray(r), np.asarray(grads["w"][0]) - np.asarray(q, np.float32) * s,
        rtol=1e-5, atol=1e-6)


def case_ef_convergence():
    """Error feedback makes quantized SGD track exact SGD on a quadratic."""
    from repro.distributed.compression import ef_compress

    rng = np.random.default_rng(4)
    A = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
    Q = A @ A.T / 16 + jnp.eye(16)
    x_exact = jnp.ones((16,))
    x_q = jnp.ones((16,))
    r = jnp.zeros((16,))
    lr = 0.05
    for _ in range(200):
        g_exact = Q @ x_exact
        x_exact = x_exact - lr * g_exact
        g = Q @ x_q
        q, s, r = ef_compress(g, r)
        x_q = x_q - lr * (q.astype(jnp.float32) * s)
    assert float(jnp.linalg.norm(x_q)) < 1e-2, float(jnp.linalg.norm(x_q))


def case_moe_sharded():
    from repro.distributed.train import make_train_step
    from repro.models.registry import load_arch
    from repro.train import optim

    model = load_arch("mixtral-8x7b", smoke=True)
    params = model.init(jax.random.PRNGKey(0))
    opt = optim.init(params)
    batch = model.make_batch(jax.random.PRNGKey(1), 4, 16)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    build = make_train_step(model, mesh, optim.AdamWConfig(), donate=False)
    fn, _ = build(params, opt, batch)
    _, _, metrics = fn(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))


def case_debug_mesh():
    """Device-backed construction of the debug mesh at the forced count
    (run at 6 and 8 devices by the wrapper) — every factorization must
    build and keep data >= model."""
    from repro.launch.mesh import make_debug_mesh

    n = jax.device_count()
    mesh = make_debug_mesh(n)
    assert int(np.prod(list(mesh.shape.values()))) == n, mesh.shape
    assert mesh.shape["data"] >= mesh.shape["model"] >= 1, mesh.shape
    if n % 2 == 0:
        m2 = make_debug_mesh(n, multi_pod=True)
        assert int(np.prod(list(m2.shape.values()))) == n, m2.shape
        assert m2.shape["pod"] == 2


def case_prune_unit_parity():
    """Acceptance anchor 1 (prune): Gram accumulation data-parallel over
    8 calibration micro-batches (one per shard + one psum) + the fused
    group solves yield BITWISE-identical pruned weights to the serial
    single-device path, for every unit of the model."""
    from repro import api
    from repro.data import CalibConfig, CorpusConfig, MarkovCorpus, \
        calibration_batches

    model, params = _tiny_model()
    corpus = MarkovCorpus(CorpusConfig(vocab=model.cfg.vocab, seed=7))
    calib = calibration_batches(corpus, CalibConfig(num_sequences=32,
                                                    seq_len=32, batch_size=4))
    assert len(calib) == 8      # one micro-batch per data shard (bitwise
    # contract of the psum merge — see distributed/executor.py)
    solver = {"fista_iters": 5, "max_outer": 4}
    serial = api.PruneRecipe(sparsity="2:4", solver=solver)
    mesh = api.PruneRecipe(sparsity="2:4", solver=solver,
                           mesh={"devices": 8, "data_parallel": 8,
                                 "model_parallel": 1})
    p1, _, _ = api.prune(model, params, calib, serial)
    p8, _, s8 = api.prune(model, params, calib, mesh)
    assert s8["mesh"] == {"data": 8, "model": 1, "devices": 8}
    for (path, a), (_, b) in zip(jax.tree_util.tree_leaves_with_path(p1),
                                 jax.tree_util.tree_leaves_with_path(p8)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            f"{jax.tree_util.keystr(path)} diverged under the 8-device mesh"


def case_gram_init_seeding():
    """sharded_group_stats seeds SHARD 0's scan with the carried-in init
    (a group spanning several shape buckets), preserving the serial
    left-fold association ((init+g0)+g1)+... — bitwise, not just close."""
    from repro.core import gram as gram_lib
    from repro.distributed.executor import MeshConfig, MeshExecutor

    ex = MeshExecutor(MeshConfig(devices=8, data_parallel=8,
                                 model_parallel=1))
    rng = np.random.default_rng(0)
    n, B = 16, 8
    xd = jnp.asarray(rng.normal(size=(B, 32, n)).astype(np.float32))
    xp = xd + 0.1 * jnp.asarray(rng.normal(size=(B, 32, n)).astype(np.float32))
    wx = jnp.asarray(rng.normal(size=(B, 32, n)).astype(np.float32))
    # nonzero carried stats, as left by an earlier shape bucket
    init = {"op": gram_lib.accumulate(
        gram_lib.init_stats(n), xd[0] * 0.3, xp[0] * 0.3, wx[0] * 0.3)}

    def scan_fn(start, current, ws, caps, ps, **kw):
        def body(acc, xs):
            return {"op": gram_lib.accumulate(acc["op"], xs["xd"], xs["xp"],
                                              xs["wx"])}, None
        out, _ = jax.lax.scan(body, start, caps)
        return out

    serial = init
    for b in range(B):
        serial = {"op": gram_lib.accumulate(serial["op"], xd[b], xp[b], wx[b])}
    sharded = ex.sharded_group_stats(
        scan_fn, init, {}, {}, {"xd": xd, "xp": xp, "wx": wx},
        jnp.zeros((B,), jnp.float32))
    for a, b in zip(jax.tree_util.tree_leaves(serial),
                    jax.tree_util.tree_leaves(sharded)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            "carried-init sharded accumulation diverged from serial fold"


def case_rowfista_solver_parity():
    """FISTA with row-sharded inner solves (PruneRecipe mesh.model_parallel
    + solver.row_shard, the distributed/rowfista path) matches the host
    Algorithm-1 oracle: identical sparsity supports, weights to fp32
    round-off."""
    from repro import api
    from repro.data import CalibConfig, CorpusConfig, MarkovCorpus, \
        calibration_batches

    model, params = _tiny_model()
    corpus = MarkovCorpus(CorpusConfig(vocab=model.cfg.vocab, seed=7))
    calib = calibration_batches(corpus, CalibConfig(num_sequences=16,
                                                    seq_len=32, batch_size=4))
    solver = {"fista_iters": 5, "max_outer": 4, "outer_impl": "host"}
    host = api.PruneRecipe(sparsity="2:4", solver=solver)
    row = api.PruneRecipe(sparsity="2:4", solver=dict(solver, row_shard=True),
                          mesh={"devices": 8, "data_parallel": 2,
                                "model_parallel": 4})
    p1, _, _ = api.prune(model, params, calib, host)
    p2, _, _ = api.prune(model, params, calib, row)
    for (path, a), (_, b) in zip(jax.tree_util.tree_leaves_with_path(p1),
                                 jax.tree_util.tree_leaves_with_path(p2)):
        a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
        assert np.array_equal(a == 0, b == 0), \
            f"{jax.tree_util.keystr(path)}: sparsity support diverged"
        np.testing.assert_allclose(b, a, rtol=2e-5, atol=2e-5,
                                   err_msg=jax.tree_util.keystr(path))


def case_eval_parity():
    """Acceptance anchor 2 (eval): held-out perplexity and KL with the
    batches sharded over "data" are BITWISE-equal to the serial loop
    (whole batches stay device-local; per-batch scalars reduce on the
    host in batch order)."""
    from repro.data import CorpusConfig, MarkovCorpus
    from repro.distributed.executor import MeshConfig, MeshExecutor
    from repro.eval import EvalConfig, evaluate_perplexity, kl_divergence

    model, params = _tiny_model()
    pruned = _tiny_model(seed=1)[1]     # any second params for KL
    corpus = MarkovCorpus(CorpusConfig(vocab=model.cfg.vocab, seed=7))
    cfg = EvalConfig(num_batches=8, batch_size=4, seq_len=32, kl_batches=8)
    for dxm in ((8, 1), (4, 2)):
        ex = MeshExecutor(MeshConfig(devices=8, data_parallel=dxm[0],
                                     model_parallel=dxm[1]))
        serial = evaluate_perplexity(model, params, corpus, cfg)
        sharded = evaluate_perplexity(model, params, corpus, cfg, executor=ex)
        assert serial.ce_nats == sharded.ce_nats and serial.ppl == sharded.ppl, \
            (dxm, serial.ce_nats, sharded.ce_nats)
        ks = kl_divergence(model, params, pruned, corpus, cfg)
        kx = kl_divergence(model, params, pruned, corpus, cfg, executor=ex)
        assert ks.kl == kx.kl and ks.top1_agreement == kx.top1_agreement, dxm


def case_batcher_tp_parity():
    """Acceptance anchor 3 (serve): a multi-request continuous-batcher run
    with params TP-sharded over "model" (Megatron col/row rules) and the
    paged KV pool heads-sharded is TOKEN-IDENTICAL to the single-device
    batcher — dense and packed-2:4, greedy and temperature."""
    from repro.core.sparsity import round_tree_nm
    from repro.distributed.executor import MeshConfig, MeshExecutor
    from repro.serve import BatchConfig, ContinuousBatcher, synthetic_trace

    model, params = _tiny_model()
    pruned = round_tree_nm(params)
    bc = BatchConfig(slots=3, block_size=8, max_blocks_per_request=3,
                     num_blocks=24)
    ex = MeshExecutor(MeshConfig(devices=8, data_parallel=2, model_parallel=4))

    def run(weights, sparse, temp, executor):
        trace = synthetic_trace(5, rate=0.0, vocab=model.cfg.vocab,
                                prompt_len=(4, 10), max_new_tokens=6,
                                temperature=temp, seed=3)
        import dataclasses
        b = ContinuousBatcher(model, weights,
                              dataclasses.replace(bc, sparse=sparse),
                              executor=executor)
        return b, b.run(trace)

    for weights, sparse in ((params, "dense"), (pruned, "packed")):
        for temp in (0.0, 0.8):
            _, r1 = run(weights, sparse, temp, None)
            b2, r2 = run(weights, sparse, temp, ex)
            if sparse == "packed":
                assert b2.sparse_stats["mode"] == "packed"
            for a, b in zip(r1, r2):
                assert np.array_equal(a.tokens, b.tokens), \
                    (sparse, temp, a.id, a.tokens, b.tokens)


def case_batcher_chunked_prefix_tp_parity():
    """Chunked prefill + radix prefix cache under tensor parallelism:
    the chunk executable's scatter/gather runs over the heads-sharded
    paged pool, and cache-shared blocks are shared ACROSS the shards —
    tokens must stay identical to the single-device chunked batcher."""
    import dataclasses
    from repro.core.sparsity import round_tree_nm
    from repro.distributed.executor import MeshConfig, MeshExecutor
    from repro.serve import BatchConfig, ContinuousBatcher, Request

    model, params = _tiny_model()
    pruned = round_tree_nm(params)
    bc = BatchConfig(slots=3, block_size=8, max_blocks_per_request=3,
                     num_blocks=24, prefill_chunk=8, prefix_cache=True)
    ex = MeshExecutor(MeshConfig(devices=8, data_parallel=2, model_parallel=4))

    rng = np.random.default_rng(31)
    prefix = rng.integers(0, model.cfg.vocab, size=8).astype(np.int32)
    spec = [(4, 6), (9, 4), (2, 5), (7, 6)]

    def trace(temp):
        return [Request(id=i, prompt=np.concatenate(
                            [prefix, rng.integers(0, model.cfg.vocab, size=p)]
                        ).astype(np.int32),
                        max_new_tokens=n, temperature=temp)
                for i, (p, n) in enumerate(spec)]

    for weights, sparse in ((params, "dense"), (pruned, "packed")):
        for temp in (0.0, 0.8):
            reqs = trace(temp)
            runs = []
            for executor in (None, ex):
                b = ContinuousBatcher(model, weights,
                                      dataclasses.replace(bc, sparse=sparse),
                                      executor=executor)
                res = b.run([dataclasses.replace(r) for r in reqs])
                assert sum(r.prefix_hit_tokens for r in res) > 0, \
                    (sparse, temp, "no cache hits")
                runs.append(res)
            for a, b2 in zip(*runs):
                assert np.array_equal(a.tokens, b2.tokens), \
                    (sparse, temp, a.id, a.tokens, b2.tokens)


def case_paged_attn_shardmap():
    """The fused decode attention's shard_map boundary (models/common.
    _paged_attn_sharded): with the KV pools heads-sharded over "model"
    and the block table / positions replicated, the output equals the
    meshless local dispatch — and a packed o_proj forces the unsharded
    bypass (the projection must stay a dense() so GSPMD can psum the
    head-partials), same result either way."""
    from repro.kernels import ops as kops
    from repro.models import common
    from repro.utils import compat

    rng = np.random.default_rng(0)
    S, nkv, g, hd, NB, BS = 3, 4, 2, 8, 10, 4   # nkv % model_parallel == 0
    T = NB * BS
    q = jnp.asarray(rng.standard_normal((S, nkv * g, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((T, nkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((T, nkv, hd)), jnp.float32)
    lengths = [9, 4, 1]
    perm = rng.permutation(np.arange(1, NB))
    tables = np.zeros((S, 3), np.int32)         # trash-padded tails
    used = 0
    for s, L in enumerate(lengths):
        nb = -(-L // BS)
        tables[s, :nb] = perm[used:used + nb]
        used += nb
    tables = jnp.asarray(tables)
    pos = jnp.asarray(np.asarray(lengths, np.int32) - 1)
    active = jnp.asarray([True, True, False])
    args = (q, k, v, tables, pos, active, BS, 3, 0.0)

    wo_dense = rng.standard_normal((16, nkv * g * hd)).astype(np.float32)
    keep = rng.random((16, nkv * g * hd // 4, 4)).argsort(axis=-1) < 2
    wv, wm = kops.pack24(jnp.asarray(wo_dense * keep.reshape(wo_dense.shape)))
    wo = {"vals": wv, "meta": wm}

    want = common._paged_attn_sharded(*args)
    want_proj = common._paged_attn_sharded(*args, wo=wo)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with mesh, compat.set_mesh(mesh):
        got = common._paged_attn_sharded(*args)
        got_proj = common._paged_attn_sharded(*args, wo=wo)
    act = np.asarray(active)
    np.testing.assert_array_equal(np.asarray(got)[act], np.asarray(want)[act])
    np.testing.assert_array_equal(np.asarray(got_proj)[act],
                                  np.asarray(want_proj)[act])


def case_engine_tp_parity():
    """Engine.generate with TP-sharded params + caches decodes the same
    tokens as the single-device engine (greedy and temperature)."""
    from repro.distributed.executor import MeshConfig, MeshExecutor
    from repro.serve import Engine, ServeConfig

    model, params = _tiny_model()
    ex = MeshExecutor(MeshConfig(devices=8, data_parallel=2, model_parallel=4))
    prompt = jnp.asarray(
        np.random.default_rng(1).integers(0, model.cfg.vocab, size=(2, 6)),
        jnp.int32)
    for temp in (0.0, 0.7):
        cfg = ServeConfig(max_new_tokens=5, temperature=temp, cache_len=32)
        t1 = Engine(model, params, cfg).generate(prompt)
        t2 = Engine(model, params, cfg, executor=ex).generate(prompt)
        assert np.array_equal(t1, t2), (temp, t1, t2)


CASES = {k[5:]: v for k, v in list(globals().items()) if k.startswith("case_")}

if __name__ == "__main__":
    name = sys.argv[1]
    CASES[name]()
    print(f"CASE_OK {name}")
