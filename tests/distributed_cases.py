"""Multi-device test cases, run in a subprocess with 8 host devices.

Invoked by tests/test_distributed.py as
    XLA_FLAGS=--xla_force_host_platform_device_count=8 python distributed_cases.py <case>
Prints "CASE_OK <case>" on success.
"""
import os
import sys

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402


def case_rowfista():
    from repro.core import fista as fista_lib
    from repro.core import gram as gram_lib
    from repro.distributed.rowfista import sharded_solve

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    m, n = 32, 48
    a = rng.normal(size=(n, n)).astype(np.float32) * 0.3
    G = jnp.asarray(a @ a.T)
    B = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    y0 = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    L = gram_lib.max_eigval(G) * 1.01
    want, _ = fista_lib.solve(G, B, y0, 0.5, L=L, max_iters=50)
    got = sharded_solve(mesh, G, B, y0, 0.5, L, max_iters=50)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-5)


def case_gram_psum():
    from repro.core import gram as gram_lib
    from repro.distributed.rowfista import sharded_accumulate

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(1)
    p, n, m = 64, 16, 8
    xd = rng.normal(size=(p, n)).astype(np.float32)
    xp = xd + 0.1 * rng.normal(size=(p, n)).astype(np.float32)
    w = rng.normal(size=(m, n)).astype(np.float32)
    wx = xd @ w.T
    serial = gram_lib.accumulate(gram_lib.init_stats(n), xd, xp, wx)
    sharded = sharded_accumulate(mesh, gram_lib.init_stats(n),
                                 jnp.asarray(xd), jnp.asarray(xp), jnp.asarray(wx))
    np.testing.assert_allclose(np.asarray(sharded.G), np.asarray(serial.G),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(sharded.h), float(serial.h), rtol=1e-5)


def case_sharded_train():
    from repro.configs.opt125m_proxy import tiny_config
    from repro.distributed.train import make_train_step
    from repro.models.registry import model_def
    from repro.train import optim

    cfg = tiny_config().replace(num_layers=2, d_model=64, d_ff=128,
                                num_heads=4, num_kv_heads=4, vocab=128)
    model = model_def(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = optim.init(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 128, jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}

    ocfg = optim.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=4)
    # unsharded reference
    def step(params, opt_state, batch):
        def loss_fn(p):
            return model.loss(p, batch)
        (l, m), g = jax.value_and_grad(lambda p: loss_fn(p)[0], has_aux=False) \
            (params), None
        return l
    def ref_step(params, opt_state, batch):
        (l, m), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(params)
        p2, o2, om = optim.update(ocfg, grads, opt_state, params)
        return p2, o2, l

    p_ref, o_ref, l_ref = jax.jit(ref_step)(params, opt, batch)

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    build = make_train_step(model, mesh, ocfg, donate=False)
    fn, _ = build(params, opt, batch)
    p_sh, o_sh, metrics = fn(params, opt, batch)
    assert np.isclose(float(metrics["loss"]), float(l_ref), rtol=1e-4), \
        (float(metrics["loss"]), float(l_ref))
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(p_ref),
            jax.tree_util.tree_leaves_with_path(p_sh)):
        np.testing.assert_allclose(np.asarray(b, np.float32),
                                   np.asarray(a, np.float32),
                                   rtol=5e-3, atol=5e-4)


def case_pipeline():
    from repro.distributed.pipeline import (pipeline_apply, split_microbatches,
                                            merge_microbatches, stack_to_stages)

    mesh = jax.make_mesh((4, 2), ("pod", "data"))
    rng = np.random.default_rng(2)
    L, D = 8, 16
    ws = jnp.asarray(rng.normal(size=(L, D, D)).astype(np.float32) * 0.2)

    def layer(w, x):
        return jnp.tanh(x @ w)

    def plain(x):
        for i in range(L):
            x = layer(ws[i], x)
        return x

    def stage_fn(stage_params, x):
        def body(h, w):
            return layer(w, h), None
        out, _ = jax.lax.scan(body, x, stage_params)
        return out

    x = jnp.asarray(rng.normal(size=(12, D)).astype(np.float32))
    xs = split_microbatches(x, 6)
    stages = stack_to_stages(ws, 4)
    got = merge_microbatches(pipeline_apply(mesh, stage_fn, stages, xs))
    np.testing.assert_allclose(np.asarray(got), np.asarray(plain(x)),
                               rtol=1e-4, atol=1e-5)


def case_compression():
    from repro.distributed.compression import (compressed_allreduce,
                                               ef_compress, init_residuals)

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(3)
    D = 8
    grads = {"w": jnp.asarray(rng.normal(size=(D, 16, 8)).astype(np.float32))}
    residuals = init_residuals(grads)
    mean, new_r = compressed_allreduce(mesh, grads, residuals)
    want = np.asarray(grads["w"]).mean(axis=0)
    got = np.asarray(mean["w"][0])
    # int8 quantization error bounded by sum of per-shard scales / 127
    scale_bound = np.abs(np.asarray(grads["w"])).max(axis=(1, 2)).sum() / 127 / D
    assert np.abs(got - want).max() <= scale_bound * 1.5 + 1e-6
    # error feedback: residual equals what quantization dropped
    q, s, r = ef_compress(grads["w"][0], residuals["w"][0])
    np.testing.assert_allclose(
        np.asarray(r), np.asarray(grads["w"][0]) - np.asarray(q, np.float32) * s,
        rtol=1e-5, atol=1e-6)


def case_ef_convergence():
    """Error feedback makes quantized SGD track exact SGD on a quadratic."""
    from repro.distributed.compression import ef_compress

    rng = np.random.default_rng(4)
    A = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
    Q = A @ A.T / 16 + jnp.eye(16)
    x_exact = jnp.ones((16,))
    x_q = jnp.ones((16,))
    r = jnp.zeros((16,))
    lr = 0.05
    for _ in range(200):
        g_exact = Q @ x_exact
        x_exact = x_exact - lr * g_exact
        g = Q @ x_q
        q, s, r = ef_compress(g, r)
        x_q = x_q - lr * (q.astype(jnp.float32) * s)
    assert float(jnp.linalg.norm(x_q)) < 1e-2, float(jnp.linalg.norm(x_q))


def case_moe_sharded():
    from repro.distributed.train import make_train_step
    from repro.models.registry import load_arch
    from repro.train import optim

    model = load_arch("mixtral-8x7b", smoke=True)
    params = model.init(jax.random.PRNGKey(0))
    opt = optim.init(params)
    batch = model.make_batch(jax.random.PRNGKey(1), 4, 16)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    build = make_train_step(model, mesh, optim.AdamWConfig(), donate=False)
    fn, _ = build(params, opt, batch)
    _, _, metrics = fn(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))


CASES = {k[5:]: v for k, v in list(globals().items()) if k.startswith("case_")}

if __name__ == "__main__":
    name = sys.argv[1]
    CASES[name]()
    print(f"CASE_OK {name}")
