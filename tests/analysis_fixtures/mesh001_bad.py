"""MESH001 true-positive: shard_map without explicit check_rep (parsed
only, never imported)."""
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def build(mesh, local):
    return shard_map(local, mesh=mesh, in_specs=(P("x"),),
                     out_specs=P("x"))       # MESH001: implicit check_rep
