"""OBS001 true positives: recording at trace time and per token."""
import jax

from repro import obs


def make_step(reg):
    m = reg.histogram("step_s")

    def step(x):
        m.observe(1.0)                  # recording inside a jitted body
        return x * 2

    return jax.jit(step)


class Driver:
    def __init__(self, reg):
        self._m_tok = reg.counter("tokens")

    def drive(self, steps, reg):
        for _ in range(steps):
            self._m_tok.inc()           # counter bump per token
            with obs.span("tick"):      # span per token
                pass
            reg.histogram("d").observe(0.1)   # chained constructor record
