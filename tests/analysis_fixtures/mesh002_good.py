"""MESH002 true-negatives: logits replicated before sampling."""
import jax

from repro.serve import sampling


def good_categorical(executor, key, logits):
    logits = executor.replicate_logits(logits)
    return jax.random.categorical(key, logits)


def good_sample(executor, logits, keys, temperature):
    full = executor.replicate_logits(logits)
    scaled = full / 2.0                       # projections stay replicated
    return sampling.sample(scaled, keys, temperature)
