"""MESH002 true-positive: sampling from possibly-sharded logits without
replicate_logits domination (parsed only, never imported)."""
import jax

from repro.serve import sampling


def bad_categorical(key, logits):
    return jax.random.categorical(key, logits)        # MESH002


def bad_sample(logits, keys, temperature):
    return sampling.sample(logits, keys, temperature)  # MESH002
