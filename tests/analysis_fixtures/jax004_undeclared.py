"""JAX004 fixture: jit sites with and without declared budgets (the test
passes a budgets table containing only `declared_fn`)."""
import jax


@jax.jit
def declared_fn(x):
    return x * 2


@jax.jit
def undeclared_fn(x):                        # JAX004 under the test table
    return x + 1
