"""JAX003 true-negatives: device values stay on device through the
loop; host conversion happens once, after (parsed only)."""
import jax
import jax.numpy as jnp
import numpy as np


def _step(params, token):
    return token + 1


_step_fn = jax.jit(_step)


def decode_loop(params, token, n, prompts):
    out = [token]
    for t in range(n):
        token = _step_fn(params, token)
        out.append(token)                    # stays on device
        host = np.asarray(prompts[t])        # host data, not a device sync
    return np.asarray(jnp.concatenate(out)), host  # one post-loop transfer
