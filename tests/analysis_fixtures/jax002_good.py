"""JAX002 true-negatives: disciplined key handling (parsed only)."""
import jax


def split_spend(key, shape):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, shape)
    b = jax.random.normal(k2, shape)
    return a + b


def folded_loop(key, n):
    out = []
    for i in range(n):
        ki = jax.random.fold_in(key, i)       # key advanced per iteration
        out.append(jax.random.uniform(ki))
    return out


def resplit_between_uses(key, shape):
    a = jax.random.normal(key, shape)
    key, sub = jax.random.split(key)          # rebound: fresh key
    b = jax.random.normal(key, shape)
    return a + b + jax.random.normal(sub, shape)
