"""JAX002 true-positives: PRNG key reuse (parsed, never imported)."""
import jax


def double_spend(key, shape):
    a = jax.random.normal(key, shape)     # spends `key`
    b = jax.random.normal(key, shape)     # JAX002: reuse without split
    return a + b


def unfolded_loop(key, n):
    out = []
    for i in range(n):
        out.append(jax.random.uniform(key))   # JAX002: same key each iter
    return out
