"""MESH001 true-negative: the replication contract is explicit."""
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def build(mesh, local):
    return shard_map(local, mesh=mesh, in_specs=(P("x"),),
                     out_specs=P("x"), check_rep=False)
