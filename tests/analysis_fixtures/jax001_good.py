"""JAX001 true-negatives: static/detainted branching a jitted function
may legitimately do (parsed by the analyzer, never imported)."""
import functools

import jax
import jax.numpy as jnp

executor = None


@functools.partial(jax.jit, static_argnames=("causal",))
def fine(x, L, causal):
    if causal:                      # static arg
        x = x * 2
    if L is None:                   # `is None` detaints
        L = jnp.float32(1.0)
    if x.shape[0] > 4:              # shape projection detaints
        x = x[:4]
    n = x.shape[-1]
    assert n % 4 == 0               # static int derived from shape
    if executor is not None:        # closure/global, not a param
        x = x + 1
    return jnp.where(x > 0, x, -x) / L
