"""OBS001 true negatives: record around the loop, never inside it."""
import jax

from repro import obs


def make_step(reg):
    def step(x):
        return x * 2                    # jitted body stays recording-free

    return jax.jit(step)


class Driver:
    def __init__(self, reg):
        self._m_tok = reg.counter("tokens")
        self._m_drive = reg.histogram("drive_s")

    def drive(self, steps):
        n = 0
        with obs.span("drive"):         # ONE span around the whole loop
            for _ in range(steps):
                n += 1                  # loop body does the work only
        self._m_tok.inc(n)              # record once, after the loop
