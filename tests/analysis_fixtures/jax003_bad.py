"""JAX003 true-positive: per-iteration host sync on a device value in a
hot path (parsed with hot=("tests.analysis_fixtures",), never imported)."""
import jax
import numpy as np


def _step(params, token):
    return token + 1


_step_fn = jax.jit(_step)


def decode_loop(params, token, n):
    out = []
    for t in range(n):
        token = _step_fn(params, token)
        out.append(np.asarray(token))       # JAX003: sync every token
        last = float(token)                 # JAX003: and again
    return out, last
