"""JAX001 true-positive: Python control flow on traced values inside
jitted functions (this file is parsed by the analyzer, never imported)."""
import functools

import jax
import jax.numpy as jnp


@jax.jit
def branch_on_tracer(x):
    if x > 0:                       # JAX001: traced `if`
        return x
    return -x


@functools.partial(jax.jit, static_argnames=("iters",))
def loop_on_tracer(x, iters):
    r = x * 2
    while r.sum() > 1.0:            # JAX001: traced `while` (derived value)
        r = r * 0.5
    return r
