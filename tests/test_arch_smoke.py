"""Per-architecture smoke tests: reduced same-family configs on CPU.

For each assigned arch: instantiate, run one forward + one train step
(loss + grads + SGD update), assert output shapes and no NaNs; check the
fast scan path and the pruning-unit path produce identical logits.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ALL_ARCHS
from repro.models.registry import load_arch

ARCHS = ALL_ARCHS + ["opt125m-proxy"]


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            d = load_arch(arch, smoke=True)
            params = d.init(jax.random.PRNGKey(0))
            batch = d.make_batch(jax.random.PRNGKey(1), 2, 32)
            cache[arch] = (d, params, batch)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, built):
    d, params, batch = built(arch)
    logits = d.forward_logits(params, batch)
    B = batch["tokens"].shape[0]
    assert logits.shape[0] == B and logits.shape[-1] == d.cfg.vocab
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch, built):
    d, params, batch = built(arch)

    def loss_fn(p):
        l, _ = d.loss(p, batch)
        return l

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)) and float(loss) > 0
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all()) for g in flat), arch
    # one SGD step must change the loss deterministically
    new = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g.astype(p.dtype), params, grads)
    loss2 = float(loss_fn(new))
    assert np.isfinite(loss2)


@pytest.mark.parametrize("arch", ARCHS)
def test_unit_path_matches_fast_path(arch, built):
    """embed -> unit_apply* -> head == forward_logits (scan path)."""
    d, params, batch = built(arch)
    from repro.utils import tree as tree_lib

    state = d.embed(params, batch)
    for spec in d.units():
        node = tree_lib.get_path(params, spec.param_path)
        up = tree_lib.tree_index(node, spec.layer_index) if spec.stacked else node
        state = d.unit_apply(up, spec.layer_index, state)
        state = d.post_unit(params, spec.layer_index, state)
    got = d.head(params, state)
    want = d.forward_logits(params, batch)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_units_cover_all_linear_ops(arch, built):
    """Every capture key in the unit groups resolves to a 2-D param."""
    d, params, batch = built(arch)
    from repro.utils import tree as tree_lib

    for spec in d.units()[:2]:  # first two units suffice (layers are uniform)
        node = tree_lib.get_path(params, spec.param_path)
        up = tree_lib.tree_index(node, spec.layer_index) if spec.stacked else node
        for group in spec.groups:
            for key in group:
                w = tree_lib.get_path(up, _param_path_of(key))
                assert w.ndim in (2, 3), f"{arch}:{key} -> ndim {w.ndim}"


def _param_path_of(capture_key: str) -> str:
    """Capture keys map to param paths; MoE expert keys index stacked experts."""
    if "expert" in capture_key:
        # moe/expert3/gate -> moe/w_gate (stacked (E, in, out))
        parts = capture_key.split("/")
        return f"{parts[0]}/w_{parts[-1]}"
    if capture_key.startswith("moe/shared/"):
        return "moe/shared/" + capture_key.split("/")[-1]
    return capture_key


@pytest.mark.parametrize("arch", ["mamba2-780m", "recurrentgemma-9b",
                                  "stablelm-1.6b", "mixtral-8x7b"])
def test_serve_step_runs(arch, built):
    d, params, batch = built(arch)
    B = batch["tokens"].shape[0]
    extras = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
    state = d.init_serve_state(params, B, 16, extras if extras else None)
    token = batch["tokens"][:, :1]
    logits, state2 = d.serve_step(params, state, token, jnp.int32(0))
    assert logits.shape == (B, 1, d.cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    logits3, _ = d.serve_step(params, state2, token, jnp.int32(1))
    assert bool(jnp.isfinite(logits3.astype(jnp.float32)).all())


def test_whisper_serve_with_frames(built):
    d, params, batch = built("whisper-base")
    B = batch["tokens"].shape[0]
    state = d.init_serve_state(params, B, 16, {"frames": batch["frames"]})
    logits, state = d.serve_step(params, state, batch["tokens"][:, :1], jnp.int32(0))
    assert logits.shape == (B, 1, d.cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    """Full configs build & param_count lands in the arch's billed range."""
    d = load_arch(arch, smoke=False)
    n = d.cfg.param_count()
    expect = {
        "mamba2-780m": (0.5e9, 1.2e9), "internvl2-2b": (1.2e9, 2.6e9),
        "minicpm-2b": (2.0e9, 3.3e9), "stablelm-1.6b": (1.2e9, 2.1e9),
        "internlm2-20b": (17e9, 23e9), "granite-20b": (17e9, 23e9),
        "recurrentgemma-9b": (7e9, 12e9), "whisper-base": (0.05e9, 0.12e9),
        "qwen2-moe-a2.7b": (12e9, 17e9), "mixtral-8x7b": (42e9, 50e9),
        "opt125m-proxy": (0.1e9, 0.2e9),
    }[arch]
    assert expect[0] <= n <= expect[1], f"{arch}: {n/1e9:.2f}B params"
