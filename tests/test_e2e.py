"""End-to-end paper validation at CPU scale (DESIGN.md §6).

Trains a tiny member of the paper's own OPT family on the synthetic
corpus, prunes with every method, and checks the MECHANISM claims:
ordering, error-correction benefit, calibration-count flattening.
Module-scoped fixtures keep total wall time down.
"""
import numpy as np
import jax
import pytest

from repro.core.pruner import PrunerConfig
from repro.core.sequential import SequentialConfig, prune_model
from repro.core.sparsity import SparsitySpec
from repro.data import CalibConfig, CorpusConfig, MarkovCorpus, calibration_batches
from repro.models.registry import model_def
from repro.train import AdamWConfig, TrainConfig, Trainer, evaluate_ppl


@pytest.fixture(scope="module")
def trained():
    from repro.configs.opt125m_proxy import tiny_config
    cfg = tiny_config().replace(num_layers=3, d_model=96, d_ff=384,
                                num_heads=4, num_kv_heads=4, vocab=256)
    model = model_def(cfg)
    corpus = MarkovCorpus(CorpusConfig(vocab=cfg.vocab, seed=13))
    tr = Trainer(model, corpus, TrainConfig(
        steps=200, batch=16, seq=48, log_every=100,
        optim=AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=200)))
    tr.run()
    dense_ppl = evaluate_ppl(model, tr.params, corpus, 8, 48, 4)
    calib = calibration_batches(corpus, CalibConfig(num_sequences=16,
                                                    seq_len=48, batch_size=8))
    return model, tr.params, corpus, calib, dense_ppl


PRUNER = PrunerConfig(warm_start="sparsegpt", fista_iters=15, eps=1e-6,
                      patience=2, max_outer=8)


def _ppl(model, params, corpus):
    return evaluate_ppl(model, params, corpus, 8, 48, 4)


def test_dense_model_learned(trained):
    model, params, corpus, calib, dense_ppl = trained
    assert dense_ppl < 30, f"tiny model failed to learn (ppl {dense_ppl})"


def test_paper_ordering_50pct(trained):
    """Tables 1-2 claim: fista <= sparsegpt, wanda at 50% unstructured."""
    model, params, corpus, calib, dense_ppl = trained
    spec = SparsitySpec(ratio=0.5)
    ppl = {}
    for method in ("wanda", "sparsegpt", "fista"):
        cfg = SequentialConfig(spec=spec, method=method, pruner=PRUNER)
        pruned, _ = prune_model(model, params, calib, cfg)
        ppl[method] = _ppl(model, pruned, corpus)
    assert ppl["fista"] <= ppl["wanda"] * 1.02, ppl
    assert ppl["fista"] <= ppl["sparsegpt"] * 1.02, ppl
    assert ppl["fista"] < dense_ppl * 2.5, ppl


def test_error_correction_helps_end_to_end(trained):
    """Fig. 4a claim: intra-layer correction gives better (or equal) ppl.

    NOTE the per-operator rel_error is NOT comparable across modes — the
    'none' ablation measures error against dense inputs (an underestimate
    of the deployed error), while 'intra' measures the true pruned-path
    error.  The honest comparison is end-to-end perplexity.
    """
    model, params, corpus, calib, _ = trained
    spec = SparsitySpec(ratio=0.65)
    ppl = {}
    for mode in ("intra", "none"):
        cfg = SequentialConfig(spec=spec, method="fista", pruner=PRUNER,
                               error_correction=mode)
        pruned, _ = prune_model(model, params, calib, cfg)
        ppl[mode] = _ppl(model, pruned, corpus)
    assert ppl["intra"] <= ppl["none"] * 1.05, ppl


def test_more_calibration_helps_then_flattens(trained):
    """Fig. 4b claim: held-out ppl improves (or flattens) with more
    calibration data.  (In-sample rel_error is not comparable across
    calibration sets — ppl is the paper's metric.)"""
    model, params, corpus, _, _ = trained
    ppls = []
    for n in (2, 24):
        calib = calibration_batches(corpus, CalibConfig(
            num_sequences=n, seq_len=48, batch_size=min(8, n)))
        cfg = SequentialConfig(spec=SparsitySpec(ratio=0.6), method="fista",
                               pruner=PRUNER)
        pruned, _ = prune_model(model, params, calib, cfg)
        ppls.append(_ppl(model, pruned, corpus))
    assert ppls[-1] <= ppls[0] * 1.05, ppls


def test_24_sparsity_pipeline_and_packing(trained):
    """2:4 end-to-end: prune -> verify pattern -> pack -> identical decode."""
    import jax.numpy as jnp
    from repro.serve import Engine, ServeConfig, pack_tree
    model, params, corpus, calib, _ = trained
    cfg = SequentialConfig(spec=SparsitySpec(kind="nm", n=2, m=4),
                           method="fista", pruner=PRUNER)
    pruned, _ = prune_model(model, params, calib, cfg)
    packed, stats = pack_tree(pruned)
    assert stats["packed_ops"] >= model.cfg.num_layers * 4
    assert stats["packed_bytes"] / stats["dense_bytes"] == pytest.approx(0.625)
    prompt = jnp.asarray(next(corpus.batches(1, 8))[1][:, :8], jnp.int32)
    a = Engine(model, pruned, ServeConfig(max_new_tokens=6)).generate(prompt)
    b = Engine(model, packed, ServeConfig(max_new_tokens=6)).generate(prompt)
    np.testing.assert_array_equal(a, b)
