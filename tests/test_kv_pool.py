"""Hypothesis invariants for the paged KV block pool (serve/kv_cache.py).

Three layers of guarantee, each load-bearing for the serving stack:
the allocator never hands a block to two requests (aliasing would
cross-contaminate contexts), alloc/free round-trips conserve the pool,
and the paged read — scatter into blocks, gather back in position order
— is **bitwise** equal to a contiguous cache, including through the full
paged decode attention (``mha_decode_paged`` vs ``mha_decode``) on
ragged per-slot lengths.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional test dep (pip install '.[test]') — see pyproject.toml")
from hypothesis import given, settings, strategies as st

from repro.configs.opt125m_proxy import tiny_config
from repro.models import common
from repro.serve.kv_cache import (TRASH_BLOCK, BlockPool, PoolExhausted,
                                  apply_defrag, flat_slots, scatter_prefill,
                                  table_row)

NB, BS = 9, 4          # 8 allocatable blocks of 4 slots

# an op is (request_id, n_blocks) for alloc, or (request_id, 0) for free
OPS = st.lists(st.tuples(st.integers(0, 4), st.integers(0, 3)),
               min_size=1, max_size=40)


def _replay(ops):
    pool, model = BlockPool(NB, BS), {}
    for rid, n in ops:
        if n == 0:
            pool.free_request(rid)
            model.pop(rid, None)
        else:
            try:
                got = pool.alloc(rid, n)
            except PoolExhausted:
                assert n > pool.num_free
                continue
            assert len(got) == n
            model.setdefault(rid, []).extend(got)
    return pool, model


class TestAllocatorProps:
    @given(OPS)
    @settings(max_examples=60, deadline=None)
    def test_conservation_and_no_aliasing(self, ops):
        pool, model = _replay(ops)
        owned = [b for bl in model.values() for b in bl]
        # the allocator agrees with the independently tracked model
        for rid, bl in model.items():
            assert pool.blocks_of(rid) == bl
        # no aliasing: a block belongs to at most one request; trash never
        assert len(owned) == len(set(owned))
        assert TRASH_BLOCK not in owned
        # conservation: free + owned is exactly the allocatable set
        free = set(range(1, NB)) - set(owned)
        assert pool.num_free == len(free)
        assert pool.num_live == len(owned)

    @given(OPS)
    @settings(max_examples=30, deadline=None)
    def test_defrag_compacts_and_remaps(self, ops):
        pool, model = _replay(ops)
        before = {rid: list(bl) for rid, bl in model.items()}
        remap = pool.defrag()
        live = sorted(b for bl in pool._owned.values() for b in bl)
        # compacted: live blocks occupy the lowest ids, order preserved
        assert live == list(range(1, len(live) + 1))
        for rid, bl in before.items():
            assert pool.blocks_of(rid) == [remap.get(b, b) for b in bl]
        # a full pool round-trips: everything frees back
        for rid in list(model):
            pool.free_request(rid)
        assert pool.num_free == NB - 1 and pool.num_live == 0


LENGTHS = st.lists(st.integers(1, 2 * BS), min_size=1, max_size=3)


class TestPagedReadBitwise:
    @given(LENGTHS, st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_scatter_gather_roundtrip(self, lengths, seed):
        """Paged read == contiguous read, bitwise, on ragged lengths."""
        rng = np.random.default_rng(seed)
        L, nkv, hd = 2, 2, 4
        pool = BlockPool(NB, BS)
        state = {"k": jnp.zeros((L, (NB) * BS, nkv, hd), jnp.float32)}
        contig, tables = {}, {}
        for rid, P in enumerate(lengths):
            blocks = pool.alloc(rid, -(-P // BS))
            kv = rng.standard_normal((L, P, nkv, hd)).astype(np.float32)
            contig[rid], tables[rid] = kv, blocks
            state = scatter_prefill(state, {"k": jnp.asarray(kv)},
                                    flat_slots(blocks, P, BS))
        for rid, P in enumerate(lengths):
            row = table_row(tables[rid], max_blocks=2)
            j = np.arange(2 * BS)
            gather = row[j // BS] * BS + j % BS
            got = np.asarray(state["k"][:, gather])[:, :P]
            np.testing.assert_array_equal(got, contig[rid])

    @given(st.integers(0, 2 ** 31 - 1),
           st.lists(st.integers(0, 15), min_size=3, max_size=3))
    @settings(max_examples=10, deadline=None)
    def test_paged_attention_matches_contiguous(self, seed, positions):
        """mha_decode_paged == mha_decode bitwise, per slot, at ragged
        per-slot positions — the strongest form of the paged-read claim."""
        cfg = tiny_config().replace(num_layers=1, d_model=16, num_heads=2,
                                    num_kv_heads=2, vocab=32)
        p = common.attn_init(cfg, jax.random.PRNGKey(seed % 1000))
        rng = np.random.default_rng(seed)
        S, W, nkv, hd = 3, 16, 2, cfg.resolved_head_dim()
        x = jnp.asarray(rng.standard_normal((S, 1, cfg.d_model)), jnp.float32)
        ck = jnp.asarray(rng.standard_normal((S, W, nkv, hd)), jnp.float32)
        cv = jnp.asarray(rng.standard_normal((S, W, nkv, hd)), jnp.float32)
        pos = np.asarray(positions, np.int32)

        # paged side: one pool, every slot's W context rows scattered in
        pool = BlockPool(num_blocks=S * (W // BS) + 1, block_size=BS)
        state = {"k": jnp.zeros((1, (S * (W // BS) + 1) * BS, nkv, hd)),
                 "v": jnp.zeros((1, (S * (W // BS) + 1) * BS, nkv, hd))}
        gather = np.zeros((S, W), np.int32)
        for b in range(S):
            blocks = pool.alloc(b, W // BS)
            flat = flat_slots(blocks, W, BS)
            state = scatter_prefill(state, {"k": ck[b][None], "v": cv[b][None]},
                                    flat)
            gather[b] = flat
        write_idx = gather[np.arange(S), pos]
        out_paged, new_paged = common.mha_decode_paged(
            cfg, p, x, jnp.asarray(pos),
            {"k": state["k"][0], "v": state["v"][0]},
            jnp.asarray(write_idx), jnp.asarray(gather),
            jnp.ones((S,), bool))

        for b in range(S):
            out_solo, new_solo = common.mha_decode(
                cfg, p, x[b:b + 1], jnp.int32(pos[b]),
                {"k": ck[b:b + 1], "v": cv[b:b + 1]})
            np.testing.assert_array_equal(np.asarray(out_paged[b:b + 1]),
                                          np.asarray(out_solo))
            # the written K/V row matches too (cache side of the contract)
            np.testing.assert_array_equal(
                np.asarray(new_paged["k"][gather[b]])[pos[b]],
                np.asarray(new_solo["k"])[0, pos[b]])


class TestDefragDeviceMove:
    def test_apply_defrag_preserves_contents(self):
        rng = np.random.default_rng(0)
        L, nkv, hd = 2, 2, 4
        pool = BlockPool(NB, BS)
        state = {"k": jnp.zeros((L, NB * BS, nkv, hd), jnp.float32)}
        data = {}
        for rid, P in ((0, 6), (1, 4), (2, 7)):
            blocks = pool.alloc(rid, -(-P // BS))
            kv = rng.standard_normal((L, P, nkv, hd)).astype(np.float32)
            data[rid] = (kv, P)
            state = scatter_prefill(state, {"k": jnp.asarray(kv)},
                                    flat_slots(blocks, P, BS))
        pool.free_request(1)
        remap = pool.defrag()
        assert remap                      # request 2's blocks moved down
        state = apply_defrag(state, remap, NB, BS)
        for rid in (0, 2):
            kv, P = data[rid]
            flat = flat_slots(pool.blocks_of(rid), P, BS)
            np.testing.assert_array_equal(np.asarray(state["k"][:, flat]), kv)
