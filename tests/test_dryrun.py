"""Dry-run machinery tests (reduced device count via subprocess)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(*args, devices="16"):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get("PYTHONPATH", "")
    env["REPRO_DRYRUN_DEVICES"] = devices
    return subprocess.run([sys.executable, "-m", "repro.launch.dryrun", *args],
                          env=env, capture_output=True, text=True, timeout=900,
                          cwd=ROOT)


@pytest.mark.parametrize("arch,shape", [
    ("stablelm-1.6b", "train_4k"),
    ("mamba2-780m", "long_500k"),
    ("whisper-base", "decode_32k"),
])
def test_cell_compiles_both_meshes(arch, shape, tmp_path):
    out = _run_dryrun("--arch", arch, "--shape", shape, "--mesh", "both",
                      "--out", str(tmp_path))
    assert out.returncode == 0, out.stdout + out.stderr
    for mesh in ("single", "multi"):
        path = tmp_path / f"{mesh}__{arch}__{shape}.json"
        rec = json.loads(path.read_text())
        assert not rec["skipped"]
        assert rec["flops_per_device"] > 0
        assert rec["peak_bytes"] > 0
        assert rec["collectives"]["total_bytes"] >= 0


def test_skip_rule_applied(tmp_path):
    out = _run_dryrun("--arch", "granite-20b", "--shape", "long_500k",
                      "--mesh", "single", "--out", str(tmp_path))
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.loads((tmp_path / "single__granite-20b__long_500k.json").read_text())
    assert rec["skipped"] and "full-attention" in rec["reason"]


def test_extrapolated_costs_scale_with_depth(tmp_path):
    """The extrapolated flops must be ~L x the scan-mode record."""
    out = _run_dryrun("--arch", "stablelm-1.6b", "--shape", "prefill_32k",
                      "--mesh", "single", "--out", str(tmp_path / "scan"))
    assert out.returncode == 0, out.stderr
    out = _run_dryrun("--arch", "stablelm-1.6b", "--shape", "prefill_32k",
                      "--mesh", "single", "--out", str(tmp_path / "ex"),
                      "--extrapolate")
    assert out.returncode == 0, out.stderr
    scan = json.loads((tmp_path / "scan" / "single__stablelm-1.6b__prefill_32k.json").read_text())
    ex = json.loads((tmp_path / "ex" / "single__stablelm-1.6b__prefill_32k.json").read_text())
    ratio = ex["flops_per_device"] / scan["flops_per_device"]
    assert 8 <= ratio <= 40, ratio     # 24 layers, scan counted ~once


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ar = f32[4,64]{1,0} all-reduce(%x), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
  %ag = bf16[8,32]{1,0} all-gather(%y), replica_groups=[4,2]<=[8], dimensions={0}
  %cp = f32[16]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    out = collective_bytes(hlo, 8)
    assert out["counts"]["all-reduce"] == 1
    assert out["counts"]["all-gather"] == 1
    assert out["counts"]["collective-permute"] == 1
    # all-reduce: 2*(4-1)/4 * 4*64*4B = 1536
    assert abs(out["bytes_by_op"]["all-reduce"] - 1536.0) < 1e-6
    # all-gather: (2-1)/2 * 8*32*2B = 256
    assert abs(out["bytes_by_op"]["all-gather"] - 256.0) < 1e-6
    assert out["bytes_by_op"]["collective-permute"] == 64.0


def test_roofline_analyze():
    from repro.launch.roofline import analyze
    rec = {"skipped": False, "chips": 256, "flops_per_device": 197e12,
           "bytes_per_device": 819e9 * 2, "model_flops_global": 197e12 * 256,
           "collectives": {"total_bytes": 50e9 * 0.5},
           "moe_flops_deflator": 1.0, "peak_bytes": 1e9}
    a = analyze(rec)
    assert a["dominant"] == "memory"
    assert abs(a["compute_s"] - 1.0) < 1e-9
    assert abs(a["memory_s"] - 2.0) < 1e-9
    assert abs(a["roofline_fraction"] - 0.5) < 1e-9
