"""The static-analysis pass (repro.analysis, DESIGN.md §12).

Every rule family is pinned from both sides against the fixture corpus
in tests/analysis_fixtures/: the bad snippet must produce the finding
(true positive) AND the good twin must not (true negative) — no rule
lands without both.  The seeded-regression cases from the issue — an
out-of-bounds BlockSpec index map, sampling without replicate_logits,
a jit exceeding its trace budget — live here too, plus the dogfood
anchor: the merged tree itself is clean modulo the committed baseline,
and the trace-budget gates on Engine.generate / evaluate_perplexity
generalizing the batcher's ``_cache_size() == 1`` pin.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import core as acore
from repro.analysis import (rules_jax, rules_mesh, rules_obs, rules_pallas,
                            trace_budget)

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
FIXTURES = os.path.join(HERE, "analysis_fixtures")
HOT = ("tests.analysis_fixtures",)


def parse(name):
    return acore.ModuleCtx.parse(os.path.join(FIXTURES, name), root=ROOT)


def rules_of(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# JAX family
# ---------------------------------------------------------------------------
class TestJAX001TracedBranching:
    def test_bad_flags_if_and_while(self):
        found = rules_jax.check_traced_branching(parse("jax001_bad.py"))
        assert rules_of(found) == ["JAX001"]
        contexts = {f.context for f in found}
        assert "branch_on_tracer" in contexts
        assert "loop_on_tracer" in contexts

    def test_good_is_clean(self):
        assert rules_jax.check_traced_branching(parse("jax001_good.py")) == []


class TestJAX002KeyReuse:
    def test_bad_flags_reuse_and_unfolded_loop(self):
        found = rules_jax.check_key_reuse(parse("jax002_bad.py"))
        details = {f.detail for f in found}
        assert "reuse:key" in details
        assert "loop:key" in details

    def test_good_is_clean(self):
        assert rules_jax.check_key_reuse(parse("jax002_good.py")) == []


class TestJAX003HostSync:
    def test_bad_flags_per_iteration_syncs(self):
        found = rules_jax.check_host_syncs(parse("jax003_bad.py"), hot=HOT)
        assert len(found) == 2          # np.asarray + float, both in-loop
        assert rules_of(found) == ["JAX003"]

    def test_good_is_clean(self):
        assert rules_jax.check_host_syncs(parse("jax003_good.py"),
                                          hot=HOT) == []

    def test_out_of_hot_scope_is_ignored(self):
        assert rules_jax.check_host_syncs(parse("jax003_bad.py"),
                                          hot=("repro.serve.",)) == []


class TestJAX004DeclaredJits:
    def test_undeclared_site_flagged_declared_passes(self):
        ctx = parse("jax004_undeclared.py")
        budgets = {
            "tests.analysis_fixtures.jax004_undeclared:declared_fn": 1}
        found = rules_jax.check_jit_declared(ctx, budgets=budgets)
        assert [f.rule for f in found] == ["JAX004"]
        assert found[0].detail.endswith(":undeclared_fn")

    def test_all_declared_is_clean(self):
        ctx = parse("jax004_undeclared.py")
        budgets = {
            "tests.analysis_fixtures.jax004_undeclared:declared_fn": 1,
            "tests.analysis_fixtures.jax004_undeclared:undeclared_fn": 1}
        assert rules_jax.check_jit_declared(ctx, budgets=budgets) == []


class TestOBS001RecordingPlacement:
    def test_bad_flags_jit_and_loop_recordings(self):
        found = rules_obs.check_module(parse("obs001_bad.py"), hot=HOT)
        assert rules_of(found) == ["OBS001"]
        details = sorted(f.detail for f in found)
        # one recording traced into a jitted body...
        assert [d for d in details if d.startswith("jit:")] == \
            ["jit:m.observe(1.0)"]
        # ...and three per-iteration recordings in the hot loop: a bound
        # counter, a span-per-token, and a chained constructor record
        loops = [d for d in details if d.startswith("loop:")]
        assert len(loops) == 3
        assert "loop:self._m_tok.inc()" in loops
        assert any("obs.span" in d for d in loops)
        assert any("reg.histogram" in d for d in loops)

    def test_good_is_clean(self):
        assert rules_obs.check_module(parse("obs001_good.py"), hot=HOT) == []

    def test_loop_check_scoped_to_hot_paths(self):
        # outside the hot-path prefixes only the jit check applies
        found = rules_obs.check_module(parse("obs001_bad.py"),
                                       hot=("repro.serve.",))
        assert [f.detail for f in found] == ["jit:m.observe(1.0)"]

    def test_module_without_obs_imports_skipped(self):
        # recording-shaped calls don't fire without a repro.obs import
        assert rules_obs.check_module(parse("jax003_bad.py"), hot=HOT) == []


# ---------------------------------------------------------------------------
# MESH family
# ---------------------------------------------------------------------------
class TestMESH001CheckRep:
    def test_implicit_check_rep_flagged(self):
        found = rules_mesh.check_shard_map_check_rep(parse("mesh001_bad.py"))
        assert rules_of(found) == ["MESH001"]

    def test_explicit_check_rep_clean(self):
        assert rules_mesh.check_shard_map_check_rep(
            parse("mesh001_good.py")) == []


class TestMESH002ReplicateBeforeSample:
    def test_unreplicated_sampling_flagged(self):
        found = rules_mesh.check_sampling_replicated(parse("mesh002_bad.py"))
        assert rules_of(found) == ["MESH002"]
        assert {f.context for f in found} == {"bad_categorical",
                                              "bad_sample"}

    def test_replicated_sampling_clean(self):
        assert rules_mesh.check_sampling_replicated(
            parse("mesh002_good.py")) == []


# ---------------------------------------------------------------------------
# PAL family: seeded kernel regressions via the capture checker
# ---------------------------------------------------------------------------
def _case(build, budget=1 << 20):
    return rules_pallas.KernelCase("fixture", "fixture.py", "fn", "fn",
                                   budget, build)


def _run_fixture_kernel(index_map, block=(128, 128), budget=1 << 20):
    from jax.experimental import pallas as pl

    def build():
        def kern(x_ref, o_ref):
            o_ref[...] = x_ref[...]
        x = jnp.zeros((512, 128), jnp.float32)
        pl.pallas_call(
            kern,
            grid=(4,),
            in_specs=[pl.BlockSpec(block, index_map)],
            out_specs=pl.BlockSpec((128, 128), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((512, 128), jnp.float32),
        )(x)

    return rules_pallas.check_kernel_case(_case(build, budget))


class TestPallasChecker:
    def test_oob_index_map_flagged(self):
        # the seeded regression: corner i=3 maps to block 4 of 4
        found = _run_fixture_kernel(lambda i: (i + 1, 0))
        assert any(f.rule == "PAL001" and "out of bounds" in f.message
                   for f in found)

    def test_in_bounds_map_clean(self):
        assert _run_fixture_kernel(lambda i: (i, 0)) == []

    def test_misaligned_lane_flagged(self):
        found = _run_fixture_kernel(lambda i: (i, 0), block=(128, 64))
        assert any(f.rule == "PAL003" and f.detail == "in[0]:lane"
                   for f in found)

    def test_vmem_budget_enforced(self):
        found = _run_fixture_kernel(lambda i: (i, 0), budget=1024)
        assert [f.rule for f in found] == ["PAL002"]

    def test_oracle_gate_requires_ref_and_dispatch(self):
        case = _case(lambda: None)
        found = rules_pallas.check_oracle_gate(case, "nothing here")
        assert sorted(f.detail for f in found) == ["gate", "oracle"]
        assert rules_pallas.check_oracle_gate(
            case, "ops routes fn to ref.fn") == []

    def test_registered_kernels_are_clean(self):
        ops = os.path.join(ROOT, "src", "repro", "kernels", "ops.py")
        with open(ops) as f:
            src = f.read()
        for case in rules_pallas.KERNEL_CASES:
            assert rules_pallas.check_kernel_case(case) == [], case.name
            assert rules_pallas.check_oracle_gate(case, src) == [], case.name


# ---------------------------------------------------------------------------
# TRB family: runtime trace budgets
# ---------------------------------------------------------------------------
def _poly(x):
    return x * 2.0


KEY = f"{__name__}:_poly"


class TestTraceBudgetRuntime:
    def _record_three_shapes(self):
        with trace_budget.record_jits(prefixes=(__name__,)) as records:
            f = jax.jit(_poly)
            for n in (4, 8, 16):        # three shapes => three executables
                f(jnp.zeros((n,), jnp.float32))
        return records

    def test_exceeded_budget_flagged(self):
        records = self._record_three_shapes()
        found = trace_budget.check_records(records, {KEY: 1}, scenario="fix")
        assert [f.rule for f in found] == ["TRB002"]
        assert "3 executables" in found[0].message

    def test_within_budget_clean(self):
        records = self._record_three_shapes()
        assert trace_budget.check_records(records, {KEY: 4},
                                          scenario="fix") == []

    def test_undeclared_jit_flagged(self):
        records = self._record_three_shapes()
        found = trace_budget.check_records(records, {}, scenario="fix")
        assert [f.rule for f in found] == ["TRB001"]
        assert found[0].detail == KEY


class TestTraceBudgetGates:
    """Satellite: Engine.generate and evaluate_perplexity get the same
    retrace gate test_serve_stack.py:67 gives the batcher step."""

    def _tiny(self):
        from repro.configs.opt125m_proxy import tiny_config
        from repro.models.registry import model_def
        cfg = tiny_config().replace(num_layers=2, d_model=32, d_ff=64,
                                    num_heads=4, num_kv_heads=4, vocab=128)
        model = model_def(cfg)
        return model, model.init(jax.random.PRNGKey(0))

    def test_engine_generate_decodes_with_one_trace(self):
        from repro.serve import Engine, ServeConfig
        model, params = self._tiny()
        eng = Engine(model, params, ServeConfig(cache_len=32))
        rng = np.random.default_rng(0)
        for rid in range(3):
            prompt = rng.integers(0, 128, size=6).astype(np.int32)
            eng.generate(jnp.asarray(prompt[None, :]), max_new_tokens=4,
                         request_ids=[rid])
        assert eng._decode_fn._cache_size() == 1

    def test_evaluate_perplexity_reuses_ce_closure(self):
        from repro.data import CorpusConfig, MarkovCorpus
        from repro.eval import EvalConfig, evaluate_perplexity
        from repro.eval import perplexity
        model, params = self._tiny()
        corpus = MarkovCorpus(CorpusConfig(vocab=128, seed=5))
        ec = EvalConfig(num_batches=2, batch_size=2, seq_len=16,
                        kl_batches=1, budget_batches=1)
        a = evaluate_perplexity(model, params, corpus, ec)
        b = evaluate_perplexity(model, params, corpus, ec)
        assert a.ppl == b.ppl
        assert perplexity._ce_fn(model)._cache_size() == 1

    def test_trainer_evaluate_ppl_shares_the_eval_closure(self):
        from repro.data import CorpusConfig, MarkovCorpus
        from repro.eval import perplexity
        from repro.train.trainer import evaluate_ppl
        model, params = self._tiny()
        corpus = MarkovCorpus(CorpusConfig(vocab=128, seed=5))
        evaluate_ppl(model, params, corpus, batch=2, seq=16, n_batches=2)
        evaluate_ppl(model, params, corpus, batch=2, seq=16, n_batches=2)
        assert perplexity._ce_fn(model)._cache_size() == 1


# ---------------------------------------------------------------------------
# baseline mechanics + the dogfood anchor
# ---------------------------------------------------------------------------
class TestBaseline:
    def test_apply_baseline_splits_new_suppressed_stale(self):
        f1 = acore.Finding("R1", "a.py", 1, "f", "d1", "m")
        f2 = acore.Finding("R2", "b.py", 2, "g", "d2", "m")
        baseline = {f2.key: "accepted", "R9:gone.py::x": "stale entry"}
        new, suppressed, stale = acore.apply_baseline([f1, f2], baseline)
        assert new == [f1] and suppressed == [f2]
        assert stale == ["R9:gone.py::x"]

    def test_key_is_line_number_free(self):
        a = acore.Finding("R1", "a.py", 10, "f", "d", "m")
        b = acore.Finding("R1", "a.py", 99, "f", "d", "m")
        assert a.key == b.key

    def test_committed_baseline_loads(self):
        baseline = acore.load_baseline(
            os.path.join(ROOT, "analysis_baseline.json"))
        assert baseline  # non-empty: the two audited exceptions
        assert all(isinstance(v, str) and v for v in baseline.values())


class TestDogfood:
    """`python -m repro.analysis src/` must exit 0 on the merged tree."""

    def test_src_static_rules_clean_modulo_baseline(self, monkeypatch):
        monkeypatch.chdir(ROOT)
        from repro.analysis import run_source_rules
        findings = run_source_rules(["src"])
        baseline = acore.load_baseline("analysis_baseline.json")
        new, _, stale = acore.apply_baseline(findings, baseline)
        assert new == [], [f.format() for f in new]
        assert stale == [], stale
