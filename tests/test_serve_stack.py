"""Continuous-batching serving stack (serve/batcher.py + paged KV pool).

The load-bearing property is the token-identity anchor: for every
request in a mixed-length trace, continuous-batched output must equal a
solo static ``Engine.generate`` of the same prompt — dense and
2:4-packed, greedy and temperature-sampled — with the decode step jitted
exactly once (joins and retirements never re-specialize).  Plus the
engine regressions this PR fixes: position overrun validation and
per-request (not per-call) sampling PRNG.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import VLMConfig
from repro.configs.opt125m_proxy import tiny_config
from repro.core.sparsity import round_tree_nm, satisfies, SparsitySpec
from repro.models.registry import load_arch, model_def
from repro.serve import (BatchConfig, ContinuousBatcher, Engine, PoolExhausted,
                         Request, ServeConfig, synthetic_trace)

#: the anchor compares against a solo engine whose cache width equals the
#: batcher's per-request context (same masked-softmax reduction widths)
BC = BatchConfig(slots=3, block_size=8, max_blocks_per_request=4,
                 num_blocks=16)

#: chunked-prefill + prefix-cache variant of the same serving shape
import dataclasses as _dc
CBC = _dc.replace(BC, prefill_chunk=8, prefix_cache=True)


@pytest.fixture(scope="module")
def tiny():
    cfg = tiny_config().replace(num_layers=2, d_model=64, d_ff=128,
                                num_heads=4, num_kv_heads=4, vocab=128)
    model = model_def(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _mixed_requests(vocab, temperature=0.0):
    rng = np.random.default_rng(3)
    spec = [(5, 6), (9, 4), (3, 8), (12, 5), (7, 7)]   # 5 requests > 3 slots
    return [Request(id=i, prompt=rng.integers(0, vocab, size=p).astype(np.int32),
                    max_new_tokens=n, temperature=temperature)
            for i, (p, n) in enumerate(spec)]


def _solo_generate(model, params, r, temperature=0.0, sparse="auto"):
    eng = Engine(model, params, ServeConfig(cache_len=BC.context_len,
                                            temperature=temperature,
                                            sparse=sparse))
    return eng.generate(jnp.asarray(r.prompt[None, :]),
                        max_new_tokens=r.max_new_tokens,
                        request_ids=[r.id])[0]


class TestTokenIdentity:
    def test_dense_mixed_lengths(self, tiny):
        model, params = tiny
        reqs = _mixed_requests(model.cfg.vocab)
        batcher = ContinuousBatcher(model, params, BC)
        results = batcher.run(list(reqs))
        assert [r.id for r in results] == [r.id for r in reqs]
        for req, res in zip(reqs, results):
            np.testing.assert_array_equal(
                res.tokens, _solo_generate(model, params, req),
                err_msg=f"request {req.id} diverged from solo generate")
            assert res.reason == "length"
        # joins and retirements never re-specialized the decode step
        assert batcher._step_fn._cache_size() == 1

    def test_packed_24_checkpoint(self, tiny):
        model, params = tiny
        sparse = round_tree_nm(params)
        assert satisfies(np.asarray(sparse["layers"]["attn"]["wq"][0]).T,
                         SparsitySpec(kind="nm", n=2, m=4))
        reqs = _mixed_requests(model.cfg.vocab)
        batcher = ContinuousBatcher(model, sparse, BC)
        assert batcher.sparse_stats["mode"] == "packed"
        results = batcher.run(list(reqs))
        for req, res in zip(reqs, results):
            np.testing.assert_array_equal(
                res.tokens, _solo_generate(model, sparse, req))

    def test_temperature_sampling(self, tiny):
        model, params = tiny
        reqs = _mixed_requests(model.cfg.vocab, temperature=0.7)
        results = ContinuousBatcher(model, params, BC).run(list(reqs))
        for req, res in zip(reqs, results):
            np.testing.assert_array_equal(
                res.tokens, _solo_generate(model, params, req, temperature=0.7))

    def test_windowed_moe_arch(self):
        """Sliding-window + MoE (mixtral smoke, window=16): the paged
        window mask must agree with the solo engine past the window."""
        d = load_arch("mixtral-8x7b", smoke=True)
        params = d.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(11)
        reqs = [Request(id=i, prompt=rng.integers(0, d.cfg.vocab, size=p)
                        .astype(np.int32), max_new_tokens=n)
                for i, (p, n) in enumerate([(14, 8), (10, 6), (18, 8)])]
        results = ContinuousBatcher(d, params, BC).run(list(reqs))
        for req, res in zip(reqs, results):
            np.testing.assert_array_equal(
                res.tokens, _solo_generate(d, params, req),
                err_msg=f"windowed request {req.id} diverged")

    def test_eos_retires_early(self, tiny):
        model, params = tiny
        base = _mixed_requests(model.cfg.vocab)[0]
        solo = _solo_generate(model, params, base)
        eos = int(solo[2])                   # force an early EOS hit
        cut = int(np.argmax(solo == eos))    # first occurrence
        req = Request(id=base.id, prompt=base.prompt,
                      max_new_tokens=base.max_new_tokens, eos_id=eos)
        res = ContinuousBatcher(model, params, BC).run([req])[0]
        assert res.reason == "eos"
        np.testing.assert_array_equal(res.tokens, solo[:cut + 1])


class TestScheduler:
    def test_pool_pressure_serializes(self, tiny):
        """A pool too small for two concurrent requests still serves all
        of them correctly — pressure queues, it never corrupts."""
        model, params = tiny
        cfg = BatchConfig(slots=2, block_size=8, max_blocks_per_request=4,
                          num_blocks=4)      # 3 allocatable blocks
        reqs = _mixed_requests(model.cfg.vocab)[:3]   # each needs 2-3 blocks
        results = ContinuousBatcher(model, params, cfg).run(list(reqs))
        assert len(results) == 3
        for req, res in zip(reqs, results):
            np.testing.assert_array_equal(
                res.tokens, _solo_generate(model, params, req))

    def test_defrag_between_ticks(self, tiny):
        """Defragmenting the pool mid-flight (blocks move, tables rewrite)
        must not change a single token."""
        model, params = tiny
        reqs = _mixed_requests(model.cfg.vocab)
        batcher = ContinuousBatcher(model, params, BC)
        for r in reqs:
            batcher.submit(r)
        while batcher.queue or batcher._active.any():
            batcher._admit(0.0)
            if batcher._active.any():
                batcher._tick(0.0)
            batcher.defrag()
        for req in reqs:
            np.testing.assert_array_equal(
                batcher.results[req.id].tokens,
                _solo_generate(model, params, req))

    def test_submit_validation(self, tiny):
        model, params = tiny
        batcher = ContinuousBatcher(model, params, BC)
        long = Request(id=0, prompt=np.zeros(30, np.int32), max_new_tokens=8)
        with pytest.raises(ValueError, match="serving context|max_seq"):
            batcher.submit(long)           # 38 > context_len 32
        batcher.submit(Request(id=1, prompt=np.zeros(4, np.int32)))
        with pytest.raises(ValueError, match="duplicate"):
            batcher.submit(Request(id=1, prompt=np.zeros(4, np.int32)))
        small = ContinuousBatcher(model, params,
                                  BatchConfig(slots=1, block_size=4,
                                              max_blocks_per_request=8,
                                              num_blocks=3))
        with pytest.raises(PoolExhausted):
            small.submit(Request(id=2, prompt=np.zeros(8, np.int32),
                                 max_new_tokens=8))

    def test_unsupported_family_raises(self):
        d = load_arch("mamba2-780m", smoke=True)
        with pytest.raises(ValueError, match="paged serving"):
            ContinuousBatcher(d, params=None)
        # vlm inherits the transformer paged step but Request carries no
        # patch extras — silently serving text-only would be wrong output
        with pytest.raises(ValueError, match="patch"):
            ContinuousBatcher(load_arch("internvl2-2b", smoke=True),
                              params=None)

    def test_synthetic_trace_shape(self):
        trace = synthetic_trace(8, rate=4.0, vocab=64, prompt_len=(4, 6),
                                max_new_tokens=5, seed=1)
        assert [r.id for r in trace] == list(range(8))
        assert all(4 <= len(r.prompt) <= 6 for r in trace)
        arr = [r.arrival for r in trace]
        assert arr == sorted(arr) and arr[0] > 0


class TestPagedBitwise:
    def test_paged_attention_matches_contiguous(self):
        """Deterministic pin of the paged-read contract (the hypothesis
        sweep lives in tests/test_kv_pool.py, an optional dep): paged
        decode attention == contiguous-cache decode attention, bitwise,
        at ragged per-slot positions."""
        from repro.models import common
        from repro.serve.kv_cache import BlockPool, flat_slots, scatter_prefill
        cfg = tiny_config().replace(num_layers=1, d_model=16, num_heads=2,
                                    num_kv_heads=2, vocab=32)
        p = common.attn_init(cfg, jax.random.PRNGKey(2))
        rng = np.random.default_rng(2)
        S, W, BS, nkv, hd = 3, 16, 4, 2, cfg.resolved_head_dim()
        x = jnp.asarray(rng.standard_normal((S, 1, cfg.d_model)), jnp.float32)
        ck = jnp.asarray(rng.standard_normal((S, W, nkv, hd)), jnp.float32)
        cv = jnp.asarray(rng.standard_normal((S, W, nkv, hd)), jnp.float32)
        pos = np.asarray([0, 7, 15], np.int32)
        pool = BlockPool(num_blocks=S * (W // BS) + 1, block_size=BS)
        T = (S * (W // BS) + 1) * BS
        state = {"k": jnp.zeros((1, T, nkv, hd)), "v": jnp.zeros((1, T, nkv, hd))}
        gather = np.zeros((S, W), np.int32)
        for b in range(S):
            flat = flat_slots(pool.alloc(b, W // BS), W, BS)
            state = scatter_prefill(state, {"k": ck[b][None], "v": cv[b][None]},
                                    flat)
            gather[b] = flat
        out_paged, _ = common.mha_decode_paged(
            cfg, p, x, jnp.asarray(pos),
            {"k": state["k"][0], "v": state["v"][0]},
            jnp.asarray(gather[np.arange(S), pos]), jnp.asarray(gather),
            jnp.ones((S,), bool))
        for b in range(S):
            out_solo, _ = common.mha_decode(
                cfg, p, x[b:b + 1], jnp.int32(pos[b]),
                {"k": ck[b:b + 1], "v": cv[b:b + 1]})
            np.testing.assert_array_equal(np.asarray(out_paged[b:b + 1]),
                                          np.asarray(out_solo))


class TestDecodeImpl:
    """decode_impl="fused" (the default) vs "reference": token-identical
    end to end — the fused path either runs the block-table kernel (TPU)
    or an oracle that is bitwise the reference gather math (here)."""

    @pytest.mark.parametrize("temperature", [0.0, 0.7])
    def test_batcher_fused_equals_reference(self, tiny, temperature):
        import dataclasses
        model, params = tiny
        for weights in (params, round_tree_nm(params)):
            reqs = _mixed_requests(model.cfg.vocab, temperature=temperature)
            results = {}
            for impl in ("fused", "reference"):
                cfg = dataclasses.replace(BC, decode_impl=impl)
                results[impl] = ContinuousBatcher(model, weights, cfg) \
                    .run(list(reqs))
            for a, b in zip(results["fused"], results["reference"]):
                np.testing.assert_array_equal(
                    a.tokens, b.tokens,
                    err_msg=f"request {a.id} diverged across decode impls")

    def test_batcher_fused_under_defrag(self, tiny):
        """Mid-run defrag (blocks move, tables rewrite) under the fused
        impl: tokens still match the solo engine."""
        import dataclasses
        model, params = tiny
        reqs = _mixed_requests(model.cfg.vocab)
        batcher = ContinuousBatcher(
            model, params, dataclasses.replace(BC, decode_impl="fused"))
        for r in reqs:
            batcher.submit(r)
        while batcher.queue or batcher._active.any():
            batcher._admit(0.0)
            if batcher._active.any():
                batcher._tick(0.0)
            batcher.defrag()
        for req in reqs:
            np.testing.assert_array_equal(
                batcher.results[req.id].tokens,
                _solo_generate(model, params, req))

    def test_unknown_impl_rejected(self, tiny):
        import dataclasses
        model, params = tiny
        with pytest.raises(ValueError, match="decode_impl"):
            ContinuousBatcher(model, params,
                              dataclasses.replace(BC, decode_impl="turbo"))
        with pytest.raises(ValueError, match="decode_impl"):
            Engine(model, params, ServeConfig(decode_impl="turbo"))

    def test_engine_flag_forwarding(self, tiny):
        """The contiguous-cache engine serves via the reference path
        either way — the flag must validate and not change tokens."""
        model, params = tiny
        prompt = jnp.asarray(np.full((1, 5), 3, np.int32))
        outs = [Engine(model, params, ServeConfig(decode_impl=impl))
                .generate(prompt, max_new_tokens=6)
                for impl in ("fused", "reference")]
        np.testing.assert_array_equal(outs[0], outs[1])


class TestChunkedPrefixServing:
    """Chunked prefill + radix prefix cache + SLA scheduling: every path
    stays on the token-identity anchor, and the chunk executable — like
    the decode step — traces exactly once."""

    def _shared_prefix_requests(self, vocab, temperature=0.0):
        rng = np.random.default_rng(9)
        prefix = rng.integers(0, vocab, size=8).astype(np.int32)
        spec = [(5, 6), (9, 4), (3, 8), (12, 5), (7, 7)]
        return [Request(id=i, prompt=np.concatenate(
                            [prefix, rng.integers(0, vocab, size=p)]
                        ).astype(np.int32),
                        max_new_tokens=n, temperature=temperature)
                for i, (p, n) in enumerate(spec)]

    def _solo_chunked(self, model, params, r, temperature=0.0):
        eng = Engine(model, params,
                     ServeConfig(cache_len=CBC.context_len,
                                 temperature=temperature,
                                 block_size=CBC.block_size,
                                 prefill_chunk=CBC.prefill_chunk))
        return eng.generate(jnp.asarray(r.prompt[None, :]),
                            max_new_tokens=r.max_new_tokens,
                            request_ids=[r.id])[0]

    @pytest.mark.parametrize("temperature", [0.0, 0.7])
    def test_token_identity_dense_and_packed(self, tiny, temperature):
        model, params = tiny
        for weights in (params, round_tree_nm(params)):
            reqs = self._shared_prefix_requests(model.cfg.vocab, temperature)
            batcher = ContinuousBatcher(model, weights, CBC)
            results = batcher.run(list(reqs))
            for req, res in zip(reqs, results):
                np.testing.assert_array_equal(
                    res.tokens,
                    self._solo_chunked(model, weights, req, temperature),
                    err_msg=f"chunked request {req.id} diverged from solo")
            # shared prefixes actually hit once the first insert lands
            assert sum(r.prefix_hit_tokens for r in results) > 0
            # one chunk executable, one decode executable — joins, hits,
            # and ragged tails never re-specialize
            assert batcher._chunk_fn._cache_size() == 1
            assert batcher._step_fn._cache_size() == 1

    def test_cache_hit_bitwise_equals_cold(self, tiny):
        """The same (prompt, id) served cold and served from a warm
        cache must produce bitwise-identical tokens (temperature on, so
        a single logit ULP would flip the comparison)."""
        model, params = tiny
        rng = np.random.default_rng(17)
        prompt = rng.integers(0, model.cfg.vocab, size=13).astype(np.int32)
        warm = ContinuousBatcher(model, params, CBC)
        warm.run([Request(id=0, prompt=prompt, max_new_tokens=6,
                          temperature=0.7)])
        warm.run([Request(id=1, prompt=prompt, max_new_tokens=6,
                          temperature=0.7)])
        hit = warm.results[1]
        assert hit.prefix_hit_tokens == 8        # (13-1)//8 = 1 block
        cold = ContinuousBatcher(model, params, CBC).run(
            [Request(id=1, prompt=prompt, max_new_tokens=6,
                     temperature=0.7)])[0]
        assert cold.prefix_hit_tokens == 0
        np.testing.assert_array_equal(hit.tokens, cold.tokens)

    def test_preempt_then_resume_identity(self, tiny):
        """An urgent arrival preempts a lower-priority active request
        (K/V swapped to host, blocks freed); the victim resumes and
        still matches its solo run bitwise — temperature on, so the
        restored sampling index is load-bearing.  The batcher is driven
        manually until both low-priority requests have grown to fill the
        pool, so the urgent request always lands under pressure."""
        import dataclasses
        model, params = tiny
        cfg = dataclasses.replace(CBC, slots=2, num_blocks=7)
        rng = np.random.default_rng(23)
        mk = lambda i, prio: Request(
            id=i, prompt=rng.integers(0, model.cfg.vocab, size=12)
            .astype(np.int32), max_new_tokens=12, temperature=0.7,
            priority=prio)
        reqs = [mk(0, 5), mk(1, 5), mk(2, 0)]
        batcher = ContinuousBatcher(model, params, cfg)
        batcher.submit(reqs[0])
        batcher.submit(reqs[1])
        while batcher.queue or not batcher._active.all():
            batcher._admit(0.0)
            if not batcher._prefill_tick(0.0) and batcher._active.any():
                batcher._tick(0.0)
        while batcher.pool.num_free:   # decode until both grow to 3 blocks
            batcher._tick(0.0)
        batcher.submit(reqs[2])
        results = batcher.run()
        assert batcher.stats["preemptions"] >= 1
        assert batcher.stats["resumes"] == batcher.stats["preemptions"]
        assert any(r.preemptions > 0 for r in results)
        for req, res in zip(reqs, results):
            np.testing.assert_array_equal(
                res.tokens, self._solo_chunked(model, params, req, 0.7),
                err_msg=f"request {req.id} diverged through preemption")

    def test_defrag_with_cache_and_prefilling_slots(self, tiny):
        """Defrag on every tick while chunked prefills are in flight and
        the radix cache holds shared blocks: tables, prefill state, and
        trie node ids all remap — tokens unchanged."""
        model, params = tiny
        reqs = self._shared_prefix_requests(model.cfg.vocab)
        batcher = ContinuousBatcher(model, params, CBC)
        for r in reqs:
            batcher.submit(r)
        moved = 0
        while batcher.queue or batcher._busy():
            batcher._admit(0.0)
            batcher._prefill_tick(0.0)
            if batcher._active.any():
                batcher._tick(0.0)
            moved += batcher.defrag()
        for req in reqs:
            np.testing.assert_array_equal(
                batcher.results[req.id].tokens,
                self._solo_chunked(model, params, req),
                err_msg=f"request {req.id} diverged under defrag")
        assert moved > 0

    def test_config_validation(self, tiny):
        import dataclasses
        model, params = tiny
        with pytest.raises(ValueError, match="prefix_cache requires"):
            ContinuousBatcher(model, params,
                              dataclasses.replace(BC, prefix_cache=True))
        with pytest.raises(ValueError, match="prefill_chunk"):
            ContinuousBatcher(model, params,
                              dataclasses.replace(BC, prefill_chunk=0))
        with pytest.raises(ValueError, match="token prompts only"):
            Engine(model, params,
                   ServeConfig(prefill_chunk=8)).generate(
                jnp.zeros((1, 4), jnp.int32), max_new_tokens=2,
                extras={"patches": jnp.zeros((1, 2, 4))})

    def test_sla_queue_orders_by_priority_then_deadline(self, tiny):
        """One slot: completion order must follow (priority, deadline)
        for requests that all arrived before the first admission."""
        import dataclasses
        model, params = tiny
        cfg = dataclasses.replace(CBC, slots=1)
        rng = np.random.default_rng(29)
        mk = lambda i, prio, dl: Request(
            id=i, prompt=rng.integers(0, model.cfg.vocab, size=6)
            .astype(np.int32), max_new_tokens=3, priority=prio, deadline=dl)
        reqs = [mk(0, 2, None), mk(1, 0, 9.0), mk(2, 0, 1.0), mk(3, 1, None)]
        batcher = ContinuousBatcher(model, params, cfg)
        results = batcher.run(list(reqs))
        order = sorted(results, key=lambda r: r.first_token)
        assert [r.id for r in order] == [2, 1, 3, 0]


class TestEngineRegressions:
    def test_position_overrun_raises(self, tiny):
        """prompt_len + max_new_tokens > max_seq used to silently wrap or
        overrun positions; now it's a hard error before any compute."""
        model, params = tiny                # tiny max_seq = 128
        eng = Engine(model, params, ServeConfig())
        prompt = jnp.zeros((1, 100), jnp.int32)
        with pytest.raises(ValueError, match="max_seq"):
            eng.generate(prompt, max_new_tokens=64)
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.generate(prompt, max_new_tokens=0)
        with pytest.raises(ValueError, match="at least one token"):
            eng.generate(jnp.zeros((1, 0), jnp.int32))

    def test_whisper_overrun_raises(self):
        """whisper's learned pos_embed lookup silently clamped past
        max_seq — the validation must fire for prefill-less families too."""
        d = load_arch("whisper-base", smoke=True)
        eng = Engine(d, params=None)        # raises before touching params
        prompt = jnp.zeros((1, d.cfg.max_seq), jnp.int32)
        with pytest.raises(ValueError, match="max_seq"):
            eng.generate(prompt, max_new_tokens=8)

    def test_temperature_independent_of_batch(self, tiny):
        """Per-request folded PRNG: a sampled request's tokens depend on
        its request id, never on what else shares the batch."""
        model, params = tiny
        eng = Engine(model, params, ServeConfig(temperature=0.8))
        rng = np.random.default_rng(5)
        p = rng.integers(0, model.cfg.vocab, size=(2, 6)).astype(np.int32)
        both = eng.generate(jnp.asarray(p), max_new_tokens=6,
                            request_ids=[7, 9])
        for row, rid in ((0, 7), (1, 9)):
            solo = eng.generate(jnp.asarray(p[row:row + 1]), max_new_tokens=6,
                                request_ids=[rid])
            np.testing.assert_array_equal(both[row], solo[0])

    def test_identical_requests_identical_output(self, tiny):
        """Two submissions of the same (prompt, request id) sample the
        same tokens — regardless of engine call boundaries."""
        model, params = tiny
        eng = Engine(model, params, ServeConfig(temperature=1.0))
        prompt = jnp.asarray(np.full((1, 5), 3, np.int32))
        a = eng.generate(prompt, max_new_tokens=5, request_ids=[42])
        b = eng.generate(prompt, max_new_tokens=5, request_ids=[42])
        np.testing.assert_array_equal(a, b)

    def test_vlm_decode_positions(self):
        """Patch embeddings occupy positions: greedy decode must continue
        at position n_patches + P, matching the teacher-forced forward
        (the engine used to restart at P, wrapping the cache)."""
        cfg = load_arch("internvl2-2b", smoke=True).cfg.replace(
            num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
            d_ff=128, vocab=128, max_seq=128, vlm=VLMConfig(num_patches=6))
        d = model_def(cfg)
        params = d.init(jax.random.PRNGKey(0))
        batch = d.make_batch(jax.random.PRNGKey(1), 1, 14)
        prompt, patches = batch["tokens"], batch["patches"]
        n = 4
        gen = Engine(d, params, ServeConfig(max_new_tokens=n)).generate(
            prompt, extras={"patches": patches})
        seq = jnp.concatenate([prompt, jnp.asarray(gen)], axis=1)
        logits = d.forward_logits(params, {"tokens": seq, "patches": patches})
        start = patches.shape[1] + prompt.shape[1] - 1
        want = np.asarray(jnp.argmax(
            logits[:, start:start + n].astype(jnp.float32), axis=-1))
        np.testing.assert_array_equal(np.asarray(gen), want)
