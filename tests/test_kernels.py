"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs ref.py."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.sparsity import round_nm
from repro.kernels import fista_step, ref, round24, spmm24
from repro.kernels import ops


def rand(shape, dtype=np.float32, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.normal(size=shape) * scale).astype(np.float32)).astype(dtype)


class TestFistaStepKernel:
    @pytest.mark.parametrize("m,n", [(128, 128), (256, 384), (130, 200),
                                     (512, 256), (64, 512), (1, 128)])
    def test_matches_ref(self, m, n):
        y = rand((m, n), seed=1)
        a = rand((n, n), seed=2, scale=0.3)
        G = a @ a.T
        B = rand((m, n), seed=3)
        inv_l, thresh = 0.01, 0.005
        want = ref.fista_prox_step(y, G, B, inv_l, thresh)
        got = fista_step.fista_prox_step(y, G, B, inv_l, thresh,
                                         bm=128, bn=128, bk=128, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_blocksize_sweep(self):
        y, B = rand((256, 256), seed=1), rand((256, 256), seed=3)
        a = rand((256, 256), seed=2, scale=0.3)
        G = a @ a.T
        want = ref.fista_prox_step(y, G, B, 0.02, 0.01)
        for bm, bn, bk in [(64, 64, 64), (128, 256, 128), (256, 256, 256)]:
            got = fista_step.fista_prox_step(y, G, B, 0.02, 0.01,
                                             bm=bm, bn=bn, bk=bk, interpret=True)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-4, atol=1e-4)

    def test_solver_with_pallas_step(self):
        """End-to-end: fista.solve(step_impl='pallas') == step_impl='jnp'."""
        from repro.core import fista as fista_lib
        m, n = 128, 160
        y0 = rand((m, n), seed=5)
        a = rand((n, n), seed=6, scale=0.2)
        G = a @ a.T
        B = rand((m, n), seed=7)
        yj, kj = fista_lib.solve(G, B, y0, 0.5, max_iters=30, step_impl="jnp")
        yp, kp = fista_lib.solve(G, B, y0, 0.5, max_iters=30, step_impl="pallas")
        assert int(kj) == int(kp)
        np.testing.assert_allclose(np.asarray(yp), np.asarray(yj), rtol=1e-3, atol=1e-3)


class TestRound24Kernel:
    @pytest.mark.parametrize("m,n", [(8, 32), (128, 512), (100, 260),
                                     (256, 2048), (1, 64)])
    def test_matches_ref(self, m, n):
        w = rand((m, n), seed=m + n)
        want = ref.round24(w)
        got = round24.round24(w, bm=64, bn=128, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_matches_sparsity_module(self):
        w = rand((64, 256), seed=9)
        np.testing.assert_array_equal(
            np.asarray(ref.round24(w)), np.asarray(round_nm(w, 2, 4)))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        w = rand((32, 128), seed=4).astype(dtype)
        got = round24.round24(w, bm=32, bn=128, interpret=True)
        want = ref.round24(w)
        np.testing.assert_array_equal(np.asarray(got.astype(jnp.float32)),
                                      np.asarray(want.astype(jnp.float32)))

    def test_ties(self):
        w = jnp.ones((4, 16), jnp.float32)
        got = np.asarray(round24.round24(w, bm=4, bn=16, interpret=True))
        g = got.reshape(4, 4, 4)
        assert ((g != 0).sum(-1) == 2).all()
        assert (g[..., :2] == 1).all() and (g[..., 2:] == 0).all()


class TestPack24:
    def test_pack_unpack_roundtrip(self):
        w = ref.round24(rand((16, 64), seed=3))
        vals, meta = ref.pack24(w)
        assert vals.shape == (16, 32) and meta.shape == (16, 16) and meta.dtype == jnp.uint8
        back = ref.unpack24(vals, meta, 64)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(w))

    def test_pack_handles_sparser_groups(self):
        w = jnp.zeros((2, 8), jnp.float32).at[0, 1].set(3.0)  # 1 nz in group
        vals, meta = ref.pack24(w)
        back = ref.unpack24(vals, meta, 8)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(w))

    def test_storage_ratio(self):
        """Packed bytes = 0.625x dense bf16 bytes (the decode roofline win)."""
        m, n = 64, 256
        w = ref.round24(rand((m, n), seed=1)).astype(jnp.bfloat16)
        vals, meta = ref.pack24(w)
        packed = vals.size * 2 + meta.size * 1
        dense = m * n * 2
        assert packed / dense == 0.625


class TestSpmm24Kernel:
    @pytest.mark.parametrize("B,m,n", [(1, 128, 256), (8, 256, 512),
                                       (4, 130, 264), (128, 256, 256)])
    def test_matches_ref(self, B, m, n):
        w = ref.round24(rand((m, n), seed=m))
        vals, meta = ref.pack24(w)
        x = rand((B, n), seed=B + 1)
        want = ref.spmm24(x, vals, meta, n)
        got = spmm24.spmm24(x, vals, meta, n, bm=128, bk=128, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_equals_dense_matmul(self):
        m, n = 256, 512
        w = ref.round24(rand((m, n), seed=7))
        vals, meta = ref.pack24(w)
        x = rand((4, n), seed=8)
        got = spmm24.spmm24(x, vals, meta, n, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w.T),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        m, n = 128, 256
        w = ref.round24(rand((m, n), seed=2)).astype(dtype)
        vals, meta = ref.pack24(w)
        x = rand((2, n), seed=3).astype(dtype)
        got = spmm24.spmm24(x, vals, meta, n, interpret=True)
        want = ref.spmm24(x, vals, meta, n)
        np.testing.assert_allclose(
            np.asarray(got.astype(jnp.float32)), np.asarray(want.astype(jnp.float32)),
            rtol=2e-2, atol=2e-2)

    def test_lossless_fp32_pack_equals_dense_matmul(self):
        """The serve fast path packs in the weight's own dtype
        (pack_tree(dtype=None)); fp32 vals through the kernel must equal
        the dense matmul of the same masked weights exactly."""
        m, n = 256, 512
        w = ref.round24(rand((m, n), seed=11))          # fp32 2:4 weights
        vals, meta = ref.pack24(w)
        assert vals.dtype == jnp.float32
        x = rand((4, n), seed=12)
        got = spmm24.spmm24(x, vals, meta, n, bm=128, bk=128, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w.T),
                                   rtol=1e-6, atol=1e-5)
        # the ref path (ops dispatch for small problems) is exactly bitwise
        np.testing.assert_array_equal(np.asarray(ref.spmm24(x, vals, meta, n)),
                                      np.asarray(x @ w.T))


class TestOpsDispatch:
    def test_small_problems_use_ref(self):
        y = rand((4, 8)); G = rand((8, 8)); B = rand((4, 8))
        out = ops.fista_prox_step(y, G, B, 0.1, 0.01)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.fista_prox_step(y, G, B, 0.1, 0.01)))

    def test_large_problems_use_pallas(self):
        w = rand((128, 512), seed=1)
        np.testing.assert_array_equal(np.asarray(ops.round24(w)),
                                      np.asarray(ref.round24(w)))
