"""Eval subsystem (repro/eval) + sparse serving fast path.

Covers the ISSUE-3 acceptance criteria: perplexity/KL/error-budget on
pruned checkpoints, and the serve engine's 2:4 fast path producing
fp32-bitwise-equal logits vs. dense matmul of the same masked weights.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.core.sparsity import SparsitySpec, round_nm, satisfies
from repro.data import CalibConfig, CorpusConfig, MarkovCorpus, calibration_batches
from repro.eval import (EvalConfig, error_budget_report, evaluate_perplexity,
                        kl_divergence, quality_report)
from repro.models.registry import model_def
from repro.serve import Engine, ServeConfig, pack_tree
from repro.serve.packed import count_packed
from repro.utils.tree import tree_map_with_path


def tiny_setup(seed=0, layers=2, d_model=32, d_ff=64, vocab=128):
    from repro.configs.opt125m_proxy import tiny_config
    cfg = tiny_config().replace(num_layers=layers, d_model=d_model,
                                d_ff=d_ff, num_heads=4, num_kv_heads=4,
                                vocab=vocab)
    model = model_def(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    corpus = MarkovCorpus(CorpusConfig(vocab=cfg.vocab, seed=5))
    return model, params, corpus


EC = EvalConfig(num_batches=3, batch_size=2, seq_len=16, kl_batches=2,
                budget_batches=1)


def mask_24(params):
    """Magnitude-2:4 every layer weight (a fake pruned checkpoint)."""

    def visit(path, w):
        if (hasattr(w, "ndim") and w.ndim == 3 and "embed" not in path
                and w.shape[-2] % 4 == 0):
            return jax.vmap(lambda x: round_nm(x.T, 2, 4).T)(w)
        return w

    return tree_map_with_path(visit, params)


class TestPerplexity:
    def test_deterministic_and_positive(self):
        model, params, corpus = tiny_setup()
        a = evaluate_perplexity(model, params, corpus, EC)
        b = evaluate_perplexity(model, params, corpus, EC)
        assert a.ppl == b.ppl > 1.0
        assert a.tokens == EC.num_batches * EC.batch_size * EC.seq_len
        assert np.isclose(a.ppl, np.exp(a.ce_nats))

    def test_split_streams_differ(self):
        """The test split is a different held-out stream than valid."""
        model, params, corpus = tiny_setup()
        t = evaluate_perplexity(model, params, corpus, EC)
        import dataclasses
        v = evaluate_perplexity(model, params, corpus,
                                dataclasses.replace(EC, split="valid"))
        assert t.ppl != v.ppl        # distinct seed streams

    def test_rejects_unknown_split(self):
        with pytest.raises(ValueError, match="split"):
            EvalConfig(split="train-ish")


class TestDivergence:
    def test_identical_params_zero_kl(self):
        model, params, corpus = tiny_setup()
        d = kl_divergence(model, params, params, corpus, EC)
        assert d.kl == 0.0 and d.top1_agreement == 1.0

    def test_damaged_params_positive_kl(self):
        model, params, corpus = tiny_setup()
        damaged = mask_24(params)
        d = kl_divergence(model, params, damaged, corpus, EC)
        assert np.isfinite(d.kl) and d.kl > 0.0
        assert 0.0 <= d.top1_agreement <= 1.0


class TestErrorBudget:
    def test_pruned_run_within_budget(self):
        """A real intra-corrected prune run: every unit's measured output
        error stays within slack x the sum of its solver errors."""
        model, params, corpus = tiny_setup()
        calib = calibration_batches(corpus, CalibConfig(
            num_sequences=4, seq_len=16, batch_size=2))
        recipe = api.PruneRecipe(
            method="fista", sparsity="2:4",
            solver={"fista_iters": 8, "max_outer": 6, "patience": 2,
                    "eps": 1e-4},
            scheduler={"workers": 1})
        pruned, reports, _ = api.prune(model, params, calib, recipe)
        rows = error_budget_report(model, params, pruned, corpus, EC,
                                   reports=reports)
        assert len(rows) == len(model.units())
        for r in rows:
            assert np.isfinite(r.output_rel_err) and r.output_rel_err > 0
            assert r.ops > 0 and np.isfinite(r.op_budget)
            assert r.within_budget, \
                f"{r.unit}: err {r.output_rel_err} vs budget {r.op_budget}"

    def test_dict_reports_accepted(self):
        """Checkpoint extras persist reports as dicts — same audit."""
        model, params, corpus = tiny_setup(layers=1)
        reports = [{"unit": "layer000", "rel_error": 10.0}]  # huge budget
        rows = error_budget_report(model, params, mask_24(params), corpus,
                                   EC, reports=reports)
        assert rows[0].ops == 1 and rows[0].op_budget == 10.0
        assert rows[0].within_budget

    def test_no_reports_still_measures(self):
        model, params, corpus = tiny_setup(layers=1)
        rows = error_budget_report(model, params, mask_24(params), corpus, EC)
        assert np.isnan(rows[0].op_budget) and rows[0].within_budget
        assert rows[0].output_rel_err > 0


class TestQualityReport:
    def test_aggregate_and_json(self, tmp_path):
        import json
        model, params, corpus = tiny_setup(layers=1)
        q = quality_report(model, mask_24(params), corpus, EC,
                           dense_params=params, meta={"method": "magnitude"})
        assert q.ppl >= q.dense_ppl * 0.5 and q.ppl_ratio == q.ppl / q.dense_ppl
        assert q.kl > 0 and q.error_budget is not None
        path = tmp_path / "q.json"
        q.to_json(str(path))
        back = json.loads(path.read_text())
        assert back["meta"]["method"] == "magnitude"
        assert back["ppl"] == q.ppl


class TestResolveRun:
    def test_recipe_override_merges_eval_only(self, tmp_path):
        """--recipe on a prune run overrides ONLY the eval section; the
        stored recipe stays the source of truth for what was pruned."""
        from repro.launch.evaluate import resolve_run
        from repro.launch.prune import save_run_models
        model, params, _ = tiny_setup(layers=1)
        stored = api.PruneRecipe(method="admm", sparsity="2:4")
        save_run_models(str(tmp_path), stored, params, params, [],
                        corpus_seed=7, smoke=True)

        run = resolve_run(str(tmp_path))
        assert run["kind"] == "prune" and run["corpus_seed"] == 7
        assert run["recipe"].method == "admm"

        override = tmp_path / "eval_only.json"
        override.write_text('{"eval": {"num_batches": 2}}')
        run = resolve_run(str(tmp_path), str(override))
        assert run["recipe"].method == "admm"          # identity preserved
        assert run["recipe"].sparsity == "2:4"
        assert run["recipe"].eval_config().num_batches == 2   # eval overridden


class TestSparseServePath:
    def test_auto_detects_and_is_bitwise_equal(self):
        """Acceptance: the spmm24 fast path's logits are fp32-bitwise-equal
        to dense matmul on the same masked weights (lossless packing)."""
        model, params, corpus = tiny_setup(layers=2, d_model=64, d_ff=128,
                                           vocab=256)
        masked = mask_24(params)
        cfg = ServeConfig(max_new_tokens=6, cache_len=32)
        import dataclasses
        eng_dense = Engine(model, masked,
                           dataclasses.replace(cfg, sparse="dense"))
        eng_auto = Engine(model, masked, cfg)   # sparse="auto" default
        assert eng_auto.sparse_stats["mode"] == "packed"
        assert eng_auto.sparse_stats["packed_ops"] > 0
        assert eng_dense.sparse_stats["mode"] == "dense"

        prompt = jnp.asarray(np.arange(2 * 8).reshape(2, 8) % 256, jnp.int32)
        # bitwise logits: prefill and one decode step
        ld, st_d = model.prefill(masked, prompt, 32, None)
        la, st_a = model.prefill(eng_auto.params, prompt, 32, None)
        np.testing.assert_array_equal(np.asarray(ld, np.float32),
                                      np.asarray(la, np.float32))
        tok = jnp.zeros((2, 1), jnp.int32)
        gd, _ = jax.jit(model.serve_step)(masked, st_d, tok, jnp.int32(8))
        ga, _ = jax.jit(model.serve_step)(eng_auto.params, st_a, tok,
                                          jnp.int32(8))
        np.testing.assert_array_equal(np.asarray(gd, np.float32),
                                      np.asarray(ga, np.float32))
        # and therefore identical greedy generations
        np.testing.assert_array_equal(eng_dense.generate(prompt),
                                      eng_auto.generate(prompt))

    def test_dense_params_stay_dense(self):
        model, params, _ = tiny_setup()
        eng = Engine(model, params, ServeConfig(max_new_tokens=4))
        assert eng.sparse_stats == {"mode": "dense", "packed_ops": 0}
        assert count_packed(eng.params) == 0

    def test_dense_fallback_unpacks(self):
        model, params, _ = tiny_setup(layers=1, d_model=64, d_ff=128,
                                      vocab=256)
        masked = mask_24(params)
        packed, stats = pack_tree(masked, dtype=None)
        assert stats["packed_ops"] == count_packed(packed) > 0
        eng = Engine(model, packed, ServeConfig(sparse="dense"))
        assert eng.sparse_stats["mode"] == "dense"
        assert count_packed(eng.params) == 0
        # unpack is exact for dtype=None packing
        for spec in model.units():
            from repro.core import sequential as seq_lib
            up = seq_lib._unit_params_of(eng.params, spec)
            uw = seq_lib._unit_params_of(masked, spec)
            for group in spec.groups:
                for key in group:
                    np.testing.assert_array_equal(
                        np.asarray(seq_lib.get_weight(up, key)),
                        np.asarray(seq_lib.get_weight(uw, key)))

    def test_packed_mode_requires_sparse_checkpoint(self):
        model, params, _ = tiny_setup()
        with pytest.raises(ValueError, match="2:4"):
            Engine(model, params, ServeConfig(sparse="packed"))
        with pytest.raises(ValueError, match="sparse mode"):
            Engine(model, params, ServeConfig(sparse="fast"))

    def test_pruned_checkpoint_satisfies_spec_after_pack_cycle(self):
        model, params, _ = tiny_setup(layers=1, d_model=64, d_ff=128,
                                      vocab=256)
        masked = mask_24(params)
        eng = Engine(model, masked, ServeConfig())
        from repro.serve.packed import unpack_tree
        spec = SparsitySpec(kind="nm", n=2, m=4)
        from repro.core import sequential as seq_lib
        up = seq_lib._unit_params_of(unpack_tree(eng.params), model.units()[0])
        for group in model.units()[0].groups:
            for key in group:
                w = seq_lib.get_weight(up, key)
                assert satisfies(np.asarray(w, np.float32).T, spec)
