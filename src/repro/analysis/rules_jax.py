"""AST-based JAX lint (rule family JAX, DESIGN.md §12).

JAX001  Python ``if``/``while`` branching on a traced value inside a
        jitted function.  Tracers have no stable truth value — the
        branch bakes one arm into the executable (or raises a
        ConcretizationError).  ``jnp.where`` / ``lax.cond`` instead.
JAX002  PRNG key reuse: the same key variable feeds two samplers
        without an intervening ``split``/``fold_in``, or a loop body
        consumes a key it never advances.  Reused keys silently
        correlate draws.
JAX003  Host sync on a device value in a serving hot path: ``.item()``,
        ``float()``/``int()`` or ``np.asarray`` applied to the result of
        a jitted step forces a blocking device->host transfer per token
        (the PR 6 "packed slower than dense" class).
JAX004  ``jax.jit`` site without a declared cache owner: every jit in
        the repo must have a trace budget registered in
        ``trace_budget.TRACE_BUDGETS`` (the PR 6 executable-accumulation
        segfault class).

All three static rules share one scope walker that assigns qualnames
(``Cls.meth.<locals>.inner``) matching ``fn.__qualname__`` at runtime,
so the static jit inventory and the ``--runtime`` recorder key the same
table.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .core import Finding, ModuleCtx, assigned_names, dotted_name, unparse

# modules whose functions are serving hot paths for JAX003 (prefix match)
HOT_PATH_PREFIXES: Tuple[str, ...] = ("repro.serve.",)

# jax.random consumers that *advance* a key rather than spend it
_KEY_MAKERS = {"PRNGKey", "key", "split", "fold_in", "wrap_key_data",
               "clone", "key_data"}
_DETAINT_ATTRS = {"shape", "ndim", "dtype", "size"}
_DETAINT_CALLS = {"len", "isinstance", "hasattr", "getattr", "type"}
_SYNC_CALLS = {"float", "int", "bool"}


def _qualname(stack: Sequence[ast.AST]) -> str:
    """Runtime-compatible qualname for a nesting stack of class/function
    nodes (functions nested in functions get ``.<locals>.``)."""
    parts: List[str] = []
    prev_fn = False
    for node in stack:
        name = getattr(node, "name", "")
        if prev_fn:
            parts.append("<locals>")
        parts.append(name)
        prev_fn = isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
    return ".".join(parts)


def _is_jax_jit(node: ast.AST) -> bool:
    return dotted_name(node) in ("jax.jit", "jit")


def _jit_call_of(node: ast.AST) -> Optional[ast.Call]:
    """The ``jax.jit(...)`` / ``functools.partial(jax.jit, ...)`` Call if
    ``node`` is one, else None."""
    if not isinstance(node, ast.Call):
        return None
    if _is_jax_jit(node.func):
        return node
    if dotted_name(node.func) in ("functools.partial", "partial"):
        if node.args and _is_jax_jit(node.args[0]):
            return node
    return None


def _static_params(jit_call: ast.Call, fn: Optional[ast.AST]) -> Set[str]:
    """Parameter names excluded from tracing via static_argnames/nums."""
    out: Set[str] = set()
    posnums: List[int] = []
    for kw in jit_call.keywords:
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    out.add(n.value)
        elif kw.arg == "static_argnums":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    posnums.append(n.value)
    if posnums and isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        names = [a.arg for a in fn.args.posonlyargs + fn.args.args]
        for i in posnums:
            if 0 <= i < len(names):
                out.add(names[i])
    return out


class JitSite:
    """One ``jax.jit`` occurrence: a decorated def, or a call assigned /
    passed somewhere."""

    def __init__(self, key: str, line: int, context: str,
                 fn: Optional[ast.FunctionDef], jit_call: ast.Call):
        self.key = key            # "module:qualname" budget-table key
        self.line = line
        self.context = context    # enclosing qualname for reporting
        self.fn = fn              # the jitted FunctionDef when resolvable
        self.jit_call = jit_call


class _ScopeWalker(ast.NodeVisitor):
    """Collects jit sites and local function defs with runtime qualnames."""

    def __init__(self, modname: str) -> None:
        self.modname = modname
        self.stack: List[ast.AST] = []
        self.sites: List[JitSite] = []
        # qualname -> FunctionDef for "jax.jit(name)" resolution,
        # per enclosing scope (keyed by scope qualname)
        self.defs_in_scope: Dict[str, Dict[str, ast.FunctionDef]] = {"": {}}

    def _scope(self) -> str:
        return _qualname(self.stack)

    def _record(self, fn: Optional[ast.FunctionDef], jit_call: ast.Call,
                line: int, fallback: str) -> None:
        if fn is not None:
            qn = fn._analysis_qualname  # type: ignore[attr-defined]
        else:
            qn = fallback
        self.sites.append(JitSite(f"{self.modname}:{qn}", line,
                                  self._scope(), fn, jit_call))

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.stack.append(node)
        # pre-register methods so jax.jit(self.method) inside an earlier
        # method (e.g. __init__) resolves regardless of definition order
        scope = self._scope()
        for child in node.body:
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                child._analysis_qualname = _qualname(  # type: ignore[union-attr]
                    self.stack + [child])
                self.defs_in_scope.setdefault(scope, {})[child.name] = child
        self.generic_visit(node)
        self.stack.pop()

    def _visit_fn(self, node: ast.FunctionDef) -> None:
        qn = _qualname(self.stack + [node])
        node._analysis_qualname = qn  # type: ignore[attr-defined]
        self.defs_in_scope.setdefault(self._scope(), {})[node.name] = node
        for dec in node.decorator_list:
            jc = _jit_call_of(dec)
            if jc is not None:
                self._record(node, jc, node.lineno, qn)
            elif _is_jax_jit(dec):
                # bare @jax.jit decorator (no call)
                self._record(node, ast.Call(func=dec, args=[], keywords=[]),
                             node.lineno, qn)
        self.stack.append(node)
        self.defs_in_scope.setdefault(self._scope(), {})
        self.generic_visit(node)
        self.stack.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn  # type: ignore[assignment]

    def visit_Call(self, node: ast.Call) -> None:
        if _is_jax_jit(node.func):
            fn = self._resolve_fn_arg(node)
            self._record(fn, node, node.lineno, fallback=self._scope())
        self.generic_visit(node)

    def _resolve_fn_arg(self, jit_call: ast.Call) -> Optional[ast.FunctionDef]:
        if not jit_call.args:
            return None
        arg = jit_call.args[0]
        if isinstance(arg, ast.Name):
            return self.defs_in_scope.get(self._scope(), {}).get(arg.id)
        if isinstance(arg, ast.Attribute) and \
                isinstance(arg.value, ast.Name) and arg.value.id == "self":
            # jax.jit(self.method): resolve against the enclosing class
            for i in range(len(self.stack) - 1, -1, -1):
                if isinstance(self.stack[i], ast.ClassDef):
                    cls_scope = _qualname(self.stack[: i + 1])
                    return self.defs_in_scope.get(cls_scope, {}).get(arg.attr)
        return None


def collect_jit_sites(ctx: ModuleCtx) -> List[JitSite]:
    w = _ScopeWalker(ctx.modname)
    w.visit(ctx.tree)
    return w.sites


# ---------------------------------------------------------------------------
# JAX001: traced-value control flow in jitted functions
# ---------------------------------------------------------------------------
def _expr_tainted(node: ast.AST, tainted: Set[str]) -> bool:
    """Is this expression data-dependent on a traced value?  Shape/dtype
    projections, ``is None`` tests and ``len``/``isinstance`` detaint."""
    if isinstance(node, ast.Name):
        return node.id in tainted
    if isinstance(node, ast.Attribute):
        if node.attr in _DETAINT_ATTRS:
            return False
        return _expr_tainted(node.value, tainted)
    if isinstance(node, ast.Call):
        fname = dotted_name(node.func)
        if fname in _DETAINT_CALLS:
            return False
        recv = (isinstance(node.func, ast.Attribute)
                and _expr_tainted(node.func.value, tainted))
        return recv or any(
            _expr_tainted(a, tainted) for a in node.args) or any(
            _expr_tainted(k.value, tainted) for k in node.keywords)
    if isinstance(node, ast.Compare):
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return False
        return any(_expr_tainted(x, tainted)
                   for x in [node.left, *node.comparators])
    if isinstance(node, ast.Subscript):
        return _expr_tainted(node.value, tainted)
    return any(_expr_tainted(c, tainted) for c in ast.iter_child_nodes(node))


class _TaintChecker(ast.NodeVisitor):
    def __init__(self, fn: ast.FunctionDef, statics: Set[str],
                 ctx: ModuleCtx, qualname: str,
                 findings: List[Finding]) -> None:
        a = fn.args
        params = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
        if a.vararg:
            params.append(a.vararg.arg)
        if a.kwarg:
            params.append(a.kwarg.arg)
        self.tainted: Set[str] = {p for p in params
                                  if p not in statics
                                  and p not in ("self", "cls")}
        self.ctx = ctx
        self.qualname = qualname
        self.findings = findings
        self.fn = fn

    def run(self) -> None:
        for stmt in self.fn.body:
            self.visit(stmt)

    def visit_Assign(self, node: ast.Assign) -> None:
        if _expr_tainted(node.value, self.tainted):
            for t in node.targets:
                self.tainted.update(assigned_names(t))
        else:
            for t in node.targets:
                self.tainted.difference_update(assigned_names(t))
        self.visit(node.value)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if _expr_tainted(node.value, self.tainted):
            self.tainted.update(assigned_names(node.target))
        self.visit(node.value)

    def visit_If(self, node: ast.If) -> None:
        self._check(node.test, "if")
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        self._check(node.test, "while")
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp) -> None:
        # `a if cond else b` on tracers is the same bug
        self._check(node.test, "ifexp")
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # nested defs get their own params; don't conflate scopes
        return

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def _check(self, test: ast.expr, kind: str) -> None:
        if _expr_tainted(test, self.tainted):
            self.findings.append(Finding(
                rule="JAX001", path=self.ctx.rel, line=test.lineno,
                context=self.qualname, detail=unparse(test),
                message=f"Python `{kind}` on traced value "
                        f"`{unparse(test)}` inside jitted function — "
                        f"use jnp.where/lax.cond or mark it static"))


def check_traced_branching(ctx: ModuleCtx,
                           sites: Optional[List[JitSite]] = None
                           ) -> List[Finding]:
    findings: List[Finding] = []
    seen: Set[int] = set()
    for site in (sites if sites is not None else collect_jit_sites(ctx)):
        if site.fn is None or id(site.fn) in seen:
            continue
        seen.add(id(site.fn))
        statics = _static_params(site.jit_call, site.fn)
        qn = getattr(site.fn, "_analysis_qualname", site.fn.name)
        _TaintChecker(site.fn, statics, ctx, qn, findings).run()
    return findings


# ---------------------------------------------------------------------------
# JAX002: PRNG key reuse
# ---------------------------------------------------------------------------
def _key_consumer_and_key(node: ast.Call) -> Optional[str]:
    """If ``node`` spends a PRNG key, return the key variable name (first
    positional arg when it is a plain Name)."""
    fname = dotted_name(node.func)
    parts = fname.split(".")
    if len(parts) >= 2 and parts[-2] in ("random", "jrandom") or \
            fname.startswith("jax.random."):
        leaf = parts[-1]
        if leaf not in _KEY_MAKERS and node.args and \
                isinstance(node.args[0], ast.Name):
            return node.args[0].id
    return None


def _advances_key(node: ast.Call) -> List[str]:
    """Key names this call re-derives (split/fold_in arguments)."""
    fname = dotted_name(node.func)
    if fname.split(".")[-1] in ("split", "fold_in"):
        return [a.id for a in node.args if isinstance(a, ast.Name)]
    return []


class _KeyChecker(ast.NodeVisitor):
    """Linear scan of one function body: a key name is *spent* after a
    sampler consumes it; spending it again without reassignment/advance
    is JAX002.  Loops whose bodies consume a key they never rebind are
    the un-folded-key variant."""

    def __init__(self, ctx: ModuleCtx, qualname: str,
                 findings: List[Finding]) -> None:
        self.ctx = ctx
        self.qualname = qualname
        self.findings = findings
        self.spent: Set[str] = set()

    def visit_Assign(self, node: ast.Assign) -> None:
        self.visit(node.value)
        for t in node.targets:
            self.spent.difference_update(assigned_names(t))

    def visit_Call(self, node: ast.Call) -> None:
        self.generic_visit(node)
        for name in _advances_key(node):
            self.spent.discard(name)
        key = _key_consumer_and_key(node)
        if key is not None:
            if key in self.spent:
                self.findings.append(Finding(
                    rule="JAX002", path=self.ctx.rel, line=node.lineno,
                    context=self.qualname, detail=f"reuse:{key}",
                    message=f"PRNG key `{key}` consumed again without "
                            f"split/fold_in — correlated draws"))
            self.spent.add(key)

    def _visit_loop(self, node: ast.AST, body: List[ast.stmt]) -> None:
        rebound: Set[str] = set()
        for st in body:
            if isinstance(st, (ast.Assign, ast.AugAssign)):
                tgts = st.targets if isinstance(st, ast.Assign) else [st.target]
                for t in tgts:
                    rebound.update(assigned_names(t))
        for st in body:
            for sub in ast.walk(st):
                if isinstance(sub, ast.Call):
                    key = _key_consumer_and_key(sub)
                    if key is not None and key not in rebound:
                        self.findings.append(Finding(
                            rule="JAX002", path=self.ctx.rel,
                            line=sub.lineno, context=self.qualname,
                            detail=f"loop:{key}",
                            message=f"loop body consumes PRNG key `{key}` "
                                    f"without folding the iteration in — "
                                    f"same key every iteration"))
        for st in body:
            self.visit(st)

    def visit_For(self, node: ast.For) -> None:
        self._visit_loop(node, node.body)

    def visit_While(self, node: ast.While) -> None:
        self._visit_loop(node, node.body)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        return  # nested functions are checked as their own scope

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]


def check_key_reuse(ctx: ModuleCtx) -> List[Finding]:
    findings: List[Finding] = []
    stack: List[ast.AST] = []

    def walk(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qn = _qualname(stack + [child])
                chk = _KeyChecker(ctx, qn, findings)
                for st in child.body:
                    chk.visit(st)
                stack.append(child)
                walk(child)
                stack.pop()
            elif isinstance(child, ast.ClassDef):
                stack.append(child)
                walk(child)
                stack.pop()
            else:
                walk(child)

    walk(ctx.tree)
    return findings


# ---------------------------------------------------------------------------
# JAX003: host syncs on device values in hot paths
# ---------------------------------------------------------------------------
def _device_fn_names(ctx: ModuleCtx) -> Set[str]:
    """Attribute/variable names bound to ``jax.jit(...)`` results anywhere
    in the module — calls through these produce device values."""
    names: Set[str] = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Assign) and _jit_call_of(node.value):
            for t in node.targets:
                if isinstance(t, ast.Attribute):
                    names.add(t.attr)
                elif isinstance(t, ast.Name):
                    names.add(t.id)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_jit_call_of(d) or _is_jax_jit(d)
                   for d in node.decorator_list):
                names.add(node.name)
    return names


class _SyncChecker(ast.NodeVisitor):
    """Per-iteration host syncs only: a sync inside a ``for``/``while``
    body blocks the dispatch pipeline every step; a single transfer
    after the loop is the idiomatic fix and is not flagged."""

    def __init__(self, ctx: ModuleCtx, qualname: str, device_fns: Set[str],
                 findings: List[Finding]) -> None:
        self.ctx = ctx
        self.qualname = qualname
        self.device_fns = device_fns
        self.findings = findings
        self.device_vars: Set[str] = set()
        self.loop_depth = 0

    def visit_For(self, node: ast.For) -> None:
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    def visit_While(self, node: ast.While) -> None:
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    def _is_device_call(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Call):
            f = node.func
            leaf = f.attr if isinstance(f, ast.Attribute) else (
                f.id if isinstance(f, ast.Name) else "")
            return leaf in self.device_fns
        return False

    def _is_device_expr(self, node: ast.AST) -> bool:
        if self._is_device_call(node):
            return True
        if isinstance(node, ast.Name):
            return node.id in self.device_vars
        if isinstance(node, ast.Subscript):
            return self._is_device_expr(node.value)
        if isinstance(node, (ast.Attribute,)):
            return False
        return any(self._is_device_expr(c) for c in ast.iter_child_nodes(node))

    def visit_Assign(self, node: ast.Assign) -> None:
        self.visit(node.value)
        if self._is_device_expr(node.value):
            for t in node.targets:
                self.device_vars.update(assigned_names(t))
                if isinstance(t, ast.Tuple):
                    for el in t.elts:
                        self.device_vars.update(assigned_names(el))
        else:
            for t in node.targets:
                self.device_vars.difference_update(assigned_names(t))

    def visit_Call(self, node: ast.Call) -> None:
        self.generic_visit(node)
        fname = dotted_name(node.func)
        leaf = fname.split(".")[-1] if fname else ""
        is_sync = (
            (isinstance(node.func, ast.Attribute) and node.func.attr == "item"
             and not node.args)
            or (leaf in _SYNC_CALLS and fname == leaf and node.args)
            or fname in ("np.asarray", "numpy.asarray", "np.array",
                         "numpy.array"))
        if not is_sync or self.loop_depth == 0:
            return
        target = (node.func.value if isinstance(node.func, ast.Attribute)
                  and node.func.attr == "item" else
                  (node.args[0] if node.args else None))
        if target is not None and self._is_device_expr(target):
            self.findings.append(Finding(
                rule="JAX003", path=self.ctx.rel, line=node.lineno,
                context=self.qualname, detail=unparse(node),
                message=f"host sync `{unparse(node)}` on a device value "
                        f"in a serving hot path — blocks the dispatch "
                        f"pipeline every step"))

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        return

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]


def check_host_syncs(ctx: ModuleCtx,
                     hot: Optional[Iterable[str]] = None) -> List[Finding]:
    prefixes = tuple(hot) if hot is not None else HOT_PATH_PREFIXES
    if not any(ctx.modname.startswith(p) or ctx.modname == p.rstrip(".")
               for p in prefixes):
        return []
    device_fns = _device_fn_names(ctx)
    if not device_fns:
        return []
    findings: List[Finding] = []
    stack: List[ast.AST] = []

    def walk(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qn = _qualname(stack + [child])
                chk = _SyncChecker(ctx, qn, device_fns, findings)
                for st in child.body:
                    chk.visit(st)
                stack.append(child)
                walk(child)
                stack.pop()
            elif isinstance(child, ast.ClassDef):
                stack.append(child)
                walk(child)
                stack.pop()
            else:
                walk(child)

    walk(ctx.tree)
    return findings


# ---------------------------------------------------------------------------
# JAX004: jit sites must have a declared cache owner (trace budget)
# ---------------------------------------------------------------------------
def check_jit_declared(ctx: ModuleCtx,
                       budgets: Optional[Dict[str, int]] = None,
                       sites: Optional[List[JitSite]] = None
                       ) -> List[Finding]:
    if budgets is None:
        from .trace_budget import TRACE_BUDGETS
        budgets = TRACE_BUDGETS
    findings: List[Finding] = []
    for site in (sites if sites is not None else collect_jit_sites(ctx)):
        if site.key not in budgets:
            findings.append(Finding(
                rule="JAX004", path=ctx.rel, line=site.line,
                context=site.context, detail=site.key,
                message=f"jax.jit site `{site.key}` has no trace budget in "
                        f"repro.analysis.trace_budget.TRACE_BUDGETS — "
                        f"declare its cache owner and retrace budget"))
    return findings


def check_module(ctx: ModuleCtx,
                 hot: Optional[Iterable[str]] = None,
                 budgets: Optional[Dict[str, int]] = None) -> List[Finding]:
    """All JAX rules for one module."""
    sites = collect_jit_sites(ctx)
    out: List[Finding] = []
    out += check_traced_branching(ctx, sites)
    out += check_key_reuse(ctx)
    out += check_host_syncs(ctx, hot)
    out += check_jit_declared(ctx, budgets, sites)
    return out
