"""repro.analysis — the repo's own static-analysis pass (DESIGN.md §12).

Four rule families, each encoding a bug class this reproduction has
actually shipped and reverted:

* ``rules_jax``    JAX001-JAX004: traced-value branching, PRNG key
                   reuse, hot-path host syncs, undeclared jit caches.
* ``rules_pallas`` PAL001-PAL004: BlockSpec index-map bounds, VMEM
                   budgets, tile alignment, oracle + dispatch gates.
* ``rules_mesh``   MESH001-MESH002: explicit shard_map check_rep,
                   replicate-before-sample domination.
* ``rules_obs``    OBS001: obs recording calls inside jitted function
                   bodies or hot-path loop bodies.
* ``trace_budget`` TRB001-TRB002: runtime jit trace budgets over the
                   tier-1 entry points (``--runtime``).

Run ``python -m repro.analysis src/`` (see README).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

from .core import (Finding, ModuleCtx, apply_baseline, iter_py_files,
                   load_baseline)

__all__ = ["Finding", "ModuleCtx", "apply_baseline", "iter_py_files",
           "load_baseline", "run_source_rules"]


def run_source_rules(paths: Iterable[str],
                     hot: Optional[Iterable[str]] = None,
                     budgets: Optional[Dict[str, int]] = None
                     ) -> List[Finding]:
    """AST rule families (JAX + MESH + OBS) over every .py under
    ``paths``."""
    from . import rules_jax, rules_mesh, rules_obs
    findings: List[Finding] = []
    for path in iter_py_files(paths):
        try:
            ctx = ModuleCtx.parse(path)
        except (SyntaxError, UnicodeDecodeError) as e:
            findings.append(Finding(
                rule="JAX000", path=path, line=getattr(e, "lineno", 0) or 0,
                context="", detail="parse-error",
                message=f"could not parse: {e}"))
            continue
        findings += rules_jax.check_module(ctx, hot=hot, budgets=budgets)
        findings += rules_mesh.check_module(ctx)
        findings += rules_obs.check_module(ctx, hot=hot)
    return findings
