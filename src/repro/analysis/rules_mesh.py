"""Sharding audit (rule family MESH, DESIGN.md §12).

MESH001  Every ``shard_map`` call must pass ``check_rep`` explicitly.
         The default flipped behavior across jax versions and silently
         governs whether replication invariants of the body are
         verified; mesh code must say which contract it relies on.
MESH002  A sampling call (``jax.random.categorical`` or
         ``sampling.sample``) must be *dominated* by a
         ``replicate_logits`` rebinding of its logits operand in the
         same function.  Under tensor parallelism the lm_head output is
         vocab-sharded; sampling a sharded row draws a different token
         on every device (the PR 5 bug class).  The one categorical
         primitive inside ``repro/serve/sampling.py`` is the audited
         chokepoint and lives in the baseline.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from .core import Finding, ModuleCtx, assigned_names, dotted_name, unparse

# dotted-name leaves treated as sampling entry points whose first
# argument is a logits row that must be replicated first
_SAMPLING_LEAVES = {"categorical"}
_SAMPLING_FNS = {"sample"}          # repro.serve.sampling.sample
_REPLICATORS = {"replicate_logits"}


def check_shard_map_check_rep(ctx: ModuleCtx) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        fname = dotted_name(node.func)
        if fname.split(".")[-1] != "shard_map":
            continue
        if any(kw.arg == "check_rep" for kw in node.keywords):
            continue
        findings.append(Finding(
            rule="MESH001", path=ctx.rel, line=node.lineno,
            context="", detail=unparse(node, 50),
            message="shard_map without explicit check_rep= — declare the "
                    "replication contract the body relies on"))
    return findings


def _sampling_logits_arg(node: ast.Call) -> Optional[ast.expr]:
    """The logits operand if this call samples from logits, else None."""
    fname = dotted_name(node.func)
    leaf = fname.split(".")[-1] if fname else ""
    if leaf in _SAMPLING_LEAVES and fname.startswith(("jax.random.",
                                                      "random.",
                                                      "jrandom.")):
        # categorical(key, logits)
        return node.args[1] if len(node.args) > 1 else None
    if leaf in _SAMPLING_FNS and (
            "sampling" in fname or fname == leaf):
        # sampling.sample(logits, keys, temperature)
        return node.args[0] if node.args else None
    return None


class _DominationChecker(ast.NodeVisitor):
    """Linear scan of one function: names rebound from a
    ``replicate_logits`` call are *replicated*; a sampling call whose
    logits operand isn't built from a replicated name is MESH002."""

    def __init__(self, ctx: ModuleCtx, qualname: str,
                 findings: List[Finding]) -> None:
        self.ctx = ctx
        self.qualname = qualname
        self.findings = findings
        self.replicated: Set[str] = set()

    def _is_replicate_call(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Call):
            fname = dotted_name(node.func)
            if fname.split(".")[-1] in _REPLICATORS:
                return True
            return any(self._is_replicate_call(a) for a in node.args)
        return False

    def _is_replicated_expr(self, node: ast.AST) -> bool:
        if self._is_replicate_call(node):
            return True
        if isinstance(node, ast.Name):
            return node.id in self.replicated
        if isinstance(node, (ast.Subscript, ast.BinOp, ast.UnaryOp)):
            return any(self._is_replicated_expr(c)
                       for c in ast.iter_child_nodes(node)
                       if isinstance(c, ast.expr))
        if isinstance(node, ast.Call):
            # projections of a replicated value stay replicated
            return any(self._is_replicated_expr(a) for a in node.args)
        return False

    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node.value)
        if self._is_replicated_expr(node.value):
            for t in node.targets:
                self.replicated.update(assigned_names(t))

    def visit_Call(self, node: ast.Call) -> None:
        self.generic_visit(node)
        logits = _sampling_logits_arg(node)
        if logits is None:
            return
        if not self._is_replicated_expr(logits):
            self.findings.append(Finding(
                rule="MESH002", path=self.ctx.rel, line=node.lineno,
                context=self.qualname, detail=unparse(node, 50),
                message=f"sampling call `{unparse(node, 50)}` not dominated "
                        f"by replicate_logits — under TP a vocab-sharded "
                        f"row draws a different token per device"))

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        return  # nested functions are their own scope

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]


def check_sampling_replicated(ctx: ModuleCtx) -> List[Finding]:
    findings: List[Finding] = []
    stack: List[str] = []

    def walk(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                prefix = ".".join(stack)
                qn = f"{prefix}.{child.name}" if prefix else child.name
                chk = _DominationChecker(ctx, qn, findings)
                for st in child.body:
                    chk.visit(st)
                stack.append(child.name + ".<locals>")
                walk(child)
                stack.pop()
            elif isinstance(child, ast.ClassDef):
                stack.append(child.name)
                walk(child)
                stack.pop()
            else:
                walk(child)

    walk(ctx.tree)
    return findings


def check_module(ctx: ModuleCtx) -> List[Finding]:
    return check_shard_map_check_rep(ctx) + check_sampling_replicated(ctx)
