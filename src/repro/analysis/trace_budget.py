"""Trace-budget enforcement (rule family TRB, DESIGN.md §12).

Generalizes the serving suite's ``_step_fn._cache_size() == 1`` pin
(tests/test_serve_stack.py): every ``jax.jit`` in the repo declares a
*trace budget* — the maximum number of compiled executables its cache
may hold after the standard tier-1 entry points have run.  PR 6's
CPU-compiler segfault came from silently accumulated executables; a jit
without a declared owner is how that class regresses unnoticed.

``TRACE_BUDGETS`` maps ``"module:qualname"`` keys to budgets.  The same
table backs two checks:

* static (JAX004 in ``rules_jax``): every ``jax.jit`` *site* found in
  the AST must have an entry;
* runtime (``--runtime`` here): ``jax.jit`` is patched *before* any
  repro module is imported, the four entry-point scenarios run (batcher
  step, engine generate, evaluate_perplexity, api.prune), and every
  recorded jit is checked — TRB001 undeclared, TRB002 budget exceeded.

On Python < 3.11 there is no ``co_qualname``, so the creation-site
fallback key (for jits wrapped around lambdas/params, e.g. the
executor's ``_cached``) is coarse: ``module:function_name``.  A runtime
record passes TRB001 if *any* of its candidate keys is declared.
"""
from __future__ import annotations

import contextlib
import functools
import sys
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

from .core import Finding

# ---------------------------------------------------------------------------
# the declaration table: "module:qualname" -> max executables
# ---------------------------------------------------------------------------
# Budget semantics: the cache size allowed after ALL runtime scenarios
# have run (one shape per hot loop => 1; shape-polymorphic helpers get
# the number of distinct shapes the scenarios legitimately feed them).
# Entries not reached by the scenarios are static declarations of cache
# ownership — JAX004 requires every jit site in src/ to appear here.
TRACE_BUDGETS: Dict[str, int] = {
    # -- serving hot loop: joins/retirements/token steps must never
    #    re-specialize (the test_serve_stack.py:67 pin, generalized) ----
    "repro.serve.batcher:ContinuousBatcher.__init__.<locals>.step": 1,
    # chunked prefill: fixed chunk width + fixed pool shapes => one
    # executable regardless of prompt length / chunk offset / hit depth
    "repro.serve.batcher:ContinuousBatcher.__init__.<locals>.chunk_step": 1,
    "repro.serve.engine:Engine._decode_step": 1,
    # engine chunked prefill retraces per distinct prompt-block count
    # (the private per-row pool is sized ceil(P/bs)+1 blocks)
    "repro.serve.engine:Engine._chunk_step": 4,
    # -- eval: one CE/KL closure per model, cached weak-keyed ----------
    "repro.eval.perplexity:_ce_fn.<locals>.fn": 1,
    "repro.eval.divergence:kl_divergence.<locals>._stats": 1,
    # -- solver core: shape-polymorphic over (m, n) unit shapes --------
    "repro.core.fista:solve": 8,
    "repro.core.fista:kkt_residual": 8,
    "repro.core.admm:_admm_single": 8,
    "repro.core.admm:_admm_group": 8,
    "repro.core.frankwolfe:_fw_single": 8,
    "repro.core.frankwolfe:_fw_group": 8,
    "repro.core.baselines:_sparsegpt_block": 8,
    "repro.core.gram:accumulate": 8,
    "repro.core.gram:target_correlation": 8,
    "repro.core.gram:frob_error_sq": 8,
    "repro.core.gram:max_eigval": 8,
    "repro.core.pruner:_fused_single": 8,
    "repro.core.pruner:_fused_single_warm": 8,
    "repro.core.pruner:_fused_group": 8,
    "repro.core.sparsity:round_unstructured": 8,
    "repro.core.sparsity:round_nm": 16,
    "repro.core.sparsity:mask_unstructured_by_score": 8,
    "repro.core.sparsity:mask_rowwise_by_score": 8,
    "repro.core.sparsity:mask_nm_by_score": 8,
    # one capture closure per (param_path, layer) unit; cached per key
    "repro.core.sequential:_capture_forward.<locals>.fn": 2,
    "repro.core.sequential:_group_stats_scan": 8,
    # -- Pallas wrappers: retrace per (shape, static-arg) combo --------
    "repro.kernels.spmm24:spmm24": 8,
    "repro.kernels.round24:round24": 8,
    "repro.kernels.fista_step:fista_prox_step": 8,
    "repro.kernels.flash_attention:flash_attention": 8,
    "repro.kernels.paged_attention:paged_decode_attn": 8,
    "repro.kernels.paged_attention:fused_mlp24": 8,
    # -- mesh substrate: one executable per cached (fn, spec) key ------
    "repro.distributed.executor:MeshExecutor.sharded_group_stats.<locals>.build": 2,
    "repro.distributed.executor:MeshExecutor.data_map.<locals>.build": 2,
    "repro.distributed.train:make_train_step.<locals>.build": 2,
    "repro.distributed.train:make_serve_step.<locals>.build": 2,
    # -- trainer: one step family per Trainer ---------------------------
    "repro.train.trainer:make_train_step.<locals>.train_step": 2,
    "repro.train.trainer:make_train_step.<locals>.grad_step": 2,
    "repro.train.trainer:make_train_step.<locals>.apply_grads": 2,
    # -- launch dry-run lowering helpers: lowered once, never executed --
    "repro.launch.dryrun:build_lowerable.<locals>.step": 2,
    "repro.launch.dryrun:build_lowerable.<locals>.prefill_step": 2,
    "repro.launch.dryrun:build_lowerable.<locals>.decode": 2,
}


class JitRecord:
    """One jax.jit creation observed by the runtime recorder."""

    def __init__(self, keys: Tuple[str, ...], line: str,
                 jitted: Any) -> None:
        self.keys = keys            # candidate TRACE_BUDGETS keys
        self.where = line           # "file:lineno" of the creation site
        # Strong reference: budgets are read after the scenario returns,
        # and a weakref would report 0 for any jit whose owner was a
        # scenario local (vacuously passing the check).  The recorder
        # only lives for one analysis process, so pinning is harmless.
        self._fn = jitted

    def cache_size(self) -> int:
        try:
            return int(self._fn._cache_size())
        except Exception:
            return 0


def _creation_site_key(prefixes: Tuple[str, ...],
                       depth: int = 2) -> Tuple[Optional[str], str]:
    """(coarse "module:funcname" key, "file:line") of the nearest
    in-scope frame above the recorder."""
    frame = sys._getframe(depth)
    while frame is not None:
        mod = frame.f_globals.get("__name__", "")
        if mod.startswith(prefixes) and \
                not mod.startswith("repro.analysis"):
            qn = getattr(frame.f_code, "co_qualname", frame.f_code.co_name)
            return (f"{mod}:{qn}",
                    f"{frame.f_code.co_filename}:{frame.f_lineno}")
        frame = frame.f_back
    return None, "<unknown>"


@contextlib.contextmanager
def record_jits(prefixes: Tuple[str, ...] = ("repro",)
                ) -> Iterator[List[JitRecord]]:
    """Patch ``jax.jit`` so every jit created while the context is active
    (wrapping a function from a ``prefixes`` module, or created from
    one) is recorded with its candidate budget keys.  Must be entered
    BEFORE importing the modules under test (module-level ``@jax.jit``
    decorators run at import)."""
    import jax

    records: List[JitRecord] = []
    real = jax.jit

    @functools.wraps(real)
    def wrapper(fun: Optional[Callable[..., Any]] = None,
                **kw: Any) -> Any:
        if fun is None:
            return functools.partial(wrapper, **kw)
        jitted = real(fun, **kw)
        keys = []
        mod = getattr(fun, "__module__", "") or ""
        qn = getattr(fun, "__qualname__", "") or ""
        if mod.startswith(prefixes):
            keys.append(f"{mod}:{qn}")
        site_key, where = _creation_site_key(prefixes)
        if site_key is not None:
            keys.append(site_key)
        if keys:  # jits created outside repro code are not ours to budget
            records.append(JitRecord(tuple(dict.fromkeys(keys)), where,
                                     jitted))
        return jitted

    jax.jit = wrapper  # type: ignore[assignment]
    try:
        yield records
    finally:
        jax.jit = real  # type: ignore[assignment]


# ---------------------------------------------------------------------------
# tier-1 entry-point scenarios (tiny CPU configs, mirror the test suite)
# ---------------------------------------------------------------------------
def _tiny_model(vocab: int = 128) -> Tuple[Any, Any]:
    import jax
    from repro.configs.opt125m_proxy import tiny_config
    from repro.models.registry import model_def
    cfg = tiny_config().replace(num_layers=2, d_model=32, d_ff=64,
                                num_heads=4, num_kv_heads=4, vocab=vocab)
    model = model_def(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def scenario_batcher() -> None:
    """Mixed-length continuous batching — joins/retirements must not
    re-specialize the step."""
    import numpy as np
    from repro.serve import BatchConfig, ContinuousBatcher, Request
    model, params = _tiny_model()
    bc = BatchConfig(slots=3, block_size=8, max_blocks_per_request=4,
                     num_blocks=16)
    rng = np.random.default_rng(3)
    reqs = [Request(id=i, prompt=rng.integers(0, 128, size=p).astype(np.int32),
                    max_new_tokens=n, temperature=0.0)
            for i, (p, n) in enumerate([(5, 6), (9, 4), (3, 8)])]
    ContinuousBatcher(model, params, bc).run(reqs)
    # chunked prefill + prefix cache: shared prefixes, varying tail
    # lengths and chunk offsets must all hit ONE chunk executable
    bc2 = BatchConfig(slots=3, block_size=8, max_blocks_per_request=4,
                      num_blocks=16, prefill_chunk=8, prefix_cache=True)
    prefix = rng.integers(0, 128, size=9).astype(np.int32)
    reqs2 = [Request(id=i, prompt=np.concatenate(
                         [prefix, rng.integers(0, 128, size=p)]
                     ).astype(np.int32),
                     max_new_tokens=n, temperature=0.0, arrival=0.0)
             for i, (p, n) in enumerate([(4, 4), (7, 3), (2, 5)])]
    ContinuousBatcher(model, params, bc2).run(reqs2)


def scenario_engine_generate() -> None:
    """Two same-shape generate calls: the decode step traces once."""
    import jax.numpy as jnp
    import numpy as np
    from repro.serve import Engine, ServeConfig
    model, params = _tiny_model()
    eng = Engine(model, params, ServeConfig(cache_len=32))
    rng = np.random.default_rng(0)
    for rid in (0, 1):
        prompt = rng.integers(0, 128, size=6).astype(np.int32)
        eng.generate(jnp.asarray(prompt[None, :]), max_new_tokens=4,
                     request_ids=[rid])


def scenario_evaluate() -> None:
    """evaluate_perplexity twice on the same model — the per-model CE
    closure must be cached, not re-jitted."""
    from repro.data import CorpusConfig, MarkovCorpus
    from repro.eval import EvalConfig, evaluate_perplexity
    model, params = _tiny_model()
    corpus = MarkovCorpus(CorpusConfig(vocab=128, seed=5))
    ec = EvalConfig(num_batches=2, batch_size=2, seq_len=16, kl_batches=1,
                    budget_batches=1)
    evaluate_perplexity(model, params, corpus, ec)
    evaluate_perplexity(model, params, corpus, ec)


def scenario_prune_unit() -> None:
    """One tiny api.prune pass (the sequential prune_unit driver)."""
    import jax
    from repro import api
    from repro.data import (CalibConfig, CorpusConfig, MarkovCorpus,
                            calibration_batches)
    model, params = _tiny_model()
    corpus = MarkovCorpus(CorpusConfig(vocab=128, seed=5))
    calib = calibration_batches(corpus, CalibConfig(num_sequences=2,
                                                    seq_len=16,
                                                    batch_size=2))
    recipe = api.PruneRecipe(
        method="fista", sparsity="50%",
        solver={"fista_iters": 4, "max_outer": 2, "patience": 1,
                "eps": 1e-3},
        scheduler={"workers": 1})
    api.prune(model, params, calib, recipe)


SCENARIOS: Dict[str, Callable[[], None]] = {
    "batcher": scenario_batcher,
    "engine_generate": scenario_engine_generate,
    "evaluate": scenario_evaluate,
    "prune_unit": scenario_prune_unit,
}


def check_records(records: List[JitRecord],
                  budgets: Optional[Dict[str, int]] = None,
                  scenario: str = "") -> List[Finding]:
    """TRB001/TRB002 over one scenario's recorded jits."""
    budgets = TRACE_BUDGETS if budgets is None else budgets
    findings: List[Finding] = []
    for rec in records:
        declared = [k for k in rec.keys if k in budgets]
        if not declared:
            findings.append(Finding(
                rule="TRB001", path=rec.keys[0].split(":")[0], line=0,
                context=scenario, detail=rec.keys[0],
                message=f"jit {rec.keys[0]} (created at {rec.where}) has "
                        f"no declared trace budget in TRACE_BUDGETS"))
            continue
        budget = max(budgets[k] for k in declared)
        size = rec.cache_size()
        if size > budget:
            findings.append(Finding(
                rule="TRB002", path=declared[0].split(":")[0], line=0,
                context=scenario, detail=declared[0],
                message=f"jit {declared[0]} holds {size} executables "
                        f"after scenario '{scenario}' — budget is "
                        f"{budget} (retrace regression)"))
    return findings


def run_runtime_check(budgets: Optional[Dict[str, int]] = None,
                      scenarios: Optional[Dict[str, Callable[[], None]]]
                      = None) -> List[Finding]:
    """Run every scenario under the recorder and enforce budgets.

    Cache sizes are checked once, AFTER all scenarios have run, so
    budgets bound the *cumulative* trace count a jit accumulates across
    the tier-1 entry points (module-level jits created at first import
    are attributed to the scenario that triggered the import).  Run in a
    fresh process — ``python -m repro.analysis --runtime`` — so the
    recorder sees every module-level ``@jax.jit``."""
    findings: List[Finding] = []
    recorded: List[Tuple[str, List[JitRecord]]] = []
    for name, fn in (scenarios or SCENARIOS).items():
        with record_jits() as records:
            try:
                fn()
            except Exception as e:
                findings.append(Finding(
                    rule="TRB001", path="repro.analysis.trace_budget",
                    line=0, context=name, detail=f"scenario-error:{name}",
                    message=f"runtime scenario '{name}' failed: "
                            f"{type(e).__name__}: {e}"))
                continue
        recorded.append((name, records))
    for name, records in recorded:
        findings += check_records(records, budgets, scenario=name)
    return findings
