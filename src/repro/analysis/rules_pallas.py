"""Pallas kernel checker (rule family PAL, DESIGN.md §12).

Static inspection of ``pl.pallas_call`` sites by *capture*: the checker
monkeypatches ``pallas_call`` and runs each registered kernel wrapper on
small representative inputs under ``jax.disable_jit()``.  The recorder
never executes the kernel body — it grabs the grid, BlockSpecs, scratch
shapes and concrete operand shapes/dtypes, and returns zeros of
``out_shape`` so the wrapper's pad/slice epilogue still runs.  Index
maps are then *evaluated numerically* at every grid corner (with the
real scalar-prefetch arrays, so block-table indirection like
``tab[s, j]`` is checked against the actual pool extent).

PAL001  BlockSpec index map out of bounds for the declared grid: some
        grid corner maps a block outside the operand.
PAL002  Estimated VMEM footprint (double-buffered blocks + scratch,
        dtype-aware) exceeds the kernel's declared budget.
PAL003  Misaligned tile: a blocked (non-full-extent) lane dim not a
        multiple of 128, or a blocked sublane dim not 1 or a multiple
        of 8 — Mosaic pads these to full tiles, silently wasting VMEM
        and bandwidth.
PAL004  Kernel without a registered ``kernels/ref.py`` oracle + dispatch
        gate in ``kernels/ops.py`` — the bitwise fused-vs-oracle
        discipline (DESIGN.md §11) requires both.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import os
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .core import Finding

LANE = 128
SUBLANE = 8
_DOUBLE_BUFFER = 2


@dataclasses.dataclass
class PallasSite:
    """One captured ``pl.pallas_call`` invocation."""

    kernel_name: str
    grid: Tuple[int, ...]
    in_specs: List[Any]                      # pl.BlockSpec
    out_specs: List[Any]
    out_shapes: List[Any]                    # jax.ShapeDtypeStruct
    scratch_shapes: List[Any]                # pltpu.VMEM MemoryRefs
    num_scalar_prefetch: int
    # filled when the wrapper invokes the (fake) compiled kernel:
    operand_shapes: List[Tuple[Tuple[int, ...], Any]] = \
        dataclasses.field(default_factory=list)
    prefetch: List[np.ndarray] = dataclasses.field(default_factory=list)
    called: bool = False


@dataclasses.dataclass
class KernelCase:
    """One registered kernel: where it lives, its oracle, its VMEM budget
    and a builder that invokes the public wrapper on sample inputs."""

    name: str                      # registry name, e.g. "spmm24"
    path: str                      # repo-relative file for findings
    fn_name: str                   # public symbol ops.py must dispatch to
    oracle: str                    # kernels/ref.py oracle symbol
    vmem_budget: int               # bytes
    build: Callable[[], None]      # runs the wrapper under capture


def _as_seq(x: Any) -> List[Any]:
    if x is None:
        return []
    if isinstance(x, (list, tuple)):
        return list(x)
    return [x]


@contextlib.contextmanager
def capture_pallas() -> Iterator[List[PallasSite]]:
    """Patch ``pallas_call`` to record call structure instead of
    compiling; yields the list of captured sites."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    records: List[PallasSite] = []
    real = pl.pallas_call

    def recorder(kernel: Any, *, out_shape: Any, grid: Any = None,
                 in_specs: Any = None, out_specs: Any = None,
                 scratch_shapes: Any = (), grid_spec: Any = None,
                 **kw: Any) -> Callable[..., Any]:
        nps = 0
        if grid_spec is not None:
            grid = getattr(grid_spec, "grid", grid)
            in_specs = getattr(grid_spec, "in_specs", in_specs)
            out_specs = getattr(grid_spec, "out_specs", out_specs)
            scratch_shapes = getattr(grid_spec, "scratch_shapes",
                                     scratch_shapes)
            nps = int(getattr(grid_spec, "num_scalar_prefetch", 0))
        kname = getattr(kernel, "__name__", None) or getattr(
            getattr(kernel, "func", None), "__name__", "<kernel>")
        site = PallasSite(
            kernel_name=kname,
            grid=tuple(int(g) for g in _as_seq(grid)) or (1,),
            in_specs=_as_seq(in_specs),
            out_specs=_as_seq(out_specs),
            out_shapes=jax.tree_util.tree_leaves(
                out_shape, is_leaf=lambda x: hasattr(x, "shape")),
            scratch_shapes=_as_seq(scratch_shapes),
            num_scalar_prefetch=nps)
        records.append(site)

        def fake(*operands: Any) -> Any:
            site.called = True
            site.prefetch = [np.asarray(o) for o in operands[:nps]]
            site.operand_shapes = [
                (tuple(int(d) for d in np.shape(o)),
                 np.dtype(getattr(o, "dtype", np.asarray(o).dtype)))
                for o in operands[nps:]]
            return jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), out_shape,
                is_leaf=lambda x: hasattr(x, "shape"))

        return fake

    pl.pallas_call = recorder  # type: ignore[assignment]
    try:
        with jax.disable_jit():
            yield records
    finally:
        pl.pallas_call = real  # type: ignore[assignment]


# ---------------------------------------------------------------------------
# per-site checks
# ---------------------------------------------------------------------------
def _grid_corners(grid: Sequence[int]) -> Iterator[Tuple[int, ...]]:
    axes = [sorted({0, g - 1}) for g in grid]
    return itertools.product(*axes)


def _block_indices(spec: Any, idx: Tuple[int, ...],
                   prefetch: Sequence[np.ndarray]) -> Optional[Tuple[int, ...]]:
    imap = getattr(spec, "index_map", None)
    if imap is None:
        return None
    out = imap(*idx, *prefetch)
    if not isinstance(out, tuple):
        out = (out,)
    return tuple(int(b) for b in out)


def _check_one_spec(case: KernelCase, site: PallasSite, spec: Any,
                    array_shape: Tuple[int, ...], dtype: Any,
                    role: str, findings: List[Finding]) -> int:
    """Bounds + alignment for one BlockSpec; returns its VMEM bytes."""
    block = getattr(spec, "block_shape", None)
    if block is None:
        block = array_shape
    block = tuple(block)
    ctx = f"{case.name}.{site.kernel_name}"

    # --- PAL001: index map bounds at every grid corner -------------------
    for corner in _grid_corners(site.grid):
        try:
            bidx = _block_indices(spec, corner, site.prefetch)
        except Exception as e:  # index map itself blew up
            findings.append(Finding(
                rule="PAL001", path=case.path, line=0, context=ctx,
                detail=f"{role}:indexmap-error",
                message=f"{role} index map raised {type(e).__name__} at "
                        f"grid corner {corner}: {e}"))
            break
        if bidx is None:
            continue
        if len(bidx) != len(block):
            findings.append(Finding(
                rule="PAL001", path=case.path, line=0, context=ctx,
                detail=f"{role}:rank",
                message=f"{role} index map returns rank {len(bidx)} for "
                        f"block rank {len(block)}"))
            break
        for d, (b, bs) in enumerate(zip(bidx, block)):
            if bs is None:
                continue
            dim = array_shape[d] if d < len(array_shape) else 0
            nblocks = max(1, -(-dim // bs))  # ceil
            if b < 0 or b >= nblocks:
                findings.append(Finding(
                    rule="PAL001", path=case.path, line=0, context=ctx,
                    detail=f"{role}:dim{d}",
                    message=f"{role} index map sends grid corner {corner} "
                            f"to block {bidx}, but axis {d} has only "
                            f"{nblocks} block(s) of {bs} over extent "
                            f"{dim} — out of bounds"))

    # --- PAL003: tile alignment on the last two dims ---------------------
    concrete = [b for b in block if b is not None]
    if len(concrete) >= 1:
        lane_b = concrete[-1]
        lane_dim = array_shape[-1] if array_shape else lane_b
        if lane_b != lane_dim and lane_b % LANE != 0:
            findings.append(Finding(
                rule="PAL003", path=case.path, line=0, context=ctx,
                detail=f"{role}:lane",
                message=f"{role} lane (last) block dim {lane_b} is neither "
                        f"full-extent ({lane_dim}) nor a multiple of "
                        f"{LANE} — Mosaic pads the tile"))
    if len(concrete) >= 2:
        sub_b = concrete[-2]
        sub_dim = array_shape[-2] if len(array_shape) >= 2 else sub_b
        if sub_b != sub_dim and sub_b != 1 and sub_b % SUBLANE != 0:
            findings.append(Finding(
                rule="PAL003", path=case.path, line=0, context=ctx,
                detail=f"{role}:sublane",
                message=f"{role} sublane block dim {sub_b} is neither "
                        f"full-extent ({sub_dim}), 1, nor a multiple of "
                        f"{SUBLANE}"))

    bytes_ = int(np.prod([b for b in block if b is not None], dtype=np.int64)
                 ) * np.dtype(dtype).itemsize
    return bytes_ * _DOUBLE_BUFFER


def check_site(case: KernelCase, site: PallasSite) -> List[Finding]:
    findings: List[Finding] = []
    ctx = f"{case.name}.{site.kernel_name}"
    if not site.called:
        findings.append(Finding(
            rule="PAL001", path=case.path, line=0, context=ctx,
            detail="not-called",
            message="pallas_call captured but the wrapper never invoked "
                    "it — sample inputs don't exercise this site"))
        return findings
    if len(site.in_specs) != len(site.operand_shapes):
        findings.append(Finding(
            rule="PAL001", path=case.path, line=0, context=ctx,
            detail="arity",
            message=f"{len(site.in_specs)} in_specs for "
                    f"{len(site.operand_shapes)} (non-prefetch) operands"))
        return findings

    vmem = 0
    for i, (spec, (shape, dtype)) in enumerate(
            zip(site.in_specs, site.operand_shapes)):
        vmem += _check_one_spec(case, site, spec, shape, dtype,
                                f"in[{i}]", findings)
    for i, (spec, struct) in enumerate(zip(site.out_specs, site.out_shapes)):
        vmem += _check_one_spec(case, site, spec,
                                tuple(struct.shape), struct.dtype,
                                f"out[{i}]", findings)
    for ref in site.scratch_shapes:
        vmem += int(np.prod(tuple(ref.shape), dtype=np.int64)) * \
            np.dtype(ref.dtype).itemsize

    if vmem > case.vmem_budget:
        findings.append(Finding(
            rule="PAL002", path=case.path, line=0, context=ctx,
            detail="vmem",
            message=f"estimated VMEM {vmem / 2**20:.2f} MiB (double-"
                    f"buffered blocks + scratch) exceeds the "
                    f"{case.vmem_budget / 2**20:.2f} MiB budget"))
    return findings


def check_kernel_case(case: KernelCase) -> List[Finding]:
    """Capture + check every pallas_call the case's builder reaches."""
    try:
        with capture_pallas() as sites:
            case.build()
    except Exception as e:
        return [Finding(
            rule="PAL001", path=case.path, line=0, context=case.name,
            detail="build-error",
            message=f"kernel builder failed under capture: "
                    f"{type(e).__name__}: {e}")]
    if not sites:
        return [Finding(
            rule="PAL001", path=case.path, line=0, context=case.name,
            detail="no-sites",
            message="builder ran but no pallas_call was captured")]
    out: List[Finding] = []
    for site in sites:
        out += check_site(case, site)
    return out


def check_oracle_gate(case: KernelCase, ops_source: str) -> List[Finding]:
    """PAL004: ops.py must reference both the ref oracle and the kernel's
    public symbol (the dispatch gate)."""
    findings: List[Finding] = []
    if f"ref.{case.oracle}" not in ops_source:
        findings.append(Finding(
            rule="PAL004", path=case.path, line=0, context=case.name,
            detail="oracle",
            message=f"kernels/ops.py never references ref.{case.oracle} — "
                    f"no registered oracle for {case.name}"))
    if case.fn_name not in ops_source:
        findings.append(Finding(
            rule="PAL004", path=case.path, line=0, context=case.name,
            detail="gate",
            message=f"kernels/ops.py never references {case.fn_name} — "
                    f"no dispatch gate for {case.name}"))
    return findings


# ---------------------------------------------------------------------------
# the registry: every shipped kernel with representative decode-ish shapes
# ---------------------------------------------------------------------------
def _build_spmm24() -> None:
    import jax.numpy as jnp
    from repro.kernels import spmm24 as mod
    x = jnp.zeros((8, 2048), jnp.float32)
    vals = jnp.zeros((512, 1024), jnp.float32)
    meta = jnp.zeros((512, 512), jnp.uint8)
    mod.spmm24(x, vals, meta, 2048)


def _build_round24() -> None:
    import jax.numpy as jnp
    from repro.kernels import round24 as mod
    mod.round24(jnp.zeros((512, 4096), jnp.float32))


def _build_fista() -> None:
    import jax.numpy as jnp
    from repro.kernels import fista_step as mod
    y = jnp.zeros((512, 1024), jnp.float32)
    G = jnp.zeros((1024, 1024), jnp.float32)
    B = jnp.zeros((512, 1024), jnp.float32)
    mod.fista_prox_step(y, G, B, 0.1, 0.01)


def _build_flash() -> None:
    import jax.numpy as jnp
    from repro.kernels import flash_attention as mod
    q = jnp.zeros((1, 4, 256, 128), jnp.float32)
    kv = jnp.zeros((1, 2, 256, 128), jnp.float32)
    mod.flash_attention(q, kv, kv, causal=True, window=64)


def _build_paged() -> None:
    import jax.numpy as jnp
    from repro.kernels import paged_attention as mod
    S, nq, nkv, hd, bs, nblocks = 2, 8, 2, 128, 8, 8
    g = nq // nkv
    q = jnp.zeros((S, nq, hd), jnp.float32)
    pool = jnp.zeros((nblocks * bs, nkv, hd), jnp.float32)
    tables = jnp.asarray([[0, 1, 2, 7], [3, 4, 5, 6]], jnp.int32)
    pos = jnp.asarray([5, 9], jnp.int32)
    active = jnp.asarray([1, 1], jnp.int32)
    mod.paged_decode_attn(q, pool, pool, tables, pos, active, block_size=bs)
    d = 256
    wo_vals = jnp.zeros((d, nq * hd // 2), jnp.float32)
    wo_meta = jnp.zeros((d, nq * hd // 4), jnp.uint8)
    mod.paged_decode_attn(q, pool, pool, tables, pos, active, block_size=bs,
                          wo_vals=wo_vals, wo_meta=wo_meta)
    del g


def _build_fused_mlp() -> None:
    import jax.numpy as jnp
    from repro.kernels import paged_attention as mod
    B, d, f = 4, 512, 1024
    w1v = jnp.zeros((f, d // 2), jnp.float32)
    w1m = jnp.zeros((f, d // 4), jnp.uint8)
    w2v = jnp.zeros((d, f // 2), jnp.float32)
    w2m = jnp.zeros((d, f // 4), jnp.uint8)
    x = jnp.zeros((B, d), jnp.float32)
    mod.fused_mlp24(x, w1v, w1m, None, w1v, w1m, w2v, w2m, None)


KERNEL_CASES: List[KernelCase] = [
    KernelCase("spmm24", "src/repro/kernels/spmm24.py", "spmm24",
               "spmm24", 4 * 2**20, _build_spmm24),
    KernelCase("round24", "src/repro/kernels/round24.py", "round24",
               "round24", 8 * 2**20, _build_round24),
    KernelCase("fista_step", "src/repro/kernels/fista_step.py",
               "fista_prox_step", "fista_prox_step", 4 * 2**20, _build_fista),
    KernelCase("flash_attention", "src/repro/kernels/flash_attention.py",
               "flash_attention", "flash_attention", 6 * 2**20, _build_flash),
    KernelCase("paged_attention", "src/repro/kernels/paged_attention.py",
               "paged_decode_attn", "paged_attention", 4 * 2**20,
               _build_paged),
    KernelCase("fused_mlp24", "src/repro/kernels/paged_attention.py",
               "fused_mlp24", "fused_mlp24", 8 * 2**20, _build_fused_mlp),
]


def check_kernels(root: str = ".",
                  cases: Optional[List[KernelCase]] = None) -> List[Finding]:
    """Run the full Pallas family over the registered kernels."""
    cases = KERNEL_CASES if cases is None else cases
    ops_path = os.path.join(root, "src", "repro", "kernels", "ops.py")
    try:
        with open(ops_path, "r", encoding="utf-8") as fh:
            ops_source = fh.read()
    except OSError:
        ops_source = ""
    findings: List[Finding] = []
    for case in cases:
        findings += check_kernel_case(case)
        findings += check_oracle_gate(case, ops_source)
    return findings
