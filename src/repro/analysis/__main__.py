"""CLI: ``python -m repro.analysis [paths...]``.

Modes:
  (default)          AST rules (JAX/MESH) over the given paths + the
                     Pallas kernel checker over the registered kernels.
  --runtime          trace-budget enforcement: patches jax.jit, runs the
                     tier-1 entry-point scenarios, checks TRACE_BUDGETS.
  --no-pallas        skip the kernel checker (pure AST pass).

Findings not present in the baseline (``--baseline``, default
``analysis_baseline.json``) fail the run with exit code 1.
``--strict-baseline`` additionally fails on stale baseline entries, so
fixed violations must be removed from the file.  ``--report`` writes
every finding (new + suppressed) as JSON for the CI artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

from .core import Finding, apply_baseline, load_baseline


def main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro's static-analysis pass (DESIGN.md §12)")
    ap.add_argument("paths", nargs="*", default=[],
                    help="files/directories to lint (default: src/)")
    ap.add_argument("--baseline", default="analysis_baseline.json",
                    help="accepted-findings file to diff against")
    ap.add_argument("--report", default=None,
                    help="write the full finding list as JSON here")
    ap.add_argument("--runtime", action="store_true",
                    help="run the trace-budget scenarios (slow; needs a "
                         "working jax install)")
    ap.add_argument("--no-pallas", action="store_true",
                    help="skip the Pallas kernel checker")
    ap.add_argument("--strict-baseline", action="store_true",
                    help="also fail on stale baseline entries")
    args = ap.parse_args(argv)
    paths = args.paths or ["src"]

    findings: List[Finding] = []
    if args.runtime:
        # patch-before-import: the recorder must see module-level jits
        from .trace_budget import run_runtime_check
        findings += run_runtime_check()
    else:
        from . import run_source_rules
        findings += run_source_rules(paths)
        if not args.no_pallas:
            from .rules_pallas import check_kernels
            findings += check_kernels()

    baseline = load_baseline(args.baseline)
    new, suppressed, stale = apply_baseline(findings, baseline)
    if args.runtime:
        # the baseline holds static findings; a runtime-only run cannot
        # re-derive them, so stale detection would false-positive
        stale = []

    if args.report:
        with open(args.report, "w", encoding="utf-8") as f:
            json.dump({
                "new": [x.to_dict() for x in new],
                "suppressed": [x.to_dict() for x in suppressed],
                "stale_baseline": stale,
            }, f, indent=1, sort_keys=True)
            f.write("\n")

    for f_ in new:
        print(f_.format())
    if suppressed:
        print(f"[baseline] {len(suppressed)} finding(s) suppressed by "
              f"{args.baseline}")
    for key in stale:
        print(f"[stale baseline] {key} no longer fires"
              + (" (remove it)" if args.strict_baseline else ""))

    failed = bool(new) or (args.strict_baseline and bool(stale))
    total = len(new) + len(suppressed)
    mode = "runtime" if args.runtime else "static"
    print(f"repro.analysis ({mode}): {len(new)} new, "
          f"{len(suppressed)} baselined, {len(stale)} stale "
          f"({total} total) -> {'FAIL' if failed else 'OK'}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
