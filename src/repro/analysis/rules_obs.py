"""AST rule OBS001: obs recording must stay off the device hot paths.

The observability layer (``repro.obs``, DESIGN.md §14) is host-side by
contract: spans and metric recordings happen around dispatches, never
inside them.  Two placements break that contract:

* **inside a jitted function body** — the recording runs at *trace*
  time (so it fires once per compilation, not once per call) and drags
  host state into a traced context;
* **inside a ``for``/``while`` body of a serving hot-path module** —
  the per-token sibling of JAX003: even a cheap counter bump per token
  adds up, and a span per token floods the ring buffer.  Record once
  per tick at the loop's top level (what ``serve/batcher.py`` does), or
  once after the loop.

Recording calls are recognized structurally, mirroring how JAX003 finds
device values: names bound from registry instrument constructors
(``reg.counter(...)``, ``obs.registry().histogram(...)``) are
*instruments*; ``.observe``/``.inc``/``.set``/``.append`` on an
instrument — or chained directly onto a constructor — and any
``span(...)``/``*.span(...)`` call are *recordings*.  Modules that never
import ``repro.obs`` are skipped entirely.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set, Tuple

from .core import Finding, ModuleCtx, assigned_names, dotted_name, unparse
from .rules_jax import _qualname, collect_jit_sites

# modules whose loop bodies are per-token hot paths (prefix match, same
# contract as rules_jax.HOT_PATH_PREFIXES for JAX003)
HOT_PATH_PREFIXES: Tuple[str, ...] = ("repro.serve.",)

# MetricsRegistry constructors whose results are recording instruments
_INSTRUMENT_MAKERS = {"counter", "gauge", "histogram", "series"}
# methods that record on an instrument
_RECORDING_METHODS = {"observe", "inc", "set", "append"}


def _uses_obs(ctx: ModuleCtx) -> bool:
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Import):
            if any(a.name == "repro.obs" or a.name.startswith("repro.obs.")
                   for a in node.names):
                return True
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod == "repro.obs" or mod.startswith("repro.obs."):
                return True
            if mod == "repro" and any(a.name == "obs" for a in node.names):
                return True
    return False


def _instrument_names(tree: ast.Module) -> Set[str]:
    """Plain names and attribute leaves (``self._m_ttft``) assigned from
    an instrument constructor anywhere in the module."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            f = node.value.func
            if isinstance(f, ast.Attribute) and f.attr in _INSTRUMENT_MAKERS:
                for t in node.targets:
                    if isinstance(t, ast.Attribute):
                        names.add(t.attr)
                    names.update(assigned_names(t))
    return names


def _is_recording_call(node: ast.AST, instruments: Set[str]) -> bool:
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    leaf = f.attr if isinstance(f, ast.Attribute) else (
        f.id if isinstance(f, ast.Name) else "")
    if leaf == "span":
        return True
    if leaf in _RECORDING_METHODS and isinstance(f, ast.Attribute):
        recv = f.value
        if isinstance(recv, ast.Name) and recv.id in instruments:
            return True
        if isinstance(recv, ast.Attribute) and recv.attr in instruments:
            return True
        # chained onto the constructor: reg.histogram("x").observe(v)
        if isinstance(recv, ast.Call) and isinstance(recv.func, ast.Attribute) \
                and recv.func.attr in _INSTRUMENT_MAKERS:
            return True
    return False


class _LoopRecordingChecker(ast.NodeVisitor):
    """JAX003's loop walk, retargeted: recording calls at loop depth >= 1
    are per-token recordings."""

    def __init__(self, ctx: ModuleCtx, qualname: str, instruments: Set[str],
                 findings: List[Finding]) -> None:
        self.ctx = ctx
        self.qualname = qualname
        self.instruments = instruments
        self.findings = findings
        self.loop_depth = 0

    def visit_For(self, node: ast.For) -> None:
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    def visit_While(self, node: ast.While) -> None:
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    def visit_Call(self, node: ast.Call) -> None:
        self.generic_visit(node)
        if self.loop_depth > 0 and _is_recording_call(node, self.instruments):
            self.findings.append(Finding(
                rule="OBS001", path=self.ctx.rel, line=node.lineno,
                context=self.qualname, detail=f"loop:{unparse(node)}",
                message=f"obs recording `{unparse(node)}` inside a hot-path "
                        f"loop body — record once per tick at the loop's "
                        f"top level, or once after the loop"))

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        return  # nested functions are checked as their own scope

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]


def check_jit_recordings(ctx: ModuleCtx) -> List[Finding]:
    """Recording calls inside jitted function bodies (any module)."""
    if not _uses_obs(ctx):
        return []
    instruments = _instrument_names(ctx.tree)
    findings: List[Finding] = []
    seen: Set[int] = set()
    for site in collect_jit_sites(ctx):
        if site.fn is None or id(site.fn) in seen:
            continue
        seen.add(id(site.fn))
        qn = getattr(site.fn, "_analysis_qualname", site.fn.name)
        for stmt in site.fn.body:
            for node in ast.walk(stmt):
                if _is_recording_call(node, instruments):
                    findings.append(Finding(
                        rule="OBS001", path=ctx.rel, line=node.lineno,
                        context=qn, detail=f"jit:{unparse(node)}",
                        message=f"obs recording `{unparse(node)}` inside a "
                                f"jitted function — it runs at trace time, "
                                f"not per call; record after the dispatch"))
    return findings


def check_loop_recordings(ctx: ModuleCtx,
                          hot: Optional[Iterable[str]] = None
                          ) -> List[Finding]:
    """Recording calls inside loop bodies of hot-path modules."""
    prefixes = tuple(hot) if hot is not None else HOT_PATH_PREFIXES
    if not any(ctx.modname.startswith(p) or ctx.modname == p.rstrip(".")
               for p in prefixes):
        return []
    if not _uses_obs(ctx):
        return []
    instruments = _instrument_names(ctx.tree)
    findings: List[Finding] = []
    stack: List[ast.AST] = []

    def walk(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qn = _qualname(stack + [child])
                chk = _LoopRecordingChecker(ctx, qn, instruments, findings)
                for st in child.body:
                    chk.visit(st)
                stack.append(child)
                walk(child)
                stack.pop()
            elif isinstance(child, ast.ClassDef):
                stack.append(child)
                walk(child)
                stack.pop()
            else:
                walk(child)

    walk(ctx.tree)
    return findings


def check_module(ctx: ModuleCtx,
                 hot: Optional[Iterable[str]] = None) -> List[Finding]:
    """All OBS rules for one module."""
    out: List[Finding] = []
    out += check_jit_recordings(ctx)
    out += check_loop_recordings(ctx, hot)
    return out
