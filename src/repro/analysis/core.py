"""Shared machinery of the static-analysis pass (DESIGN.md §12).

A *finding* is one rule violation at one source location.  Its identity
(:attr:`Finding.key`) deliberately excludes the line number — baselines
must survive unrelated edits above the flagged line — and is instead
``rule:path:context:detail`` where ``context`` is the enclosing function
qualname and ``detail`` a short stable token (usually the flagged
expression's source text).

The committed ``analysis_baseline.json`` maps finding keys to one-line
justifications.  CI fails on any finding whose key is not in the
baseline; under ``--strict-baseline`` it also fails on stale entries, so
the baseline can only shrink unless a justified exception is added in
the same PR that introduces it.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
from typing import Dict, Iterable, Iterator, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str        # e.g. "JAX001"
    path: str        # repo-relative posix path
    line: int        # 1-based; informational only (not part of the key)
    context: str     # enclosing function/kernel qualname ("" = module)
    detail: str      # short stable token naming the violating construct
    message: str     # human-readable explanation

    @property
    def key(self) -> str:
        return f"{self.rule}:{self.path}:{self.context}:{self.detail}"

    def format(self) -> str:
        return (f"{self.path}:{self.line}: {self.rule} "
                f"[{self.context or '<module>'}] {self.message}")

    def to_dict(self) -> Dict[str, object]:
        return {**dataclasses.asdict(self), "key": self.key}


@dataclasses.dataclass
class ModuleCtx:
    """One parsed source module handed to the AST rules."""

    path: str                 # filesystem path as given
    rel: str                  # repo-relative posix path (finding identity)
    modname: str              # dotted module name, best effort ("" if n/a)
    tree: ast.Module
    source: str

    @classmethod
    def parse(cls, path: str, root: Optional[str] = None) -> "ModuleCtx":
        with open(path, "r", encoding="utf-8") as f:
            source = f.read()
        rel = relpath(path, root)
        return cls(path=path, rel=rel, modname=modname_of(rel),
                   tree=ast.parse(source, filename=path), source=source)


def relpath(path: str, root: Optional[str] = None) -> str:
    root = root or os.getcwd()
    ap = os.path.abspath(path)
    try:
        rel = os.path.relpath(ap, root)
    except ValueError:          # different drive (windows)
        rel = ap
    return rel.replace(os.sep, "/")


def modname_of(rel: str) -> str:
    """``src/repro/serve/engine.py`` -> ``repro.serve.engine`` (best
    effort; non-package files keep their stem as the module name)."""
    p = rel[:-3] if rel.endswith(".py") else rel
    parts = [x for x in p.split("/") if x]
    if "src" in parts:
        parts = parts[parts.index("src") + 1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def iter_py_files(paths: Iterable[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        elif os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(d for d in dirnames
                                     if d not in ("__pycache__", ".git"))
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        yield os.path.join(dirpath, fn)


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------
def load_baseline(path: str) -> Dict[str, str]:
    """``analysis_baseline.json``: {"findings": {key: justification}}.
    Missing file = empty baseline."""
    if not os.path.exists(path):
        return {}
    with open(path, "r", encoding="utf-8") as f:
        data = json.load(f)
    entries = data.get("findings", data) if isinstance(data, dict) else {}
    if not isinstance(entries, dict) or not all(
            isinstance(k, str) and isinstance(v, str)
            for k, v in entries.items()):
        raise ValueError(f"{path}: baseline must map finding keys to "
                         f"one-line justification strings")
    return dict(entries)


def apply_baseline(findings: List[Finding], baseline: Dict[str, str]
                   ) -> Tuple[List[Finding], List[Finding], List[str]]:
    """Split into (new, suppressed, stale-baseline-keys)."""
    new: List[Finding] = []
    suppressed: List[Finding] = []
    seen = set()
    for f in findings:
        if f.key in baseline:
            suppressed.append(f)
            seen.add(f.key)
        else:
            new.append(f)
    stale = sorted(set(baseline) - seen)
    return new, suppressed, stale


# ---------------------------------------------------------------------------
# small AST helpers shared by the rule modules
# ---------------------------------------------------------------------------
def dotted_name(node: ast.AST) -> str:
    """``jax.random.categorical`` for the matching Attribute/Name chain
    ("" when the expression is not a plain dotted name)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def unparse(node: ast.AST, limit: int = 60) -> str:
    try:
        text = ast.unparse(node)
    except Exception:
        text = f"<{type(node).__name__}>"
    return text if len(text) <= limit else text[: limit - 3] + "..."


def assigned_names(target: ast.AST) -> List[str]:
    """Flat list of plain names bound by an assignment target."""
    out: List[str] = []
    for n in ast.walk(target):
        if isinstance(n, ast.Name) and isinstance(n.ctx, (ast.Store,)):
            out.append(n.id)
    return out
