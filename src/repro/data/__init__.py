"""Data pipeline: synthetic corpus, byte tokenizer, calibration sampling."""
from repro.data.corpus import CorpusConfig, MarkovCorpus, batch_to_model_inputs
from repro.data.calibration import CalibConfig, calibration_batches

__all__ = ["CorpusConfig", "MarkovCorpus", "batch_to_model_inputs",
           "CalibConfig", "calibration_batches"]
