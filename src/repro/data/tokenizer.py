"""Byte-level tokenizer (vocab = 256 bytes + specials).

Lossless on arbitrary UTF-8; used by the text-facing examples.  IDs:
0 = PAD, 1 = BOS, 2 = EOS, byte b -> b + 3.
"""
from __future__ import annotations

from typing import List

PAD, BOS, EOS = 0, 1, 2
OFFSET = 3
VOCAB = 256 + OFFSET


def encode(text: str, bos: bool = True, eos: bool = True) -> List[int]:
    ids = [b + OFFSET for b in text.encode("utf-8")]
    return ([BOS] if bos else []) + ids + ([EOS] if eos else [])


def decode(ids) -> str:
    data = bytes(i - OFFSET for i in ids if i >= OFFSET)
    return data.decode("utf-8", errors="replace")


def pad_to(ids: List[int], length: int) -> List[int]:
    return (ids + [PAD] * length)[:length]
