"""Synthetic corpus with learnable structure (C4 stand-in).

The container has no datasets, so calibration/training/eval text is
generated from a seeded sparse 2-gram Markov chain over the model's
token vocabulary with Zipfian marginals.  The chain has real structure
(per-state branching factor ``branch``), so a language model trained on
it converges toward the chain entropy — giving the e2e pruning
benchmarks a meaningful perplexity axis, and held-out splits a
train/test distinction (disjoint seed streams).

Tokens are drawn directly (no byte detour) so every architecture's
vocab size is served; ``repro.data.tokenizer`` provides the byte-level
path for real-text use.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    vocab: int
    branch: int = 8            # out-degree of each chain state
    zipf_a: float = 1.2        # Zipf exponent of target marginals
    temperature: float = 0.7   # <1 sharpens transitions (lower entropy)
    seed: int = 0


class MarkovCorpus:
    """Deterministic synthetic token stream."""

    def __init__(self, cfg: CorpusConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V, B = cfg.vocab, min(cfg.branch, cfg.vocab)
        # Zipfian candidate pool: successors biased toward frequent tokens
        ranks = np.arange(1, V + 1, dtype=np.float64)
        zipf = 1.0 / ranks ** cfg.zipf_a
        zipf /= zipf.sum()
        self.succ = np.empty((V, B), np.int64)
        self.prob = np.empty((V, B), np.float64)
        for s in range(V):
            # per-state successor set: mix of global-frequent + random tokens
            cand = rng.choice(V, size=B, replace=False, p=zipf)
            self.succ[s] = cand
            logits = rng.normal(size=B) / cfg.temperature
            p = np.exp(logits - logits.max())
            self.prob[s] = p / p.sum()
        # stationary-ish start distribution
        self.start = zipf

    @property
    def entropy_per_token(self) -> float:
        """Mean transition entropy in nats (ppl floor = exp of this)."""
        h = -(self.prob * np.log(self.prob)).sum(axis=1)
        return float(h.mean())

    def sample(self, length: int, rng: np.random.Generator) -> np.ndarray:
        out = np.empty((length,), np.int64)
        s = rng.choice(self.cfg.vocab, p=self.start)
        for t in range(length):
            j = rng.choice(self.succ.shape[1], p=self.prob[s])
            s = self.succ[s, j]
            out[t] = s
        return out

    def batches(self, batch: int, seq: int, split: str = "train",
                start_step: int = 0) -> Iterator[Tuple[int, np.ndarray]]:
        """Infinite deterministic batch stream.  Each (step, tokens) is a
        pure function of (seed, split, step) => checkpoint/resume replays
        the exact stream from any cursor."""
        split_off = {"train": 0, "valid": 1_000_003, "calib": 2_000_003,
                     "test": 3_000_017}[split]
        step = start_step
        while True:
            rng = np.random.default_rng(
                (self.cfg.seed * 2654435761 + split_off + step) % (2 ** 63))
            toks = np.stack([self.sample(seq + 1, rng) for _ in range(batch)])
            yield step, toks.astype(np.int32)
            step += 1


def batch_to_model_inputs(tokens: np.ndarray) -> dict:
    """(B, S+1) sampled tokens -> {"tokens": (B,S), "labels": (B,S)}."""
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:].copy()}
