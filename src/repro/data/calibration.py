"""Calibration sampling for post-training pruning (paper Sec. 4.1).

The paper draws 128 sequences of max-embedding-length tokens from the
first shard of C4.  Here the C4 stand-in is the synthetic Markov corpus;
the sampler yields a fixed, seeded list of calibration batches shaped
for the pruning relay.  Batches are kept small (few long sequences) so
the activation relay holds ONE layer's activations at a time, matching
the paper's 40GB single-GPU footprint claim.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.data.corpus import MarkovCorpus, batch_to_model_inputs


@dataclasses.dataclass(frozen=True)
class CalibConfig:
    num_sequences: int = 128     # paper default
    seq_len: int = 2048          # "max embedding length of the LLM"
    batch_size: int = 8          # relay micro-batch (memory knob)
    seed: int = 1234


def calibration_batches(corpus: MarkovCorpus, cfg: CalibConfig,
                        extras: Dict[str, np.ndarray] | None = None
                        ) -> List[Dict[str, jnp.ndarray]]:
    """List of model-input batches totalling ``num_sequences`` sequences."""
    out: List[Dict[str, jnp.ndarray]] = []
    it = corpus.batches(cfg.batch_size, cfg.seq_len, split="calib",
                        start_step=cfg.seed)
    done = 0
    while done < cfg.num_sequences:
        _, toks = next(it)
        take = min(cfg.batch_size, cfg.num_sequences - done)
        b = batch_to_model_inputs(toks[:take])
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        if extras:
            for k, v in extras.items():
                batch[k] = jnp.asarray(v[:take])
        out.append(batch)
        done += take
    return out
