"""Roofline analysis over the dry-run artifacts.

Three terms per (arch x shape), TPU v5e constants:

    compute    = flops_per_device / 197e12           [s]
    memory     = bytes_per_device / 819e9            [s]
    collective = collective_bytes_per_device / 50e9  [s]

Record sources (see launch/dryrun.py):

* ``--dir``   : scan-mode records — authoritative for per-device MEMORY
  (memory_analysis of the production lowering), but XLA cost analysis
  counts scan bodies once, so flops/bytes/collectives are ~L too small;
* ``--extra`` : depth-extrapolation records — authoritative for FLOPs,
  bytes-accessed and collective bytes.

The table merges them (costs from extra, memory from dir).  For MoE
archs the recorded ``moe_flops_deflator`` divides the flops term — XLA
charges ragged_dot as dense over ALL experts while each row only visits
top-k.  "bytes accessed" counts every HLO op's operands (upper bound on
HBM traffic, ignores fusion reuse); it is the standard first-order proxy
and is consistent across cells.  The dominant term is the bottleneck the
§Perf loop iterates on; MODEL_FLOPS / HLO_FLOPS flags remat/redundancy
waste; roofline fraction = useful model flops per chip / (bound * peak).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any, Dict, List, Optional

PEAK_FLOPS = 197e12        # bf16 / chip (v5e)
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_records(dir_: str, mesh: str = "single") -> Dict[str, Dict]:
    recs = {}
    for path in sorted(glob.glob(os.path.join(dir_, f"{mesh}__*.json"))):
        with open(path) as f:
            r = json.load(f)
        recs[f"{r['arch']}__{r['shape']}"] = r
    return recs


def merge(scan_recs: Dict[str, Dict], extrap_recs: Optional[Dict[str, Dict]]
          ) -> List[Dict]:
    out = []
    for key, r in scan_recs.items():
        if extrap_recs and key in extrap_recs and not extrap_recs[key].get("skipped"):
            e = extrap_recs[key]
            r = {**r,
                 "flops_per_device": e["flops_per_device"],
                 "bytes_per_device": e["bytes_per_device"],
                 "collectives": e["collectives"],
                 "moe_flops_deflator": e.get("moe_flops_deflator", 1.0),
                 "cost_method": e.get("method", "extrapolated")}
        out.append(r)
    return out


def analyze(rec: Dict[str, Any]) -> Dict[str, Any]:
    if rec.get("skipped"):
        return {**rec, "dominant": "—"}
    defl = rec.get("moe_flops_deflator", 1.0) or 1.0
    flops = rec["flops_per_device"] / defl
    compute = flops / PEAK_FLOPS
    memory = rec["bytes_per_device"] / HBM_BW
    coll = rec["collectives"]["total_bytes"] / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = rec["model_flops_global"] / rec["chips"]
    useful = mf / max(flops, 1.0)
    frac = mf / max(bound * PEAK_FLOPS, 1e-30)
    return {**rec, "compute_s": compute, "memory_s": memory,
            "collective_s": coll, "dominant": dominant,
            "useful_flops_ratio": useful, "roofline_fraction": frac}


def _fmt(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x*1e6:.1f}µs"
    if x < 0.1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.3f}s"


def markdown_table(recs: List[Dict[str, Any]]) -> str:
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "mem/dev GB | useful-FLOPs | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    key = lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]))
    for r in sorted(recs, key=key):
        if r.get("skipped"):
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — | — |")
            continue
        a = analyze(r)
        mem_gb = a.get("peak_bytes", 0) / 1e9
        rows.append(
            f"| {a['arch']} | {a['shape']} | {_fmt(a['compute_s'])} | "
            f"{_fmt(a['memory_s'])} | {_fmt(a['collective_s'])} | "
            f"**{a['dominant']}** | {mem_gb:.2f} | "
            f"{a['useful_flops_ratio']:.2f} | {a['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--extra", default="experiments/dryrun_extrap",
                    help="depth-extrapolation records (accurate costs)")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default=None, help="write markdown here")
    args = ap.parse_args()
    scan = load_records(args.dir, args.mesh)
    extra = load_records(args.extra, args.mesh) if args.extra and \
        os.path.isdir(args.extra) else None
    recs = merge(scan, extra)
    if not recs:
        raise SystemExit(f"no dry-run records in {args.dir} for mesh={args.mesh}")
    table = markdown_table(recs)
    print(table)
    if args.out:
        with open(args.out, "w") as f:
            f.write(table + "\n")


if __name__ == "__main__":
    main()
