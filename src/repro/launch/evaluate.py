"""Quality-evaluation driver: perplexity / KL / error budget on any
checkpoint-store run.

    # a prune run (dense_model + pruned_model saved by launch/prune.py):
    python -m repro.launch.evaluate --checkpoint /tmp/run --against-dense

    # a training run (step_* checkpoints): dense perplexity only
    python -m repro.launch.evaluate --checkpoint /tmp/train_run

    # override eval knobs via a recipe's `eval` section
    python -m repro.launch.evaluate --checkpoint /tmp/run --recipe r.json

The evaluated checkpoint is resolved in order: ``pruned_model`` (saved by
launch/prune.py), a ``dense_model`` + per-unit ``unit_*`` scheduler
checkpoints (a prune run that died before its final save — units are
merged back into the dense params), then the latest trainer ``step_*``.
``--against-dense`` additionally loads the dense reference and reports
KL divergence, greedy-decode agreement and the per-unit error-budget
audit (DESIGN.md §8).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro import api
from repro.checkpoint import store
from repro.core import sequential as seq_lib
from repro.data import CorpusConfig, MarkovCorpus
from repro.eval import quality_report
from repro.utils import get_logger

log = get_logger("launch.evaluate")

DENSE_MODEL, PRUNED_MODEL = api.DENSE_MODEL, api.PRUNED_MODEL


def _load_params(run_dir: str, name: str, like) -> Tuple[Any, Dict]:
    tree, extra = store.load(run_dir, name, {"params": like})
    return tree["params"], extra


def _assemble_from_units(model, dense_params, run_dir: str
                         ) -> Tuple[Any, List[Dict]]:
    """Merge a prune run's per-unit checkpoints into the dense params."""
    params, reports = dense_params, []
    merged = 0
    for spec in model.units():
        name = f"unit_{spec.name}"
        if not store.exists(run_dir, name):
            continue
        like = {"unit_params": seq_lib._unit_params_of(dense_params, spec)}
        tree, extra = store.load(run_dir, name, like)
        params = seq_lib._write_unit_params(params, spec, tree["unit_params"])
        reports.extend(extra.get("reports", []))
        merged += 1
    if merged == 0:
        raise FileNotFoundError(f"no unit_* checkpoints under {run_dir}")
    log.info("assembled pruned params from %d unit checkpoints", merged)
    return params, reports


def resolve_run(run_dir: str, recipe_path: Optional[str] = None
                ) -> Dict[str, Any]:
    """Inspect a checkpoint-store run dir; returns what it holds.

    {kind: "prune" | "units" | "train", recipe, smoke, corpus_seed, extra}

    The run's own recipe (persisted with its checkpoints) stays the
    source of truth for what was pruned — a ``--recipe`` file only
    overrides the evaluation: its ``eval`` section replaces the stored
    one.  Without a stored recipe (e.g. a bare train run with no
    recorded arch) the ``--recipe`` file is used wholesale.
    """
    # a typo'd recipe (e.g. an unknown `eval` key) must die before any
    # checkpoint is touched, matching PruneRecipe's load-time strictness
    override = api.PruneRecipe.from_json(recipe_path) if recipe_path else None
    if not os.path.isdir(run_dir):
        raise FileNotFoundError(f"checkpoint run dir not found: {run_dir}")
    extra: Dict[str, Any] = {}
    if store.exists(run_dir, PRUNED_MODEL):
        kind = "prune"
        with open(os.path.join(run_dir, PRUNED_MODEL, "MANIFEST.json")) as f:
            extra = json.load(f)["extra"]
    elif store.exists(run_dir, DENSE_MODEL):
        kind = "units"
        with open(os.path.join(run_dir, DENSE_MODEL, "MANIFEST.json")) as f:
            extra = json.load(f)["extra"]
    elif store.latest_step(run_dir) is not None:
        kind = "train"
        name = store.step_name(store.latest_step(run_dir))
        with open(os.path.join(run_dir, name, "MANIFEST.json")) as f:
            extra = json.load(f)["extra"]
    else:
        raise FileNotFoundError(
            f"{run_dir} holds no pruned_model/dense_model/step_* checkpoint")
    if "recipe" in extra:
        recipe = api.PruneRecipe.from_dict(extra["recipe"])
    elif "arch" in extra:
        # train runs record arch/smoke but no recipe
        recipe = api.PruneRecipe(arch=extra["arch"])
    else:
        recipe = override if override is not None else api.PruneRecipe()
    if override is not None and recipe is not override:
        recipe = dataclasses.replace(recipe, eval=override.eval)
    return {"kind": kind, "recipe": recipe, "extra": extra,
            "smoke": bool(extra.get("smoke", True)),
            "corpus_seed": int(extra.get("corpus_seed", 0))}


def evaluate_run(run_dir: str, recipe_path: Optional[str] = None,
                 against_dense: bool = False, corpus_seed: Optional[int] = None,
                 mesh: Optional[str] = None):
    """Evaluate a checkpoint-store run; returns a QualityReport.

    ``mesh`` ("DATAxMODEL", e.g. "4x2") shards the perplexity/KL batches
    over the mesh "data" axis via one MeshExecutor (distributed layer);
    it overrides the mesh recorded in the run's recipe."""
    run = resolve_run(run_dir, recipe_path)
    recipe, kind = run["recipe"], run["kind"]
    if mesh is not None:
        executor = api.MeshExecutor.from_spec(mesh)   # explicit: fail loudly
    else:
        try:
            executor = recipe.build_executor()
        except ValueError as exc:
            # the run was pruned on a mesh this machine doesn't have —
            # a checkpoint must stay evaluable anywhere, so degrade to
            # the (bitwise-identical) single-device eval path
            log.warning("recorded mesh unavailable (%s); evaluating "
                        "single-device", exc)
            executor = None
    model = recipe.load_model(smoke=run["smoke"])
    like = model.init(jax.random.PRNGKey(0))
    seed = run["corpus_seed"] if corpus_seed is None else corpus_seed
    corpus = MarkovCorpus(CorpusConfig(vocab=model.cfg.vocab, seed=seed))
    cfg = recipe.eval_config()

    dense_params = reports = None
    if kind == "train":
        step = store.latest_step(run_dir)
        params, _ = _load_params(run_dir, store.step_name(step), like)
        source = store.step_name(step)
    elif kind == "prune":
        params, extra = _load_params(run_dir, PRUNED_MODEL, like)
        reports = extra.get("reports") or None
        source = PRUNED_MODEL
    else:  # units: dense_model + unit_* scheduler checkpoints
        dense0, _ = _load_params(run_dir, DENSE_MODEL, like)
        params, reports = _assemble_from_units(model, dense0, run_dir)
        source = "dense_model+unit_*"
    if against_dense:
        if kind == "train":
            raise ValueError("--against-dense needs a prune run "
                             "(dense_model checkpoint); this is a train run")
        dense_params = (dense0 if kind == "units"
                        else _load_params(run_dir, DENSE_MODEL, like)[0])

    report = quality_report(
        model, params, corpus, cfg, dense_params=dense_params,
        reports=reports,
        meta={"checkpoint": run_dir, "source": source, "kind": kind,
              "arch": recipe.arch, "method": recipe.method,
              "sparsity": recipe.sparsity},
        executor=executor)
    return report


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--checkpoint", required=True,
                    help="checkpoint-store run dir (a launch/prune.py "
                         "--ckpt-dir or a launch/train.py --ckpt-dir)")
    ap.add_argument("--recipe", default=None,
                    help="PruneRecipe JSON overriding the one stored with "
                         "the checkpoint (its `eval` section configures "
                         "this evaluation)")
    ap.add_argument("--against-dense", action="store_true",
                    help="also evaluate the run's dense reference: dense "
                         "perplexity, KL(dense||pruned), greedy agreement "
                         "and the per-unit error-budget audit")
    ap.add_argument("--corpus-seed", type=int, default=None,
                    help="override the corpus seed recorded with the run")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="device mesh 'dataxmodel' (e.g. '4x2'): shard the "
                         "eval batches over the mesh 'data' axis")
    ap.add_argument("--out", default=None, help="write the JSON report here")
    args = ap.parse_args(argv)

    try:
        report = evaluate_run(args.checkpoint, args.recipe,
                              args.against_dense, args.corpus_seed,
                              mesh=args.mesh)
    except (FileNotFoundError, ValueError, KeyError) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    meta = report.meta
    print(f"checkpoint={meta['checkpoint']} source={meta['source']} "
          f"arch={meta['arch']} method={meta['method']} "
          f"sparsity={meta['sparsity']}")
    print(report.summary())
    if report.error_budget:
        worst = max(report.error_budget,
                    key=lambda r: r["output_rel_err"])
        print(f"error budget: {len(report.error_budget)} units audited, "
              f"worst {worst['unit']} rel_err={worst['output_rel_err']:.4f} "
              f"budget={worst['op_budget']:.4f} within={worst['within_budget']}")
    if args.out:
        report.to_json(args.out)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
