"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before calling.

Axis roles (DESIGN.md §5, §10):
    pod   — outer data-parallel axis (or pipeline stages with --pipeline)
    data  — within-pod data parallelism (+ layer-unit queue for pruning,
            calibration/eval batch sharding)
    model — tensor/expert parallelism (+ row-parallel FISTA, decode TP)
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def factor_debug_mesh(devices: int, multi_pod: bool = False
                      ) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Factor ``devices`` into the debug-mesh shape (pure, no jax state).

    Invariants (pinned in tests/test_mesh.py):
      * the shape's product is exactly ``devices`` — EVERY count builds,
        including 1, odd counts, and non-powers-of-two (6, 12);
      * "model" is the largest power-of-two divisor that does not exceed
        "data" (model^2 <= per-pod devices), so the model axis never
        dominates the data axis and never degenerates the data axis to 0.

    The seed implementation grew "model" while ``devices % (2*model)``
    held, which (a) divided by zero-sized data axes for devices < 4
    (``make_debug_mesh(1)`` -> a (0, 2) mesh) and (b) mis-factored
    2*odd counts under ``multi_pod`` (6 -> (2, 1, 2): product 4 != 6).
    """
    if devices < 1:
        raise ValueError(f"need >= 1 device, got {devices}")
    pod: Tuple[int, ...] = ()
    rest = devices
    if multi_pod:
        if devices % 2 != 0:
            raise ValueError(f"multi_pod needs an even device count, got {devices}")
        pod, rest = (2,), devices // 2
    model = 1
    while rest % (model * 2) == 0 and (model * 2) ** 2 <= rest:
        model *= 2
    shape = pod + (rest // model, model)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return shape, axes


def make_debug_mesh(devices: int, multi_pod: bool = False):
    """Scaled-down mesh with the same axis names (tests / CI)."""
    shape, axes = factor_debug_mesh(devices, multi_pod=multi_pod)
    return jax.make_mesh(shape, axes)
