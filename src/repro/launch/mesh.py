"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before calling.

Axis roles (DESIGN.md §5):
    pod   — outer data-parallel axis (or pipeline stages with --pipeline)
    data  — within-pod data parallelism (+ layer-unit queue for pruning)
    model — tensor/expert parallelism (+ row-parallel FISTA)
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(devices: int, multi_pod: bool = False):
    """Scaled-down mesh with the same axis names (tests / CI)."""
    if multi_pod:
        assert devices % 2 == 0
        rest = devices // 2
        model = 2
        while rest % (model * 2) == 0 and model < rest // model:
            model *= 2
        return jax.make_mesh((2, rest // model, model), ("pod", "data", "model"))
    model = 2
    while devices % (model * 2) == 0 and model < devices // model:
        model *= 2
    return jax.make_mesh((devices // model, model), ("data", "model"))
