"""Training driver: ``python -m repro.launch.train --arch <id> [--smoke]``.

End-to-end: synthetic corpus -> Trainer (AdamW, schedule, checkpoints,
restart) -> held-out perplexity.  ``--smoke`` uses the reduced config
(CPU-friendly); full configs expect accelerators and the sharded step
from distributed/train.py (enabled with --mesh).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.base import ALL_ARCHS
from repro.data import CorpusConfig, MarkovCorpus
from repro.models.registry import load_arch
from repro.train import AdamWConfig, TrainConfig, Trainer, evaluate_ppl
from repro.utils import get_logger

log = get_logger("launch.train")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt125m-proxy",
                    choices=ALL_ARCHS + ["opt125m-proxy"])
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd", "const"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    model = load_arch(args.arch, smoke=args.smoke)
    corpus = MarkovCorpus(CorpusConfig(vocab=model.cfg.vocab, seed=args.seed))
    extras_fn = None
    if model.cfg.family in ("vlm", "encdec"):
        proto = model.make_batch(jax.random.PRNGKey(0), args.batch, args.seq)
        extra = {k: v for k, v in proto.items() if k not in ("tokens", "labels")}
        extras_fn = lambda b: {k: v[:b] for k, v in extra.items()}

    cfg = TrainConfig(
        steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, seed=args.seed,
        ckpt_extra={"arch": args.arch, "smoke": args.smoke,
                    "corpus_seed": args.seed},
        optim=AdamWConfig(lr=args.lr, schedule=args.schedule,
                          warmup_steps=max(args.steps // 20, 1),
                          total_steps=args.steps))
    tr = Trainer(model, corpus, cfg, extras_fn=extras_fn)
    if args.resume and tr.restore():
        log.info("resuming at step %d", tr.step)
    out = tr.run()
    ppl = evaluate_ppl(model, tr.params, corpus, args.batch, args.seq, 4,
                       extras=extras_fn(args.batch) if extras_fn else None)
    loss_s = "n/a" if out["final_loss"] is None else f"{out['final_loss']:.4f}"
    print(f"arch={args.arch} steps={out['steps']} final_loss={loss_s} "
          f"valid_ppl={ppl:.3f} wall={out['wall_seconds']:.1f}s")


if __name__ == "__main__":
    main()
