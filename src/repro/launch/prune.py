"""Pruning driver: train (or load) a model, prune it with any method,
report perplexity before/after.

    python -m repro.launch.prune --arch opt125m-proxy --method fista \
        --sparsity 50% --workers 4 --ckpt-dir /tmp/prune_ckpts

This is the end-to-end path of the paper: calibration data -> layer-wise
FISTAPruner with intra-layer error correction -> pruned checkpoint ->
WikiText-style perplexity table row.
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs.base import ALL_ARCHS
from repro.core.driver import parallel_prune
from repro.core.pruner import PrunerConfig
from repro.core.scheduler import SchedulerConfig
from repro.core.sequential import SequentialConfig
from repro.core.sparsity import SparsitySpec
from repro.data import CalibConfig, CorpusConfig, MarkovCorpus, calibration_batches
from repro.models.registry import load_arch
from repro.train import AdamWConfig, TrainConfig, Trainer, evaluate_ppl
from repro.utils import get_logger

log = get_logger("launch.prune")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt125m-proxy",
                    choices=ALL_ARCHS + ["opt125m-proxy"])
    ap.add_argument("--method", default="fista",
                    choices=["fista", "wanda", "sparsegpt", "magnitude"])
    ap.add_argument("--sparsity", default="50%", help="'50%%' or '2:4'")
    ap.add_argument("--correction", default="intra", choices=["intra", "none", "full"])
    ap.add_argument("--warm-start", default="wanda",
                    choices=["wanda", "sparsegpt", "magnitude", "dense"])
    ap.add_argument("--outer-impl", default="fused", choices=["fused", "host"],
                    help="Algorithm-1 outer loop: fused on-device lax.while_loop"
                         " (default) or the host-Python reference")
    ap.add_argument("--no-group-batch", action="store_true",
                    help="disable the vmap-batched solve of same-shape"
                         " operator groups (wq/wk/wv, gate/up, MoE experts)")
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--calib-sequences", type=int, default=32)
    ap.add_argument("--calib-seq-len", type=int, default=64)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--out", default=None, help="write a JSON report here")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    model = load_arch(args.arch, smoke=True)
    corpus = MarkovCorpus(CorpusConfig(vocab=model.cfg.vocab, seed=args.seed))

    log.info("training the dense model (%d steps)", args.train_steps)
    tr = Trainer(model, corpus, TrainConfig(
        steps=args.train_steps, batch=8, seq=args.calib_seq_len,
        optim=AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.train_steps)))
    tr.run()
    dense_ppl = evaluate_ppl(model, tr.params, corpus, 8, args.calib_seq_len, 4)

    calib = calibration_batches(corpus, CalibConfig(
        num_sequences=args.calib_sequences, seq_len=args.calib_seq_len,
        batch_size=8, seed=args.seed))
    cfg = SequentialConfig(
        spec=SparsitySpec.parse(args.sparsity),
        pruner=PrunerConfig(warm_start=args.warm_start,
                            outer_impl=args.outer_impl,
                            group_batch=not args.no_group_batch),
        method=args.method, error_correction=args.correction)
    pruned, reports, stats = parallel_prune(
        model, tr.params, calib, cfg,
        SchedulerConfig(workers=args.workers, checkpoint_dir=args.ckpt_dir))
    pruned_ppl = evaluate_ppl(model, pruned, corpus, 8, args.calib_seq_len, 4)

    rel = sum(r.rel_error for r in reports) / max(len(reports), 1)
    batched = sum(1 for r in reports if r.solver == "fused-group")
    print(f"arch={args.arch} method={args.method} sparsity={args.sparsity} "
          f"correction={args.correction} outer_impl={args.outer_impl}")
    print(f"dense_ppl={dense_ppl:.3f} pruned_ppl={pruned_ppl:.3f} "
          f"mean_rel_err={rel:.4f} units={stats.get('completed', 'n/a')} "
          f"group_batched_ops={batched}/{len(reports)}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"arch": args.arch, "method": args.method,
                       "sparsity": args.sparsity, "dense_ppl": dense_ppl,
                       "pruned_ppl": pruned_ppl, "mean_rel_err": rel,
                       "outer_impl": args.outer_impl,
                       "group_batched_ops": batched}, f)


if __name__ == "__main__":
    main()
