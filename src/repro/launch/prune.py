"""Pruning driver: train (or load) a model, prune it with any registered
solver, report perplexity before/after.

    python -m repro.launch.prune --arch opt125m-proxy --method fista \
        --sparsity 50% --workers 4 --ckpt-dir /tmp/prune_ckpts
    python -m repro.launch.prune --method admm --sparsity 2:4
    python -m repro.launch.prune --recipe my_recipe.json

This is the end-to-end path of the paper: calibration data -> layer-wise
pruning with intra-layer error correction -> pruned checkpoint ->
WikiText-style perplexity table row.  All pruning configuration flows
through one ``repro.api.PruneRecipe`` (serialized into the JSON report,
so any run is reproducible from its report alone).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

from repro import api, obs
from repro.checkpoint import store
from repro.core.solvers import registered_solvers
from repro.data import CorpusConfig, MarkovCorpus
from repro.train import AdamWConfig, TrainConfig, Trainer, evaluate_ppl
from repro.utils import get_logger

log = get_logger("launch.prune")

#: model checkpoints a prune run leaves in its checkpoint dir (next to the
#: scheduler's per-unit checkpoints) — `launch/evaluate.py` and the serve
#: path consume these by name
DENSE_MODEL, PRUNED_MODEL = api.DENSE_MODEL, api.PRUNED_MODEL


def save_run_models(ckpt_dir: str, recipe: api.PruneRecipe, dense_params,
                    pruned_params=None, reports=None, save_dense: bool = True,
                    **extra) -> None:
    """Persist the run's dense (and pruned) model params with everything
    needed to re-evaluate them: the recipe, the corpus seed, and the
    per-operator solver reports (the error-budget audit's budgets).
    ``save_dense=False`` skips the dense write when an identical snapshot
    was already saved (the pre-prune call)."""
    meta = dict(extra, recipe=recipe.to_dict())
    if save_dense:
        store.save(ckpt_dir, DENSE_MODEL, {"params": dense_params},
                   extra=meta)
    if pruned_params is not None:
        meta = dict(meta, reports=[dataclasses.asdict(r)
                                   for r in (reports or [])])
        store.save(ckpt_dir, PRUNED_MODEL, {"params": pruned_params},
                   extra=meta)


def recipe_from_args(args: argparse.Namespace) -> api.PruneRecipe:
    """CLI flags -> PruneRecipe (the only place flags map onto config)."""
    mesh = api.MeshConfig.parse(args.mesh).to_dict() if args.mesh else {}
    if args.recipe:
        recipe = api.PruneRecipe.from_json(args.recipe)
        if mesh:      # --mesh overrides the recipe's mesh section only
            recipe = dataclasses.replace(recipe, mesh=mesh)
        return recipe
    solver_kwargs = {}
    if args.method == "fista":
        solver_kwargs = {"warm_start": args.warm_start,
                         "outer_impl": args.outer_impl,
                         "group_batch": not args.no_group_batch,
                         "trace_len": args.solver_trace_len}
    elif args.method == "admm":
        solver_kwargs = {"warm_start": args.warm_start}
    return api.PruneRecipe(
        arch=args.arch, method=args.method, solver=solver_kwargs,
        sparsity=args.sparsity, correction=args.correction,
        calibration={"num_sequences": args.calib_sequences,
                     "seq_len": args.calib_seq_len, "batch_size": 8,
                     "seed": args.seed},
        scheduler={"workers": args.workers,
                   "checkpoint_dir": args.ckpt_dir},
        mesh=mesh)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt125m-proxy",
                    choices=list(api.ARCH_CHOICES))
    ap.add_argument("--method", default="fista",
                    choices=sorted(registered_solvers()))
    ap.add_argument("--sparsity", default="50%", help="'50%%' or '2:4'")
    ap.add_argument("--correction", default="intra",
                    choices=["intra", "none", "full", "cross"])
    ap.add_argument("--warm-start", default="wanda",
                    choices=["wanda", "sparsegpt", "magnitude", "dense"])
    ap.add_argument("--outer-impl", default="fused", choices=["fused", "host"],
                    help="Algorithm-1 outer loop: fused on-device lax.while_loop"
                         " (default) or the host-Python reference")
    ap.add_argument("--no-group-batch", action="store_true",
                    help="disable the vmap-batched solve of same-shape"
                         " operator groups (wq/wk/wv, gate/up, MoE experts)")
    ap.add_argument("--solver-trace-len", type=int, default=8,
                    help="per-operator convergence trace budget: keep this "
                         "many outer-iteration (error, lambda) pairs per "
                         "solve, recorded into repro.obs (0 disables)")
    ap.add_argument("--recipe", default=None,
                    help="load the full PruneRecipe from this JSON file "
                         "(overrides every other pruning flag)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="device mesh 'dataxmodel' (e.g. '4x2'): Gram "
                         "accumulation shards calibration batches over "
                         "'data', solves can row-shard over 'model' "
                         "(resolved through distributed/executor.py)")
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--calib-sequences", type=int, default=32)
    ap.add_argument("--calib-seq-len", type=int, default=64)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--out", default=None, help="write a JSON report here")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    try:
        recipe = recipe_from_args(args)
        # a bad --mesh (unparseable, or more devices than visible) must
        # die HERE — before the dense model is trained — with the same
        # clean error/exit-2 contract as the evaluate and serve CLIs
        executor = recipe.build_executor()
    except (ValueError, FileNotFoundError) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    model = recipe.load_model(smoke=True)
    corpus = MarkovCorpus(CorpusConfig(vocab=model.cfg.vocab, seed=args.seed))

    log.info("training the dense model (%d steps)", args.train_steps)
    seq_len = recipe.calib_config().seq_len
    tr = Trainer(model, corpus, TrainConfig(
        steps=args.train_steps, batch=8, seq=seq_len,
        optim=AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.train_steps)))
    tr.run()
    dense_ppl = evaluate_ppl(model, tr.params, corpus, 8, seq_len, 4)

    ckpt_dir = recipe.scheduler_config().checkpoint_dir
    if ckpt_dir:
        # dense snapshot BEFORE pruning: a run killed mid-prune leaves
        # dense_model + the scheduler's unit_* checkpoints, which
        # launch/evaluate.py can assemble into the pruned model
        save_run_models(ckpt_dir, recipe, tr.params,
                        corpus_seed=args.seed, smoke=True,
                        dense_ppl=dense_ppl)

    if executor is not None:
        log.info("mesh-native run: %s", executor.describe())
    calib = api.calibration_for(recipe, corpus)
    obs.enable()            # spans + prune metrics for the whole prune phase
    pruned, reports, stats = api.prune(model, tr.params, calib, recipe,
                                       executor=executor)
    pruned_ppl = evaluate_ppl(model, pruned, corpus, 8, seq_len, 4)

    if ckpt_dir:
        save_run_models(ckpt_dir, recipe, tr.params, pruned, reports,
                        save_dense=False,   # identical snapshot saved above
                        corpus_seed=args.seed, smoke=True,
                        dense_ppl=dense_ppl, pruned_ppl=pruned_ppl)
        log.info("saved %s + %s under %s", DENSE_MODEL, PRUNED_MODEL, ckpt_dir)
        obs_dir = obs.save_run_dir(ckpt_dir)
        if obs_dir:
            log.info("obs artifacts under %s — render with "
                     "`python -m repro.obs report %s`", obs_dir, ckpt_dir)

    rel = sum(r.rel_error for r in reports) / max(len(reports), 1)
    batched = sum(1 for r in reports if r.group_size > 1)
    print(f"arch={recipe.arch} method={recipe.method} "
          f"sparsity={recipe.sparsity} correction={recipe.correction}")
    print(f"dense_ppl={dense_ppl:.3f} pruned_ppl={pruned_ppl:.3f} "
          f"mean_rel_err={rel:.4f} units={stats.get('completed', 'n/a')} "
          f"group_batched_ops={batched}/{len(reports)}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"arch": recipe.arch, "method": recipe.method,
                       "sparsity": recipe.sparsity, "dense_ppl": dense_ppl,
                       "pruned_ppl": pruned_ppl, "mean_rel_err": rel,
                       "group_batched_ops": batched,
                       "recipe": recipe.to_dict()}, f)
    return 0


if __name__ == "__main__":
    sys.exit(main())
