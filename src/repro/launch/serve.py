"""Continuous-batching serving driver over a synthetic Poisson trace.

    # smoke drive on a random-init tiny model
    python -m repro.launch.serve --arch opt125m-proxy --smoke \
        --requests 8 --rate 4 --max-new-tokens 12

    # serve a pruned run (2:4 checkpoints auto-pack onto spmm24)
    python -m repro.launch.serve --checkpoint /tmp/run --requests 32 --rate 8

Builds a Poisson(``--rate``) arrival trace of random-token prompts,
replays it through the continuous batcher (``serve/batcher.py``:
paged KV pool + one jitted decode step with active-slot masking), and
reports throughput and latency percentiles.  ``--checkpoint`` loads a
``launch/prune.py`` run dir (its ``pruned_model``, falling back to
``dense_model`` + unit checkpoints or the latest trainer step, exactly
like ``launch/evaluate.py``); otherwise ``--arch`` is random-initialized
for a scheduling smoke drive.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

import jax
import numpy as np

from repro import api, obs
from repro.checkpoint import store
from repro.serve import (BatchConfig, ContinuousBatcher, PoolExhausted,
                         synthetic_trace)
from repro.utils import get_logger

log = get_logger("launch.serve")


def load_serving_model(args: argparse.Namespace):
    """Returns (model, params, source string)."""
    if args.checkpoint:
        from repro.launch import evaluate as eval_cli
        run = eval_cli.resolve_run(args.checkpoint)
        model = run["recipe"].load_model(smoke=run["smoke"])
        like = model.init(jax.random.PRNGKey(0))
        if run["kind"] == "prune":
            params, _ = eval_cli._load_params(args.checkpoint,
                                              eval_cli.PRUNED_MODEL, like)
            source = f"{args.checkpoint}:{eval_cli.PRUNED_MODEL}"
        elif run["kind"] == "units":
            dense0, _ = eval_cli._load_params(args.checkpoint,
                                              eval_cli.DENSE_MODEL, like)
            params, _ = eval_cli._assemble_from_units(model, dense0,
                                                      args.checkpoint)
            source = f"{args.checkpoint}:dense_model+unit_*"
        else:
            step = store.latest_step(args.checkpoint)
            params, _ = eval_cli._load_params(args.checkpoint,
                                              store.step_name(step), like)
            source = f"{args.checkpoint}:{store.step_name(step)}"
        return model, params, source
    model = api.load_model(args.arch, smoke=args.smoke)
    params = model.init(jax.random.PRNGKey(args.seed))
    return model, params, f"random-init {args.arch}"


def serve_trace(model, params, args: argparse.Namespace) -> dict:
    if args.requests < 1:
        raise ValueError(f"--requests must be >= 1, got {args.requests}")
    cfg = BatchConfig(slots=args.slots, block_size=args.block_size,
                      max_blocks_per_request=args.max_blocks_per_request,
                      num_blocks=args.blocks, seed=args.seed,
                      sparse=args.sparse, decode_impl=args.decode_impl,
                      prefill_chunk=args.prefill_chunk,
                      prefix_cache=args.prefix_cache)
    pmax = min(args.prompt_len_max,
               cfg.context_len - args.max_new_tokens,
               model.cfg.max_seq - args.max_new_tokens)
    if pmax < args.prompt_len_min:
        raise ValueError(
            f"prompt lengths [{args.prompt_len_min}, {args.prompt_len_max}] "
            f"don't fit the serving context ({cfg.context_len}) or max_seq "
            f"({model.cfg.max_seq}) with max_new_tokens={args.max_new_tokens}")
    prefix_len = args.shared_prefix
    if prefix_len and prefix_len + args.prompt_len_min > pmax:
        raise ValueError(
            f"--shared-prefix {prefix_len} leaves no room for prompt tails "
            f"within the serving context ({cfg.context_len})")
    trace = synthetic_trace(args.requests, args.rate, model.cfg.vocab,
                            prompt_len=(args.prompt_len_min,
                                        pmax - prefix_len),
                            max_new_tokens=args.max_new_tokens,
                            temperature=args.temperature, seed=args.seed,
                            priorities=args.priorities,
                            deadline_s=args.deadline_s,
                            shared_prefix_len=prefix_len)
    executor = api.MeshExecutor.from_spec(args.mesh) if args.mesh else None
    if executor is not None:
        log.info("tensor-parallel serving: %s", executor.describe())
    batcher = ContinuousBatcher(model, params, cfg, executor=executor)
    with obs.span("serve.run", requests=len(trace)):
        results = batcher.run(trace)

    lat = np.asarray([r.latency for r in results])
    tokens = int(sum(len(r.tokens) for r in results))
    wall = max(r.finished for r in results)
    walls = batcher.stats["step_walls"]
    prompt_tokens = int(sum(len(r.prompt) for r in trace))
    hit_tokens = int(sum(r.prefix_hit_tokens for r in results))
    return {
        "sparse_mode": batcher.sparse_stats["mode"],
        "decode_impl": cfg.decode_impl,
        "requests": len(results), "tokens": tokens,
        "wall_s": wall, "tok_s": tokens / max(wall, 1e-9),
        "steps": batcher.stats["steps"],
        "measured_step_us": float(np.median(walls[1:]) * 1e6)
                            if len(walls) > 1 else None,
        "mean_occupancy": batcher.stats["active_slot_steps"]
                          / max(batcher.stats["steps"], 1),
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "prefill_chunks": batcher.stats["prefill_chunks"],
        "preemptions": batcher.stats["preemptions"],
        "resumes": batcher.stats["resumes"],
        "prefix_hit_tokens": hit_tokens,
        "prefix_hit_rate": hit_tokens / max(prompt_tokens, 1),
        "config": {"slots": cfg.slots, "block_size": cfg.block_size,
                   "num_blocks": cfg.num_blocks,
                   "context_len": cfg.context_len, "rate": args.rate,
                   "decode_impl": cfg.decode_impl,
                   "prefill_chunk": cfg.prefill_chunk,
                   "prefix_cache": cfg.prefix_cache,
                   "shared_prefix": prefix_len,
                   "priorities": args.priorities,
                   "mesh": executor.describe() if executor is not None
                           else {"data": 1, "model": 1, "devices": 1}},
    }


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt125m-proxy",
                    choices=list(api.ARCH_CHOICES))
    ap.add_argument("--smoke", action="store_true",
                    help="smoke-size config for --arch (random init)")
    ap.add_argument("--checkpoint", default=None,
                    help="checkpoint-store run dir (launch/prune.py "
                         "--ckpt-dir); serves its pruned_model")
    ap.add_argument("--sparse", default="auto",
                    choices=("auto", "packed", "dense"))
    ap.add_argument("--decode-impl", default="fused",
                    choices=("fused", "reference"),
                    help="decode fast path: 'fused' walks the block table "
                         "in a flash-decoding Pallas kernel (falls back to "
                         "the oracle off-TPU); 'reference' is the gather "
                         "path that anchors it bitwise")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=4.0,
                    help="Poisson arrival rate (req/s); <=0: all at t=0")
    ap.add_argument("--prefill-chunk", type=int, default=None, metavar="C",
                    help="chunked prefill: admit prompts through C-token "
                         "chunks interleaved with decode ticks (bounds "
                         "inter-token stalls under long-prompt arrivals)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prompt-prefix cache over the paged pool "
                         "(requires --prefill-chunk); cache-hit tokens are "
                         "bitwise identical to cold prefill")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="prepend one shared N-token prefix to every "
                         "prompt in the synthetic trace (exercises "
                         "--prefix-cache hits)")
    ap.add_argument("--priorities", type=int, default=1, metavar="K",
                    help="draw request priorities uniformly from [0, K) "
                         "(0 = most urgent; K>1 enables preemption of "
                         "lower-priority actives under pool pressure)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline (seconds after arrival) "
                         "used as the tiebreak within a priority class")
    ap.add_argument("--prompt-len-min", type=int, default=8)
    ap.add_argument("--prompt-len-max", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--max-blocks-per-request", type=int, default=4)
    ap.add_argument("--blocks", type=int, default=64,
                    help="KV pool size in blocks (incl. reserved trash)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="device mesh 'dataxmodel' (e.g. '1x2'): serve "
                         "tensor-parallel over the 'model' axis (params "
                         "per the Megatron rules, paged KV pool "
                         "heads-sharded); tokens identical to 1-device")
    ap.add_argument("--out", default=None, help="write the JSON report here")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="record serve SLO metrics (TTFT, inter-token "
                         "latency, queue depth, pool occupancy) and write "
                         "them as metrics JSONL here")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace.json of the run's "
                         "spans here (implies recording, like --metrics-out)")
    args = ap.parse_args(argv)

    if args.metrics_out or args.trace_out:
        # must precede the batcher build: its instruments bind in __init__
        obs.enable()
    try:
        model, params, source = load_serving_model(args)
        report = serve_trace(model, params, args)
    except (FileNotFoundError, ValueError, PoolExhausted) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    report["source"] = source
    print(f"served {report['requests']} requests from {source} "
          f"(sparse={report['sparse_mode']})")
    print(f"throughput {report['tok_s']:.1f} tok/s over {report['wall_s']:.2f}s "
          f"({report['steps']} decode steps, mean occupancy "
          f"{report['mean_occupancy']:.2f}/{args.slots})")
    print(f"latency p50 {report['latency_p50_s']*1e3:.0f} ms, "
          f"p99 {report['latency_p99_s']*1e3:.0f} ms")
    if args.prefix_cache or args.prefill_chunk:
        print(f"prefix hit rate {report['prefix_hit_rate']:.2f} "
              f"({report['prefix_hit_tokens']} tokens), "
              f"{report['prefill_chunks']} prefill chunks, "
              f"{report['preemptions']} preemptions "
              f"({report['resumes']} resumed)")
    if args.metrics_out or args.trace_out:
        reg = obs.registry()
        ttft = reg.get("serve.ttft_s")
        itl = reg.get("serve.inter_token_s")
        if ttft is not None and itl is not None and ttft.total and itl.total:
            print(f"SLO: ttft p50 {ttft.quantile(0.5)*1e3:.0f} ms / "
                  f"p99 {ttft.quantile(0.99)*1e3:.0f} ms, inter-token "
                  f"p50 {itl.quantile(0.5)*1e3:.1f} ms")
        waits = [(name, reg.get(name)) for name in sorted(reg.snapshot())
                 if name.startswith("serve.admission_wait_s.p")]
        if waits:
            parts = [f"{name.rsplit('.', 1)[1]} "
                     f"{h.quantile(0.5)*1e3:.0f} ms"
                     for name, h in waits if h is not None and h.total]
            if parts:
                print("admission wait p50 by priority: " + ", ".join(parts))
        if args.metrics_out:
            reg.dump_jsonl(args.metrics_out)
            print(f"wrote {args.metrics_out}")
        if args.trace_out:
            from repro.obs import spans as spans_lib
            spans_lib.export_perfetto(obs.recorder().spans(), args.trace_out)
            print(f"wrote {args.trace_out}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
