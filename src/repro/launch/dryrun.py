import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
                           + " " + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the
device count on first init).  For every cell this driver

    1. builds the full-size config and ShapeDtypeStruct inputs
       (zero device allocation — weak-type-correct stand-ins),
    2. jits the right step (train_step for train shapes, prefill for
       prefill shapes, serve_step for decode shapes) with the sharding
       rules of distributed/sharding.py on the production mesh,
    3. ``.lower().compile()`` — any sharding mismatch, OOM-at-compile or
       unsupported collective is a bug in the framework, surfaced here,
    4. records memory_analysis / cost_analysis / the collective-bytes
       parse of the optimized HLO into experiments/dryrun/*.json for the
       roofline analysis (launch/roofline.py).

Usage:
    python -m repro.launch.dryrun --arch stablelm-1.6b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import re
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import api
from repro.configs.base import ALL_ARCHS, SHAPES, ShapeSpec, shape_applicable
from repro.distributed import sharding as rules
from repro.launch.mesh import make_production_mesh
from repro.models.registry import ModelDef
from repro.train import optim
from repro.utils import compat

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "u16": 2,
                "s16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------
def _shape_bytes(token: str) -> int:
    m = re.match(r"(\w+?)\[([\d,]*)\]", token)
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    size = _DTYPE_BYTES.get(dt, 4)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * size


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes(hlo_text: str, n_devices: int) -> Dict[str, Any]:
    """Per-device wire bytes of every collective in the optimized HLO.

    Ring-model normalization on the RESULT shapes parsed from each op's
    defining line: all-reduce 2(g-1)/g * size, all-gather (g-1)/g * size,
    reduce-scatter (g-1) * shard size, all-to-all (g-1)/g, permute 1x.
    First-order (ignores tree algorithms / ICI contention), consistent
    across cells — exactly what the roofline comparison needs.
    """
    totals = {op: 0.0 for op in _COLLECTIVES}
    counts = {op: 0 for op in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if not ls.startswith("%") and " = " not in ls:
            continue
        for op in _COLLECTIVES:
            if f"{op}(" in ls or f"{op}-start(" in ls or f"{op}-done(" in ls:
                if f"{op}-done(" in ls:
                    continue  # counted at -start
                lhs = ls.split(" = ", 1)[-1]
                shapes = re.findall(r"\w+\[[\d,]*\]", lhs.split("(")[0])
                size = sum(_shape_bytes(s) for s in shapes)
                g = _group_size(ls, n_devices)
                if g <= 1:
                    continue
                if op == "all-reduce":
                    wire = 2.0 * (g - 1) / g * size
                elif op == "all-gather":
                    wire = (g - 1) / g * size
                elif op == "reduce-scatter":
                    wire = float(g - 1) * size
                elif op == "all-to-all":
                    wire = (g - 1) / g * size
                else:
                    wire = float(size)
                totals[op] += wire
                counts[op] += 1
                break
    return {"bytes_by_op": totals, "counts": counts,
            "total_bytes": sum(totals.values())}


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------
def _dp_axes(mesh, batch: int) -> Tuple[str, ...]:
    axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and batch % size == 0 and batch >= size:
        return axes
    return ()   # small batches (long_500k B=1) replicate the batch dim


def model_flops(model: ModelDef, shape: ShapeSpec) -> float:
    n = model.cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch          # decode: per emitted token


def build_lowerable(model: ModelDef, shape: ShapeSpec, mesh):
    """Returns (fn, example_args, in_shardings) for the cell's step."""
    cfg = model.cfg
    batch_specs = model.batch_specs(shape)
    dp = _dp_axes(mesh, shape.global_batch)
    repl = NamedSharding(mesh, P())

    if shape.kind in ("train", "prefill"):
        params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        psh = rules.make_shardings(mesh, rules.param_specs(params_shape), params_shape)
        bsh = rules.make_shardings(mesh, rules.batch_specs(batch_specs, dp), batch_specs)
        if shape.kind == "train":
            ocfg = optim.AdamWConfig()
            opt_shape = jax.eval_shape(optim.init, params_shape)
            osh = optim.AdamWState(step=repl, mu=psh, nu=psh)

            def step(p, o, b):
                (l, m), g = jax.value_and_grad(
                    lambda pp: model.loss(pp, b), has_aux=True)(p)
                p2, o2, om = optim.update(ocfg, g, o, p)
                return p2, o2, l

            fn = jax.jit(step, in_shardings=(psh, osh, bsh),
                         out_shardings=(psh, osh, None))
            return fn, (params_shape, opt_shape, batch_specs)

        if model.prefill is not None:
            # true prefill: fill KV caches, unembed ONLY the last position
            # (§Perf iteration 2 — the full (B,S,V) logits tensor dominated
            # the memory term for large-vocab archs)
            cache_len = min(shape.seq_len, cfg.max_seq)
            if cfg.window:
                cache_len = min(cache_len, cfg.window)
            extras = {k: v for k, v in batch_specs.items()
                      if k not in ("tokens", "labels")}

            def prefill_step(p, b):
                toks = b["tokens"]
                ex = {k: v for k, v in b.items() if k not in ("tokens", "labels")}
                return model.prefill(p, toks, cache_len, ex if ex else None,
                                     last_only=True)

            fn = jax.jit(prefill_step, in_shardings=(psh, bsh),
                         out_shardings=None)
            return fn, (params_shape, batch_specs)

        def prefill_step(p, b):
            return model.forward_logits(p, b)

        fn = jax.jit(prefill_step, in_shardings=(psh, bsh),
                     out_shardings=None)
        return fn, (params_shape, batch_specs)

    # decode: one new token against a seq_len-deep cache/state
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    psh = rules.make_shardings(mesh, rules.param_specs(params_shape), params_shape)
    B = shape.global_batch
    cache_len = min(shape.seq_len, cfg.max_seq)
    if cfg.window:
        cache_len = min(cache_len, cfg.window)
    extras = {k: v for k, v in batch_specs.items()
              if k not in ("tokens", "labels")}
    state_shape = jax.eval_shape(
        lambda p, ex: model.init_serve_state(p, B, cache_len, ex if ex else None),
        params_shape, extras)
    bidx = 0 if cfg.family == "hybrid" else 1
    ssh = rules.make_shardings(mesh, rules.state_specs(state_shape, dp, bidx),
                               state_shape) \
        if dp else rules.make_shardings(
            mesh, jax.tree_util.tree_map(lambda x: P(), state_shape))
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tsh = NamedSharding(mesh, P(dp)) if dp else repl

    def decode(p, s, t):
        return model.serve_step(p, s, t, jnp.int32(cache_len - 1))

    fn = jax.jit(decode, in_shardings=(psh, ssh, tsh),
                 out_shardings=(None, ssh))
    return fn, (params_shape, state_shape, token)


def moe_flops_deflator(cfg) -> float:
    """XLA's cost model charges ragged_dot as DENSE over all experts; the
    true per-row cost is one expert.  Deflator ~= (counted/true), estimated
    by the param-proportional flop split between routed-expert matmuls and
    everything else.  1.0 for non-MoE archs."""
    m = cfg.moe
    if m is None:
        return 1.0
    d = cfg.d_model
    hd = cfg.resolved_head_dim()
    attn = d * (cfg.num_heads * hd) + 2 * d * (cfg.num_kv_heads * hd) \
        + (cfg.num_heads * hd) * d
    shared = 3 * d * m.shared_ff if (m.num_shared and m.shared_ff) else 0
    routed_active = m.top_k * 3 * d * m.expert_ff
    routed_counted = m.num_experts * 3 * d * m.expert_ff
    true = attn + shared + routed_active
    counted = attn + shared + routed_counted
    return counted / true


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Optional[str] = None, verbose: bool = True,
             unroll: bool = False) -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(arch, shape_name)
    if not ok:
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "multi" if multi_pod else "single",
               "skipped": True, "reason": why}
        _write(rec, out_dir)
        return rec

    # same arch builder as launch/prune.py (repro.api) — the two drivers
    # must not drift on how an arch name resolves to a config
    model = api.load_model(arch)
    if unroll:  # unrolled layers: accurate HLO cost accounting (scan bodies
        # are otherwise counted ONCE by XLA's cost analysis)
        from repro.models.registry import model_def
        model = model_def(model.cfg.replace(scan_layers=False))
    need = 512 if multi_pod else 256
    if jax.device_count() >= need:
        mesh = make_production_mesh(multi_pod=multi_pod)
    else:  # REPRO_DRYRUN_DEVICES reduced run (CI): same axes, smaller mesh
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(jax.device_count(), multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    t0 = time.perf_counter()
    with mesh, compat.set_mesh(mesh):
        fn, args = build_lowerable(model, shape, mesh)
        lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
        ma = compiled.memory_analysis()
        ca = compat.cost_analysis(compiled)
        coll = collective_bytes(compiled.as_text(), n_dev)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "kind": shape.kind, "chips": n_dev, "skipped": False,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "peak_bytes": int(ma.argument_size_in_bytes + ma.temp_size_in_bytes
                          + ma.output_size_in_bytes),
        "collectives": coll,
        "model_flops_global": model_flops(model, shape),
        "params": int(model.cfg.param_count()),
        "params_active": int(model.cfg.param_count(active_only=True)),
        "moe_flops_deflator": moe_flops_deflator(model.cfg),
        "unrolled": unroll,
        "lower_seconds": t_lower, "compile_seconds": t_compile,
    }
    if verbose:
        print(f"[{rec['mesh']}] {arch} x {shape_name}: "
              f"compile {t_compile:.1f}s  "
              f"mem/dev {rec['peak_bytes']/1e9:.2f} GB  "
              f"flops/dev {rec['flops_per_device']:.3e}  "
              f"coll/dev {coll['total_bytes']/1e6:.1f} MB")
        print("  memory_analysis:", ma)
    _write(rec, out_dir)
    return rec


def _write(rec: Dict[str, Any], out_dir: Optional[str]) -> None:
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['mesh']}__{rec['arch']}__{rec['shape']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)


# ---------------------------------------------------------------------------
# accurate cost accounting via two-point depth extrapolation
# ---------------------------------------------------------------------------
def _reduced_cfg(cfg, n_layers: int):
    """Same arch at ``n_layers`` layers, unrolled (for cost extrapolation)."""
    kw = {"num_layers": n_layers, "scan_layers": False}
    if cfg.encdec is not None:
        import dataclasses as dc
        kw["encdec"] = dc.replace(cfg.encdec, enc_layers=n_layers // 2,
                                  dec_layers=n_layers // 2)
    return cfg.replace(**kw)


def _cell_costs(model: ModelDef, shape: ShapeSpec, mesh, n_dev: int) -> Dict[str, Any]:
    fn, args = build_lowerable(model, shape, mesh)
    compiled = fn.lower(*args).compile()
    ca = compat.cost_analysis(compiled)
    coll = collective_bytes(compiled.as_text(), n_dev)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll["total_bytes"],
            "coll_by_op": coll["bytes_by_op"]}


def flash_attn_analytic(cfg, shape: ShapeSpec, n_dev: int, dp: int) -> Dict[str, float]:
    """Analytic per-device fwd attention cost when the flash kernel is in
    use (the pallas grid body is counted once by XLA, like a scan).
    flops = 4 * B * Hq * S * K_eff * D (QK^T + PV), K_eff = S/2 causal or
    the window; bytes = Q + K + V + O only (the kernel's whole point)."""
    B = max(shape.global_batch // max(dp, 1), 1)
    S = min(shape.seq_len, cfg.max_seq)
    D = cfg.resolved_head_dim()
    Hq_local = max(cfg.num_heads // 16, 1)   # model-axis sharding of heads
    k_eff = min(cfg.window or S, S) if cfg.window else S / 2.0
    L = cfg.num_layers
    flops = 4.0 * B * Hq_local * S * k_eff * D * L
    bytes_ = 2.0 * B * S * D * (2 * Hq_local + 2 * max(cfg.num_kv_heads // 16, 1)) * L
    return {"flops": flops, "bytes": bytes_}


def run_cell_extrapolated(arch: str, shape_name: str, multi_pod: bool,
                          out_dir: Optional[str] = None,
                          verbose: bool = True, flash: bool = False) -> Dict[str, Any]:
    """Accurate cost accounting: XLA counts a lax.scan body ONCE regardless
    of trip count, so the scan-mode records undercount flops/bytes/
    collectives by ~num_layers.  Here the same cell is lowered UNROLLED at
    two small pattern-complete depths L1 < L2, the exact linear model
    cost = outside + depth * per_layer is solved, and extrapolated to the
    full depth.  Memory numbers still come from the scan-mode dry-run
    (that IS the production execution)."""
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(arch, shape_name)
    if not ok:
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "multi" if multi_pod else "single",
               "skipped": True, "reason": why}
        _write(rec, out_dir)
        return rec

    model = api.load_model(arch)
    if flash:
        from repro.models.registry import model_def as _md
        model = _md(model.cfg.replace(attn_impl="flash"))
    cfg = model.cfg
    if cfg.rglru is not None:
        period = len(cfg.rglru.block_pattern)
    elif cfg.encdec is not None:
        period = 2                      # one enc + one dec layer
    else:
        period = 1
    L1, L2 = period, 2 * period
    full_depth = cfg.num_layers

    need = 512 if multi_pod else 256
    if jax.device_count() >= need:
        mesh = make_production_mesh(multi_pod=multi_pod)
    else:
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(jax.device_count(), multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))

    from repro.models.registry import model_def
    t0 = time.perf_counter()
    with mesh, compat.set_mesh(mesh):
        c1 = _cell_costs(model_def(_reduced_cfg(cfg, L1)), shape, mesh, n_dev)
        c2 = _cell_costs(model_def(_reduced_cfg(cfg, L2)), shape, mesh, n_dev)
    elapsed = time.perf_counter() - t0

    def extrap(a, b):
        per_layer = (b - a) / (L2 - L1)
        outside = a - per_layer * L1
        return max(outside + per_layer * full_depth, 0.0)

    coll_by_op = {op: extrap(c1["coll_by_op"][op], c2["coll_by_op"][op])
                  for op in c1["coll_by_op"]}
    flops_x = extrap(c1["flops"], c2["flops"])
    bytes_x = extrap(c1["bytes"], c2["bytes"])
    flash_add = None
    if flash and shape.kind in ("train", "prefill"):
        dp = 1
        for a in ("pod", "data"):
            if a in mesh.shape and shape.global_batch % (dp * mesh.shape[a]) == 0:
                dp *= mesh.shape[a]
        flash_add = flash_attn_analytic(cfg, shape, n_dev, dp)
        flops_x += flash_add["flops"]
        bytes_x += flash_add["bytes"]
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "kind": shape.kind, "chips": n_dev, "skipped": False,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "flops_per_device": flops_x,
        "bytes_per_device": bytes_x,
        "flash": flash, "flash_analytic_add": flash_add,
        "collectives": {"total_bytes": extrap(c1["coll"], c2["coll"]),
                        "bytes_by_op": coll_by_op},
        "model_flops_global": model_flops(model, shape),
        "params": int(cfg.param_count()),
        "params_active": int(cfg.param_count(active_only=True)),
        "moe_flops_deflator": moe_flops_deflator(cfg),
        "method": f"two-point depth extrapolation (L={L1},{L2} -> {full_depth})",
        "compile_seconds": elapsed,
    }
    if verbose:
        print(f"[extrap/{rec['mesh']}] {arch} x {shape_name}: "
              f"flops/dev {rec['flops_per_device']:.3e}  "
              f"bytes/dev {rec['bytes_per_device']:.3e}  "
              f"coll/dev {rec['collectives']['total_bytes']/1e6:.1f} MB  "
              f"({elapsed:.1f}s)")
    _write(rec, out_dir)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(api.ARCH_CHOICES))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer scans for accurate cost accounting")
    ap.add_argument("--extrapolate", action="store_true",
                    help="two-point depth extrapolation cost records")
    ap.add_argument("--flash", action="store_true",
                    help="use the Pallas flash-attention kernel (Perf it. 3)")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for arch in ALL_ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for multi in meshes:
        for arch, shape in cells:
            try:
                if args.extrapolate:
                    run_cell_extrapolated(arch, shape, multi, args.out,
                                          flash=args.flash)
                else:
                    run_cell(arch, shape, multi, args.out, unroll=args.unroll)
            except Exception as exc:  # noqa: BLE001 — report-all driver
                failures.append((arch, shape, multi, repr(exc)))
                print(f"FAILED [{'multi' if multi else 'single'}] {arch} x {shape}: {exc}")
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: "
                         + "; ".join(f"{a}/{s}" for a, s, _, _ in failures))
    print("dry-run complete: all cells lowered + compiled")


if __name__ == "__main__":
    main()
