"""Pallas TPU kernels for FISTAPruner's compute hot-spots.

* fista_step : fused FISTA iteration (matmul + gradient + shrinkage)
* round24    : 2:4 semi-structured rounding (Eq. 8)
* spmm24     : packed-2:4 sparse matmul for memory-bound decode

Each kernel ships with a jnp oracle in ``ref.py``; ``ops.py`` holds the
public jit'd wrappers (interpret=True off-TPU).
"""
