"""Flash attention (forward) Pallas kernel: online-softmax, causal/windowed,
GQA-aware.

The memory roofline term of every >=32k prefill cell is dominated by the
(B, H, S, S) score/prob tensors the unfused XLA attention round-trips to
HBM (minicpm prefill_32k: 309 GB/layer).  This kernel streams K/V blocks
through VMEM with the classic online-softmax recurrence — HBM traffic is
exactly Q+K+V+O, independent of S.

Layout: q (B, Hq, S, D), k/v (B, Hkv, S, D).  Grid (B, Hq, S/bq, S/bk),
k innermost; scratch carries (m, l, acc) across k-blocks.  Causal and
sliding-window masks are applied block-wise; fully-masked k-blocks still
iterate (grid is static) but contribute nothing.  GQA maps query head h
to kv head h // (Hq // Hkv) in the BlockSpec index maps — repeated KV
heads are never materialized.

Backward: ops.flash_mha wraps this in a custom_vjp whose backward is the
standard analytic attention gradient in plain XLA (scores materialize
ONCE in bwd instead of 3x fwd+bwd+remat).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int):
    kb = pl.program_id(3)
    nk = pl.num_programs(3)
    qb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    rows = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= (rows - cols) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                   # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                       # (bq, 1)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kb == nk - 1)
    def _finish():
        out_ref[0, 0] = (acc_ref[...] /
                         jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0, bq: int = 512,
                    bk: int = 512, interpret: bool = False) -> jnp.ndarray:
    """q (B, Hq, S, D); k/v (B, Hkv, S, D) -> (B, Hq, S, D).

    VMEM per step: bq*d + 2*bk*d + bq*bk + bq*(d+2) fp32 — default
    512x512 blocks with d<=256: ~1.8 MB.  S padded to block multiples
    (padded k-columns are masked via the column iota; padded q-rows are
    sliced off)."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    scale = 1.0 / np.sqrt(D)
    bq_, bk_ = min(bq, S), min(bk, S)
    pq, pk = -S % bq_, -S % bk_
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    Sq, Sk = S + pq, S + pk
    # padded key columns must never win the max: mask them via column index
    # (cols >= S) — encode through the window/causal mask by noting padded
    # cols have index >= S: add to kernel mask via cols < S.
    grid = (B, Hq, Sq // bq_, Sk // bk_)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=int(window or 0),
        bq=bq_, bk=bk_)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq_, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk_, D), lambda b, h, i, j, g=g: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk_, D), lambda b, h, i, j, g=g: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq_, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),   # running max
            pltpu.VMEM((bq_, 1), jnp.float32),   # running denom
            pltpu.VMEM((bq_, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :S, :]
