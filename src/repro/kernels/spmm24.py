"""Pallas packed-2:4 sparse matmul: y = x @ W^T with W stored compressed.

TPU adaptation of the paper's 2:4 motivation (DESIGN.md §2): TPUs have no
sparse MXU, so the win is **HBM bandwidth** in the memory-bound decode
GEMV.  Storage per 4-group: 2 bf16 values + 2 uint8 position ids =
5 bytes vs 8 bytes dense bf16 => 0.625x weight traffic, the roofline
bound for batch-1 decode.

The kernel never gathers: the dense (bm, bk) weight tile is rebuilt in
VMEM from the packed slabs with iota-compares —

    w[:, 4q+g] = v0[:, q] * (i0[:, q]==g) + v1[:, q] * (i1[:, q]==g)

(strided vector selects), then hits the MXU against the activation tile.
Grid (m/bm, n/bk) with k innermost for accumulation; x is small (decode
batch) and stays resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, vals_ref, meta_ref, out_ref, acc_ref):
    k = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    vals = vals_ref[...]                      # (bm, bk/2)
    meta = meta_ref[...].astype(jnp.int32)    # (bm, bk/4): pos0 | pos1<<2
    v0, v1 = vals[:, 0::2], vals[:, 1::2]     # (bm, bk/4) slot values
    i0, i1 = meta & 3, (meta >> 2) & 3
    bm = vals.shape[0]
    bk = vals.shape[1] * 2
    w = jnp.zeros((bm, bk), vals.dtype)
    for g in range(4):
        wg = v0 * (i0 == g).astype(vals.dtype) + v1 * (i1 == g).astype(vals.dtype)
        w = w.at[:, g::4].set(wg)             # strided store (lane select)
    # (B, bk) @ (bk, bm): contract x lanes against the rebuilt tile
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "bm", "bk", "interpret"))
def spmm24(x: jnp.ndarray, vals: jnp.ndarray, meta: jnp.ndarray, n: int, *,
           bm: int = 256, bk: int = 1024, interpret: bool = False) -> jnp.ndarray:
    """x (B, n) times packed-2:4 W^T -> (B, m).

    ``vals`` (m, n/2), ``meta`` (m, n/4) uint8 from ``ref.pack24``.  B is
    the decode batch (kept whole in VMEM — decode batches are small).
    Pads m and n to tile multiples; padded vals are 0 => contribute
    nothing.
    """
    Bsz, n_in = x.shape
    assert n_in == n
    m = vals.shape[0]
    bm_ = min(bm, m)
    bk_ = min(bk, n)
    bk_ -= bk_ % 8  # keep /2 and /4 slabs lane-aligned
    pm, pk = -m % bm_, -n % bk_
    vp = jnp.pad(vals, ((0, pm), (0, pk // 2)))
    mp = jnp.pad(meta, ((0, pm), (0, pk // 4)))
    xp = jnp.pad(x, ((0, 0), (0, pk)))
    M, K = m + pm, n + pk

    out = pl.pallas_call(
        _kernel,
        grid=(M // bm_, K // bk_),
        in_specs=[
            pl.BlockSpec((Bsz, bk_), lambda i, k: (0, k)),        # x
            pl.BlockSpec((bm_, bk_ // 2), lambda i, k: (i, k)),   # vals
            pl.BlockSpec((bm_, bk_ // 4), lambda i, k: (i, k)),   # meta
        ],
        out_specs=pl.BlockSpec((Bsz, bm_), lambda i, k: (0, i)),
        out_shape=jax.ShapeDtypeStruct((Bsz, M), x.dtype),
        scratch_shapes=[pltpu.VMEM((Bsz, bm_), jnp.float32)],
        interpret=interpret,
    )(xp, vp, mp)
    return out[:, :m]
