"""Flash-decoding Pallas kernels that walk the serving block table.

The continuous batcher's reference decode path (``models/common.
mha_decode_paged``) gathers every slot's context out of the flat KV pool
into position order — a ``(S, W, nkv, hd)`` HBM tensor written and
re-read every step, per layer.  The kernels here never materialize that
gather: the block table rides in as a **scalar-prefetch** operand, so
each grid step's BlockSpec index map reads ``tables[s, j]`` and DMAs the
j-th context block of slot ``s`` straight out of the pool, while the
per-slot length mask, the sliding-window cut and the active-slot mask
fold into the online-softmax accumulator.

Grid layout (``paged_decode_attn``): ``(S, nkv, MB)`` — serving slot x
kv head x table column, table column innermost so the (m, l, acc)
scratch carries the online-softmax state across one slot-head's context
blocks, exactly like ``flash_attention.py`` carries it across k-blocks.
GQA is grid-native: each step loads the ``g = nq/nkv`` query heads of
one kv head, so repeated KV heads are never materialized and a
tensor-parallel mesh can map the head axis onto the grid by sharding
``nkv`` (see ``models/common._paged_attn_sharded``).

Two fused epilogues consume the packed-2:4 store (``serve/packed.py``)
without a separate dispatch per matmul:

* ``paged_decode_attn(..., wo_vals, wo_meta)`` — attn -> o_proj: at the
  last table column the normalized per-head output hits the rebuilt
  ``wo`` tile in VMEM and accumulates into the (1, d_model) output
  block across kv heads (the block revisits over ``h``/``j``), so the
  attention output never round-trips HBM before the projection.
* ``fused_mlp24`` — the whole decode MLP (gate/up/down or fc1/fc2) in
  ONE pallas_call, grid over d_ff tiles: every packed operand tile is
  rebuilt in VMEM with the same iota-compare trick as ``spmm24`` and
  the hidden activation never leaves VMEM.

The jnp oracles live in ``kernels/ref.py``; ``kernels/ops.py`` routes
CPU (and kernel-unfriendly shapes) to them — the oracle math is
element-for-element the reference gather path, which is what keeps the
fused decode flag token-identical (DESIGN.md §11).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _rebuild24(vals: jnp.ndarray, meta: jnp.ndarray) -> jnp.ndarray:
    """Rebuild a dense (rows, 2*valcols) tile from packed 2:4 slabs in
    VMEM — strided iota-compare selects, no gather (same trick as
    ``spmm24._kernel``)."""
    v0, v1 = vals[:, 0::2], vals[:, 1::2]
    mi = meta.astype(jnp.int32)
    i0, i1 = mi & 3, (mi >> 2) & 3
    rows, half = vals.shape
    w = jnp.zeros((rows, half * 2), vals.dtype)
    for g in range(4):
        wg = v0 * (i0 == g).astype(vals.dtype) + v1 * (i1 == g).astype(vals.dtype)
        w = w.at[:, g::4].set(wg)
    return w


# ---------------------------------------------------------------------------
# block-table flash decode attention (+ optional packed o_proj epilogue)
# ---------------------------------------------------------------------------
def _attn_kernel(tab_ref, pos_ref, act_ref, q_ref, k_ref, v_ref, *rest,
                 scale: float, block_size: int, window: int, softcap: float,
                 fuse_o: bool):
    if fuse_o:
        wov_ref, wom_ref, out_ref, m_ref, l_ref, acc_ref = rest
    else:
        out_ref, m_ref, l_ref, acc_ref = rest
    s = pl.program_id(0)
    h = pl.program_id(1)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g, hd = q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0, 0].astype(jnp.float32)                    # (g, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)                 # (bs, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)
    sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        sc = jnp.tanh(sc / softcap) * softcap

    # absolute token positions covered by table column j; the per-slot
    # length mask (tok <= pos), the sliding-window cut and the
    # active-slot mask all fold into the softmax here — trash-padded
    # table tail columns alias positions > pos and mask out on their own
    tok = j * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (g, block_size), 1)
    p = pos_ref[s]
    valid = (tok <= p) & (act_ref[s] > 0)
    if window > 0:
        valid &= tok > p - window
    sc = jnp.where(valid, sc, NEG_INF)

    m_prev = m_ref[...]                                    # (g, 1)
    m_new = jnp.maximum(m_prev, jnp.max(sc, axis=1, keepdims=True))
    pr = jnp.exp(sc - m_new)                               # (g, bs)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(pr, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        pr, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nj - 1)
    def _finish():
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)  # (g, hd)
        if not fuse_o:
            out_ref[0, 0] = o.astype(out_ref.dtype)
        else:
            # packed o_proj epilogue: o hits this kv head's rebuilt wo
            # slab and accumulates into the slot's (1, d) output block,
            # which stays resident in VMEM across the h revisits
            w = _rebuild24(wov_ref[...], wom_ref[...])     # (d, g*hd)
            contrib = jax.lax.dot_general(
                o.reshape(1, g * hd), w, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)        # (1, d)
            prev = jnp.where(h == 0, jnp.zeros_like(out_ref[...]),
                             out_ref[...])
            out_ref[...] = prev + contrib


@functools.partial(jax.jit, static_argnames=("block_size", "window",
                                             "softcap", "interpret"))
def paged_decode_attn(q: jnp.ndarray, k_pool: jnp.ndarray,
                      v_pool: jnp.ndarray, tables: jnp.ndarray,
                      pos: jnp.ndarray, active: jnp.ndarray, *,
                      block_size: int, window: int = 0, softcap: float = 0.0,
                      wo_vals: jnp.ndarray = None,
                      wo_meta: jnp.ndarray = None,
                      interpret: bool = False) -> jnp.ndarray:
    """Block-table flash decode: q (S, nq, hd) against the flat pools
    (T, nkv, hd), T = num_blocks * block_size.

    ``tables`` (S, MB) int32 block tables, ``pos`` (S,) per-slot write
    positions, ``active`` (S,) bool.  Without the epilogue returns the
    attention output (S, nq, hd) in q.dtype; with ``wo_vals``/``wo_meta``
    (a packed-2:4 o_proj in paper layout (d_model, nq*hd)) returns the
    projected (S, d_model) in float32.
    """
    S, nq, hd = q.shape
    T, nkv, _ = k_pool.shape
    MB = tables.shape[1]
    g = nq // nkv
    fuse_o = wo_vals is not None
    scale = 1.0 / np.sqrt(hd)

    q4 = q.reshape(S, nkv, g, hd)
    kb = k_pool.reshape(T // block_size, block_size, nkv, hd)
    vb = v_pool.reshape(T // block_size, block_size, nkv, hd)

    in_specs = [
        pl.BlockSpec((1, 1, g, hd), lambda s, h, j, tab, p, a: (s, h, 0, 0)),
        pl.BlockSpec((1, block_size, 1, hd),
                     lambda s, h, j, tab, p, a: (tab[s, j], 0, h, 0)),
        pl.BlockSpec((1, block_size, 1, hd),
                     lambda s, h, j, tab, p, a: (tab[s, j], 0, h, 0)),
    ]
    operands = [q4, kb, vb]
    if fuse_o:
        d = wo_vals.shape[0]
        if (g * hd) % 4 != 0:
            raise ValueError(f"fused o_proj needs g*hd % 4 == 0, got {g * hd}")
        in_specs += [
            pl.BlockSpec((d, g * hd // 2), lambda s, h, j, tab, p, a: (0, h)),
            pl.BlockSpec((d, g * hd // 4), lambda s, h, j, tab, p, a: (0, h)),
        ]
        operands += [wo_vals, wo_meta]
        out_spec = pl.BlockSpec((1, d), lambda s, h, j, tab, p, a: (s, 0))
        out_shape = jax.ShapeDtypeStruct((S, d), jnp.float32)
    else:
        out_spec = pl.BlockSpec((1, 1, g, hd),
                                lambda s, h, j, tab, p, a: (s, h, 0, 0))
        out_shape = jax.ShapeDtypeStruct((S, nkv, g, hd), q.dtype)

    kernel = functools.partial(
        _attn_kernel, scale=scale, block_size=block_size,
        window=int(window or 0), softcap=float(softcap), fuse_o=fuse_o)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(S, nkv, MB),
        in_specs=in_specs,
        out_specs=out_spec,
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),    # running max
            pltpu.VMEM((g, 1), jnp.float32),    # running denom
            pltpu.VMEM((g, hd), jnp.float32),   # output accumulator
        ])
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec, out_shape=out_shape,
        interpret=interpret,
    )(tables.astype(jnp.int32), pos.astype(jnp.int32),
      active.astype(jnp.int32), *operands)
    return out if fuse_o else out.reshape(S, nq, hd)


# ---------------------------------------------------------------------------
# fused packed-2:4 decode MLP: one dispatch for gate/up/down (or fc1/fc2)
# ---------------------------------------------------------------------------
def _mlp_kernel(x_ref, *rest, act: str, gated: bool):
    if gated:
        (w1v_ref, w1m_ref, b1_ref, upv_ref, upm_ref, w2v_ref, w2m_ref,
         b2_ref, out_ref, acc_ref) = rest
    else:
        (w1v_ref, w1m_ref, b1_ref, w2v_ref, w2m_ref, b2_ref, out_ref,
         acc_ref) = rest
    f = pl.program_id(0)
    nf = pl.num_programs(0)

    @pl.when(f == 0)
    def _init():
        acc_ref[...] = jnp.broadcast_to(b2_ref[...], acc_ref.shape
                                        ).astype(jnp.float32)

    x = x_ref[...].astype(jnp.float32)                     # (B, d)
    w1 = _rebuild24(w1v_ref[...], w1m_ref[...]).astype(jnp.float32)  # (bf, d)
    h = jax.lax.dot_general(x, w1, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)      # (B, bf)
    h = h + b1_ref[...]
    h = jax.nn.gelu(h) if act in ("gelu", "geglu") else jax.nn.silu(h)
    if gated:
        up = _rebuild24(upv_ref[...], upm_ref[...]).astype(jnp.float32)
        h = h * jax.lax.dot_general(x, up, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    w2 = _rebuild24(w2v_ref[...], w2m_ref[...]).astype(jnp.float32)  # (do, bf)
    acc_ref[...] += jax.lax.dot_general(
        h, w2, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(f == nf - 1)
    def _done():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("act", "bf", "interpret"))
def fused_mlp24(x: jnp.ndarray, w1_vals, w1_meta, b1, up_vals, up_meta,
                w2_vals, w2_meta, b2, *, act: str = "silu", bf: int = 512,
                interpret: bool = False) -> jnp.ndarray:
    """Whole decode MLP in one pallas_call over packed-2:4 operands.

    x (B, d).  ``w1`` (gate or fc1) packed (f, d); optional ``up``
    packed (f, d) (pass None for the fc1/fc2 form); ``w2`` (down or fc2)
    packed (d_out, f).  ``b1`` (f,) / ``b2`` (d_out,) may be None.
    Grid over d_ff tiles of ``bf``; the hidden activation tile lives and
    dies in VMEM — HBM traffic is x + packed weights + out.  ``bf``
    defaults to 512 so the quarter-width w2 meta tile (d_out, bf/4)
    stays 128-lane aligned (PAL003).
    """
    B, d = x.shape
    f = w1_vals.shape[0]
    d_out = w2_vals.shape[0]
    gated = up_vals is not None
    bf_ = min(bf, f)
    bf_ -= bf_ % 4 or 0
    bf_ = max(bf_, 4)
    pf = -f % bf_
    b1v = jnp.zeros((f,), jnp.float32) if b1 is None else b1.astype(jnp.float32)
    b2v = jnp.zeros((d_out,), jnp.float32) if b2 is None else b2.astype(jnp.float32)
    w1v = jnp.pad(w1_vals, ((0, pf), (0, 0)))
    w1m = jnp.pad(w1_meta, ((0, pf), (0, 0)))
    w2v = jnp.pad(w2_vals, ((0, 0), (0, pf // 2)))
    w2m = jnp.pad(w2_meta, ((0, 0), (0, pf // 4)))
    b1p = jnp.pad(b1v, (0, pf)).reshape(1, f + pf)
    F = f + pf

    in_specs = [
        pl.BlockSpec((B, d), lambda i: (0, 0)),                    # x
        pl.BlockSpec((bf_, d // 2), lambda i: (i, 0)),             # w1 vals
        pl.BlockSpec((bf_, d // 4), lambda i: (i, 0)),             # w1 meta
        pl.BlockSpec((1, bf_), lambda i: (0, i)),                  # b1
    ]
    operands = [x, w1v, w1m, b1p]
    if gated:
        upv = jnp.pad(up_vals, ((0, pf), (0, 0)))
        upm = jnp.pad(up_meta, ((0, pf), (0, 0)))
        in_specs += [pl.BlockSpec((bf_, d // 2), lambda i: (i, 0)),
                     pl.BlockSpec((bf_, d // 4), lambda i: (i, 0))]
        operands += [upv, upm]
    in_specs += [
        pl.BlockSpec((d_out, bf_ // 2), lambda i: (0, i)),         # w2 vals
        pl.BlockSpec((d_out, bf_ // 4), lambda i: (0, i)),         # w2 meta
        pl.BlockSpec((1, d_out), lambda i: (0, 0)),                # b2
    ]
    operands += [w2v, w2m, b2v.reshape(1, d_out)]

    out = pl.pallas_call(
        functools.partial(_mlp_kernel, act=act, gated=gated),
        grid=(F // bf_,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((B, d_out), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, d_out), x.dtype),
        scratch_shapes=[pltpu.VMEM((B, d_out), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out
