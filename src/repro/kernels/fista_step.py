"""Fused FISTA iteration kernel: shrink(Y - (1/L)(Y G - B), lam/L).

One VMEM pass per output tile: the (m,n)x(n,n) matmul runs on the MXU
with a k-innermost accumulation grid, and the gradient step + soft
shrinkage epilogue happens in registers before the tile is written back.
This removes the extra HBM round-trips of the unfused form (write YG,
read YG & B, write P, read P for shrink): per iteration the unfused
chain moves ~5 m*n fp32 tensors of traffic, the fused kernel moves 2.

Tiling: grid (m/bm, n/bn, n/bk), k innermost.  VMEM per step =
    bm*bk (Y k-slab) + bk*bn (G) + 3 * bm*bn (B, Y elementwise, acc)
fp32; the default 256x256x512 tiles use ~1.4 MB, comfortably inside the
~16 MB/core v5e VMEM with double buffering.  All dims 128-aligned for
the MXU.

vmap contract: the batched group solver (core/pruner.py prune_group)
maps this step over stacked operators with per-operator G/B/inv_l/
thresh.  That works through JAX's pallas_call batching rule (a leading
grid axis is prepended; the scalar pair rides along as a batched (1,2)
operand), pinned by tests/test_pruner_fused.py::TestKernelVmap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ymat_ref, g_ref, b_ref, ytile_ref, scal_ref, out_ref, acc_ref):
    """Grid (i, j, k): acc[i,j] += Y[i,k] @ G[k,j]; epilogue at k = nk-1."""
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(ymat_ref[...], g_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        inv_l = scal_ref[0, 0]
        thresh = scal_ref[0, 1]
        grad = acc_ref[...] - b_ref[...]
        p = ytile_ref[...] - inv_l * grad
        out_ref[...] = jnp.sign(p) * jnp.maximum(jnp.abs(p) - thresh, 0.0)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def fista_prox_step(y: jnp.ndarray, G: jnp.ndarray, B: jnp.ndarray,
                    inv_l, thresh, *, bm: int = 256, bn: int = 256,
                    bk: int = 512, interpret: bool = False) -> jnp.ndarray:
    """Pallas FISTA step for fp32 (m, n) x (n, n).  Pads to tile multiples.

    Zero padding is exact: padded Y/G rows contribute 0 to the matmul and
    shrink(0 - inv_l*(0 - 0)) = 0 in the padded output region.
    """
    m, n = y.shape
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, n)
    pm, pn, pk = -m % bm_, -n % bn_, -n % bk_
    yp = jnp.pad(y.astype(jnp.float32), ((0, pm), (0, max(pn, pk))))
    gp = jnp.pad(G.astype(jnp.float32), ((0, pk), (0, pn)))
    bp = jnp.pad(B.astype(jnp.float32), ((0, pm), (0, pn)))
    M, N, K = m + pm, n + pn, n + pk
    scal = jnp.stack([jnp.asarray(inv_l, jnp.float32),
                      jnp.asarray(thresh, jnp.float32)]).reshape(1, 2)

    out = pl.pallas_call(
        _kernel,
        grid=(M // bm_, N // bn_, K // bk_),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),   # Y (matmul slab)
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),   # G
            pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),   # B
            pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),   # Y (elementwise)
            pl.BlockSpec((1, 2), lambda i, j, k: (0, 0)),       # scalars
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(yp[:, :K], gp, bp, yp[:, :N], scal)
    return out[:m, :n]
