"""Public jit'd wrappers around the Pallas kernels.

On a TPU backend these call sites compile to Mosaic.  On CPU (this
container) the *offline* kernels (fista_prox_step, round24, flash
prefill) still run in ``interpret=True`` mode for correctness coverage,
but the **decode hot loop** (spmm24, paged_decode_attn, fused_mlp24)
routes to the jnp oracles in ``ref.py`` instead: interpret-mode Pallas
inside a jitted per-token step is ~10x slower than the oracle (the
measured packed-slower-than-dense serve regression), and the
interpret-mode coverage lives in the dedicated ``kernels_interpret``
test marker rather than the serving path.  Small problems always fall
back to the oracle, where kernel launch overhead would dominate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fista_step as _fista_step
from repro.kernels import paged_attention as _paged
from repro.kernels import ref
from repro.kernels import round24 as _round24
from repro.kernels import spmm24 as _spmm24


@functools.cache
def _interpret() -> bool:
    return jax.default_backend() != "tpu"


_MIN_PALLAS_DIM = 128  # below this, use the jnp oracle


def fista_prox_step(y: jnp.ndarray, G: jnp.ndarray, B: jnp.ndarray,
                    inv_l, thresh) -> jnp.ndarray:
    m, n = y.shape
    if min(m, n) < _MIN_PALLAS_DIM:
        return ref.fista_prox_step(y, G, B, inv_l, thresh)
    return _fista_step.fista_prox_step(y, G, B, inv_l, thresh,
                                       interpret=_interpret())


def round24(w: jnp.ndarray) -> jnp.ndarray:
    m, n = w.shape
    if m < 8 or n < 32:
        return ref.round24(w)
    return _round24.round24(w, interpret=_interpret())


def spmm24(x: jnp.ndarray, vals: jnp.ndarray, meta: jnp.ndarray, n: int) -> jnp.ndarray:
    if _interpret() or vals.shape[0] < _MIN_PALLAS_DIM or n < 2 * _MIN_PALLAS_DIM:
        return ref.spmm24(x, vals, meta, n)
    return _spmm24.spmm24(x, vals, meta, n, interpret=False)


pack24 = ref.pack24
unpack24 = ref.unpack24


# ---------------------------------------------------------------------------
# fused decode fast path (kernels/paged_attention.py)
# ---------------------------------------------------------------------------
def use_decode_kernel(head_dim: int, block_size: int) -> bool:
    """True when the block-table decode kernels compile for these shapes:
    TPU backend, lane-width head_dim, sublane-aligned block_size.  When
    False the fused decode path runs the ``ref.py`` oracles — which on
    CPU is exactly the reference gather math, keeping fused == reference
    bitwise (DESIGN.md §11 fallback rules)."""
    return (not _interpret()) and head_dim >= _MIN_PALLAS_DIM \
        and block_size % 8 == 0


def paged_decode_attn(q, k_pool, v_pool, tables, pos, active, *,
                      block_size: int, window: int = 0, softcap: float = 0.0,
                      wo_vals=None, wo_meta=None):
    """Block-table flash decode (+ optional packed o_proj epilogue).

    Kernel on TPU-compilable shapes, ``ref.paged_attention`` otherwise.
    Without ``wo_vals`` returns (S, nq, hd) in q.dtype; with it, the
    projected (S, d_model) in float32 (caller casts).
    """
    if not use_decode_kernel(q.shape[-1], block_size):
        out = ref.paged_attention(q, k_pool, v_pool, tables, pos, active,
                                  block_size=block_size, window=window,
                                  softcap=softcap)
        if wo_vals is None:
            return out
        S, nq, hd = q.shape
        return ref.spmm24(out.reshape(S, nq * hd).astype(jnp.float32),
                          wo_vals.astype(jnp.float32), wo_meta, nq * hd)
    return _paged.paged_decode_attn(q, k_pool, v_pool, tables, pos, active,
                                    block_size=block_size, window=window,
                                    softcap=softcap, wo_vals=wo_vals,
                                    wo_meta=wo_meta, interpret=False)


def use_fused_mlp(d_model: int, d_ff: int) -> bool:
    """True when ``fused_mlp24`` compiles for these dims (TPU, tiles wide
    enough for the MXU); same fallback contract as ``use_decode_kernel``."""
    return (not _interpret()) and d_model >= _MIN_PALLAS_DIM \
        and d_ff >= 2 * _MIN_PALLAS_DIM


def fused_mlp24(x, w1_vals, w1_meta, b1, up_vals, up_meta, w2_vals, w2_meta,
                b2, *, act: str = "silu"):
    """Whole decode MLP over packed-2:4 operands in one dispatch; oracle
    on CPU / small shapes (same fallback contract as above)."""
    d = w1_vals.shape[1] * 2
    f = w1_vals.shape[0]
    if not use_fused_mlp(d, f):
        return ref.fused_mlp24(x, w1_vals, w1_meta, b1, up_vals, up_meta,
                               w2_vals, w2_meta, b2, act=act)
    return _paged.fused_mlp24(x, w1_vals, w1_meta, b1, up_vals, up_meta,
                              w2_vals, w2_meta, b2, act=act, interpret=False)


# ---------------------------------------------------------------------------
# flash attention: Pallas forward + analytic XLA backward (custom_vjp)
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_mha(q, k, v, causal: bool = True, window: int = 0):
    """Flash attention, (B, Hq, S, D) x (B, Hkv, S, D) -> (B, Hq, S, D).

    Forward streams K/V through VMEM (HBM traffic = Q+K+V+O, no S^2
    tensors).  Backward uses the standard analytic attention gradient in
    plain XLA — scores materialize ONCE in bwd instead of 3x
    (fwd + bwd + remat-recompute) with the unfused reference.
    """
    return _flash_fwd_impl(q, k, v, causal, window)


def _flash_fwd_impl(q, k, v, causal, window):
    from repro.kernels import flash_attention as fa
    S = q.shape[2]
    if S < 128:
        return ref.flash_attention(q, k, v, causal, window)
    bq = bk = min(512, S)
    return fa.flash_attention(q, k, v, causal=causal, window=int(window or 0),
                              bq=bq, bk=bk, interpret=_interpret())


def _flash_fwd(q, k, v, causal, window):
    return _flash_fwd_impl(q, k, v, causal, window), (q, k, v)


def _flash_bwd(causal, window, res, do):
    import numpy as np
    q, k, v = res
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k, g, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, g, axis=1).astype(jnp.float32)
    dof = do.astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) / np.sqrt(D)
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= cols <= rows
    if window:
        mask &= (rows - cols) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, dof)
    dp = jnp.einsum("bhqd,bhkd->bhqk", dof, vf)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True)) / np.sqrt(D)
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, kf)
    dk = jnp.einsum("bhqk,bhqd->bhkd", ds, qf)
    # fold repeated-KV-head grads back onto the Hkv heads
    dk = dk.reshape(B, Hkv, g, S, D).sum(axis=2)
    dv = dv.reshape(B, Hkv, g, S, D).sum(axis=2)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_mha.defvjp(_flash_fwd, _flash_bwd)
