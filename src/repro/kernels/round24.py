"""Pallas 2:4 rounding kernel (paper Eq. 8 for n:m = 2:4).

Keeps the 2 largest-|value| entries of every 4 consecutive entries of a
row.  The group members are accessed as four strided lane slices
``x[:, g::4]`` (Mosaic-supported strided vector loads, no gathers), the
within-group total-order rank is computed with six pairwise compares,
and survivors are written back with strided stores.  Pure VPU work —
one read + one write of the tile, so the kernel is exactly
bandwidth-bound at 2 * bytes(W).

Tie-break matches ``core.sparsity.nm_rank``: equal magnitudes keep the
lower position, so kernel == oracle bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, out_ref):
    a = [x_ref[:, g::4] for g in range(4)]          # 4 x (bm, bn/4)
    mag = [jnp.abs(v) for v in a]
    for g in range(4):
        # rank = #{g': |a_g'| > |a_g|  or  (== and g' < g)}
        rank = jnp.zeros_like(mag[g], jnp.int32)
        for gp in range(4):
            if gp == g:
                continue
            bigger = mag[gp] > mag[g]
            if gp < g:
                bigger = bigger | (mag[gp] == mag[g])
            rank += bigger.astype(jnp.int32)
        out_ref[:, g::4] = jnp.where(rank < 2, a[g], 0.0)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def round24(w: jnp.ndarray, *, bm: int = 256, bn: int = 2048,
            interpret: bool = False) -> jnp.ndarray:
    """2:4 rounding of (m, n) with n % 4 == 0.  Pads rows/cols to tiles;
    column padding is in whole groups of 4 zeros (rank of a zero group is
    positional, output stays 0), so padding is exact."""
    m, n = w.shape
    assert n % 4 == 0, f"n={n} must be a multiple of 4"
    bm_, bn_ = min(bm, m), min(bn, n)
    bn_ -= bn_ % 4
    pm, pn = -m % bm_, -n % bn_
    wp = jnp.pad(w, ((0, pm), (0, pn)))
    M, N = m + pm, n + pn

    out = pl.pallas_call(
        _kernel,
        grid=(M // bm_, N // bn_),
        in_specs=[pl.BlockSpec((bm_, bn_), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), w.dtype),
        interpret=interpret,
    )(wp)
    return out[:m, :n]
