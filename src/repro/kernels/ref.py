"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

These are the *definitions*; kernels must match them bit-for-bit up to
accumulation order.  They are also the CPU fallback for small problems.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sparsity import nm_rank


def fista_prox_step(y: jnp.ndarray, G: jnp.ndarray, B: jnp.ndarray,
                    inv_l, thresh) -> jnp.ndarray:
    """shrink(Y - inv_l * (Y @ G - B), thresh)  — paper (5a)+(5b) fused."""
    p = y - inv_l * (y @ G - B)
    return jnp.sign(p) * jnp.maximum(jnp.abs(p) - thresh, 0.0)


def round24(w: jnp.ndarray) -> jnp.ndarray:
    """Keep the 2 largest-|value| entries of every 4-group (row-wise)."""
    rows, cols = w.shape
    g = w.reshape(rows, cols // 4, 4)
    rank = nm_rank(jnp.abs(g), 4)
    return jnp.where(rank < 2, g, 0).reshape(rows, cols)


def pack24(w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pack an (exactly-)2:4 matrix into (vals (m, n/2), meta (m, n/4) uint8).

    Per 4-group the two surviving entries are stored in position order in
    ``vals``; ``meta`` packs both within-group positions into one byte
    (``pos0 | pos1 << 2``).  Storage per group: 2 bf16 + 1 uint8 = 5 bytes
    vs 8 bytes dense bf16 => 0.625x.  Groups with fewer than 2 nonzeros
    are padded with zero values (meta picks unused slots), so
    ``pack24(round24(w))`` is always well-formed.
    """
    m, n = w.shape
    g = w.reshape(m, n // 4, 4)
    nz = g != 0
    # order positions: nonzeros first (by position), then zeros (by position)
    pos = jnp.arange(4)[None, None, :]
    key = jnp.where(nz, pos, pos + 4)            # nonzeros sort before zeros
    order = jnp.argsort(key, axis=-1)            # (m, n/4, 4)
    first2 = order[..., :2]                      # positions of the 2 kept
    vals = jnp.take_along_axis(g, first2, axis=-1)           # (m, n/4, 2)
    meta = (first2[..., 0] | (first2[..., 1] << 2)).astype(jnp.uint8)
    return vals.reshape(m, n // 2), meta


def unpack24(vals: jnp.ndarray, meta: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of pack24 -> dense (m, n)."""
    m = vals.shape[0]
    v = vals.reshape(m, n // 4, 2)
    mi = meta.astype(jnp.int32)
    i = jnp.stack([mi & 3, (mi >> 2) & 3], axis=-1)          # (m, n/4, 2)
    out = jnp.zeros((m, n // 4, 4), vals.dtype)
    out = out.at[jnp.arange(m)[:, None, None], jnp.arange(n // 4)[None, :, None], i].add(v)
    return out.reshape(m, n)


def spmm24(x: jnp.ndarray, vals: jnp.ndarray, meta: jnp.ndarray, n: int) -> jnp.ndarray:
    """x (B, n) @ W^T where W (m, n) is 2:4-packed -> (B, m)."""
    w = unpack24(vals, meta, n)
    return x @ w.T


def flash_attention(q, k, v, causal: bool = True, window: int = 0):
    """Reference attention in (B, H, S, D) layout with GQA head mapping."""
    import numpy as np
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / np.sqrt(D)
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= cols <= rows
    if window:
        mask &= (rows - cols) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)
