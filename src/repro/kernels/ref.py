"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

These are the *definitions*; kernels must match them bit-for-bit up to
accumulation order.  They are also the CPU fallback for small problems.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sparsity import nm_rank


def fista_prox_step(y: jnp.ndarray, G: jnp.ndarray, B: jnp.ndarray,
                    inv_l, thresh) -> jnp.ndarray:
    """shrink(Y - inv_l * (Y @ G - B), thresh)  — paper (5a)+(5b) fused."""
    p = y - inv_l * (y @ G - B)
    return jnp.sign(p) * jnp.maximum(jnp.abs(p) - thresh, 0.0)


def round24(w: jnp.ndarray) -> jnp.ndarray:
    """Keep the 2 largest-|value| entries of every 4-group (row-wise)."""
    rows, cols = w.shape
    g = w.reshape(rows, cols // 4, 4)
    rank = nm_rank(jnp.abs(g), 4)
    return jnp.where(rank < 2, g, 0).reshape(rows, cols)


def pack24(w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pack an (exactly-)2:4 matrix into (vals (m, n/2), meta (m, n/4) uint8).

    Per 4-group the two surviving entries are stored in position order in
    ``vals``; ``meta`` packs both within-group positions into one byte
    (``pos0 | pos1 << 2``).  Storage per group: 2 bf16 + 1 uint8 = 5 bytes
    vs 8 bytes dense bf16 => 0.625x.  Groups with fewer than 2 nonzeros
    are padded with zero values (meta picks unused slots), so
    ``pack24(round24(w))`` is always well-formed.
    """
    m, n = w.shape
    g = w.reshape(m, n // 4, 4)
    nz = g != 0
    # order positions: nonzeros first (by position), then zeros (by position)
    pos = jnp.arange(4)[None, None, :]
    key = jnp.where(nz, pos, pos + 4)            # nonzeros sort before zeros
    order = jnp.argsort(key, axis=-1)            # (m, n/4, 4)
    first2 = order[..., :2]                      # positions of the 2 kept
    vals = jnp.take_along_axis(g, first2, axis=-1)           # (m, n/4, 2)
    meta = (first2[..., 0] | (first2[..., 1] << 2)).astype(jnp.uint8)
    return vals.reshape(m, n // 2), meta


def unpack24(vals: jnp.ndarray, meta: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of pack24 -> dense (m, n).

    Scatter-free: per within-group position g the dense column is an
    iota-compare select over the two packed slabs (duplicate meta
    positions sum, matching a scatter-add) — the same rebuild the Pallas
    kernels run in VMEM, and ~10x faster than the old gather-scatter on
    CPU, which matters because ``serve.packed.decode_view`` unpacks
    whole checkpoints through here.
    """
    m = vals.shape[0]
    v0, v1 = vals[:, 0::2], vals[:, 1::2]                    # (m, n/4) each
    mi = meta.astype(jnp.int32)
    i0, i1 = mi & 3, (mi >> 2) & 3
    cols = [v0 * (i0 == g).astype(vals.dtype) + v1 * (i1 == g).astype(vals.dtype)
            for g in range(4)]
    return jnp.stack(cols, axis=-1).reshape(m, n)


def spmm24(x: jnp.ndarray, vals: jnp.ndarray, meta: jnp.ndarray, n: int) -> jnp.ndarray:
    """x (B, n) @ W^T where W (m, n) is 2:4-packed -> (B, m)."""
    w = unpack24(vals, meta, n)
    return x @ w.T


def paged_attention(q, k_pool, v_pool, tables, pos, active, *,
                    block_size: int, window: int = 0, softcap: float = 0.0):
    """Block-table decode attention oracle (kernels/paged_attention.py).

    q (S, nq, hd) post-RoPE queries; pools (T, nkv, hd) flat block pools
    with the current token's K/V already written; tables (S, MB) int32;
    pos (S,) absolute positions; active (S,) bool.  Returns (S, nq, hd).

    Element-for-element the reference gather path: the table row is
    expanded to the same position-order ``gather_idx`` that
    ``transformer.paged_serve_step`` feeds ``mha_decode_paged``, and the
    attention math below repeats that function's exact einsum / cast /
    mask sequence — so on CPU (where ``ops.paged_decode_attn`` routes
    here) the fused decode flag is *bitwise* the reference one.
    """
    import numpy as np
    S, MB = tables.shape
    nq, hd = q.shape[1], q.shape[2]
    nkv = k_pool.shape[1]
    g = nq // nkv
    W = MB * block_size
    j = jnp.arange(W, dtype=jnp.int32)
    blocks = jnp.take_along_axis(tables, jnp.broadcast_to(j // block_size,
                                                          (S, W)), axis=1)
    gather_idx = blocks * block_size + (j % block_size)[None, :]
    kg = jnp.take(k_pool, gather_idx, axis=0)                # (S,W,nkv,hd)
    vg = jnp.take(v_pool, gather_idx, axis=0)
    idx = jnp.arange(W, dtype=jnp.int32)
    valid = (idx[None, :] <= pos[:, None]) & active[:, None]
    if window:
        valid &= idx[None, :] > pos[:, None] - window
    qg = q.reshape(S, 1, nkv, g, hd)
    scores = jnp.einsum("bqngh,bknh->bngqk", qg, kg).astype(jnp.float32) / np.sqrt(hd)
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bngqk,bknh->bqngh", probs, vg)
    return out.reshape(S, nq, hd)


def fused_mlp24(x, w1_vals, w1_meta, b1, up_vals, up_meta, w2_vals, w2_meta,
                b2, act: str = "silu"):
    """Oracle for the fused packed-2:4 decode MLP: unpack + plain matmuls
    in float32, matching the kernel's accumulation layout."""
    d = x.shape[-1]
    f = w1_vals.shape[0]
    xf = x.astype(jnp.float32)
    h = xf @ unpack24(w1_vals, w1_meta, d).astype(jnp.float32).T
    if b1 is not None:
        h = h + b1.astype(jnp.float32)
    h = jax.nn.gelu(h) if act in ("gelu", "geglu") else jax.nn.silu(h)
    if up_vals is not None:
        h = h * (xf @ unpack24(up_vals, up_meta, d).astype(jnp.float32).T)
    y = h @ unpack24(w2_vals, w2_meta, f).astype(jnp.float32).T
    if b2 is not None:
        y = y + b2.astype(jnp.float32)
    return y.astype(x.dtype)


def flash_attention(q, k, v, causal: bool = True, window: int = 0):
    """Reference attention in (B, H, S, D) layout with GQA head mapping."""
    import numpy as np
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / np.sqrt(D)
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= cols <= rows
    if window:
        mask &= (rows - cols) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)
