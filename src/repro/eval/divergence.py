"""KL divergence of pruned vs. dense logits on held-out data.

Perplexity alone can hide distribution damage (a pruned model can match
mean CE while reshuffling per-token probabilities); the serving-quality
metric that predicts downstream behavior is the token-level divergence
from the dense reference:

    KL(p_dense || p_pruned) = sum_v p_dense(v) * (log p_dense(v) - log p_pruned(v))

averaged over label-valid positions, plus greedy-decode agreement (the
fraction of positions where both models argmax the same token — exactly
what a greedy serving path emits).
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.corpus import MarkovCorpus
from repro.eval.perplexity import EvalConfig, eval_batches
from repro.models.registry import ModelDef

_KL_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


@dataclasses.dataclass
class DivergenceReport:
    kl: float                   # mean KL(dense || pruned) per token, nats
    top1_agreement: float       # greedy-decode match rate
    tokens: int

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def _kl_and_agreement(logits_ref: jnp.ndarray, logits_cmp: jnp.ndarray,
                      labels: jnp.ndarray):
    """Per-batch (sum KL, sum agreement, count) over labels >= 0."""
    lr = jax.nn.log_softmax(logits_ref.astype(jnp.float32), axis=-1)
    lc = jax.nn.log_softmax(logits_cmp.astype(jnp.float32), axis=-1)
    kl = jnp.sum(jnp.exp(lr) * (lr - lc), axis=-1)          # (B, S)
    agree = (jnp.argmax(lr, axis=-1) == jnp.argmax(lc, axis=-1))
    mask = (labels >= 0).astype(jnp.float32)
    cnt = jnp.sum(mask)
    return jnp.sum(kl * mask), jnp.sum(agree * mask), cnt


def kl_divergence(model: ModelDef, dense_params, pruned_params,
                  corpus: MarkovCorpus, cfg: EvalConfig = EvalConfig(),
                  extras: Optional[Dict] = None,
                  executor: Optional[Any] = None) -> DivergenceReport:
    """Mean token KL(dense || pruned) + argmax agreement over
    ``cfg.kl_batches`` held-out batches.

    ``executor`` shards the batches over the mesh "data" axis exactly as
    :func:`repro.eval.perplexity.evaluate_perplexity` does: whole batches
    stay device-local and the host accumulates per-batch sums in batch
    order, so the sharded result matches the serial loop bitwise.
    """
    forward = model.forward_logits

    def _stats(pd, pp, b):
        lr = forward(pd, b)
        lc = forward(pp, b)
        # modality prefixes (VLM patches) lengthen the logit stream;
        # score the label-aligned tail
        S = b["labels"].shape[1]
        return _kl_and_agreement(lr[:, -S:, :], lc[:, -S:, :], b["labels"])

    if (executor is not None and not extras
            and executor.can_shard_batches(cfg.kl_batches)):
        from repro.utils.tree import tree_stack
        stacked = tree_stack(list(eval_batches(corpus, cfg, n=cfg.kl_batches)))
        ks, ags, cs = executor.data_map(
            lambda b, pd, pp: _stats(pd, pp, b), stacked,
            dense_params, pruned_params, cache_key=(model, "kl"))
        per_batch = zip(np.asarray(ks), np.asarray(ags), np.asarray(cs))
    else:
        batch_stats = _KL_CACHE.get(model)
        if batch_stats is None:
            batch_stats = jax.jit(_stats)
            _KL_CACHE[model] = batch_stats

        def _serial():
            for b in eval_batches(corpus, cfg, n=cfg.kl_batches):
                if extras:
                    b = dict(b, **{k: jnp.asarray(v[:cfg.batch_size])
                                   for k, v in extras.items()})
                yield batch_stats(dense_params, pruned_params, b)

        per_batch = _serial()

    kl_sum = agree_sum = count = 0.0
    for k, a, c in per_batch:
        kl_sum += float(k)
        agree_sum += float(a)
        count += float(c)
    count = max(count, 1.0)
    return DivergenceReport(kl=float(kl_sum / count),
                            top1_agreement=float(agree_sum / count),
                            tokens=int(count))
