"""Model-level quality evaluation: perplexity, KL divergence, error budget.

The measurement layer the paper's claims are judged by (DESIGN.md §8):

* :func:`evaluate_perplexity` — batched teacher-forced perplexity on a
  held-out corpus split;
* :func:`kl_divergence` — token KL(dense || pruned) + greedy agreement;
* :func:`error_budget_report` — per-unit audit of the intra-layer
  cumulative error-correction mechanism;
* :func:`quality_report` — all of the above as one serializable report,
  configured by the strict :class:`EvalConfig` (``PruneRecipe.eval``).
"""
from repro.eval.divergence import DivergenceReport, kl_divergence
from repro.eval.error_budget import UnitBudgetRow, error_budget_report
from repro.eval.perplexity import (EvalConfig, PerplexityReport, eval_batches,
                                   evaluate_perplexity)
from repro.eval.report import QualityReport, quality_report

__all__ = ["EvalConfig", "PerplexityReport", "evaluate_perplexity",
           "eval_batches", "DivergenceReport", "kl_divergence",
           "UnitBudgetRow", "error_budget_report", "QualityReport",
           "quality_report"]
