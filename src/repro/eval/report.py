"""Aggregate quality report: perplexity + KL + error budget, one call.

``quality_report`` is the single function behind every quality surface —
``launch/evaluate.py``, ``benchmarks/quality_bench.py`` and the tests
all call it, so "model quality" means exactly one thing repo-wide.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence

from repro.data.corpus import MarkovCorpus
from repro.eval.divergence import kl_divergence
from repro.eval.error_budget import error_budget_report
from repro.eval.perplexity import (EvalConfig, PerplexityReport,
                                   evaluate_perplexity)
from repro.models.registry import ModelDef


@dataclasses.dataclass
class QualityReport:
    """One evaluated checkpoint, JSON-serializable."""

    ppl: float
    ce_nats: float
    tokens: int
    dense_ppl: Optional[float] = None       # set when a dense reference ran
    ppl_ratio: Optional[float] = None       # ppl / dense_ppl
    kl: Optional[float] = None              # mean KL(dense || pruned), nats
    top1_agreement: Optional[float] = None
    error_budget: Optional[List[Dict]] = None   # per-unit audit rows
    budget_ok: Optional[bool] = None            # all units within budget
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self, path: Optional[str] = None) -> str:
        text = json.dumps(self.to_dict(), indent=1, sort_keys=True,
                          default=float)
        if path is not None:
            with open(path, "w") as f:
                f.write(text + "\n")
        return text

    def summary(self) -> str:
        parts = [f"ppl={self.ppl:.3f}"]
        if self.dense_ppl is not None:
            parts.append(f"dense_ppl={self.dense_ppl:.3f}")
            parts.append(f"ppl_ratio={self.ppl_ratio:.4f}")
        if self.kl is not None:
            parts.append(f"kl={self.kl:.5f}")
            parts.append(f"top1_agree={self.top1_agreement:.3f}")
        if self.budget_ok is not None:
            parts.append(f"budget_ok={self.budget_ok}")
        return " ".join(parts)


def quality_report(model: ModelDef, params: Any, corpus: MarkovCorpus,
                   cfg: EvalConfig = EvalConfig(),
                   dense_params: Optional[Any] = None,
                   reports: Optional[Sequence] = None,
                   extras: Optional[Dict] = None,
                   meta: Optional[Dict[str, Any]] = None,
                   dense_eval: Optional[PerplexityReport] = None,
                   executor: Optional[Any] = None
                   ) -> QualityReport:
    """Evaluate ``params``; with ``dense_params`` also KL + error budget.

    ``reports`` (a prune run's OperatorReports, dataclass or dict form)
    give the error-budget audit its per-unit budgets.  ``dense_eval``
    short-circuits the dense perplexity pass when the caller already
    evaluated the same dense params under the same config (the quality
    bench scores many pruned checkpoints against one dense reference).
    ``executor`` (distributed/executor.py) shards the perplexity and KL
    batches over the mesh "data" axis; the error-budget audit drives the
    pruning-unit relay and stays serial.
    """
    ppl = evaluate_perplexity(model, params, corpus, cfg, extras=extras,
                              executor=executor)
    out = QualityReport(ppl=ppl.ppl, ce_nats=ppl.ce_nats, tokens=ppl.tokens,
                        meta=dict(meta or {}, eval=dataclasses.asdict(cfg)))
    if executor is not None:
        out.meta["mesh"] = executor.describe()
    if dense_params is None:
        return out
    dense = dense_eval if dense_eval is not None else \
        evaluate_perplexity(model, dense_params, corpus, cfg, extras=extras,
                            executor=executor)
    out.dense_ppl = dense.ppl
    out.ppl_ratio = ppl.ppl / dense.ppl if dense.ppl else float("nan")
    if cfg.kl_batches > 0:
        div = kl_divergence(model, dense_params, params, corpus, cfg,
                            extras=extras, executor=executor)
        out.kl, out.top1_agreement = div.kl, div.top1_agreement
    if cfg.budget_batches > 0:
        rows = error_budget_report(model, dense_params, params, corpus, cfg,
                                   reports=reports, extras=extras)
        out.error_budget = [r.to_dict() for r in rows]
        out.budget_ok = all(r.within_budget for r in rows)
    return out
