"""Batched held-out perplexity (the paper's model-level metric).

The paper's headline numbers (Tables 1-2) are WikiText-2 perplexities of
the pruned model; the in-repo analog is teacher-forced perplexity on a
held-out slice of the synthetic corpus (``data/corpus.py``).  The eval
stream uses its own ``"test"`` split — a seed stream disjoint from the
``train``/``valid``/``calib`` splits — so neither training nor
calibration ever sees an eval token.

``EvalConfig`` is the strict, serializable knob set of the whole eval
subsystem (perplexity + KL + error budget); ``PruneRecipe.eval`` maps
onto it and unknown keys fail at recipe-load time, matching the rest of
the recipe surface.
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.corpus import MarkovCorpus, batch_to_model_inputs
from repro.models.registry import ModelDef

# jitted per-model eval closures, weak-keyed on the ModelDef so repeated
# evaluations (the quality bench's 8-row matrix, CLI runs in one process)
# reuse the compiled forward instead of re-tracing a fresh closure
_CE_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _ce_fn(model: ModelDef):
    fn = _CE_CACHE.get(model)
    if fn is None:
        loss = model.loss

        @jax.jit
        def fn(p, b):
            _, metrics = loss(p, b)
            return metrics["ce"]

        _CE_CACHE[model] = fn
    return fn


@dataclasses.dataclass(frozen=True)
class EvalConfig:
    """Knobs of the quality-evaluation subsystem (``PruneRecipe.eval``)."""

    num_batches: int = 8        # perplexity batches
    batch_size: int = 8
    seq_len: int = 64
    split: str = "test"         # held-out corpus split (test | valid)
    kl_batches: int = 4         # KL / agreement batches (0 disables)
    budget_batches: int = 2     # error-budget audit batches (0 disables)
    budget_slack: float = 2.0   # within-budget factor (see error_budget.py)

    def __post_init__(self) -> None:
        if self.split not in ("test", "valid"):
            raise ValueError(f"unknown eval split {self.split!r}; "
                             f"choices: ('test', 'valid')")


@dataclasses.dataclass
class PerplexityReport:
    ppl: float
    ce_nats: float              # mean CE per token, nats
    tokens: int
    batches: int

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def eval_batches(corpus: MarkovCorpus, cfg: EvalConfig, n: Optional[int] = None):
    """The eval stream: deterministic (seed, split, step) batches."""
    it = corpus.batches(cfg.batch_size, cfg.seq_len, split=cfg.split)
    for _ in range(cfg.num_batches if n is None else n):
        _, toks = next(it)
        yield {k: jnp.asarray(v) for k, v in batch_to_model_inputs(toks).items()}


def evaluate_perplexity(model: ModelDef, params, corpus: MarkovCorpus,
                        cfg: EvalConfig = EvalConfig(),
                        extras: Optional[Dict] = None,
                        executor: Optional[Any] = None) -> PerplexityReport:
    """Teacher-forced perplexity over ``cfg.num_batches`` held-out batches.

    Uses the model's own ``loss`` metrics (labels < 0 are masked), so every
    architecture family evaluates through the same path it trains through.

    With a ``executor`` (distributed/executor.py) whose "data" axis
    divides ``cfg.num_batches``, the batches shard over the mesh: each
    device scores whole batches locally and the per-batch CE values come
    back in batch order, so the host-side mean below is bitwise-identical
    to the serial loop (pinned in tests/distributed_cases.py).
    """
    loss = model.loss
    if (executor is not None and not extras
            and executor.can_shard_batches(cfg.num_batches)):
        from repro.utils.tree import tree_stack
        stacked = tree_stack(list(eval_batches(corpus, cfg)))
        ces = np.asarray(
            executor.data_map(lambda b, p: loss(p, b)[1]["ce"],
                              stacked, params, cache_key=(model, "ce")))
        tot, nb = 0.0, 0
        for c in ces:                      # same reduction order as serial
            tot += float(c)
            nb += 1
    else:
        ce_of = _ce_fn(model)
        tot, nb = 0.0, 0
        for b in eval_batches(corpus, cfg):
            if extras:
                b = dict(b, **{k: jnp.asarray(v[:cfg.batch_size])
                               for k, v in extras.items()})
            tot += float(ce_of(params, b))
            nb += 1
    ce = tot / max(nb, 1)
    return PerplexityReport(ppl=float(np.exp(ce)), ce_nats=float(ce),
                            tokens=nb * cfg.batch_size * cfg.seq_len,
                            batches=nb)
