"""Per-unit error-budget audit of the intra-layer correction mechanism.

The paper's core mechanism (Sec. 3.1, DESIGN.md §4): inside a pruning
unit, operator k is solved against X* — the input produced by the
already-pruned prefix of the unit — so each solve *absorbs* the error
its upstream peers introduced instead of compounding it.  The testable
consequence is a budget: the unit's end-to-end output error should stay
bounded by (a small constant times) the sum of its per-operator solver
errors,

    ||unit_pruned(x) - unit_dense(x)||_F / ||unit_dense(x)||_F
        <=  slack * sum_k rel_err_k

where ``rel_err_k = ||Y_k X*_k - W_k X_k|| / ||W_k X_k||`` is exactly
what every solver reports in its ``OperatorReport``.  Without the
correction (the "none" ablation) downstream operators never see the
upstream error, and the measured output error routinely escapes the
budget — this audit is the Fig. 4a claim turned into a per-unit
regression check.

Each unit is audited at its DENSE input (units are independent under the
paper's scheme), so the audit runs layer-parallel-safe on any
checkpoint-store run.

The audit also spans unit boundaries: a REALIZED relay (the pruned net's
own activations) is advanced alongside the dense one, giving each row

* ``realized_rel_err``  — the unit's output error measured at the input
  the pruned net actually sees (what ``correction="cross"`` optimizes);
* ``cumulative_rel_err`` — end-to-end drift of the pruned relay vs the
  dense relay at this unit's output, i.e. how much error has compounded
  across ALL units so far.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import sequential as seq_lib
from repro.data.corpus import MarkovCorpus
from repro.eval.perplexity import EvalConfig, eval_batches
from repro.models.registry import ModelDef


@dataclasses.dataclass
class UnitBudgetRow:
    unit: str
    output_rel_err: float       # measured ||unit_p(x)-unit_d(x)||/||unit_d(x)||
    op_budget: float            # sum of the unit's per-operator solver rel errs
    ratio: float                # output_rel_err / op_budget (nan without reports)
    within_budget: bool         # ratio <= slack (true when budget unknown)
    ops: int                    # operator reports attributed to this unit
    # cross-unit view (defaults keep persisted pre-PR rows loadable)
    realized_rel_err: float = float("nan")   # unit error at the REALIZED input
    cumulative_rel_err: float = float("nan")  # pruned-vs-dense relay drift here

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def _budget_of(reports: Optional[Sequence], unit: str):
    if not reports:
        return float("nan"), 0
    rel = [r["rel_error"] if isinstance(r, dict) else r.rel_error
           for r in reports
           if (r["unit"] if isinstance(r, dict) else r.unit) == unit]
    return (float(sum(rel)), len(rel)) if rel else (float("nan"), 0)


def error_budget_report(model: ModelDef, dense_params: Any, pruned_params: Any,
                        corpus: MarkovCorpus, cfg: EvalConfig = EvalConfig(),
                        reports: Optional[Sequence] = None,
                        extras: Optional[Dict] = None) -> List[UnitBudgetRow]:
    """Audit every pruning unit of ``pruned_params`` against its budget.

    ``reports`` are the run's ``OperatorReport``s (dataclasses or their
    dict form as persisted in checkpoint extras); without them the audit
    still measures output errors, with ``op_budget`` = nan.
    """
    batches = list(eval_batches(corpus, cfg, n=max(cfg.budget_batches, 1)))
    if extras:
        batches = [dict(b, **{k: jnp.asarray(v[:cfg.batch_size])
                              for k, v in extras.items()}) for b in batches]
    states = [model.embed(dense_params, b) for b in batches]
    real_states = [dict(s) for s in states]   # the pruned net's own relay
    rows: List[UnitBudgetRow] = []
    units = list(model.units())
    for i, spec in enumerate(units):
        dense_unit = seq_lib._unit_params_of(dense_params, spec)
        pruned_unit = seq_lib._unit_params_of(pruned_params, spec)
        out_err = seq_lib.unit_output_error(model, spec, dense_unit,
                                            pruned_unit, states)
        # cross-unit view: this unit at the input the pruned net really
        # sees, and the total relay drift at its output
        real_err = seq_lib.unit_output_error(model, spec, dense_unit,
                                             pruned_unit, real_states)
        fwd = seq_lib._capture_forward(model, spec)
        num = den = 0.0
        for ds, rs in zip(states, real_states):
            yd = np.asarray(fwd(dense_unit, ds)[0]["x"], np.float32)
            yp = np.asarray(fwd(pruned_unit, rs)[0]["x"], np.float32)
            num += float(np.sum((yp - yd) ** 2))
            den += float(np.sum(yd ** 2))
        cum_err = float(np.sqrt(num / max(den, 1e-30)))
        budget, n_ops = _budget_of(reports, spec.name)
        ratio = out_err / budget if budget and np.isfinite(budget) else float("nan")
        rows.append(UnitBudgetRow(
            unit=spec.name, output_rel_err=float(out_err),
            op_budget=budget, ratio=float(ratio),
            within_budget=bool(not np.isfinite(ratio)
                               or ratio <= cfg.budget_slack),
            ops=n_ops, realized_rel_err=float(real_err),
            cumulative_rel_err=cum_err))
        if i + 1 < len(units):  # advance both relays to the next unit
            states = [fwd(dense_unit, s)[0] for s in states]
            states = [model.post_unit(dense_params, spec.layer_index, s)
                      for s in states]
            real_states = [fwd(pruned_unit, s)[0] for s in real_states]
            real_states = [model.post_unit(pruned_params, spec.layer_index, s)
                           for s in real_states]
    return rows
