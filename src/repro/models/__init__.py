"""Model zoo: shared layers + one module per architecture family."""
