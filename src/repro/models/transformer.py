"""Decoder-only transformer (dense GQA / MQA / SWA / MoE variants).

Covers stablelm-1.6b, minicpm-2b, internlm2-20b, granite-20b, the
internvl2-2b LLM backbone, qwen2-moe and mixtral.  Two execution paths
share the same per-layer code:

* fast path — ``loss`` / ``forward_logits`` / ``serve_step`` scan over
  layer-stacked params (HLO size independent of depth, per-layer remat);
* unit path — ``unit_apply`` applies one decoder layer with activation
  capture; this is what the calibration/pruning relay drives.

The pruning-unit protocol (used by core/sequential.py):
    state  : dict of arrays  ({"x": hidden, "positions": pos, ...})
    embed(cfg, params, batch)            -> state
    units(cfg)                           -> [UnitSpec, ...]
    unit_apply(cfg, unit_params, i, state, cap=None) -> state
    head(cfg, params, state)             -> logits
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common, moe as moe_lib
from repro.models.common import (Captures, Params, chunked_cross_entropy, dense,
                                 dense_init, dtype_of, embed_init, mha,
                                 mha_decode, mlp, mlp_init, norm_apply,
                                 norm_init)
from repro.utils import tree as tree_lib


class UnitSpec(NamedTuple):
    name: str
    param_path: str                       # e.g. "layers" (stacked) or "layers/3"
    layer_index: int
    groups: Tuple[Tuple[str, ...], ...]   # sequential capture-key groups
    stacked: bool = True                  # params stacked on a leading L axis?


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def layer_init(cfg: ModelConfig, key) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p: Params = {
        "ln1": norm_init(cfg, cfg.d_model),
        "attn": common.attn_init(cfg, k1),
        "ln2": norm_init(cfg, cfg.d_model),
    }
    if cfg.moe is not None:
        p["moe"] = moe_lib.moe_init(cfg, k2)
    else:
        p["mlp"] = mlp_init(cfg, k2)
    return p


def init(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, cfg.num_layers + 3)
    layers = tree_lib.tree_stack([layer_init(cfg, ks[i]) for i in range(cfg.num_layers)])
    p: Params = {
        "embed": embed_init(ks[-1], cfg.vocab, cfg.d_model, dtype_of(cfg.param_dtype)),
        "layers": layers,
        "final_norm": norm_init(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[-2], cfg.d_model, cfg.vocab, dtype_of(cfg.param_dtype))
    return p


# ---------------------------------------------------------------------------
# per-layer forward (shared by both paths)
# ---------------------------------------------------------------------------
def _layer_window(cfg: ModelConfig, i: int) -> Optional[int]:
    return cfg.window


def layer_apply(cfg: ModelConfig, p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                cap: Captures = None, window: Optional[int] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One decoder layer; returns (x, moe_aux_loss)."""
    rs = cfg.residual_scale
    h = norm_apply(cfg, p["ln1"], x)
    a = mha(cfg, p["attn"], h, positions, cap, "attn/", window=window)
    x = x + a.astype(x.dtype) * rs
    h = norm_apply(cfg, p["ln2"], x)
    if cfg.moe is not None:
        f, aux = moe_lib.moe_apply(cfg, p["moe"], h, cap, "moe/")
    else:
        f, aux = mlp(cfg, p["mlp"], h, cap, "mlp/"), jnp.float32(0.0)
    x = x + f.astype(x.dtype) * rs
    return x, aux


# ---------------------------------------------------------------------------
# fast path: scan over stacked layers
# ---------------------------------------------------------------------------
def hidden_states(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                  extra_embeddings: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Embed + all layers (scan).  Returns (hidden (B,S,D), moe aux loss)."""
    x = params["embed"][tokens] * cfg.emb_scale
    if extra_embeddings is not None:  # VLM: prepend patch embeddings
        x = jnp.concatenate([extra_embeddings.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))

    def body(carry, lp):
        h, aux = carry
        h2, a = layer_apply(cfg, lp, h, positions, window=cfg.window)
        return (h2, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)), params["layers"])
    else:  # unrolled: accurate per-layer HLO cost accounting (dry-run)
        carry = (x, jnp.float32(0.0))
        for i in range(cfg.num_layers):
            carry, _ = body_fn(carry, tree_lib.tree_index(params["layers"], i))
        x, aux = carry
    return norm_apply(cfg, params["final_norm"], x), aux


def unembed(cfg: ModelConfig, params: Params, h: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", h, params["embed"]) * cfg.logit_scale
    else:
        logits = dense(h, params["head"]) * cfg.logit_scale
    if cfg.logit_softcap > 0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


def forward_logits(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                   extra_embeddings: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    h, _ = hidden_states(cfg, params, tokens, extra_embeddings)
    return unembed(cfg, params, h)


def loss(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray]
         ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """batch: {"tokens": (B,S), "labels": (B,S)} (+"patches" for VLM)."""
    h, aux = hidden_states(cfg, params, batch["tokens"], batch.get("patches"))
    labels = batch["labels"]
    if batch.get("patches") is not None:
        pad = jnp.full(batch["patches"].shape[:2], -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    if cfg.tie_embeddings or cfg.ce_chunk:
        emb = params["embed"] if cfg.tie_embeddings else params["head"].T
        ce = chunked_cross_entropy(h * cfg.logit_scale, emb, labels,
                                   cfg.ce_chunk, cfg.logit_softcap)
    else:
        ce = common.cross_entropy(unembed(cfg, params, h), labels)
    aux_coef = cfg.moe.router_aux_coef if cfg.moe is not None else 0.0
    total = ce + aux_coef * aux
    return total, {"ce": ce, "moe_aux": aux}


# ---------------------------------------------------------------------------
# serving path: prefill + single-token decode with per-layer KV caches
# ---------------------------------------------------------------------------
def init_kv_caches(cfg: ModelConfig, batch: int, cache_len: int) -> Dict[str, jnp.ndarray]:
    hd = cfg.resolved_head_dim()
    shape = (cfg.num_layers, batch, cache_len, cfg.num_kv_heads, hd)
    dt = dtype_of(cfg.compute_dtype)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def serve_step(cfg: ModelConfig, params: Params, caches: Dict[str, jnp.ndarray],
               token: jnp.ndarray, pos: jnp.ndarray
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One decode step.  token (B,1) int32, pos scalar int32.
    Returns (logits (B,1,V), new caches)."""
    x = params["embed"][token] * cfg.emb_scale

    def body(h, xs):
        lp, cache = xs
        rs = cfg.residual_scale
        hn = norm_apply(cfg, lp["ln1"], h)
        a, new_cache = mha_decode(cfg, lp["attn"], hn, pos, cache, window=cfg.window)
        h = h + a.astype(h.dtype) * rs
        hn = norm_apply(cfg, lp["ln2"], h)
        if cfg.moe is not None:
            f, _ = moe_lib.moe_apply(cfg, lp["moe"], hn)
        else:
            f = mlp(cfg, lp["mlp"], hn)
        return h + f.astype(h.dtype) * rs, new_cache

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    else:
        outs = []
        for i in range(cfg.num_layers):
            lp = tree_lib.tree_index(params["layers"], i)
            ci = jax.tree_util.tree_map(lambda c: c[i], caches)
            x, co = body(x, (lp, ci))
            outs.append(co)
        new_caches = tree_lib.tree_stack(outs)
    h = norm_apply(cfg, params["final_norm"], x)
    return unembed(cfg, params, h), new_caches


def prefill(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
            cache_len: int, extra_embeddings: Optional[jnp.ndarray] = None,
            last_only: bool = False) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence prefill; fills KV caches (last ``cache_len`` positions)
    and returns (logits, caches).  ``extra_embeddings`` prepends modality
    embeddings (VLM patches) to the token stream.

    ``last_only`` unembeds ONLY the final position (§Perf iteration 2):
    prefill needs the next-token logits + caches, and materializing the
    full (B, S, V) logits tensor dominated the memory roofline term for
    large-vocab archs (minicpm: 122k vocab x 32k seq)."""
    B, S = tokens.shape
    x = params["embed"][tokens] * cfg.emb_scale
    if extra_embeddings is not None:
        x = jnp.concatenate([extra_embeddings.astype(x.dtype), x], axis=1)
        S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    hd = cfg.resolved_head_dim()

    def body(carry, lp):
        h = carry
        rs = cfg.residual_scale
        hn = norm_apply(cfg, lp["ln1"], h)
        # capture K/V of the last cache_len positions for the cache
        src = hn
        k = common._split_heads(dense(src, lp["attn"]["wk"], bias=lp["attn"].get("bk")),
                                cfg.num_kv_heads, hd)
        v = common._split_heads(dense(src, lp["attn"]["wv"], bias=lp["attn"].get("bv")),
                                cfg.num_kv_heads, hd)
        if cfg.partial_rotary > 0:
            inv = common.rope_freqs(hd, cfg.partial_rotary, cfg.rope_theta)
            k = common.apply_rope(k, positions, inv)
        a = mha(cfg, lp["attn"], hn, positions, window=cfg.window)
        h = h + a.astype(h.dtype) * rs
        hn = norm_apply(cfg, lp["ln2"], h)
        if cfg.moe is not None:
            f, _ = moe_lib.moe_apply(cfg, lp["moe"], hn)
        else:
            f = mlp(cfg, lp["mlp"], hn)
        h = h + f.astype(h.dtype) * rs
        dt = dtype_of(cfg.compute_dtype)
        # place the last min(S, cache_len) positions at slot (pos % cache_len)
        # so decode's ring indexing lines up with absolute positions
        t = min(S, cache_len)
        slots = (jnp.arange(S - t, S) % cache_len).astype(jnp.int32)
        kf = jnp.zeros((B, cache_len) + k.shape[2:], dt).at[:, slots].set(
            k[:, -t:].astype(dt))
        vf = jnp.zeros((B, cache_len) + v.shape[2:], dt).at[:, slots].set(
            v[:, -t:].astype(dt))
        return h, {"k": kf, "v": vf}

    if cfg.scan_layers:
        x, caches = jax.lax.scan(body, x, params["layers"])
    else:
        outs = []
        for i in range(cfg.num_layers):
            x, co = body(x, tree_lib.tree_index(params["layers"], i))
            outs.append(co)
        caches = tree_lib.tree_stack(outs)
    h = norm_apply(cfg, params["final_norm"], x)
    if last_only:
        h = h[:, -1:, :]
    return unembed(cfg, params, h), caches


# ---------------------------------------------------------------------------
# paged serving path: slot-batched decode against a block-pooled KV cache
# ---------------------------------------------------------------------------
def init_paged_caches(cfg: ModelConfig, num_blocks: int, block_size: int
                      ) -> Dict[str, jnp.ndarray]:
    """Paged KV pool: one flat (L, num_blocks*block_size, nkv, hd) tensor
    per K/V.  Block ``b``, offset ``s`` lives at flat slot
    ``b*block_size + s``; block 0 is the serving stack's reserved trash
    block (``serve/kv_cache.py``) — inactive slots write there."""
    hd = cfg.resolved_head_dim()
    shape = (cfg.num_layers, num_blocks * block_size, cfg.num_kv_heads, hd)
    dt = dtype_of(cfg.compute_dtype)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def paged_serve_step(cfg: ModelConfig, params: Params,
                     caches: Dict[str, jnp.ndarray], tables: jnp.ndarray,
                     token: jnp.ndarray, pos: jnp.ndarray,
                     active: jnp.ndarray, block_size: int,
                     impl: str = "reference"
                     ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One decode step over the serving slots, slot-indexed into the
    paged KV pool.  token (S,1) int32; pos (S,) per-slot absolute
    positions; tables (S, MB) int32 block tables; active (S,) bool.
    Returns (logits (S,1,V), new caches).

    The step is shape-stable in everything but the params: the
    continuous batcher jits it once per slot count, and requests join or
    retire by flipping ``active`` / rewriting table rows — never by
    reshaping.  Inactive slots compute masked garbage (writes land in
    the trash block, reads attend to nothing) that the caller discards.

    ``impl="fused"`` skips materializing the (S, W) position-order
    ``gather_idx`` and hands the block tables straight to the fused
    decode fast path (block-table flash attention + packed-operand
    epilogues, kernels/paged_attention.py); ``"reference"`` is the
    gather path that anchors it bitwise.
    """
    S, MB = tables.shape
    fused = impl == "fused"
    write_block = jnp.take_along_axis(tables, pos[:, None] // block_size,
                                      axis=1)[:, 0]
    write_idx = write_block * block_size + pos % block_size          # (S,)
    if fused:
        gather_idx = None
    else:
        j = jnp.arange(MB * block_size, dtype=jnp.int32)
        gather_blocks = jnp.take_along_axis(
            tables, jnp.broadcast_to(j[None, :] // block_size,
                                     (S, j.shape[0])), axis=1)
        gather_idx = gather_blocks * block_size + (j % block_size)[None, :]

    x = params["embed"][token] * cfg.emb_scale

    def body(h, xs):
        lp, cache = xs
        rs = cfg.residual_scale
        hn = norm_apply(cfg, lp["ln1"], h)
        a, new_cache = common.mha_decode_paged(
            cfg, lp["attn"], hn, pos, cache, write_idx, gather_idx, active,
            window=cfg.window, tables=tables if fused else None,
            block_size=block_size, impl=impl)
        h = h + a.astype(h.dtype) * rs
        hn = norm_apply(cfg, lp["ln2"], h)
        if cfg.moe is not None:
            f, _ = moe_lib.moe_apply(cfg, lp["moe"], hn)
        else:
            f = common.mlp_decode(cfg, lp["mlp"], hn, impl=impl)
        return h + f.astype(h.dtype) * rs, new_cache

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    else:
        outs = []
        for i in range(cfg.num_layers):
            lp = tree_lib.tree_index(params["layers"], i)
            ci = jax.tree_util.tree_map(lambda c: c[i], caches)
            x, co = body(x, (lp, ci))
            outs.append(co)
        new_caches = tree_lib.tree_stack(outs)
    h = norm_apply(cfg, params["final_norm"], x)
    return unembed(cfg, params, h), new_caches


def paged_prefill_chunk(cfg: ModelConfig, params: Params,
                        caches: Dict[str, jnp.ndarray], table: jnp.ndarray,
                        tokens: jnp.ndarray, pos0: jnp.ndarray,
                        n_valid: jnp.ndarray, block_size: int
                        ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One fixed-width prefill chunk for a single request's block table.

    table (MB,) int32 block table (trash-padded past the prompt);
    tokens (1, C) int32 chunk (rows past ``n_valid`` are padding);
    pos0 / n_valid traced scalars — the chunk covers absolute positions
    ``pos0 .. pos0 + n_valid - 1``.  Returns ``(logits (1,1,V), new
    caches)``: the logits of the chunk's *last valid* row only (all a
    prefill needs — the first sampled token), sliced before unembedding
    so the (C, V) logits tensor is never materialized.

    The chunk is shape-stable in everything but the scalars: the
    batcher and the solo engine jit it once (declared in
    ``TRACE_BUDGETS``) and drive any prompt length / chunk offset
    through the same executable.  Attention gathers the full
    fixed-width context per row (``common.mha_prefill_paged``), which
    keeps the chunked prefill bitwise self-consistent across chunk
    groupings — the prefix cache's hit path resumes mid-prompt through
    this very executable.  Padded rows write their K/V into the trash
    block and their outputs are discarded.
    """
    MB = table.shape[0]
    C = tokens.shape[1]
    pos = pos0 + jnp.arange(C, dtype=jnp.int32)                   # (C,)
    valid_q = jnp.arange(C, dtype=jnp.int32) < n_valid
    blk = jnp.take(table, jnp.clip(pos // block_size, 0, MB - 1))
    write_idx = jnp.where(valid_q, blk * block_size + pos % block_size,
                          pos % block_size)
    j = jnp.arange(MB * block_size, dtype=jnp.int32)
    gather_idx = jnp.take(table, j // block_size) * block_size + j % block_size

    x = params["embed"][tokens] * cfg.emb_scale

    def body(h, xs):
        lp, cache = xs
        rs = cfg.residual_scale
        hn = norm_apply(cfg, lp["ln1"], h)
        a, new_cache = common.mha_prefill_paged(
            cfg, lp["attn"], hn, pos, cache, write_idx, gather_idx,
            window=cfg.window)
        h = h + a.astype(h.dtype) * rs
        hn = norm_apply(cfg, lp["ln2"], h)
        if cfg.moe is not None:
            f, _ = moe_lib.moe_apply(cfg, lp["moe"], hn)
        else:
            f = mlp(cfg, lp["mlp"], hn)
        return h + f.astype(h.dtype) * rs, new_cache

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    else:
        outs = []
        for i in range(cfg.num_layers):
            lp = tree_lib.tree_index(params["layers"], i)
            ci = jax.tree_util.tree_map(lambda c: c[i], caches)
            x, co = body(x, (lp, ci))
            outs.append(co)
        new_caches = tree_lib.tree_stack(outs)
    h = norm_apply(cfg, params["final_norm"], x)
    h_last = jax.lax.dynamic_slice_in_dim(h, n_valid - 1, 1, axis=1)
    return unembed(cfg, params, h_last), new_caches


# ---------------------------------------------------------------------------
# unit path (pruning relay)
# ---------------------------------------------------------------------------
def attn_groups(cfg: ModelConfig) -> List[List[str]]:
    return [["attn/wq", "attn/wk", "attn/wv"], ["attn/wo"]]


def ffn_groups(cfg: ModelConfig) -> List[List[str]]:
    if cfg.moe is not None:
        return moe_lib.moe_operator_groups(cfg, "moe/")
    if cfg.act == "silu":
        return [["mlp/gate", "mlp/up"], ["mlp/down"]]
    return [["mlp/fc1"], ["mlp/fc2"]]


def units(cfg: ModelConfig) -> List[UnitSpec]:
    groups = tuple(tuple(g) for g in attn_groups(cfg) + ffn_groups(cfg))
    return [UnitSpec(f"layer{i:03d}", "layers", i, groups)
            for i in range(cfg.num_layers)]


def embed(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    x = params["embed"][batch["tokens"]] * cfg.emb_scale
    if batch.get("patches") is not None:
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    return {"x": x, "positions": positions}


def unit_apply(cfg: ModelConfig, unit_params: Params, i: int,
               state: Dict[str, jnp.ndarray], cap: Captures = None
               ) -> Dict[str, jnp.ndarray]:
    x, aux = layer_apply(cfg, unit_params, state["x"], state["positions"],
                         cap, window=_layer_window(cfg, i))
    return dict(state, x=x)


def head(cfg: ModelConfig, params: Params, state: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    return unembed(cfg, params, norm_apply(cfg, params["final_norm"], state["x"]))
