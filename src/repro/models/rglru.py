"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention.

Residual layer i = temporal block (RG-LRU recurrence or sliding-window
attention per ``cfg.rglru.block_pattern``, default 2:1) followed by a
GeGLU MLP block.  The RG-LRU gated linear recurrence

    r_t = sigmoid(W_a xi_t + b_a)          recurrence gate
    i_t = sigmoid(W_i xi_t + b_i)          input gate
    a_t = exp(-c * softplus(Lambda) * r_t) per-channel decay, c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * xi_t)

runs as a jax.lax.associative_scan over the sequence (log-depth) for
train/prefill and as an O(1) state update for decode — the reason this
arch runs the long_500k shape.

Hardware note (DESIGN.md §2): the published RecurrentGemma uses
block-diagonal gate matrices; we use full (lru_width, lru_width) dense
gates, which makes W_a/W_i first-class prunable operators for the paper's
technique.  Prunable ops per recurrent block: wx, wy, wa, wi, wo (+ MLP).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import (Captures, Params, chunked_cross_entropy, dense,
                                 dense_init, dtype_of, embed_init, mha,
                                 mha_decode, mlp, mlp_init, norm_apply,
                                 norm_init)
from repro.models.transformer import UnitSpec, unembed
from repro.utils import tree as tree_lib

RG_C = 8.0  # Griffin's fixed decay sharpness


def lru_width(cfg: ModelConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def block_kind(cfg: ModelConfig, i: int) -> str:
    pat = cfg.rglru.block_pattern
    return pat[i % len(pat)]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def layer_init(cfg: ModelConfig, key, kind: str) -> Params:
    dt = dtype_of(cfg.param_dtype)
    w = lru_width(cfg)
    ks = jax.random.split(key, 8)
    p: Params = {"ln1": norm_init(cfg, cfg.d_model), "ln2": norm_init(cfg, cfg.d_model),
                 "mlp": mlp_init(cfg, ks[0])}
    if kind == "attention":
        p["attn"] = common.attn_init(cfg, ks[1])
    else:
        p["rg"] = {
            "wx": dense_init(ks[2], cfg.d_model, w, dt),
            "wy": dense_init(ks[3], cfg.d_model, w, dt),
            "wa": dense_init(ks[4], w, w, dt),
            "ba": jnp.zeros((w,), jnp.float32),
            "wi": dense_init(ks[5], w, w, dt),
            "bi": jnp.zeros((w,), jnp.float32),
            "conv_w": (jax.random.normal(ks[6], (cfg.rglru.conv_width, w), jnp.float32)
                       * 0.5).astype(dt),
            "conv_b": jnp.zeros((w,), dt),
            # Lambda init so a^c in [0.9, 0.999] at r=1 (Griffin App. A)
            "lam": jnp.log(jnp.expm1(-jnp.log(
                jnp.linspace(0.9, 0.999, w, dtype=jnp.float32)) / RG_C)),
            "wo": dense_init(ks[7], w, cfg.d_model, dt),
        }
    return p


def init(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, cfg.num_layers + 2)
    # NOTE: mixed block kinds => params are NOT scan-stackable across all
    # layers; we stack per-kind groups and scan within runs (see below).
    layers = [layer_init(cfg, ks[i], block_kind(cfg, i)) for i in range(cfg.num_layers)]
    return {
        "embed": embed_init(ks[-1], cfg.vocab, cfg.d_model, dtype_of(cfg.param_dtype)),
        "layers": layers,
        "final_norm": norm_init(cfg, cfg.d_model),
    }


# ---------------------------------------------------------------------------
# RG-LRU recurrence
# ---------------------------------------------------------------------------
def causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    W = w.shape[0]
    xf = x.astype(jnp.float32)
    out = jnp.zeros_like(xf)
    for j in range(W):
        shift = W - 1 - j
        xs = jnp.pad(xf, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xs * w[j].astype(jnp.float32)[None, None, :]
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _gates(p: Params, xi: jnp.ndarray, cap: Captures, prefix: str
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(log_a, gated input) for the recurrence, fp32."""
    r = jax.nn.sigmoid(dense(xi, p["wa"], prefix + "wa", cap).astype(jnp.float32)
                       + p["ba"][None, None, :])
    i = jax.nn.sigmoid(dense(xi, p["wi"], prefix + "wi", cap).astype(jnp.float32)
                       + p["bi"][None, None, :])
    log_a = -RG_C * jax.nn.softplus(p["lam"])[None, None, :] * r
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * i * xi.astype(jnp.float32)
    return log_a, gated


def lru_scan(log_a: jnp.ndarray, x: jnp.ndarray,
             h0: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """h_t = exp(log_a_t) h_{t-1} + x_t along axis 1, associative scan."""
    if h0 is not None:
        x = x.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 + a2, jnp.exp(a2) * b1 + b2

    _, h = jax.lax.associative_scan(combine, (log_a, x), axis=1)
    return h


def rg_block(cfg: ModelConfig, p: Params, x: jnp.ndarray, cap: Captures = None,
             prefix: str = "rg/") -> jnp.ndarray:
    """Full-sequence recurrent temporal block (input already normed)."""
    y = jax.nn.gelu(dense(x, p["wy"], prefix + "wy", cap).astype(jnp.float32))
    xi = causal_conv(dense(x, p["wx"], prefix + "wx", cap), p["conv_w"], p["conv_b"])
    log_a, gated = _gates(p, xi, cap, prefix)
    h = lru_scan(log_a, gated)
    out = (y * h).astype(x.dtype)
    return dense(out, p["wo"], prefix + "wo", cap)


def layer_apply(cfg: ModelConfig, p: Params, i: int, x: jnp.ndarray,
                positions: jnp.ndarray, cap: Captures = None) -> jnp.ndarray:
    h = norm_apply(cfg, p["ln1"], x)
    if block_kind(cfg, i) == "attention":
        t = mha(cfg, p["attn"], h, positions, cap, "attn/", window=cfg.window)
    else:
        t = rg_block(cfg, p["rg"], h, cap)
    x = x + t.astype(x.dtype)
    h = norm_apply(cfg, p["ln2"], x)
    return x + mlp(cfg, p["mlp"], h, cap, "mlp/").astype(x.dtype)


# ---------------------------------------------------------------------------
# fast paths
# ---------------------------------------------------------------------------
def hidden_states(cfg: ModelConfig, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    x = params["embed"][tokens] * cfg.emb_scale
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    for i, lp in enumerate(params["layers"]):
        fn = jax.checkpoint(lambda h, lp=lp, i=i: layer_apply(cfg, lp, i, h, positions)) \
            if cfg.remat else (lambda h, lp=lp, i=i: layer_apply(cfg, lp, i, h, positions))
        x = fn(x)
    return norm_apply(cfg, params["final_norm"], x)


def forward_logits(cfg: ModelConfig, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return unembed(cfg, params, hidden_states(cfg, params, tokens))


def loss(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray]):
    h = hidden_states(cfg, params, batch["tokens"])
    emb = params["embed"] if cfg.tie_embeddings else params["head"].T
    ce = chunked_cross_entropy(h * cfg.logit_scale, emb, batch["labels"],
                               cfg.ce_chunk, cfg.logit_softcap)
    return ce, {"ce": ce}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def init_serve_state(cfg: ModelConfig, batch: int, cache_len: int) -> Dict:
    hd = cfg.resolved_head_dim()
    w = lru_width(cfg)
    dt = dtype_of(cfg.compute_dtype)
    state: Dict = {"layers": []}
    for i in range(cfg.num_layers):
        if block_kind(cfg, i) == "attention":
            clen = min(cache_len, cfg.window or cache_len)
            state["layers"].append({
                "k": jnp.zeros((batch, clen, cfg.num_kv_heads, hd), dt),
                "v": jnp.zeros((batch, clen, cfg.num_kv_heads, hd), dt)})
        else:
            state["layers"].append({
                "h": jnp.zeros((batch, w), jnp.float32),
                "conv": jnp.zeros((batch, cfg.rglru.conv_width - 1, w), dt)})
    return state


def _rg_step(cfg: ModelConfig, p: Params, x: jnp.ndarray, st: Dict) -> Tuple[jnp.ndarray, Dict]:
    """x (B,1,D) -> (out (B,1,D), new state)."""
    y = jax.nn.gelu(dense(x, p["wy"]).astype(jnp.float32))
    xi_raw = dense(x, p["wx"])[:, 0]                         # (B,w)
    window = jnp.concatenate([st["conv"], xi_raw[:, None, :].astype(st["conv"].dtype)], axis=1)
    xi = (jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                     p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32))
    xi = xi[:, None, :].astype(x.dtype)                      # (B,1,w)
    log_a, gated = _gates(p, xi, None, "")
    h = jnp.exp(log_a[:, 0]) * st["h"] + gated[:, 0]
    out = (y * h[:, None, :]).astype(x.dtype)
    return dense(out, p["wo"]), {"h": h, "conv": window[:, 1:]}


def serve_step(cfg: ModelConfig, params: Params, state: Dict,
               token: jnp.ndarray, pos: jnp.ndarray):
    x = params["embed"][token] * cfg.emb_scale
    new_layers = []
    for i, lp in enumerate(params["layers"]):
        h = norm_apply(cfg, lp["ln1"], x)
        if block_kind(cfg, i) == "attention":
            t, st = mha_decode(cfg, lp["attn"], h, pos, state["layers"][i],
                               window=cfg.window)
        else:
            t, st = _rg_step(cfg, lp["rg"], h, state["layers"][i])
        new_layers.append(st)
        x = x + t.astype(x.dtype)
        h = norm_apply(cfg, lp["ln2"], x)
        x = x + mlp(cfg, lp["mlp"], h).astype(x.dtype)
    h = norm_apply(cfg, params["final_norm"], x)
    return unembed(cfg, params, h), {"layers": new_layers}


# ---------------------------------------------------------------------------
# unit path
# ---------------------------------------------------------------------------
def units(cfg: ModelConfig) -> List[UnitSpec]:
    out = []
    mlp_g = [("mlp/gate", "mlp/up"), ("mlp/down",)]
    for i in range(cfg.num_layers):
        if block_kind(cfg, i) == "attention":
            groups = [("attn/wq", "attn/wk", "attn/wv"), ("attn/wo",)] + mlp_g
        else:
            groups = [("rg/wx", "rg/wy"), ("rg/wa", "rg/wi"), ("rg/wo",)] + mlp_g
        out.append(UnitSpec(f"layer{i:03d}", f"layers/{i}", i, tuple(groups),
                            stacked=False))
    return out


def embed(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray]):
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    return {"x": params["embed"][tokens] * cfg.emb_scale, "positions": positions}


def unit_apply(cfg: ModelConfig, unit_params: Params, i: int,
               state: Dict[str, jnp.ndarray], cap: Captures = None):
    x = layer_apply(cfg, unit_params, i, state["x"], state["positions"], cap)
    return dict(state, x=x)


def head(cfg: ModelConfig, params: Params, state: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    return unembed(cfg, params, norm_apply(cfg, params["final_norm"], state["x"]))
