"""Mamba2 (SSD — state-space duality) blocks, arXiv:2405.21060.

TPU-adapted chunked SSD: the sequence is split into chunks of length Q;
within a chunk the recurrence is computed as a masked (Q,Q) "attention"
matmul (MXU work), across chunks a short scan carries the (H, N, P)
state.  All decay math in fp32 via cumulative log-decays (exponents are
<= 0 by construction, so exp() is stable).

Per block the prunable operators are ``in_proj`` and ``out_proj`` —
conv (depthwise, tiny), A/D/dt_bias (vectors) and norms are excluded,
mirroring the paper's exclusion of non-matrix params (DESIGN.md §4).

Unit protocol identical to models/transformer.py.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import (Captures, Params, chunked_cross_entropy, dense,
                                 dense_init, dtype_of, embed_init, norm_apply,
                                 norm_init, rmsnorm)
from repro.models.transformer import UnitSpec
from repro.utils import tree as tree_lib


def dims(cfg: ModelConfig) -> Dict[str, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.headdim
    conv_ch = d_inner + 2 * s.ngroups * s.state
    zxbcdt = 2 * d_inner + 2 * s.ngroups * s.state + nheads
    return dict(d_inner=d_inner, nheads=nheads, conv_ch=conv_ch, zxbcdt=zxbcdt,
                state=s.state, headdim=s.headdim, ngroups=s.ngroups,
                conv_w=s.conv_width)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def layer_init(cfg: ModelConfig, key) -> Params:
    d = dims(cfg)
    dt = dtype_of(cfg.param_dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # dt_bias init: softplus^-1 of dt in [1e-3, 1e-1] (mamba2 default)
    u = jax.random.uniform(k3, (d["nheads"],), jnp.float32)
    dt0 = jnp.exp(u * (np.log(0.1) - np.log(1e-3)) + np.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
    return {
        "norm": norm_init(cfg, cfg.d_model),
        "in_proj": dense_init(k1, cfg.d_model, d["zxbcdt"], dt),
        "conv_w": (jax.random.normal(k2, (d["conv_w"], d["conv_ch"]), jnp.float32)
                   / np.sqrt(d["conv_w"])).astype(dt),
        "conv_b": jnp.zeros((d["conv_ch"],), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, d["nheads"], dtype=jnp.float32)),
        "D": jnp.ones((d["nheads"],), jnp.float32),
        "dt_bias": dt_bias,
        "out_norm": jnp.ones((d["d_inner"],), dt),
        "out_proj": dense_init(k4, d["d_inner"], cfg.d_model, dt),
    }


def init(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, cfg.num_layers + 2)
    layers = tree_lib.tree_stack([layer_init(cfg, ks[i]) for i in range(cfg.num_layers)])
    return {
        "embed": embed_init(ks[-1], cfg.vocab, cfg.d_model, dtype_of(cfg.param_dtype)),
        "layers": layers,
        "final_norm": norm_init(cfg, cfg.d_model),
    }


# ---------------------------------------------------------------------------
# pieces
# ---------------------------------------------------------------------------
def causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv.  x (B,S,C), w (W,C) -> (B,S,C)."""
    W = w.shape[0]
    xf = x.astype(jnp.float32)
    out = jnp.zeros_like(xf)
    for j in range(W):  # W=4: unrolled shifts, no conv primitive needed
        shift = W - 1 - j
        xs = jnp.pad(xf, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xs * w[j].astype(jnp.float32)[None, None, :]
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _split_zxbcdt(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    d = dims(cfg)
    gn = d["ngroups"] * d["state"]
    z, xc, Bm, Cm, dt = jnp.split(
        zxbcdt, [d["d_inner"], 2 * d["d_inner"], 2 * d["d_inner"] + gn,
                 2 * d["d_inner"] + 2 * gn], axis=-1)
    return z, xc, Bm, Cm, dt


def ssd_chunked(cfg: ModelConfig, x: jnp.ndarray, Bm: jnp.ndarray, Cm: jnp.ndarray,
                log_a: jnp.ndarray, dt: jnp.ndarray,
                h0: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD.

    x (B,S,H,P); Bm/Cm (B,S,H,N) (already head-expanded); log_a, dt (B,S,H)
    fp32.  Returns (y (B,S,H,P), final state (B,H,N,P)).
    """
    d = dims(cfg)
    Bsz, S, H, P = x.shape
    N = d["state"]
    Q = min(cfg.ssm.chunk, S)
    pad = -S % Q
    if pad:  # pad to a chunk multiple: padded positions are causally after
        # every real position, so y[:, :S] is unaffected (hT would change,
        # but callers of the padded path discard it)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    S_p = S + pad
    nc = S_p // Q

    xr = x.reshape(Bsz, nc, Q, H, P).astype(jnp.float32)
    Br = Bm.reshape(Bsz, nc, Q, H, N).astype(jnp.float32)
    Cr = Cm.reshape(Bsz, nc, Q, H, N).astype(jnp.float32)
    la = log_a.reshape(Bsz, nc, Q, H)
    dtr = dt.reshape(Bsz, nc, Q, H)

    cum = jnp.cumsum(la, axis=2)                       # inclusive (B,nc,Q,H)
    # intra-chunk: M[t,s] = exp(cum_t - cum_s) * (C_t . B_s) * dt_s, s<=t
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.exp(jnp.where(mask[None, None, :, :, None], diff, -jnp.inf))
    cb = jnp.einsum("bcqhn,bcshn->bcqsh", Cr, Br)
    M = cb * decay * dtr[:, :, None, :, :]
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", M, xr)

    # chunk states: S_c = sum_s exp(cum_last - cum_s) dt_s B_s (x) x_s
    decay_last = jnp.exp(cum[:, :, -1:, :] - cum)               # (B,nc,Q,H)
    states = jnp.einsum("bcsh,bcshn,bcshp->bchnp", decay_last * dtr, Br, xr)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                     # (B,nc,H)

    # inter-chunk scan over nc
    def scan_fn(h, inp):
        s_c, cd = inp                                  # (B,H,N,P), (B,H)
        h_new = h * cd[:, :, None, None] + s_c
        return h_new, h                                # emit PREVIOUS state

    init_h = jnp.zeros((Bsz, H, N, P), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    hT, h_prevs = jax.lax.scan(
        scan_fn, init_h,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)          # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcqhn,bchnp->bcqhp", Cr * jnp.exp(cum)[..., None], h_prevs)
    y = (y_intra + y_inter).reshape(Bsz, S_p, H, P)[:, :S]
    return y, hT


def mixer(cfg: ModelConfig, p: Params, x: jnp.ndarray, cap: Captures = None,
          prefix: str = "") -> jnp.ndarray:
    """Full-sequence Mamba2 mixer (norm -> in_proj -> conv -> SSD -> out_proj)."""
    d = dims(cfg)
    h = norm_apply(cfg, p["norm"], x)
    zxbcdt = dense(h, p["in_proj"], prefix + "in_proj", cap)
    z, xc, Bm, Cm, dtv = _split_zxbcdt(cfg, zxbcdt)
    xbc = causal_conv(jnp.concatenate([xc, Bm, Cm], axis=-1), p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    gn = d["ngroups"] * d["state"]
    xc, Bm, Cm = jnp.split(xbc, [d["d_inner"], d["d_inner"] + gn], axis=-1)

    Bsz, S, _ = x.shape
    H, P, N, G = d["nheads"], d["headdim"], d["state"], d["ngroups"]
    xh = xc.reshape(Bsz, S, H, P)
    rep = H // G
    Bh = jnp.repeat(Bm.reshape(Bsz, S, G, N), rep, axis=2)
    Ch = jnp.repeat(Cm.reshape(Bsz, S, G, N), rep, axis=2)
    dt = jax.nn.softplus(dtv.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])                               # (H,) negative
    log_a = dt * A[None, None, :]

    y, _ = ssd_chunked(cfg, xh, Bh, Ch, log_a, dt)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, S, d["d_inner"]).astype(x.dtype)
    # gated RMSNorm then out_proj
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), p["out_norm"])
    return dense(y, p["out_proj"], prefix + "out_proj", cap)


def layer_apply(cfg: ModelConfig, p: Params, x: jnp.ndarray, cap: Captures = None
                ) -> jnp.ndarray:
    return x + mixer(cfg, p, x, cap).astype(x.dtype)


# ---------------------------------------------------------------------------
# fast paths
# ---------------------------------------------------------------------------
def hidden_states(cfg: ModelConfig, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    x = params["embed"][tokens] * cfg.emb_scale

    def body(h, lp):
        return layer_apply(cfg, lp, h), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body_fn, x, params["layers"])
    else:
        for i in range(cfg.num_layers):
            x, _ = body_fn(x, tree_lib.tree_index(params["layers"], i))
    return norm_apply(cfg, params["final_norm"], x)


def forward_logits(cfg: ModelConfig, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    h = hidden_states(cfg, params, tokens)
    return jnp.einsum("...d,vd->...v", h, params["embed"])


def loss(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray]):
    h = hidden_states(cfg, params, batch["tokens"])
    ce = chunked_cross_entropy(h, params["embed"], batch["labels"], cfg.ce_chunk)
    return ce, {"ce": ce}


# ---------------------------------------------------------------------------
# serving: O(1)-state decode
# ---------------------------------------------------------------------------
def init_serve_state(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    d = dims(cfg)
    return {
        "ssm": jnp.zeros((cfg.num_layers, batch, d["nheads"], d["state"], d["headdim"]),
                         jnp.float32),
        "conv": jnp.zeros((cfg.num_layers, batch, d["conv_w"] - 1, d["conv_ch"]),
                          dtype_of(cfg.compute_dtype)),
    }


def _mixer_step(cfg: ModelConfig, p: Params, x: jnp.ndarray, ssm: jnp.ndarray,
                conv: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-token mixer.  x (B,1,D); ssm (B,H,N,P); conv (B,W-1,C)."""
    d = dims(cfg)
    h = norm_apply(cfg, p["norm"], x)
    zxbcdt = dense(h, p["in_proj"])
    z, xc, Bm, Cm, dtv = _split_zxbcdt(cfg, zxbcdt)
    xbc_new = jnp.concatenate([xc, Bm, Cm], axis=-1)[:, 0]      # (B,C)
    window = jnp.concatenate([conv, xbc_new[:, None, :]], axis=1)  # (B,W,C)
    wsum = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                      p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xbc = jax.nn.silu(wsum).astype(x.dtype)
    gn = d["ngroups"] * d["state"]
    xc1, B1, C1 = jnp.split(xbc, [d["d_inner"], d["d_inner"] + gn], axis=-1)

    Bsz = x.shape[0]
    H, P, N, G = d["nheads"], d["headdim"], d["state"], d["ngroups"]
    xh = xc1.reshape(Bsz, H, P).astype(jnp.float32)
    rep = H // G
    Bh = jnp.repeat(B1.reshape(Bsz, G, N), rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C1.reshape(Bsz, G, N), rep, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dtv[:, 0].astype(jnp.float32) + p["dt_bias"][None, :])
    a = jnp.exp(dt * (-jnp.exp(p["A_log"]))[None, :])           # (B,H)

    ssm_new = ssm * a[:, :, None, None] + jnp.einsum(
        "bh,bhn,bhp->bhnp", dt, Bh, xh)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, ssm_new) + p["D"][None, :, None] * xh
    y = y.reshape(Bsz, 1, d["d_inner"]).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), p["out_norm"])
    out = dense(y, p["out_proj"])
    return out, ssm_new, window[:, 1:].astype(conv.dtype)


def serve_step(cfg: ModelConfig, params: Params, state: Dict[str, jnp.ndarray],
               token: jnp.ndarray, pos: jnp.ndarray):
    x = params["embed"][token] * cfg.emb_scale

    def body(h, xs):
        lp, ssm, conv = xs
        out, ssm2, conv2 = _mixer_step(cfg, lp, h, ssm, conv)
        return h + out.astype(h.dtype), {"ssm": ssm2, "conv": conv2}

    if cfg.scan_layers:
        x, new_state = jax.lax.scan(
            body, x, (params["layers"], state["ssm"], state["conv"]))
    else:
        outs = []
        for i in range(cfg.num_layers):
            lp = tree_lib.tree_index(params["layers"], i)
            x, st = body(x, (lp, state["ssm"][i], state["conv"][i]))
            outs.append(st)
        new_state = tree_lib.tree_stack(outs)
    h = norm_apply(cfg, params["final_norm"], x)
    return jnp.einsum("...d,vd->...v", h, params["embed"]), new_state


# ---------------------------------------------------------------------------
# unit path
# ---------------------------------------------------------------------------
def units(cfg: ModelConfig) -> List[UnitSpec]:
    groups = (("in_proj",), ("out_proj",))
    return [UnitSpec(f"layer{i:03d}", "layers", i, groups)
            for i in range(cfg.num_layers)]


def embed(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray]):
    return {"x": params["embed"][batch["tokens"]] * cfg.emb_scale}


def unit_apply(cfg: ModelConfig, unit_params: Params, i: int,
               state: Dict[str, jnp.ndarray], cap: Captures = None):
    return dict(state, x=layer_apply(cfg, unit_params, state["x"], cap))


def head(cfg: ModelConfig, params: Params, state: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    h = norm_apply(cfg, params["final_norm"], state["x"])
    return jnp.einsum("...d,vd->...v", h, params["embed"])
