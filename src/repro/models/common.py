"""Shared model layers: norms, rotary, GQA/SWA attention, MLPs, KV caches.

Conventions
-----------
* Params are nested dicts of jnp arrays.  Linear weights are stored
  ``(in_dim, out_dim)`` ("model layout"); the pruner transposes to the
  paper's ``(out, in)`` layout at its boundary.
* Every linear goes through :func:`dense` which optionally *captures* its
  input activation into a dict — this is how the calibration pipeline
  records X / X* for FISTAPruner without touching model code.
* Attention never materializes repeated KV heads: GQA is computed with a
  grouped einsum, which also gives GSPMD a clean head axis to shard.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

Params = Dict[str, Any]
Captures = Optional[Dict[str, jnp.ndarray]]


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# captured linear
# ---------------------------------------------------------------------------
def dense(x: jnp.ndarray, w, name: str = "", cap: Captures = None,
          bias: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """``x @ w`` with optional activation capture (input of this operator).

    ``w`` is either a dense (in, out) array or a packed-2:4 dict
    ``{"vals": (out, in/2), "meta": (out, in/4) uint8}`` produced by
    ``repro.serve.packed.pack_tree`` — the memory-bound decode path then
    runs through the spmm24 Pallas kernel with 0.625x weight traffic.
    """
    if cap is not None and name:
        cap[name] = x
    if isinstance(w, dict) and "vals" in w:
        from repro.kernels import ops as kops
        n = w["vals"].shape[-1] * 2
        lead = x.shape[:-1]
        y = kops.spmm24(x.reshape(-1, n), w["vals"], w["meta"], n)
        y = y.reshape(lead + (y.shape[-1],)).astype(x.dtype)
    else:
        y = jnp.einsum("...i,io->...o", x, w)
    if bias is not None:
        y = y + bias
    return y


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm_apply(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


def norm_init(cfg: ModelConfig, d: int) -> Params:
    dt = dtype_of(cfg.param_dtype)
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), dt), "bias": jnp.zeros((d,), dt)}
    return {"scale": jnp.ones((d,), dt)}


# ---------------------------------------------------------------------------
# rotary embeddings (partial rotary + configurable theta)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, partial: float, theta: float) -> jnp.ndarray:
    rot = int(head_dim * partial)
    rot -= rot % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv  # (rot/2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, inv_freq: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, hd) rotate first 2*len(inv_freq) dims; positions: (..., S)."""
    rot = 2 * inv_freq.shape[0]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    ang = positions[..., :, None].astype(jnp.float32) * inv_freq[None, :]  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    y = jnp.stack([y1, y2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([y.astype(x.dtype), x_pass], axis=-1) if x_pass.shape[-1] else y.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def attn_init(cfg: ModelConfig, key) -> Params:
    dt = dtype_of(cfg.param_dtype)
    d, hd = cfg.d_model, cfg.resolved_head_dim()
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], d, nq * hd, dt),
        "wk": dense_init(ks[1], d, nkv * hd, dt),
        "wv": dense_init(ks[2], d, nkv * hd, dt),
        "wo": dense_init(ks[3], nq * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), dt)
        p["bk"] = jnp.zeros((nkv * hd,), dt)
        p["bv"] = jnp.zeros((nkv * hd,), dt)
    return p


def _split_heads(x: jnp.ndarray, n: int, hd: int) -> jnp.ndarray:
    return x.reshape(x.shape[:-1] + (n, hd))


def _causal_window_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray, window: Optional[int],
                        causal: bool = True) -> jnp.ndarray:
    """(..., Sq, Sk) boolean mask. window w => attend to (i-w, i]."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    mask = jnp.ones(diff.shape, bool)
    if causal:
        mask &= diff >= 0
    if window is not None:
        mask &= diff < window
    return mask


def decode_window_mask(idx: jnp.ndarray, pos: jnp.ndarray,
                       window: Optional[int]) -> jnp.ndarray:
    """Decode-step length + sliding-window validity over cache slots.

    ``idx`` are slot indices in absolute-position order, ``pos`` the
    decoding position(s) (broadcast against idx): a slot is attendable
    iff it's filled (``idx <= pos``) and, when windowed, within the
    trailing window ``(pos - window, pos]``.  Shared by the contiguous
    (:func:`mha_decode`, non-ring branch) and paged
    (:func:`mha_decode_paged`) decode paths so the two can't drift —
    equivalence pinned in tests/test_decode_consistency.py.
    """
    valid = idx <= pos
    if window is not None:
        valid &= idx > pos - window
    return valid


def _flash_sharded(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   causal: bool, window: int) -> jnp.ndarray:
    """Flash attention behind an explicit shard_map boundary.

    GSPMD cannot partition through the kernel's grid loop (measured: it
    all-gathers q/k/v per layer — 5.5x the baseline collective bytes on
    granite prefill).  shard_map pins batch to the DP axes and query
    heads to "model"; each device runs a fully local pallas_call.  KV
    heads replicate over "model" when they don't divide (MQA) — AD
    through shard_map inserts the dk/dv psum automatically.  Without an
    ambient mesh (single-device tests) this is a plain local call.
    """
    from repro.kernels import ops as kops
    from repro.utils import compat

    mesh = compat.ambient_mesh()
    if mesh is None or not mesh.axis_names:
        return kops.flash_mha(q, k, v, causal, window)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    bspec = dp if (dp and B % dp_size == 0 and B >= dp_size) else None
    m_ax = "model" if "model" in mesh.axis_names else None
    msize = mesh.shape[m_ax] if m_ax else 1
    hq_spec = m_ax if (m_ax and Hq % msize == 0 and Hq >= msize) else None
    hkv_spec = m_ax if (hq_spec and Hkv % msize == 0 and Hkv >= msize) else None
    g_global = Hq // Hkv
    hq_local = Hq // msize if hq_spec else Hq
    # GQA with kv heads that don't divide the axis: each q-head shard must
    # see ITS kv head, not all of them — slice by axis index inside the
    # region (requires each shard's q heads to fall within one kv group).
    slice_kv = (hq_spec is not None and hkv_spec is None and Hkv > 1)
    if slice_kv and (hq_local > g_global or g_global % hq_local != 0):
        hq_spec = None            # misaligned groups: replicate heads
        slice_kv = False
        hq_local = Hq

    def local(q_, k_, v_):
        if slice_kv:
            idx = jax.lax.axis_index(m_ax)
            kv_head = idx * hq_local // g_global
            k_ = jax.lax.dynamic_slice_in_dim(k_, kv_head, 1, axis=1)
            v_ = jax.lax.dynamic_slice_in_dim(v_, kv_head, 1, axis=1)
        return kops.flash_mha(q_, k_, v_, causal, window)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec, hq_spec, None, None),
                  P(bspec, hkv_spec, None, None),
                  P(bspec, hkv_spec, None, None)),
        out_specs=P(bspec, hq_spec, None, None),
        check_rep=False)  # pallas out_shape carries no vma/rep annotations
    return fn(q, k, v)


def mha(cfg: ModelConfig, p: Params, x: jnp.ndarray, positions: jnp.ndarray,
        cap: Captures = None, prefix: str = "", kv_x: Optional[jnp.ndarray] = None,
        causal: bool = True, window: Optional[int] = None,
        kv_positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full-sequence attention (training / prefill).  kv_x != None => cross-attn."""
    hd = cfg.resolved_head_dim()
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    g = nq // nkv
    src = x if kv_x is None else kv_x
    q = dense(x, p["wq"], prefix + "wq", cap, p.get("bq"))
    k = dense(src, p["wk"], prefix + "wk", cap, p.get("bk"))
    v = dense(src, p["wv"], prefix + "wv", cap, p.get("bv"))
    q = _split_heads(q, nq, hd)              # (B,Sq,nq,hd)
    k = _split_heads(k, nkv, hd)             # (B,Sk,nkv,hd)
    v = _split_heads(v, nkv, hd)
    if kv_x is None:  # self-attention gets RoPE
        inv = rope_freqs(hd, cfg.partial_rotary, cfg.rope_theta)
        if cfg.partial_rotary > 0:
            q = apply_rope(q, positions, inv)
            kv_pos = positions if kv_positions is None else kv_positions
            k = apply_rope(k, kv_pos, inv)
    if (cfg.attn_impl == "flash" and kv_x is None and causal
            and cfg.attn_logit_softcap == 0 and kv_positions is None):
        # Pallas flash attention (§Perf iteration 3): no (S, S) HBM tensor
        o = _flash_sharded(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                           v.transpose(0, 2, 1, 3), causal, int(window or 0))
        o = o.transpose(0, 2, 1, 3).reshape(x.shape[:2] + (nq * hd,))
        return dense(o.astype(x.dtype), p["wo"], prefix + "wo", cap)
    qg = q.reshape(q.shape[:2] + (nkv, g, hd))
    # grouped-query attention without materializing repeated KV heads
    scores = jnp.einsum("bqngh,bknh->bngqk", qg, k).astype(jnp.float32) / np.sqrt(hd)
    if cfg.attn_logit_softcap > 0:
        c = cfg.attn_logit_softcap
        scores = jnp.tanh(scores / c) * c
    if kv_x is not None:  # cross-attention: attend everywhere
        mask = jnp.ones((x.shape[0], q.shape[1], k.shape[1]), bool)
    else:
        kv_pos = positions if kv_positions is None else kv_positions
        mask = _causal_window_mask(positions, kv_pos, window, causal)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bngqk,bknh->bqngh", probs, v)
    out = out.reshape(out.shape[:2] + (nq * hd,))
    return dense(out, p["wo"], prefix + "wo", cap)


@dataclasses.dataclass
class KVCache:
    """Fixed-capacity per-layer KV cache.  ``cache_len`` = min(window, seq)."""
    k: jnp.ndarray  # (B, cache_len, nkv, hd)
    v: jnp.ndarray


def kv_cache_init(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> Dict[str, jnp.ndarray]:
    hd = cfg.resolved_head_dim()
    shape = (batch, cache_len, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def mha_decode(cfg: ModelConfig, p: Params, x: jnp.ndarray, pos: jnp.ndarray,
               cache: Dict[str, jnp.ndarray], window: Optional[int] = None,
               cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode. x: (B,1,D); pos scalar int32 (same for the batch).

    Self-attn path appends K/V into the (ring-buffered when windowed) cache.
    ``cross_kv`` short-circuits to cross attention against fixed K/V.
    """
    hd = cfg.resolved_head_dim()
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    g = nq // nkv
    q = dense(x, p["wq"], bias=p.get("bq"))
    q = _split_heads(q, nq, hd)  # (B,1,nq,hd)
    if cross_kv is not None:
        k, v = cross_kv
        new_cache = cache
        valid = jnp.ones((k.shape[1],), bool)
    else:
        k_new = _split_heads(dense(x, p["wk"], bias=p.get("bk")), nkv, hd)
        v_new = _split_heads(dense(x, p["wv"], bias=p.get("bv")), nkv, hd)
        inv = rope_freqs(hd, cfg.partial_rotary, cfg.rope_theta)
        pos_b = jnp.full((x.shape[0], 1), pos, jnp.int32)
        if cfg.partial_rotary > 0:
            q = apply_rope(q, pos_b, inv)
            k_new = apply_rope(k_new, pos_b, inv)
        cache_len = cache["k"].shape[1]
        slot = jnp.mod(pos, cache_len)  # ring buffer when windowed
        k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
        new_cache = {"k": k, "v": v}
        idx = jnp.arange(cache_len)
        if window is not None and cache_len <= window:
            # ring: every slot valid once pos >= cache_len, else slots <= pos
            valid = (idx <= slot) | (pos >= cache_len)
        else:
            # non-ring: slot == absolute position, so the shared decode
            # mask applies directly (window cut matches the windowed full
            # forward and the paged decode path)
            valid = decode_window_mask(idx, slot, window)
    qg = q.reshape(q.shape[0], 1, nkv, g, hd)
    scores = jnp.einsum("bqngh,bknh->bngqk", qg, k).astype(jnp.float32) / np.sqrt(hd)
    if cfg.attn_logit_softcap > 0:
        c = cfg.attn_logit_softcap
        scores = jnp.tanh(scores / c) * c
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bngqk,bknh->bqngh", probs, v)
    out = out.reshape(out.shape[0], 1, nq * hd)
    return dense(out, p["wo"]), new_cache


def _paged_attn_sharded(q: jnp.ndarray, k_pool: jnp.ndarray,
                        v_pool: jnp.ndarray, tables: jnp.ndarray,
                        pos: jnp.ndarray, active: jnp.ndarray,
                        block_size: int, window: int, softcap: float,
                        wo: Optional[Params] = None) -> jnp.ndarray:
    """Block-table decode attention behind an optional shard_map boundary.

    Mirrors :func:`_flash_sharded`: under an ambient mesh the kv-head
    axis of the pools (and the group-aligned q heads) maps onto "model",
    so each device runs the kernel grid over its local heads — the head
    axis IS a grid axis, so sharding it just shrinks the grid.  The
    scalar-prefetch operands (tables/pos/active) replicate.  The packed
    o_proj epilogue only fuses unsharded: under TP the projection stays
    a separate dense() so GSPMD can psum head-partial contributions.
    Without an ambient mesh this is a plain local dispatch.
    """
    from repro.kernels import ops as kops
    from repro.utils import compat

    def local(q_, k_, v_, tab_, pos_, act_):
        return kops.paged_decode_attn(
            q_, k_, v_, tab_, pos_, act_, block_size=block_size,
            window=window, softcap=softcap,
            wo_vals=None if wo is None else wo["vals"],
            wo_meta=None if wo is None else wo["meta"])

    mesh = compat.ambient_mesh()
    nkv = k_pool.shape[1]
    if (mesh is None or "model" not in mesh.axis_names or wo is not None
            or nkv % mesh.shape["model"] != 0):
        return local(q, k_pool, v_pool, tables, pos, active)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    # q heads shard group-aligned with kv heads: nkv % msize == 0 makes
    # every "model" shard's contiguous q chunk a whole set of kv groups
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(None, "model", None), P(None, "model", None),
                  P(None, "model", None), P(None, None), P(None), P(None)),
        out_specs=P(None, "model", None),
        check_rep=False)
    return fn(q, k_pool, v_pool, tables, pos, active)


def mha_decode_paged(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                     pos: jnp.ndarray, cache: Dict[str, jnp.ndarray],
                     write_idx: jnp.ndarray, gather_idx: Optional[jnp.ndarray],
                     active: jnp.ndarray, window: Optional[int] = None,
                     *, tables: Optional[jnp.ndarray] = None,
                     block_size: Optional[int] = None,
                     impl: str = "reference",
                     ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode against a paged (block-pooled) KV cache.

    x: (S, 1, D) one token per serving slot; pos: (S,) per-slot absolute
    positions (unlike :func:`mha_decode`, slots decode at independent
    positions); cache: ``{"k", "v"}`` flat block pool for this layer,
    shape (T, nkv, hd) with T = num_blocks * block_size; write_idx: (S,)
    flat pool slot receiving this token's K/V; gather_idx: (S, W) flat
    pool slots of each slot's context *in position order*; active: (S,)
    bool — inactive slots write to the trash block and attend to
    nothing (their output is garbage the caller discards).

    The attention math is element-for-element that of :func:`mha_decode`
    on a contiguous (B, W, nkv, hd) cache: the paged read gathers the
    pages into position order first, masked tail entries underflow to
    exactly 0 after softmax, and the reductions run over the same axis
    widths — so the outputs are bitwise-equal to the contiguous path
    (pinned in tests/test_kv_pool.py).

    ``impl="fused"`` (with ``tables``/``block_size`` in place of
    ``gather_idx``) routes the attention through the block-table flash
    kernel (kernels/paged_attention.py): the kernel walks the table via
    scalar prefetch instead of materializing the (S, W, nkv, hd) gather,
    and when ``wo`` is packed the o_proj fuses into the kernel epilogue.
    On CPU / kernel-unfriendly shapes the fused route falls back to an
    oracle that repeats this function's exact math, so the two impls
    stay token-identical (DESIGN.md §11).
    """
    hd = cfg.resolved_head_dim()
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    g = nq // nkv
    q = dense(x, p["wq"], bias=p.get("bq"))
    q = _split_heads(q, nq, hd)                                   # (S,1,nq,hd)
    k_new = _split_heads(dense(x, p["wk"], bias=p.get("bk")), nkv, hd)
    v_new = _split_heads(dense(x, p["wv"], bias=p.get("bv")), nkv, hd)
    inv = rope_freqs(hd, cfg.partial_rotary, cfg.rope_theta)
    pos_b = pos[:, None]                                          # (S,1)
    if cfg.partial_rotary > 0:
        q = apply_rope(q, pos_b, inv)
        k_new = apply_rope(k_new, pos_b, inv)
    k = cache["k"].at[write_idx].set(k_new[:, 0].astype(cache["k"].dtype))
    v = cache["v"].at[write_idx].set(v_new[:, 0].astype(cache["v"].dtype))
    new_cache = {"k": k, "v": v}
    if impl == "fused" and tables is not None:
        from repro.kernels import ops as kops
        wo = p["wo"]
        fuse_o = (isinstance(wo, dict) and "vals" in wo
                  and kops.use_decode_kernel(hd, block_size))
        o = _paged_attn_sharded(q[:, 0], k, v, tables, pos, active,
                                block_size, int(window or 0),
                                float(cfg.attn_logit_softcap),
                                wo if fuse_o else None)
        if fuse_o:
            return o.astype(x.dtype)[:, None, :], new_cache
        out = o.reshape(o.shape[0], 1, nq * hd)
        return dense(out, p["wo"]), new_cache
    kg = jnp.take(k, gather_idx, axis=0)                          # (S,W,nkv,hd)
    vg = jnp.take(v, gather_idx, axis=0)
    idx = jnp.arange(gather_idx.shape[1], dtype=jnp.int32)
    valid = decode_window_mask(idx[None, :], pos[:, None], window) \
        & active[:, None]
    qg = q.reshape(q.shape[0], 1, nkv, g, hd)
    scores = jnp.einsum("bqngh,bknh->bngqk", qg, kg).astype(jnp.float32) / np.sqrt(hd)
    if cfg.attn_logit_softcap > 0:
        c = cfg.attn_logit_softcap
        scores = jnp.tanh(scores / c) * c
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bngqk,bknh->bqngh", probs, vg)
    out = out.reshape(out.shape[0], 1, nq * hd)
    return dense(out, p["wo"]), new_cache


def mha_prefill_paged(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                      pos: jnp.ndarray, cache: Dict[str, jnp.ndarray],
                      write_idx: jnp.ndarray, gather_idx: jnp.ndarray,
                      window: Optional[int] = None,
                      ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One fixed-width prefill *chunk* against the paged KV cache.

    x: (1, C, D) post-ln1 hidden of one prompt chunk for a single
    request; pos: (C,) absolute positions of the chunk rows; cache:
    this layer's flat block pool (T, nkv, hd); write_idx: (C,) flat
    pool slot per row — padded rows (beyond the caller's ``n_valid``)
    point into the trash block; gather_idx: (W,) flat slots of the
    request's full fixed-width context in position order, W = table
    width * block_size.

    Every chunk row gathers the *same* fixed-width context and masks it
    with :func:`decode_window_mask`, so the reductions run over
    identical axis widths regardless of chunk size, chunk offset, or
    how positions are grouped into chunks.  That makes the chunked
    prefill bitwise self-consistent across chunk groupings — the
    property the prefix cache's hit path (which resumes mid-prompt at a
    block boundary) relies on for bitwise-identical outputs
    (DESIGN.md §15, pinned in tests/test_serve_stack.py).
    """
    hd = cfg.resolved_head_dim()
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    g = nq // nkv
    C = x.shape[1]
    q = _split_heads(dense(x, p["wq"], bias=p.get("bq")), nq, hd)  # (1,C,nq,hd)
    k_new = _split_heads(dense(x, p["wk"], bias=p.get("bk")), nkv, hd)
    v_new = _split_heads(dense(x, p["wv"], bias=p.get("bv")), nkv, hd)
    if cfg.partial_rotary > 0:
        inv = rope_freqs(hd, cfg.partial_rotary, cfg.rope_theta)
        pos_b = pos[None, :]                                      # (1,C)
        q = apply_rope(q, pos_b, inv)
        k_new = apply_rope(k_new, pos_b, inv)
    k = cache["k"].at[write_idx].set(k_new[0].astype(cache["k"].dtype))
    v = cache["v"].at[write_idx].set(v_new[0].astype(cache["v"].dtype))
    new_cache = {"k": k, "v": v}
    kg = jnp.take(k, gather_idx, axis=0)                          # (W,nkv,hd)
    vg = jnp.take(v, gather_idx, axis=0)
    idx = jnp.arange(gather_idx.shape[0], dtype=jnp.int32)
    valid = decode_window_mask(idx[None, :], pos[:, None], window)  # (C,W)
    qg = q.reshape(1, C, nkv, g, hd)
    scores = jnp.einsum("bqngh,knh->bngqk", qg, kg).astype(jnp.float32) / np.sqrt(hd)
    if cfg.attn_logit_softcap > 0:
        c = cfg.attn_logit_softcap
        scores = jnp.tanh(scores / c) * c
    scores = jnp.where(valid[None, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bngqk,knh->bqngh", probs, vg)
    out = out.reshape(1, C, nq * hd)
    return dense(out, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_init(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> Params:
    dt = dtype_of(cfg.param_dtype)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act in ("silu", "geglu"):
        return {
            "gate": dense_init(ks[0], d, f, dt),
            "up": dense_init(ks[1], d, f, dt),
            "down": dense_init(ks[2], f, d, dt),
        }
    return {"fc1": dense_init(ks[0], d, f, dt), "b1": jnp.zeros((f,), dt),
            "fc2": dense_init(ks[1], f, d, dt), "b2": jnp.zeros((d,), dt)}


def mlp(cfg: ModelConfig, p: Params, x: jnp.ndarray, cap: Captures = None,
        prefix: str = "") -> jnp.ndarray:
    if "gate" in p:
        act = jax.nn.gelu if cfg.act == "geglu" else jax.nn.silu
        g = dense(x, p["gate"], prefix + "gate", cap)
        u = dense(x, p["up"], prefix + "up", cap)
        h = act(g.astype(jnp.float32)).astype(x.dtype) * u
        return dense(h, p["down"], prefix + "down", cap)
    h = dense(x, p["fc1"], prefix + "fc1", cap, p.get("b1"))
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return dense(h, p["fc2"], prefix + "fc2", cap, p.get("b2"))


def mlp_decode(cfg: ModelConfig, p: Params, x: jnp.ndarray,
               impl: str = "reference") -> jnp.ndarray:
    """Decode-step MLP: ONE fused kernel dispatch for the whole layer
    when ``impl="fused"`` and every matmul operand is 2:4-packed and
    kernel-compilable (kernels/paged_attention.py ``fused_mlp24`` — the
    hidden activation never leaves VMEM); otherwise the reference
    per-matmul :func:`mlp`.  On CPU the fused route always takes the
    reference path, so the decode impls stay bitwise-identical there.
    """
    if impl == "fused":
        from repro.kernels import ops as kops
        gated = "gate" in p
        keys = ("gate", "up", "down") if gated else ("fc1", "fc2")
        packed = all(isinstance(p.get(kk), dict) and "vals" in p[kk]
                     for kk in keys)
        if packed:
            d = x.shape[-1]
            f = p[keys[0]]["vals"].shape[0]
            if kops.use_fused_mlp(d, f):
                lead = x.shape[:-1]
                x2 = x.reshape(-1, d)
                if gated:
                    y = kops.fused_mlp24(
                        x2, p["gate"]["vals"], p["gate"]["meta"], None,
                        p["up"]["vals"], p["up"]["meta"],
                        p["down"]["vals"], p["down"]["meta"], None,
                        act=cfg.act)
                else:
                    y = kops.fused_mlp24(
                        x2, p["fc1"]["vals"], p["fc1"]["meta"], p.get("b1"),
                        None, None,
                        p["fc2"]["vals"], p["fc2"]["meta"], p.get("b2"),
                        act="gelu")
                return y.reshape(lead + (y.shape[-1],)).astype(x.dtype)
    return mlp(cfg, p, x)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean CE over labels >= 0 (labels==-1 masked).  logits (..., V)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_cross_entropy(hidden: jnp.ndarray, emb: jnp.ndarray, labels: jnp.ndarray,
                          chunk: int, softcap: float = 0.0) -> jnp.ndarray:
    """CE computed per sequence-chunk so the (B,S,V) logits tensor is never
    materialized.  hidden (B,S,D), emb (V,D) [tied head], labels (B,S)."""
    B, S, D = hidden.shape
    if chunk <= 0 or S % chunk != 0 or S == chunk:
        logits = jnp.einsum("bsd,vd->bsv", hidden, emb)
        if softcap > 0:
            logits = jnp.tanh(logits / softcap) * softcap
        return cross_entropy(logits, labels)
    n = S // chunk
    h = hidden.reshape(B, n, chunk, D).swapaxes(0, 1)      # (n,B,c,D)
    y = labels.reshape(B, n, chunk).swapaxes(0, 1)         # (n,B,c)

    def body(carry, xs):
        hc, yc = xs
        logits = jnp.einsum("bsd,vd->bsv", hc, emb)
        if softcap > 0:
            logits = jnp.tanh(logits / softcap) * softcap
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        ll = jnp.take_along_axis(lf, jnp.maximum(yc, 0)[..., None], axis=-1)[..., 0]
        m = (yc >= 0).astype(jnp.float32)
        return (carry[0] + jnp.sum((lse - ll) * m), carry[1] + jnp.sum(m)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (h, y))
    return tot / jnp.maximum(cnt, 1.0)
