"""Mixture-of-Experts FFN: dropless ragged dispatch (MegaBlocks-style).

Tokens are sorted by their assigned expert and the three SwiGLU matmuls run
as grouped (ragged) matmuls over the expert dimension — no capacity factor,
no dropped tokens, no (T, E, C) one-hot dispatch tensors.  This is the
TPU-idiomatic dropless formulation (cf. MaxText): ``jax.lax.ragged_dot``
lowers to a tiled grouped GEMM.

Supports the two assigned MoE flavors:
* mixtral-8x7b  — 8 routed experts, top-2, no shared expert
* qwen2-moe     — 60 routed top-4 + one fused shared expert with sigmoid gate
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import Captures, Params, dense, dense_init, dtype_of


def moe_init(cfg: ModelConfig, key) -> Params:
    m = cfg.moe
    dt = dtype_of(cfg.param_dtype)
    d, fe = cfg.d_model, m.expert_ff
    ks = jax.random.split(key, 6)
    scale = 1.0 / jnp.sqrt(d)
    p: Params = {
        "router": dense_init(ks[0], d, m.num_experts, jnp.float32),
        # experts stored stacked: (E, in, out)
        "w_gate": (jax.random.normal(ks[1], (m.num_experts, d, fe), jnp.float32) * scale).astype(dt),
        "w_up": (jax.random.normal(ks[2], (m.num_experts, d, fe), jnp.float32) * scale).astype(dt),
        "w_down": (jax.random.normal(ks[3], (m.num_experts, fe, d), jnp.float32) / jnp.sqrt(fe)).astype(dt),
    }
    if m.num_shared and m.shared_ff:
        p["shared"] = common.mlp_init(cfg, ks[4], d_ff=m.shared_ff)
        p["shared_gate"] = dense_init(ks[5], d, 1, dt)
    return p


def _ragged_expert_ffn(xs: jnp.ndarray, group_sizes: jnp.ndarray, p: Params) -> jnp.ndarray:
    """xs: (T*k, D) sorted by expert; grouped SwiGLU."""
    g = jax.lax.ragged_dot(xs, p["w_gate"], group_sizes)
    u = jax.lax.ragged_dot(xs, p["w_up"], group_sizes)
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(xs.dtype)
    return jax.lax.ragged_dot(h, p["w_down"], group_sizes)


def route(cfg: ModelConfig, p: Params, x_flat: jnp.ndarray
          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Router probabilities -> (weights (T,k), expert_ids (T,k), aux_loss)."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    vals, ids = jax.lax.top_k(probs, m.top_k)
    if m.norm_topk:
        vals = vals / jnp.maximum(jnp.sum(vals, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss
    T = x_flat.shape[0]
    frac_tokens = jnp.zeros((m.num_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0) / (T * m.top_k)
    frac_probs = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(frac_tokens * frac_probs)
    return vals, ids, aux


def moe_apply(cfg: ModelConfig, p: Params, x: jnp.ndarray, cap: Captures = None,
              prefix: str = "") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MoE FFN over (B, S, D) (or (T, D)).  Returns (out, aux_loss).

    In capture mode (cap != None) additionally records, per expert, the
    routing-masked input activation under ``{prefix}expert{e}/{gate,up}`` and
    the masked hidden under ``{prefix}expert{e}/down`` — zero columns for
    tokens not routed to that expert, which contribute nothing to the Gram
    statistics (see DESIGN.md §4).
    """
    m = cfg.moe
    orig_shape = x.shape
    x_flat = x.reshape(-1, x.shape[-1])
    T, D = x_flat.shape
    if cap is not None:
        cap[prefix + "router"] = x_flat
    w, ids, aux = route(cfg, p, x_flat)

    k = m.top_k
    flat_exp = ids.reshape(-1)                       # (T*k,)
    flat_tok = jnp.repeat(jnp.arange(T), k)          # (T*k,)
    flat_w = w.reshape(-1)
    order = jnp.argsort(flat_exp)                    # stable sort by expert
    sort_exp = flat_exp[order]
    sort_tok = flat_tok[order]
    sort_w = flat_w[order]
    xs = x_flat[sort_tok]                            # (T*k, D) sorted by expert
    group_sizes = jnp.zeros((m.num_experts,), jnp.int32).at[sort_exp].add(1)

    ys = _ragged_expert_ffn(xs, group_sizes, p)
    out = jnp.zeros((T, D), jnp.float32).at[sort_tok].add(
        ys.astype(jnp.float32) * sort_w[:, None])

    if cap is not None:
        # per-expert capture for the pruner (dense masked form; outside jit)
        onehot = jax.nn.one_hot(ids, m.num_experts, dtype=x_flat.dtype)   # (T,k,E)
        tok_w = jnp.einsum("tk,tke->te", w.astype(x_flat.dtype), onehot)  # (T,E)
        for e in range(m.num_experts):
            mask = (tok_w[:, e] > 0).astype(x_flat.dtype)[:, None]
            xe = x_flat * mask
            cap[f"{prefix}expert{e}/gate"] = xe
            cap[f"{prefix}expert{e}/up"] = xe
            g = dense(xe, p["w_gate"][e])
            u = dense(xe, p["w_up"][e])
            he = (jax.nn.silu(g.astype(jnp.float32)).astype(x_flat.dtype) * u) * mask
            cap[f"{prefix}expert{e}/down"] = he

    if "shared" in p:
        sh = common.mlp(cfg, p["shared"], x_flat, cap, prefix + "shared/")
        gate = jax.nn.sigmoid(dense(x_flat, p["shared_gate"]).astype(jnp.float32))
        out = out + sh.astype(jnp.float32) * gate
    return out.astype(x.dtype).reshape(orig_shape), aux


def moe_operator_groups(cfg: ModelConfig, prefix: str = "mlp/") -> list:
    """Sequential pruning groups for a MoE FFN (peers pruned together)."""
    m = cfg.moe
    groups = []
    first = [f"{prefix}expert{e}/gate" for e in range(m.num_experts)]
    first += [f"{prefix}expert{e}/up" for e in range(m.num_experts)]
    if m.num_shared and m.shared_ff:
        first += [f"{prefix}shared/gate", f"{prefix}shared/up"]
    groups.append(first)
    second = [f"{prefix}expert{e}/down" for e in range(m.num_experts)]
    if m.num_shared and m.shared_ff:
        second.append(f"{prefix}shared/down")
    groups.append(second)
    return groups
