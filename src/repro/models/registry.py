"""Model registry: one ModelDef per architecture family.

A ModelDef bundles every function the rest of the framework needs —
training loss, eval logits, serving (prefill/decode), the pruning-unit
protocol, and synthetic batch construction for smoke tests and the
dry-run's ShapeDtypeStruct inputs.

Families: dense (GQA/MQA/SWA transformer), moe, vlm (transformer +
patch-embedding stub), ssm (Mamba2), hybrid (RG-LRU), encdec (Whisper).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import encdec, mamba2, rglru, transformer
from repro.models.common import dtype_of
from repro.models.transformer import UnitSpec


@dataclasses.dataclass(frozen=True)
class ModelDef:
    cfg: ModelConfig
    init: Callable                 # (key) -> params
    loss: Callable                 # (params, batch) -> (loss, metrics)
    forward_logits: Callable       # (params, batch) -> logits
    units: Callable                # () -> [UnitSpec]
    embed: Callable                # (params, batch) -> state
    unit_apply: Callable           # (unit_params, i, state, cap) -> state
    head: Callable                 # (params, state) -> logits
    post_unit: Callable            # (params, i, state) -> state (relay hook)
    serve_step: Callable           # (params, state, token, pos) -> (logits, state)
    init_serve_state: Callable     # (params, batch, cache_len, batch_extras) -> state
    prefill: Optional[Callable]    # (params, tokens, cache_len, extras) -> (logits, state)
    make_batch: Callable           # (key, batch, seq) -> host batch dict
    batch_specs: Callable          # (shape: ShapeSpec) -> dict of ShapeDtypeStruct
    # paged serving (continuous batcher, serve/batcher.py); None for
    # families without a paged decode path (ssm / hybrid / encdec)
    init_paged_state: Optional[Callable] = None  # (num_blocks, block_size) -> pool
    paged_step: Optional[Callable] = None        # (params, pool, tables, token,
                                                 #  pos, active, block_size,
                                                 #  impl="reference"|"fused")
                                                 # -> (logits, pool)
    paged_prefill_chunk: Optional[Callable] = None  # (params, pool, table,
                                                    #  tokens, pos0, n_valid,
                                                    #  block_size)
                                                    # -> (last logits, pool)


def _identity_post_unit(params, i, state):
    return state


def _token_batch(cfg: ModelConfig, key, batch: int, seq: int) -> Dict[str, jnp.ndarray]:
    tokens = jax.random.randint(key, (batch, seq), 0, cfg.vocab, jnp.int32)
    labels = jnp.concatenate([tokens[:, 1:], jnp.full((batch, 1), -1, jnp.int32)], axis=1)
    return {"tokens": tokens, "labels": labels}


def _token_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}


# ---------------------------------------------------------------------------
# dense / moe transformer
# ---------------------------------------------------------------------------
def _transformer_def(cfg: ModelConfig) -> ModelDef:
    return ModelDef(
        cfg=cfg,
        init=lambda key: transformer.init(cfg, key),
        loss=lambda p, b: transformer.loss(cfg, p, b),
        forward_logits=lambda p, b: transformer.forward_logits(cfg, p, b["tokens"],
                                                               b.get("patches")),
        units=lambda: transformer.units(cfg),
        embed=lambda p, b: transformer.embed(cfg, p, b),
        unit_apply=lambda up, i, s, cap=None: transformer.unit_apply(cfg, up, i, s, cap),
        head=lambda p, s: transformer.head(cfg, p, s),
        post_unit=_identity_post_unit,
        serve_step=lambda p, s, t, pos: transformer.serve_step(cfg, p, s, t, pos),
        init_serve_state=lambda p, b, cache_len, extras=None:
            transformer.init_kv_caches(cfg, b, cache_len),
        prefill=lambda p, tokens, cache_len, extras=None, last_only=False:
            transformer.prefill(cfg, p, tokens, cache_len,
                                None if extras is None else extras.get("patches"),
                                last_only=last_only),
        make_batch=lambda key, b, s: _token_batch(cfg, key, b, s),
        batch_specs=lambda shape: _token_specs(cfg, shape),
        init_paged_state=lambda num_blocks, block_size:
            transformer.init_paged_caches(cfg, num_blocks, block_size),
        paged_step=lambda p, pool, tables, token, pos, active, block_size,
                          impl="reference":
            transformer.paged_serve_step(cfg, p, pool, tables, token, pos,
                                         active, block_size, impl=impl),
        paged_prefill_chunk=lambda p, pool, table, tokens, pos0, n_valid,
                                   block_size:
            transformer.paged_prefill_chunk(cfg, p, pool, table, tokens,
                                            pos0, n_valid, block_size),
    )


# ---------------------------------------------------------------------------
# vlm: transformer backbone + precomputed patch embeddings (stub frontend)
# ---------------------------------------------------------------------------
def _vlm_def(cfg: ModelConfig) -> ModelDef:
    base = _transformer_def(cfg)
    npatch = cfg.vlm.num_patches

    def make_batch(key, b, s):
        k1, k2 = jax.random.split(key)
        out = _token_batch(cfg, k1, b, max(s - npatch, 8))
        out["patches"] = jax.random.normal(
            k2, (b, npatch, cfg.d_model), jnp.float32) * 0.02
        return out

    def batch_specs(shape: ShapeSpec):
        B = shape.global_batch
        S = max(shape.seq_len - npatch, 8)
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "patches": jax.ShapeDtypeStruct((B, npatch, cfg.d_model), jnp.float32)}

    return dataclasses.replace(base, make_batch=make_batch, batch_specs=batch_specs)


# ---------------------------------------------------------------------------
# ssm (Mamba2)
# ---------------------------------------------------------------------------
def _ssm_def(cfg: ModelConfig) -> ModelDef:
    return ModelDef(
        cfg=cfg,
        init=lambda key: mamba2.init(cfg, key),
        loss=lambda p, b: mamba2.loss(cfg, p, b),
        forward_logits=lambda p, b: mamba2.forward_logits(cfg, p, b["tokens"]),
        units=lambda: mamba2.units(cfg),
        embed=lambda p, b: mamba2.embed(cfg, p, b),
        unit_apply=lambda up, i, s, cap=None: mamba2.unit_apply(cfg, up, i, s, cap),
        head=lambda p, s: mamba2.head(cfg, p, s),
        post_unit=_identity_post_unit,
        serve_step=lambda p, s, t, pos: mamba2.serve_step(cfg, p, s, t, pos),
        init_serve_state=lambda p, b, cache_len, extras=None:
            mamba2.init_serve_state(cfg, b),
        prefill=None,
        make_batch=lambda key, b, s: _token_batch(cfg, key, b, s),
        batch_specs=lambda shape: _token_specs(cfg, shape),
    )


# ---------------------------------------------------------------------------
# hybrid (RG-LRU)
# ---------------------------------------------------------------------------
def _hybrid_def(cfg: ModelConfig) -> ModelDef:
    return ModelDef(
        cfg=cfg,
        init=lambda key: rglru.init(cfg, key),
        loss=lambda p, b: rglru.loss(cfg, p, b),
        forward_logits=lambda p, b: rglru.forward_logits(cfg, p, b["tokens"]),
        units=lambda: rglru.units(cfg),
        embed=lambda p, b: rglru.embed(cfg, p, b),
        unit_apply=lambda up, i, s, cap=None: rglru.unit_apply(cfg, up, i, s, cap),
        head=lambda p, s: rglru.head(cfg, p, s),
        post_unit=_identity_post_unit,
        serve_step=lambda p, s, t, pos: rglru.serve_step(cfg, p, s, t, pos),
        init_serve_state=lambda p, b, cache_len, extras=None:
            rglru.init_serve_state(cfg, b, cache_len),
        prefill=None,
        make_batch=lambda key, b, s: _token_batch(cfg, key, b, s),
        batch_specs=lambda shape: _token_specs(cfg, shape),
    )


# ---------------------------------------------------------------------------
# encdec (Whisper)
# ---------------------------------------------------------------------------
def _encdec_def(cfg: ModelConfig) -> ModelDef:
    enc_seq = cfg.encdec.enc_seq

    def make_batch(key, b, s):
        k1, k2 = jax.random.split(key)
        out = _token_batch(cfg, k1, b, s)
        out["frames"] = jax.random.normal(k2, (b, enc_seq, cfg.d_model), jnp.float32) * 0.02
        return out

    def batch_specs(shape: ShapeSpec):
        B = shape.global_batch
        S = min(shape.seq_len, cfg.max_seq)  # whisper decoder is 448-capped
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "frames": jax.ShapeDtypeStruct((B, enc_seq, cfg.d_model), jnp.float32)}

    return ModelDef(
        cfg=cfg,
        init=lambda key: encdec.init(cfg, key),
        loss=lambda p, b: encdec.loss(cfg, p, b),
        forward_logits=lambda p, b: encdec.forward_logits(cfg, p, b["tokens"], b["frames"]),
        units=lambda: encdec.units(cfg),
        embed=lambda p, b: encdec.embed(cfg, p, b),
        unit_apply=lambda up, i, s, cap=None: encdec.unit_apply(cfg, up, i, s, cap),
        head=lambda p, s: encdec.head(cfg, p, s),
        post_unit=lambda p, i, s: encdec.finalize_encoder(cfg, p, s),
        serve_step=lambda p, s, t, pos: encdec.serve_step(cfg, p, s, t, pos),
        init_serve_state=lambda p, b, cache_len, extras:
            encdec.init_serve_state(cfg, p, extras["frames"], cache_len),
        prefill=None,
        make_batch=make_batch,
        batch_specs=batch_specs,
    )


_FAMILY_BUILDERS = {
    "dense": _transformer_def,
    "moe": _transformer_def,
    "vlm": _vlm_def,
    "ssm": _ssm_def,
    "hybrid": _hybrid_def,
    "encdec": _encdec_def,
}


def model_def(cfg: ModelConfig) -> ModelDef:
    try:
        builder = _FAMILY_BUILDERS[cfg.family]
    except KeyError:
        raise ValueError(f"unknown family {cfg.family!r} for arch {cfg.arch!r}")
    return builder(cfg)


def load_arch(name: str, smoke: bool = False) -> ModelDef:
    """Build a ModelDef from a config module in repro/configs."""
    import importlib

    mod = importlib.import_module(
        f"repro.configs.{name.replace('-', '_').replace('.', '_')}")
    cfg = mod.smoke_config() if smoke else mod.config()
    return model_def(cfg)
