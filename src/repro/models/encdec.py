"""Whisper-style encoder-decoder transformer backbone.

Per the assignment the conv/mel frontend is a STUB: the model consumes
precomputed frame embeddings (B, enc_seq, D) — ``batch["frames"]`` —
with sinusoidal positions already added.  Encoder layers are
bidirectional (LayerNorm + GELU MLP); decoder layers add causal
self-attention with learned positions and cross-attention to the encoder
output.  Head is tied to the decoder token embedding (Whisper).

Pruning units: enc_layers encoder units followed by dec_layers decoder
units.  Cross-attention W_k/W_v consume the (pruned) encoder output —
the intra-layer error-correction relay handles this naturally because
the encoder units run before any decoder unit and the relay state keeps
the evolving ``enc`` tensor (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import (Captures, Params, cross_entropy, dense,
                                 dense_init, dtype_of, embed_init, mha,
                                 mha_decode, mlp, mlp_init, norm_apply,
                                 norm_init)
from repro.models.transformer import UnitSpec
from repro.utils import tree as tree_lib


def enc_layer_init(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln1": norm_init(cfg, cfg.d_model), "attn": common.attn_init(cfg, k1),
            "ln2": norm_init(cfg, cfg.d_model), "mlp": mlp_init(cfg, k2)}


def dec_layer_init(cfg: ModelConfig, key) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": norm_init(cfg, cfg.d_model), "self": common.attn_init(cfg, k1),
            "lnx": norm_init(cfg, cfg.d_model), "cross": common.attn_init(cfg, k2),
            "ln2": norm_init(cfg, cfg.d_model), "mlp": mlp_init(cfg, k3)}


def init(cfg: ModelConfig, key) -> Params:
    e = cfg.encdec
    ks = jax.random.split(key, e.enc_layers + e.dec_layers + 3)
    return {
        "embed": embed_init(ks[-1], cfg.vocab, cfg.d_model, dtype_of(cfg.param_dtype)),
        "pos_embed": embed_init(ks[-2], cfg.max_seq, cfg.d_model, dtype_of(cfg.param_dtype)),
        "enc_layers": tree_lib.tree_stack(
            [enc_layer_init(cfg, ks[i]) for i in range(e.enc_layers)]),
        "dec_layers": tree_lib.tree_stack(
            [dec_layer_init(cfg, ks[e.enc_layers + i]) for i in range(e.dec_layers)]),
        "enc_norm": norm_init(cfg, cfg.d_model),
        "dec_norm": norm_init(cfg, cfg.d_model),
    }


# ---------------------------------------------------------------------------
# per-layer forwards
# ---------------------------------------------------------------------------
def enc_layer_apply(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                    positions: jnp.ndarray, cap: Captures = None) -> jnp.ndarray:
    h = norm_apply(cfg, p["ln1"], x)
    a = mha(cfg, p["attn"], h, positions, cap, "attn/", causal=False)
    x = x + a.astype(x.dtype)
    h = norm_apply(cfg, p["ln2"], x)
    return x + mlp(cfg, p["mlp"], h, cap, "mlp/").astype(x.dtype)


def dec_layer_apply(cfg: ModelConfig, p: Params, x: jnp.ndarray, enc: jnp.ndarray,
                    positions: jnp.ndarray, cap: Captures = None) -> jnp.ndarray:
    h = norm_apply(cfg, p["ln1"], x)
    a = mha(cfg, p["self"], h, positions, cap, "self/")
    x = x + a.astype(x.dtype)
    h = norm_apply(cfg, p["lnx"], x)
    a = mha(cfg, p["cross"], h, positions, cap, "cross/", kv_x=enc)
    x = x + a.astype(x.dtype)
    h = norm_apply(cfg, p["ln2"], x)
    return x + mlp(cfg, p["mlp"], h, cap, "mlp/").astype(x.dtype)


# ---------------------------------------------------------------------------
# fast paths
# ---------------------------------------------------------------------------
def encode(cfg: ModelConfig, params: Params, frames: jnp.ndarray) -> jnp.ndarray:
    B, S, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    x = frames.astype(dtype_of(cfg.compute_dtype))

    def body(h, lp):
        return enc_layer_apply(cfg, lp, h, positions), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    else:
        for i in range(cfg.encdec.enc_layers):
            x, _ = body_fn(x, tree_lib.tree_index(params["enc_layers"], i))
    return norm_apply(cfg, params["enc_norm"], x)


def decode_hidden(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                  enc: jnp.ndarray) -> jnp.ndarray:
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    x = params["embed"][tokens] + params["pos_embed"][:S][None]

    def body(h, lp):
        return dec_layer_apply(cfg, lp, h, enc, positions), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    else:
        for i in range(cfg.encdec.dec_layers):
            x, _ = body_fn(x, tree_lib.tree_index(params["dec_layers"], i))
    return norm_apply(cfg, params["dec_norm"], x)


def forward_logits(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                   frames: jnp.ndarray) -> jnp.ndarray:
    enc = encode(cfg, params, frames)
    h = decode_hidden(cfg, params, tokens, enc)
    return jnp.einsum("...d,vd->...v", h, params["embed"])


def loss(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray]):
    logits = forward_logits(cfg, params, batch["tokens"], batch["frames"])
    ce = cross_entropy(logits, batch["labels"])
    return ce, {"ce": ce}


# ---------------------------------------------------------------------------
# serving: decoder decode with self-KV cache + fixed cross-KV
# ---------------------------------------------------------------------------
def init_serve_state(cfg: ModelConfig, params: Params, frames: jnp.ndarray,
                     cache_len: int) -> Dict[str, jnp.ndarray]:
    """Runs the encoder once; precomputes per-layer cross K/V."""
    enc = encode(cfg, params, frames)
    B = frames.shape[0]
    hd = cfg.resolved_head_dim()
    dt = dtype_of(cfg.compute_dtype)

    def kv(lp):
        k = common._split_heads(dense(enc, lp["cross"]["wk"], bias=lp["cross"].get("bk")),
                                cfg.num_kv_heads, hd)
        v = common._split_heads(dense(enc, lp["cross"]["wv"], bias=lp["cross"].get("bv")),
                                cfg.num_kv_heads, hd)
        return k.astype(dt), v.astype(dt)

    _, (cross_k, cross_v) = jax.lax.scan(
        lambda c, lp: (c, kv(lp)), 0, params["dec_layers"])
    shape = (cfg.encdec.dec_layers, B, cache_len, cfg.num_kv_heads, hd)
    return {"self_k": jnp.zeros(shape, dt), "self_v": jnp.zeros(shape, dt),
            "cross_k": cross_k, "cross_v": cross_v}


def serve_step(cfg: ModelConfig, params: Params, state: Dict[str, jnp.ndarray],
               token: jnp.ndarray, pos: jnp.ndarray):
    x = params["embed"][token] + params["pos_embed"][pos][None, None, :]

    def body(h, xs):
        lp, sk, sv, ck, cv = xs
        hn = norm_apply(cfg, lp["ln1"], h)
        a, cache = mha_decode(cfg, lp["self"], hn, pos, {"k": sk, "v": sv})
        h = h + a.astype(h.dtype)
        hn = norm_apply(cfg, lp["lnx"], h)
        a, _ = mha_decode(cfg, lp["cross"], hn, pos, {}, cross_kv=(ck, cv))
        h = h + a.astype(h.dtype)
        hn = norm_apply(cfg, lp["ln2"], h)
        h = h + mlp(cfg, lp["mlp"], hn).astype(h.dtype)
        return h, cache

    if cfg.scan_layers:
        x, caches = jax.lax.scan(
            body, x, (params["dec_layers"], state["self_k"], state["self_v"],
                      state["cross_k"], state["cross_v"]))
    else:
        outs = []
        for i in range(cfg.encdec.dec_layers):
            lp = tree_lib.tree_index(params["dec_layers"], i)
            x, co = body(x, (lp, state["self_k"][i], state["self_v"][i],
                             state["cross_k"][i], state["cross_v"][i]))
            outs.append(co)
        caches = tree_lib.tree_stack(outs)
    h = norm_apply(cfg, params["dec_norm"], x)
    logits = jnp.einsum("...d,vd->...v", h, params["embed"])
    return logits, dict(state, self_k=caches["k"], self_v=caches["v"])


# ---------------------------------------------------------------------------
# unit path
# ---------------------------------------------------------------------------
def units(cfg: ModelConfig) -> List[UnitSpec]:
    e = cfg.encdec
    enc_groups = (("attn/wq", "attn/wk", "attn/wv"), ("attn/wo",),
                  ("mlp/fc1",), ("mlp/fc2",))
    dec_groups = (("self/wq", "self/wk", "self/wv"), ("self/wo",),
                  ("cross/wq", "cross/wk", "cross/wv"), ("cross/wo",),
                  ("mlp/fc1",), ("mlp/fc2",))
    out = [UnitSpec(f"enc{i:03d}", "enc_layers", i, enc_groups)
           for i in range(e.enc_layers)]
    out += [UnitSpec(f"dec{i:03d}", "dec_layers", i, dec_groups)
            for i in range(e.dec_layers)]
    return out


def embed(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray]):
    frames = batch["frames"]
    tokens = batch["tokens"]
    B, Se, _ = frames.shape
    S = tokens.shape[1]
    return {
        "x": frames.astype(dtype_of(cfg.compute_dtype)),   # encoder stream first
        "dec_x": params["embed"][tokens] + params["pos_embed"][:S][None],
        "enc_positions": jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32)[None, :], (B, Se)),
        "positions": jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S)),
    }


def unit_apply(cfg: ModelConfig, unit_params: Params, i: int,
               state: Dict[str, jnp.ndarray], cap: Captures = None):
    """``i`` is the layer index WITHIN its stack (enc or dec); the stacks
    are told apart by their param structure ("cross" => decoder)."""
    e = cfg.encdec
    if "cross" not in unit_params:
        x = enc_layer_apply(cfg, unit_params, state["x"], state["enc_positions"], cap)
        state = dict(state, x=x)
        if i == e.enc_layers - 1:
            state = dict(state, enc=x)  # post_unit hook applies enc_norm
        return state
    x = dec_layer_apply(cfg, unit_params, state["dec_x"], state["enc_normed"],
                        state["positions"], cap)
    return dict(state, dec_x=x)


def finalize_encoder(cfg: ModelConfig, params: Params, state: Dict) -> Dict:
    """Apply the encoder final norm once all encoder units ran (relay hook)."""
    if "enc" in state and "enc_normed" not in state:
        state = dict(state, enc_normed=norm_apply(cfg, params["enc_norm"], state["enc"]))
    return state


def head(cfg: ModelConfig, params: Params, state: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    h = norm_apply(cfg, params["dec_norm"], state["dec_x"])
    return jnp.einsum("...d,vd->...v", h, params["embed"])
