"""Projection-free Frank-Wolfe backend for the layer-wise pruning objective.

Solves the same Gram-form problem as FISTAPruner (core/gram.py)

    min_Y  1/2 ||Y X* - W X||_F^2   s.t.  Y in S(spec)

by relaxing S to the convex hull of a k-sparse L2 ball ("Don't Be Greedy,
Just Relax!", arXiv:2510.13713): atoms are tau-radius matrices supported
on the top-k entries of the gradient, so the linear minimization oracle
is a single top-k — no projection, no factorization:

    grad  = Y G - B
    s     = -tau * P_k(grad) / ||P_k(grad)||_F     # LMO: top-k of |grad|
    gamma = clip(<grad, Y - s> / <(s-Y) G, s-Y>, 0, 1)   # exact line search
    Y    <- Y + gamma (s - Y)

P_k keeps the spec's own pattern (global top-k for unstructured, per-group
top-n for n:m), every iterate stays in the hull, and the quadratic's exact
line search makes the objective monotone non-increasing.  Each iterate is
rounded (core/sparsity.round_to) into a feasible candidate; the best
candidate by exact Gram-form error is tracked (strict improvement only,
so re-solving an already-optimal feasible point is a bitwise no-op), then
polished with support-restricted projected-gradient steps — the same
cheap back-solve analog the ADMM backend uses.

Like the fused FISTA outer loop (core/pruner.py) and ADMM (core/admm.py),
the whole solve is one ``lax.while_loop`` inside a single jitted
computation — zero per-iteration host syncs — and ``vmap``s across stacked
same-shape operators for the group-batched path.  Registered as solver
"frankwolfe" in core/solvers.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as baselines_lib
from repro.core import gram as gram_lib
from repro.core.gram import GramStats
from repro.core.pruner import PruneResult, _make_result
from repro.core.sparsity import SparsitySpec, mask_nm_by_score, round_to


@dataclasses.dataclass(frozen=True)
class FrankWolfeConfig:
    """Defaults tuned for parity with the FISTA/ADMM paths at golden-test
    scale (tests/test_golden_solvers.py)."""

    max_iters: int = 64           # FW iterations (while_loop bound)
    tol: float = 1e-6             # stop when dual gap <= tol * h
    radius_rel: float = 1.25      # atom L2 radius relative to ||warm||_F
    polish_iters: int = 16        # masked projected-gradient steps at the end
    warm_start: str = "wanda"     # wanda | sparsegpt | magnitude | dense


def keep_count(shape: Sequence[int], spec: SparsitySpec) -> int:
    """Entries the spec keeps nonzero (the LMO's k / the support budget)."""
    size = int(np.prod(shape))
    if spec.kind == "nm":
        return size * spec.n // spec.m
    return size - int(round(spec.ratio * size))


def lmo_atom(grad: jnp.ndarray, spec: SparsitySpec,
             tau: jnp.ndarray) -> jnp.ndarray:
    """argmin_{s in tau-radius k-sparse L2 ball} <grad, s>.

    The minimizer is supported on the spec-pattern top-k of |grad| and
    points along -grad there, scaled to the ball radius.
    """
    if spec.kind == "nm":
        mask = mask_nm_by_score(jnp.abs(grad), spec.n, spec.m)
    else:
        size = grad.size
        k = keep_count(grad.shape, spec)
        if k <= 0:
            mask = jnp.zeros(grad.shape, bool)
        elif k >= size:
            mask = jnp.ones(grad.shape, bool)
        else:
            _, idx = jax.lax.top_k(jnp.abs(grad).reshape(-1), k)
            mask = (jnp.zeros((size,), bool).at[idx].set(True)
                    .reshape(grad.shape))
    g = jnp.where(mask, grad, 0.0)
    return -tau * g / (jnp.linalg.norm(g) + 1e-12)


def fw_step(y: jnp.ndarray, G: jnp.ndarray, B: jnp.ndarray,
            spec: SparsitySpec, tau: jnp.ndarray) -> tuple:
    """One Frank-Wolfe iteration with exact line search on the quadratic.

    Returns ``(y_next, gap)`` where ``gap = <grad, y - s> >= f(y) - f*``
    is the Frank-Wolfe dual gap (nonnegative whenever y is in the hull).
    Exact line search guarantees f(y_next) <= f(y).
    """
    grad = y @ G - B
    s = lmo_atom(grad, spec, tau)
    d = s - y
    gap = -jnp.sum(grad * d)
    curv = jnp.sum((d @ G) * d)
    gamma = jnp.clip(gap / jnp.maximum(curv, 1e-12), 0.0, 1.0)
    return y + gamma * d, gap


class FwState(NamedTuple):
    """while_loop carry (all device arrays)."""

    y: jnp.ndarray        # current hull iterate (not necessarily feasible)
    z_best: jnp.ndarray   # best ROUNDED (feasible) candidate so far
    e_best: jnp.ndarray   # its exact error ||Z X* - W X||_F
    gap: jnp.ndarray      # dual gap of the last step
    k: jnp.ndarray        # int32 iterations executed


def _fused_fw(G: jnp.ndarray, B: jnp.ndarray, h: jnp.ndarray,
              w0: jnp.ndarray, spec: SparsitySpec,
              cfg: FrankWolfeConfig) -> tuple:
    """One XLA computation: FW loop + per-iterate rounding + support polish.

    Returns (z_best, e_best, iters, warm_error, tau).
    """
    z0 = round_to(w0.astype(jnp.float32), spec)
    e0 = gram_lib.frob_error_gh(G, h, z0, B)
    tau = cfg.radius_rel * jnp.linalg.norm(z0) + 1e-8
    gap_floor = cfg.tol * (h + 1e-8)
    state = FwState(y=z0, z_best=z0, e_best=e0,
                    gap=jnp.float32(jnp.inf), k=jnp.int32(0))

    def cond(s: FwState):
        return (s.k < cfg.max_iters) & (s.gap >= gap_floor)

    def body(s: FwState) -> FwState:
        y, gap = fw_step(s.y, G, B, spec, tau)
        z = round_to(y, spec)
        e = gram_lib.frob_error_gh(G, h, z, B)
        better = e < s.e_best      # strict: ties keep the earlier candidate
        z_best = jnp.where(better, z, s.z_best)
        e_best = jnp.where(better, e, s.e_best)
        return FwState(y, z_best, e_best, gap, s.k + 1)

    out = jax.lax.while_loop(cond, body, state)

    # polish: projected gradient restricted to the winning support (keeps
    # feasibility — zeros stay zero, so the spec is still satisfied exactly)
    mask = out.z_best != 0
    inv_l = 1.0 / jnp.maximum(gram_lib.max_eigval(G) * 1.01, 1e-12)

    def pbody(_, z):
        return jnp.where(mask, z - inv_l * (z @ G - B), 0.0)

    z_pol = jax.lax.fori_loop(0, cfg.polish_iters, pbody, out.z_best)
    e_pol = gram_lib.frob_error_gh(G, h, z_pol, B)
    z_fin = jnp.where(e_pol < out.e_best, z_pol, out.z_best)
    e_fin = jnp.minimum(e_pol, out.e_best)
    return z_fin, e_fin, out.k, e0, tau


def _solve_one(w: jnp.ndarray, stats: GramStats, spec: SparsitySpec,
               cfg: FrankWolfeConfig, warm: str) -> tuple:
    w = w.astype(jnp.float32)
    B = gram_lib.target_correlation(stats, w)
    w0 = baselines_lib.warm_start(warm, w, stats, spec)
    return _fused_fw(stats.G, B, stats.h, w0, spec, cfg)


@partial(jax.jit, static_argnames=("spec", "cfg", "warm"))
def _fw_single(w: jnp.ndarray, stats: GramStats, spec: SparsitySpec,
               cfg: FrankWolfeConfig, warm: str) -> tuple:
    return _solve_one(w, stats, spec, cfg, warm)


@partial(jax.jit, static_argnames=("spec", "cfg", "warm"))
def _fw_group(ws: jnp.ndarray, stats: GramStats, spec: SparsitySpec,
              cfg: FrankWolfeConfig, warm: str) -> tuple:
    return jax.vmap(lambda w, st: _solve_one(w, st, spec, cfg, warm))(ws, stats)


def prune_operator_fw(w: jnp.ndarray, stats: GramStats, spec: SparsitySpec,
                      cfg: FrankWolfeConfig = FrankWolfeConfig(),
                      warm: Optional[str] = None) -> PruneResult:
    """Prune one operator ``w`` (paper layout (out, in)) with Frank-Wolfe."""
    w = jnp.asarray(w, jnp.float32)
    z, e, k, e0, tau = _fw_single(w, stats, spec, cfg,
                                  cfg.warm_start if warm is None else warm)
    return _make_result(z.astype(w.dtype), float(e), float(tau), int(k), 0,
                        float(e0), float(stats.h))


def prune_group_fw(ws: Union[jnp.ndarray, Sequence[jnp.ndarray]],
                   stats: Union[GramStats, Sequence[GramStats]],
                   spec: SparsitySpec,
                   cfg: FrankWolfeConfig = FrankWolfeConfig(),
                   warm: Optional[str] = None) -> List[PruneResult]:
    """vmap-batched FW over stacked same-shape operators (one dispatch)."""
    if isinstance(ws, (list, tuple)):
        shapes = {tuple(jnp.asarray(w).shape) for w in ws}
        if len(shapes) != 1:
            raise ValueError(f"prune_group_fw needs same-shape operators, "
                             f"got {shapes}")
        ws = jnp.stack([jnp.asarray(w, jnp.float32) for w in ws])
    else:
        ws = jnp.asarray(ws, jnp.float32)
    if isinstance(stats, (list, tuple)):
        stats = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *stats)
    z, e, k, e0, tau = _fw_group(ws, stats, spec, cfg,
                                 cfg.warm_start if warm is None else warm)
    h_np = np.asarray(stats.h, np.float32)
    e_np, k_np = np.asarray(e, np.float32), np.asarray(k, np.int32)
    e0_np, tau_np = np.asarray(e0, np.float32), np.asarray(tau, np.float32)
    return [_make_result(z[i], float(e_np[i]), float(tau_np[i]), int(k_np[i]),
                         0, float(e0_np[i]), float(h_np[i]))
            for i in range(ws.shape[0])]
