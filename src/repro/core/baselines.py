"""Baseline one-shot pruners the paper compares against (and warm-starts from).

* magnitude : keep largest |w| (global for unstructured, per-group for n:m).
* Wanda     : score |W_ij| * ||x_j||_2 from calibration activations; per-row
              comparison groups (Sun et al. 2023), no weight update.
* SparseGPT : OBS column sweep with Cholesky-factored inverse Hessian and
              weight compensation (Frantar & Alistarh 2023), blockwise.

All operate in the paper layout W (out=m, in=n) and consume the same
GramStats as FISTAPruner — one calibration sweep serves every method.
SparseGPT uses the dense-path Gram H = X X^T by default; pass
``use_pruned_gram=True`` to run it against X* (what it sees when used as a
warm start inside the intra-layer-corrected pipeline).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import gram as gram_lib
from repro.core.gram import GramStats
from repro.core.sparsity import (SparsitySpec, mask_by_score, nm_rank)


# ---------------------------------------------------------------------------
# magnitude
# ---------------------------------------------------------------------------
def magnitude(w: jnp.ndarray, spec: SparsitySpec) -> jnp.ndarray:
    w = jnp.asarray(w, jnp.float32)
    mask = mask_by_score(jnp.abs(w), spec, rowwise=False)
    return jnp.where(mask, w, 0.0)


# ---------------------------------------------------------------------------
# Wanda
# ---------------------------------------------------------------------------
def wanda(w: jnp.ndarray, stats: GramStats, spec: SparsitySpec) -> jnp.ndarray:
    """|W| * ||x_j||_2 with per-output-row comparison groups."""
    w = jnp.asarray(w, jnp.float32)
    norms = jnp.sqrt(jnp.maximum(stats.hdiag, 0.0))        # (n,)
    score = jnp.abs(w) * norms[None, :]
    mask = mask_by_score(score, spec, rowwise=True)
    return jnp.where(mask, w, 0.0)


# ---------------------------------------------------------------------------
# SparseGPT
# ---------------------------------------------------------------------------
def _hinv_cholesky(H: jnp.ndarray, damp_rel: float) -> jnp.ndarray:
    """Upper-Cholesky factor of H^{-1} (SparseGPT's working matrix).

    Returns U upper-triangular with H^{-1} = U^T U ... processed so that
    U[j, j:] plays the role of the reference implementation's Hinv rows.
    """
    n = H.shape[0]
    Hd = H + (damp_rel * jnp.mean(jnp.diag(H)) + 1e-10) * jnp.eye(n, dtype=H.dtype)
    Hinv = jnp.linalg.inv(Hd)
    # reference impl: Hinv = cholesky(Hinv, upper=True)
    Lc = jnp.linalg.cholesky(Hinv)          # lower: Hinv = Lc Lc^T
    return Lc.T                              # upper factor


@partial(jax.jit, static_argnames=("bs", "nm_n", "nm_m", "ratio", "use_nm"))
def _sparsegpt_block(W1: jnp.ndarray, U1: jnp.ndarray, ratio: float,
                     use_nm: bool, nm_n: int, nm_m: int, bs: int):
    """Process one column block: returns (Q1 pruned block, Err1).

    W1 (m, bs), U1 (bs, bs) the corresponding diagonal block of the upper
    Cholesky factor of H^{-1}.  Column i of the block is pruned with OBS
    saliency w^2/d^2 (d = U1[i,i]) and the remaining columns compensated
    with err * U1[i, i:].
    """
    m = W1.shape[0]
    diag = jnp.diag(U1)                                     # (bs,)

    if not use_nm:
        # global-within-block threshold (reference implementation)
        score = (W1 ** 2) / (diag[None, :] ** 2)
        k = int(round(ratio * m * bs))
        flat = jnp.sort(score.reshape(-1))
        thresh = flat[min(max(k - 1, 0), m * bs - 1)] if k > 0 else -jnp.inf
        prune_mask0 = score <= thresh if k > 0 else jnp.zeros_like(score, bool)
    else:
        prune_mask0 = jnp.zeros((m, bs), bool)

    def body(i, carry):
        W1c, Err1, pmask = carry
        col = jax.lax.dynamic_slice(W1c, (0, i), (m, 1))[:, 0]
        d = diag[i]

        if use_nm:
            # at group starts, pick the n:m mask for columns [i, i+m)
            def pick(pm):
                blk = jax.lax.dynamic_slice(W1c, (0, i), (m, nm_m))
                dg = jax.lax.dynamic_slice(diag, (i,), (nm_m,))
                sc = (blk ** 2) / (dg[None, :] ** 2)
                rank = nm_rank(sc[:, None, :], nm_m)[:, 0, :]
                grp_prune = rank >= nm_n                     # prune smallest m-n
                return jax.lax.dynamic_update_slice(pm, grp_prune, (0, i))

            pmask = jax.lax.cond(i % nm_m == 0, pick, lambda pm: pm, pmask)

        pruned_here = jax.lax.dynamic_slice(pmask, (0, i), (m, 1))[:, 0]
        q = jnp.where(pruned_here, 0.0, col)
        err = (col - q) / d                                 # (m,)
        # compensate columns i.. (masked to the future) within the block
        row = U1[i] * (jnp.arange(bs) >= i + 1)             # zero past+self
        W1c = W1c - err[:, None] * row[None, :]
        W1c = jax.lax.dynamic_update_slice(W1c, q[:, None], (0, i))
        Err1 = jax.lax.dynamic_update_slice(Err1, err[:, None], (0, i))
        return (W1c, Err1, pmask)

    Err0 = jnp.zeros((m, bs), jnp.float32)
    W1f, Err1, _ = jax.lax.fori_loop(0, bs, body, (W1, Err0, prune_mask0))
    return W1f, Err1


def warm_start(name_or_w, w: jnp.ndarray, stats: GramStats,
               spec: SparsitySpec) -> jnp.ndarray:
    """Dispatch a warm-start candidate by name (or pass an array through).

    Shared by the iterative solvers (FISTA's Algorithm 1, the ADMM
    backend): all of them start from a baseline solution per paper Sec. 4.1.
    """
    if not isinstance(name_or_w, str):
        return jnp.asarray(name_or_w, jnp.float32)
    if name_or_w == "wanda":
        return wanda(w, stats, spec)
    if name_or_w == "sparsegpt":
        return sparsegpt(w, stats, spec)
    if name_or_w == "magnitude":
        return magnitude(w, spec)
    if name_or_w == "dense":
        return w.astype(jnp.float32)
    raise ValueError(f"unknown warm start {name_or_w!r}")


def sparsegpt(w: jnp.ndarray, stats: GramStats, spec: SparsitySpec,
              blocksize: int = 128, damp_rel: float = 0.01,
              use_pruned_gram: bool = False) -> jnp.ndarray:
    """SparseGPT sweep over column blocks with cross-block compensation."""
    W = jnp.asarray(w, jnp.float32)
    m, n = W.shape
    H = stats.G if use_pruned_gram else stats.H
    # dead inputs (never activated): reference impl zeroes those columns
    dead = jnp.diag(H) == 0
    H = H + jnp.diag(jnp.where(dead, 1.0, 0.0))
    W = jnp.where(dead[None, :], 0.0, W)

    U = _hinv_cholesky(H, damp_rel)                          # (n, n) upper
    bs = min(blocksize, n)
    use_nm = spec.kind == "nm"
    ratio = 0.0 if use_nm else spec.ratio

    out = W
    for j1 in range(0, n, bs):
        j2 = min(j1 + bs, n)
        cur = j2 - j1
        W1 = jax.lax.dynamic_slice(out, (0, j1), (m, cur))
        U1 = U[j1:j2, j1:j2]
        # rescale so the block factor is self-consistent (reference keeps the
        # global factor; U rows already encode cross-block couplings below)
        Q1, Err1 = _sparsegpt_block(W1, U1, ratio, use_nm, spec.n, spec.m, cur)
        out = jax.lax.dynamic_update_slice(out, Q1, (0, j1))
        if j2 < n:
            # lazy batch compensation of all future columns
            out = out.at[:, j2:].add(-(Err1 @ U[j1:j2, j2:]))
    return jnp.where(dead[None, :], 0.0, out)
