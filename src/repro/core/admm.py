"""ALPS-style ADMM backend for the layer-wise convex pruning objective.

Solves the same Gram-form problem as FISTAPruner (core/gram.py)

    min_Y  1/2 ||Y X* - W X||_F^2   s.t.  Y in S(spec)

by operator splitting (Meng et al., ALPS, arXiv:2406.07831): introduce a
copy Z constrained to the sparsity set S and run scaled-dual ADMM

    Y^{k+1} = argmin_Y f(Y) + rho/2 ||Y - Z^k + U^k||_F^2
            = (B + rho (Z^k - U^k)) (G + rho I)^{-1}
    Z^{k+1} = round(Y^{k+1} + U^k, spec)          # projection onto S
    U^{k+1} = U^k + Y^{k+1} - Z^{k+1}

The Y-update reuses a single Cholesky factorization of G + rho I; the
Z-update is exactly the paper's rounding step (core/sparsity.round_to),
so every iterate Z is feasible.  The best feasible iterate (by the exact
Gram-form error) is tracked, then polished with a few projected-gradient
steps restricted to its support (the cheap analog of ALPS's
support-restricted back-solve).

Like the fused FISTA outer loop (core/pruner.py), the whole solve is one
``lax.while_loop`` inside a single jitted computation — zero per-iteration
host syncs — and ``vmap``s across stacked same-shape operators for the
group-batched path.  Registered as solver "admm" in core/solvers.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as baselines_lib
from repro.core import gram as gram_lib
from repro.core.gram import GramStats
from repro.core.pruner import PruneResult, _make_result
from repro.core.sparsity import SparsitySpec, round_to


@dataclasses.dataclass(frozen=True)
class AdmmConfig:
    """Defaults tuned for parity with the FISTA path at container scale."""

    rho_rel: float = 0.1          # penalty relative to mean(diag(G))
    max_iters: int = 64           # ADMM iterations (while_loop bound)
    tol: float = 1e-5             # stop when the Z iterate stabilizes
    polish_iters: int = 16        # masked projected-gradient steps at the end
    warm_start: str = "wanda"     # wanda | sparsegpt | magnitude | dense


class AdmmState(NamedTuple):
    """while_loop carry (all device arrays)."""

    z: jnp.ndarray        # current feasible iterate
    u: jnp.ndarray        # scaled dual
    z_best: jnp.ndarray   # best feasible iterate so far
    e_best: jnp.ndarray   # its exact error ||Z X* - W X||_F
    delta: jnp.ndarray    # relative change of Z in the last step
    k: jnp.ndarray        # int32 iterations executed


def _fused_admm(G: jnp.ndarray, B: jnp.ndarray, h: jnp.ndarray,
                w0: jnp.ndarray, spec: SparsitySpec, cfg: AdmmConfig) -> tuple:
    """One XLA computation: ADMM loop + support polish.

    Returns (z_best, e_best, iters, warm_error, rho).
    """
    n = G.shape[0]
    rho = cfg.rho_rel * jnp.mean(jnp.diag(G)) + 1e-8
    cho = jax.scipy.linalg.cho_factor(
        G + rho * jnp.eye(n, dtype=jnp.float32))

    z0 = round_to(w0.astype(jnp.float32), spec)
    e0 = gram_lib.frob_error_gh(G, h, z0, B)
    state = AdmmState(z=z0, u=jnp.zeros_like(z0), z_best=z0, e_best=e0,
                      delta=jnp.float32(jnp.inf), k=jnp.int32(0))

    def cond(s: AdmmState):
        return (s.k < cfg.max_iters) & (s.delta >= cfg.tol)

    def body(s: AdmmState) -> AdmmState:
        rhs = B + rho * (s.z - s.u)
        y = jax.scipy.linalg.cho_solve(cho, rhs.T).T
        z = round_to(y + s.u, spec)
        u = s.u + y - z
        e = gram_lib.frob_error_gh(G, h, z, B)
        better = e < s.e_best
        z_best = jnp.where(better, z, s.z_best)
        e_best = jnp.where(better, e, s.e_best)
        delta = jnp.linalg.norm(z - s.z) / (jnp.linalg.norm(z) + 1e-12)
        return AdmmState(z, u, z_best, e_best, delta, s.k + 1)

    out = jax.lax.while_loop(cond, body, state)

    # polish: projected gradient restricted to the winning support (keeps
    # feasibility — zeros stay zero, so the spec is still satisfied exactly)
    mask = out.z_best != 0
    inv_l = 1.0 / jnp.maximum(gram_lib.max_eigval(G) * 1.01, 1e-12)

    def pbody(_, z):
        return jnp.where(mask, z - inv_l * (z @ G - B), 0.0)

    z_pol = jax.lax.fori_loop(0, cfg.polish_iters, pbody, out.z_best)
    e_pol = gram_lib.frob_error_gh(G, h, z_pol, B)
    z_fin = jnp.where(e_pol < out.e_best, z_pol, out.z_best)
    e_fin = jnp.minimum(e_pol, out.e_best)
    return z_fin, e_fin, out.k, e0, rho


def _solve_one(w: jnp.ndarray, stats: GramStats, spec: SparsitySpec,
               cfg: AdmmConfig, warm: str) -> tuple:
    w = w.astype(jnp.float32)
    B = gram_lib.target_correlation(stats, w)
    w0 = baselines_lib.warm_start(warm, w, stats, spec)
    return _fused_admm(stats.G, B, stats.h, w0, spec, cfg)


@partial(jax.jit, static_argnames=("spec", "cfg", "warm"))
def _admm_single(w: jnp.ndarray, stats: GramStats, spec: SparsitySpec,
                 cfg: AdmmConfig, warm: str) -> tuple:
    return _solve_one(w, stats, spec, cfg, warm)


@partial(jax.jit, static_argnames=("spec", "cfg", "warm"))
def _admm_group(ws: jnp.ndarray, stats: GramStats, spec: SparsitySpec,
                cfg: AdmmConfig, warm: str) -> tuple:
    return jax.vmap(lambda w, st: _solve_one(w, st, spec, cfg, warm))(ws, stats)


def prune_operator_admm(w: jnp.ndarray, stats: GramStats, spec: SparsitySpec,
                        cfg: AdmmConfig = AdmmConfig(),
                        warm: Optional[str] = None) -> PruneResult:
    """Prune one operator ``w`` (paper layout (out, in)) with ADMM."""
    w = jnp.asarray(w, jnp.float32)
    z, e, k, e0, rho = _admm_single(w, stats, spec, cfg,
                                    cfg.warm_start if warm is None else warm)
    return _make_result(z.astype(w.dtype), float(e), float(rho), int(k), 0,
                        float(e0), float(stats.h))


def prune_group_admm(ws: Union[jnp.ndarray, Sequence[jnp.ndarray]],
                     stats: Union[GramStats, Sequence[GramStats]],
                     spec: SparsitySpec, cfg: AdmmConfig = AdmmConfig(),
                     warm: Optional[str] = None) -> List[PruneResult]:
    """vmap-batched ADMM over stacked same-shape operators (one dispatch)."""
    if isinstance(ws, (list, tuple)):
        shapes = {tuple(jnp.asarray(w).shape) for w in ws}
        if len(shapes) != 1:
            raise ValueError(f"prune_group_admm needs same-shape operators, "
                             f"got {shapes}")
        ws = jnp.stack([jnp.asarray(w, jnp.float32) for w in ws])
    else:
        ws = jnp.asarray(ws, jnp.float32)
    if isinstance(stats, (list, tuple)):
        stats = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *stats)
    z, e, k, e0, rho = _admm_group(ws, stats, spec, cfg,
                                   cfg.warm_start if warm is None else warm)
    h_np = np.asarray(stats.h, np.float32)
    e_np, k_np = np.asarray(e, np.float32), np.asarray(k, np.int32)
    e0_np, rho_np = np.asarray(e0, np.float32), np.asarray(rho, np.float32)
    return [_make_result(z[i], float(e_np[i]), float(rho_np[i]), int(k_np[i]),
                         0, float(e0_np[i]), float(h_np[i]))
            for i in range(ws.shape[0])]
