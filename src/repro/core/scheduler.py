"""Fault-tolerant work-queue scheduler for layer-unit pruning.

The paper's Sec. 3.4 parallelism: pruning units (decoder layers) are
independent, so they form an embarrassingly-parallel work queue.  At
cluster scale each worker is a pod; here workers are threads driving the
same math.  Production behaviors implemented and tested:

* per-unit atomic checkpointing — a completed unit's pruned weights land
  in the checkpoint store (crc-verified); a restarted job skips them;
* retry with backoff — a failed unit is re-queued up to ``max_retries``;
* straggler mitigation — once the queue drains, units still running
  longer than ``straggler_factor`` x the median completed duration are
  speculatively re-dispatched; first completion wins (units are pure
  functions of (layer, calibration), so duplicates are idempotent);
* elasticity — workers pull from the queue; adding/removing workers
  between units never invalidates state.
"""
from __future__ import annotations

import dataclasses
import inspect
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.checkpoint import store
from repro.utils import get_logger

log = get_logger("scheduler")


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    workers: int = 1
    max_retries: int = 2
    retry_backoff: float = 0.05        # seconds, doubled per retry
    straggler_factor: float = 4.0      # x median duration before duplication
    straggler_min_wait: float = 1.0    # don't duplicate before this many seconds
    checkpoint_dir: Optional[str] = None


@dataclasses.dataclass
class UnitResult:
    unit: str
    payload: Any
    seconds: float
    attempts: int
    worker: int


class UnitFailed(RuntimeError):
    pass


class PruneScheduler:
    """Runs ``run_unit(name) -> payload`` for every unit name."""

    def __init__(self, units: Sequence[str], run_unit: Callable[[str], Any],
                 cfg: SchedulerConfig = SchedulerConfig(),
                 save_payload: Optional[Callable[[str, Any], Any]] = None,
                 load_payload: Optional[Callable[[str], Any]] = None):
        self.units = list(units)
        self.run_unit = run_unit
        self.cfg = cfg
        self.save_payload = save_payload
        self.load_payload = load_payload
        # telemetry-aware persistence: a 3-arg save_payload additionally
        # receives {"worker", "seconds", "attempts"} so multi-worker runs
        # are attributable post-hoc from the checkpoints alone; 2-arg
        # callbacks keep working unchanged
        self._save_wants_meta = False
        if save_payload is not None:
            try:
                self._save_wants_meta = (
                    len(inspect.signature(save_payload).parameters) >= 3)
            except (TypeError, ValueError):
                pass
        self._results: Dict[str, UnitResult] = {}
        self._attempts: Dict[str, int] = {u: 0 for u in self.units}
        self._lock = threading.Lock()
        self._queue: "queue.Queue[Optional[str]]" = queue.Queue()
        self._inflight: Dict[str, float] = {}    # unit -> start time
        self._failed: Dict[str, str] = {}
        self._duplicated: set = set()
        self._pending_persist = 0                # results not yet on disk

    # -- persistence ---------------------------------------------------------
    def _ckpt_name(self, unit: str) -> str:
        return f"unit_{unit}"

    def _try_resume(self, unit: str) -> bool:
        cfg = self.cfg
        if not cfg.checkpoint_dir or self.load_payload is None:
            return False
        if not store.exists(cfg.checkpoint_dir, self._ckpt_name(unit)):
            return False
        try:
            payload = self.load_payload(unit)
        except store.CheckpointCorrupt:
            log.warning("unit %s checkpoint corrupt; re-running", unit)
            return False
        self._results[unit] = UnitResult(unit, payload, 0.0, 0, -1)
        return True

    def _persist(self, unit: str, payload: Any,
                 result: Optional["UnitResult"] = None) -> None:
        if self.cfg.checkpoint_dir and self.save_payload is not None:
            if self._save_wants_meta and result is not None:
                self.save_payload(unit, payload,
                                  {"worker": result.worker,
                                   "seconds": result.seconds,
                                   "attempts": result.attempts})
            else:
                self.save_payload(unit, payload)

    # -- worker loop -----------------------------------------------------------
    def _worker(self, wid: int) -> None:
        while True:
            try:
                unit = self._queue.get(timeout=0.05)
            except queue.Empty:
                with self._lock:
                    if self._all_done() or self._aborted():
                        return
                continue
            if unit is None:
                return
            with self._lock:
                if unit in self._results:          # duplicate lost the race
                    self._queue.task_done()
                    continue
                self._inflight[unit] = time.perf_counter()
                self._attempts[unit] += 1
                attempt = self._attempts[unit]
            t0 = time.perf_counter()
            try:
                payload = self.run_unit(unit)
            except Exception as exc:  # noqa: BLE001 — worker boundary
                with self._lock:
                    self._inflight.pop(unit, None)
                    if unit in self._results:
                        self._queue.task_done()
                        continue
                    if attempt <= self.cfg.max_retries:
                        log.warning("unit %s failed (attempt %d): %s — retrying",
                                    unit, attempt, exc)
                        delay = self.cfg.retry_backoff * (2 ** (attempt - 1))
                        threading.Timer(delay, self._queue.put, args=(unit,)).start()
                    else:
                        log.error("unit %s failed permanently: %s", unit, exc)
                        self._failed[unit] = repr(exc)
                self._queue.task_done()
                continue
            dt = time.perf_counter() - t0
            first = False
            with self._lock:
                self._inflight.pop(unit, None)
                if unit not in self._results:      # first completion wins
                    result = UnitResult(unit, payload, dt, attempt, wid)
                    self._results[unit] = result
                    first = True
                    # reserve the persist before releasing the lock so run()
                    # cannot observe "all done" with this checkpoint still
                    # in flight (a resumed job would recompute the unit)
                    self._pending_persist += 1
            if first:
                try:
                    self._persist(unit, payload, result)
                except Exception as exc:  # noqa: BLE001 — a checkpoint
                    # failure must not kill the worker (the result is already
                    # recorded); a resumed job just recomputes this unit
                    log.warning("unit %s checkpoint save failed: %s", unit, exc)
                finally:
                    with self._lock:
                        self._pending_persist -= 1
            self._queue.task_done()

    def _all_done(self) -> bool:
        return len(self._results) + len(self._failed) >= len(self.units)

    def _aborted(self) -> bool:
        return bool(self._failed)

    def _watch_stragglers(self) -> None:
        """Speculatively re-dispatch slow units (duplicate once)."""
        cfg = self.cfg
        while True:
            time.sleep(0.05)
            with self._lock:
                if self._all_done() or self._aborted():
                    return
                done = [r.seconds for r in self._results.values() if r.seconds > 0]
                if not done or not self._inflight:
                    continue
                med = sorted(done)[len(done) // 2]
                now = time.perf_counter()
                for unit, started in list(self._inflight.items()):
                    run = now - started
                    if (unit not in self._duplicated and unit not in self._results
                            and run > max(cfg.straggler_factor * med,
                                          cfg.straggler_min_wait)):
                        log.warning("unit %s running %.2fs (median %.2fs) — "
                                    "speculative duplicate", unit, run, med)
                        self._duplicated.add(unit)
                        self._queue.put(unit)

    # -- entry -----------------------------------------------------------------
    def run(self) -> Dict[str, UnitResult]:
        todo = []
        for u in self.units:
            if self._try_resume(u):
                log.info("unit %s resumed from checkpoint", u)
            else:
                todo.append(u)
        for u in todo:
            self._queue.put(u)

        threads = [threading.Thread(target=self._worker, args=(i,), daemon=True)
                   for i in range(max(self.cfg.workers, 1))]
        watcher = threading.Thread(target=self._watch_stragglers, daemon=True)
        for t in threads:
            t.start()
        watcher.start()
        # poll for completion instead of joining: a worker stuck inside an
        # abandoned straggler must not block the job once its duplicate won
        while True:
            with self._lock:
                if self._all_done() and self._pending_persist == 0:
                    break
            time.sleep(0.01)
        if self._failed:
            raise UnitFailed(f"units failed after retries: {self._failed}")
        return dict(self._results)

    @property
    def stats(self) -> Dict[str, Any]:
        durations = {u: r.seconds for u, r in self._results.items()}
        fresh = [s for s in durations.values() if s > 0]  # resumed units are 0
        return {
            "completed": len(self._results),
            "duplicated": sorted(self._duplicated),
            "attempts": dict(self._attempts),
            "durations": durations,
            "workers": {u: r.worker for u, r in self._results.items()},
            "total_unit_seconds": sum(fresh),
            "median_unit_seconds": (sorted(fresh)[len(fresh) // 2]
                                    if fresh else 0.0),
        }

    @property
    def run_summary(self) -> Dict[str, Any]:
        """Run-level telemetry persisted as ``run_summary.json`` next to the
        unit checkpoints and rendered by ``python -m repro.obs report``."""
        durations = {u: r.seconds for u, r in self._results.items()}
        fresh = {u: s for u, s in durations.items() if s > 0}
        hist: Dict[str, int] = {}
        for u in self._results:
            a = str(self._attempts.get(u, 1))
            hist[a] = hist.get(a, 0) + 1
        slowest = (max(fresh.items(), key=lambda kv: kv[1])
                   if fresh else None)
        return {
            "total_solver_seconds": sum(fresh.values()),
            "attempts_histogram": hist,
            "slowest_unit": (None if slowest is None
                             else {"unit": slowest[0],
                                   "seconds": slowest[1]}),
            "completed": len(self._results),
            "resumed": len(durations) - len(fresh),
            "duplicated": sorted(self._duplicated),
        }
