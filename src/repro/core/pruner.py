"""FISTAPruner Algorithm 1: outer loop with adaptive lambda bisection.

Per operator (paper Sec. 3.3/3.4):

    t=0; W_best = W_0; E_best = ||W_0 X* - W X||_F
    repeat:
        W_K  = FISTA(lam, warm start W_best, K iters)
        W_K1 = round(W_K, s% or n:m)                      # Eq. (8)
        E_total = ||W_K1 X* - W X||_F
        E_round = E_total - ||W_K X* - W X||_F
        if E_total < E_best: E_stop=(E_best-E_total)/E_best; keep W_K1; t=0
        else: t += 1
        bisect lam on [0, 1e6] by E_round/E_total vs xi=0.3
    until t >= T or E_stop < eps

Two implementations of the outer loop are provided:

* ``outer_impl="fused"`` (default) — the whole of Algorithm 1 (FISTA solve,
  rounding, error evaluations, patience/eps stop, lambda bisection) is one
  ``lax.while_loop`` inside a single jitted computation: zero per-iteration
  host<->device syncs.  :func:`prune_group` additionally ``vmap``s the fused
  loop across all same-shape operators of a pruning group, so one dispatch
  solves e.g. wq/wk/wv or every MoE expert's gate+up at once.
* ``outer_impl="host"`` — the reference host-Python loop (one device sync
  per outer iteration).  Kept as the equivalence oracle for tests and for
  step-by-step debugging.

Both implementations run the same math; see DESIGN.md §3.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as baselines_lib
from repro.core import fista as fista_lib
from repro.core import gram as gram_lib
from repro.core.gram import GramStats
from repro.core.sparsity import SparsitySpec, round_to
from repro.utils import get_logger

log = get_logger("pruner")


@dataclasses.dataclass(frozen=True)
class PrunerConfig:
    """Paper Sec. 4.1 defaults: lam_init=1e-5, K=20, T=3, xi=0.3; eps is
    1e-6 for OPT-family and 1e-3 for LLaMA-family in the paper."""

    lam_init: float = 1e-5
    lam_lo: float = 0.0
    lam_hi: float = 1e6
    fista_iters: int = 20          # K
    fista_tol: float = fista_lib.DEFAULT_TOL
    patience: int = 3              # T
    eps: float = 1e-3              # relative-improvement stop
    xi: float = 0.3                # E_round/E_total threshold (Sec. 3.3)
    max_outer: int = 40            # safety bound on the bisection loop
    warm_start: str = "wanda"      # wanda | sparsegpt | magnitude | dense
    momentum: str = "fista"        # fista | paper  (see core/fista.py)
    step_impl: str = "jnp"         # jnp | pallas
    outer_impl: str = "fused"      # fused (device-resident) | host (reference)
    group_batch: bool = True       # vmap same-shape operators of a group
    # shard the m rows of each inner FISTA solve over the mesh "model"
    # axis (distributed/rowfista.py).  Only takes effect when a
    # MeshExecutor with model_parallel > 1 is bound to the solver
    # (SequentialConfig.executor / PruneRecipe.mesh); otherwise ignored.
    row_shard: bool = False
    # keep the first trace_len-1 outer iterations' (e_total, lam) plus the
    # last one as PruneResult.trace — the convergence trajectory the obs
    # layer persists (repro.obs, DESIGN.md §14).  The history rides the
    # fused while_loop as device arrays and is transferred ONCE after the
    # solve (no per-iteration host sync).  0 (default) records nothing.
    trace_len: int = 0


@dataclasses.dataclass
class PruneResult:
    weight: jnp.ndarray            # W_best, satisfies the sparsity spec
    error: float                   # E_best = ||W_best X* - W X||_F
    rel_error: float               # E_best / ||W X||_F
    lam: float                     # final lambda
    outer_iters: int
    fista_iters: int               # total inner iterations across the loop
    warm_error: float              # error of the warm start (for ablation)
    # per-outer-iteration {"e_total", "lam"} host arrays when
    # cfg.trace_len > 0 (length min(outer_iters, trace_len); iterations
    # beyond the budget collapse into the last slot), else None
    trace: Optional[dict] = None


# warm-start dispatch lives with the baselines it selects from
_warm_start = baselines_lib.warm_start


# ---------------------------------------------------------------------------
# fused device-resident outer loop
# ---------------------------------------------------------------------------
class OuterState(NamedTuple):
    """while_loop carry of the fused Algorithm 1 (all device arrays)."""

    w_best: jnp.ndarray   # (m, n) best feasible candidate so far
    e_best: jnp.ndarray   # scalar ||W_best X* - W X||_F
    lam: jnp.ndarray      # current lambda
    lo: jnp.ndarray       # bisection bracket
    hi: jnp.ndarray
    t: jnp.ndarray        # int32 patience counter
    e_stop: jnp.ndarray   # last relative improvement (inf until first)
    k: jnp.ndarray        # int32 outer iterations executed
    inner: jnp.ndarray    # int32 total FISTA iterations


def _fused_outer(G: jnp.ndarray, B: jnp.ndarray, h: jnp.ndarray,
                 w0: jnp.ndarray, L: jnp.ndarray, spec: SparsitySpec,
                 cfg: PrunerConfig) -> tuple:
    """Algorithm 1 as one XLA computation.  Returns (OuterState,
    warm_error, trace) — ``trace`` is a {"e_total", "lam"} dict of
    (trace_len,) device arrays when ``cfg.trace_len > 0``, else None.

    Branches of the host loop become ``jnp.where`` selects; the stopping
    rule (t >= T or E_stop < eps, checked after the bisection update)
    becomes the while_loop condition.  Trip count, bisection trajectory and
    accepted candidates match the host reference exactly up to fp32
    round-off of the lambda midpoints.

    The optional convergence trace rides the carry as fixed-shape device
    arrays written at ``min(k, trace_len - 1)`` — iteration k's candidate
    error and the lambda that produced it — so the caller can transfer
    the whole history in one post-solve host sync (the JAX003 discipline).
    """
    w0 = round_to(w0.astype(jnp.float32), spec)  # feasible warm start
    e0 = gram_lib.frob_error_gh(G, h, w0, B)
    state = OuterState(
        w_best=w0, e_best=e0,
        lam=jnp.float32(cfg.lam_init), lo=jnp.float32(cfg.lam_lo),
        hi=jnp.float32(cfg.lam_hi), t=jnp.int32(0),
        e_stop=jnp.float32(jnp.inf), k=jnp.int32(0),
        inner=jnp.int32(0))
    tl = int(cfg.trace_len)   # static: the carry's structure is fixed
    trace0 = None if tl <= 0 else {"e_total": jnp.zeros((tl,), jnp.float32),
                                   "lam": jnp.zeros((tl,), jnp.float32)}

    def cond(carry):
        s = carry[0]
        return (s.k < cfg.max_outer) & (s.t < cfg.patience) & (s.e_stop >= cfg.eps)

    def body(carry):
        s, tr = carry
        w_k, iters = fista_lib.solve(
            G, B, s.w_best, s.lam, L=L, max_iters=cfg.fista_iters,
            tol=cfg.fista_tol, momentum=cfg.momentum, step_impl=cfg.step_impl)
        w_k1 = round_to(w_k, spec)
        e_fista = gram_lib.frob_error_gh(G, h, w_k, B)
        e_total = gram_lib.frob_error_gh(G, h, w_k1, B)
        e_round = e_total - e_fista

        improved = e_total < s.e_best
        e_stop = jnp.where(
            improved, (s.e_best - e_total) / jnp.maximum(s.e_best, 1e-30),
            s.e_stop)
        w_best = jnp.where(improved, w_k1, s.w_best)
        e_best = jnp.where(improved, e_total, s.e_best)
        t = jnp.where(improved, jnp.int32(0), s.t + 1)

        # bisection on lambda driven by the rounding-error share (Sec. 3.3):
        # high share => FISTA solution not sparse enough => raise lambda.
        ratio = e_round / jnp.maximum(e_total, 1e-30)
        raise_lam = ratio > cfg.xi
        lo = jnp.where(raise_lam, s.lam, s.lo)
        hi = jnp.where(raise_lam, s.hi, s.lam)
        lam = 0.5 * (lo + hi)
        if tr is not None:
            idx = jnp.minimum(s.k, tl - 1)
            tr = {"e_total": tr["e_total"].at[idx].set(e_total),
                  "lam": tr["lam"].at[idx].set(s.lam)}
        return (OuterState(w_best, e_best, lam, lo, hi, t, e_stop,
                           s.k + 1, s.inner + iters.astype(jnp.int32)), tr)

    out, trace = jax.lax.while_loop(cond, body, (state, trace0))
    return out, e0, trace


def _solve_one(w: jnp.ndarray, stats: GramStats, spec: SparsitySpec,
               cfg: PrunerConfig, warm: str) -> tuple:
    """Warm start + fused Algorithm 1 for one operator (trace-level)."""
    w = w.astype(jnp.float32)
    B = gram_lib.target_correlation(stats, w)
    L = gram_lib.max_eigval(stats.G) * 1.01
    w0 = _warm_start(warm, w, stats, spec)
    return _fused_outer(stats.G, B, stats.h, w0, L, spec, cfg)


@partial(jax.jit, static_argnames=("spec", "cfg", "warm"))
def _fused_single(w: jnp.ndarray, stats: GramStats, spec: SparsitySpec,
                  cfg: PrunerConfig, warm: str) -> tuple:
    return _solve_one(w, stats, spec, cfg, warm)


@partial(jax.jit, static_argnames=("spec", "cfg"))
def _fused_single_warm(w: jnp.ndarray, stats: GramStats, w0: jnp.ndarray,
                       spec: SparsitySpec, cfg: PrunerConfig) -> tuple:
    """Fused solve with an explicitly provided (array) warm start."""
    w = w.astype(jnp.float32)
    B = gram_lib.target_correlation(stats, w)
    L = gram_lib.max_eigval(stats.G) * 1.01
    return _fused_outer(stats.G, B, stats.h, w0.astype(jnp.float32), L, spec, cfg)


@partial(jax.jit, static_argnames=("spec", "cfg", "warm"))
def _fused_group(ws: jnp.ndarray, stats: GramStats, spec: SparsitySpec,
                 cfg: PrunerConfig, warm: str) -> tuple:
    """vmap of the fused Algorithm 1 over stacked same-shape operators.

    ``ws`` (k, m, n); every GramStats leaf carries a leading k axis.  JAX's
    while_loop batching keeps converged lanes frozen (select on the batched
    predicate), so each lane's trajectory is identical to its unbatched
    solve while the whole group is one dispatch.
    """
    return jax.vmap(lambda w, st: _solve_one(w, st, spec, cfg, warm))(ws, stats)


def _make_result(weight, e_best: float, lam: float, outer: int, inner: int,
                 warm_error: float, stats_h: float,
                 trace: Optional[dict] = None) -> PruneResult:
    wx_norm = float(np.sqrt(max(stats_h, 1e-30)))
    return PruneResult(
        weight=weight, error=e_best, rel_error=e_best / max(wx_norm, 1e-30),
        lam=lam, outer_iters=outer, fista_iters=inner, warm_error=warm_error,
        trace=trace)


def _trim_trace(trace: Optional[dict], outer: int, tl: int) -> Optional[dict]:
    """Host copy of one operator's device trace, cut to the iterations
    actually executed (one transfer per array, AFTER the solve)."""
    if trace is None:
        return None
    n = min(outer, tl)
    return {k: np.asarray(v, np.float32)[:n] for k, v in trace.items()}


def _result_from_outer(out: OuterState, e0, w_dtype, stats_h: float,
                       trace: Optional[dict] = None,
                       trace_len: int = 0) -> PruneResult:
    outer = int(out.k)
    return _make_result(out.w_best.astype(w_dtype), float(out.e_best),
                        float(out.lam), outer, int(out.inner), float(e0),
                        stats_h, trace=_trim_trace(trace, outer, trace_len))


def prune_operator(w: jnp.ndarray, stats: GramStats, spec: SparsitySpec,
                   cfg: PrunerConfig = PrunerConfig(),
                   warm: Optional[Union[str, jnp.ndarray]] = None) -> PruneResult:
    """Prune one linear operator ``w`` (paper layout (out,in)) to ``spec``.

    ``stats`` must hold the Gram statistics accumulated with this operator's
    dense/pruned calibration activations (see core/gram.py).
    """
    w = jnp.asarray(w, jnp.float32)
    if cfg.outer_impl == "host":
        return _prune_operator_host(w, stats, spec, cfg, warm)
    if cfg.outer_impl != "fused":
        raise ValueError(f"unknown outer_impl {cfg.outer_impl!r}")
    warm_in = cfg.warm_start if warm is None else warm
    if isinstance(warm_in, str):
        out, e0, trace = _fused_single(w, stats, spec, cfg, warm_in)
    else:
        out, e0, trace = _fused_single_warm(
            w, stats, jnp.asarray(warm_in, jnp.float32), spec, cfg)
    return _result_from_outer(out, e0, w.dtype, float(stats.h),
                              trace=trace, trace_len=cfg.trace_len)


def prune_group(ws: Union[jnp.ndarray, Sequence[jnp.ndarray]],
                stats: Union[GramStats, Sequence[GramStats]],
                spec: SparsitySpec, cfg: PrunerConfig = PrunerConfig(),
                warm: Optional[str] = None) -> List[PruneResult]:
    """Prune a whole group of SAME-SHAPE operators in one batched dispatch.

    ``ws`` is either a stacked (k, m, n) array or a sequence of (m, n)
    operators; ``stats`` the matching stacked GramStats (leaves with a
    leading k axis) or a sequence of per-operator GramStats.  Only string
    warm starts are supported (the warm start is computed inside the
    batched computation).  Heterogeneous groups must be partitioned by
    shape before calling (core/sequential.py does this automatically).

    With ``cfg.outer_impl == "host"`` this falls back to per-operator
    host-loop solves — the equivalence oracle for the batched path.
    """
    if isinstance(ws, (list, tuple)):
        shapes = {tuple(jnp.asarray(w).shape) for w in ws}
        if len(shapes) != 1:
            raise ValueError(f"prune_group needs same-shape operators, got {shapes}")
        ws = jnp.stack([jnp.asarray(w, jnp.float32) for w in ws])
    else:
        ws = jnp.asarray(ws, jnp.float32)
    if isinstance(stats, (list, tuple)):
        stats = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *stats)
    warm_name = cfg.warm_start if warm is None else warm
    if not isinstance(warm_name, str):
        raise ValueError("prune_group supports only string warm starts")

    if cfg.outer_impl == "host":
        from repro.utils.tree import tree_index
        return [_prune_operator_host(ws[i], tree_index(stats, i), spec, cfg,
                                     warm_name)
                for i in range(ws.shape[0])]
    if cfg.outer_impl != "fused":
        raise ValueError(f"unknown outer_impl {cfg.outer_impl!r}")

    out, e0, trace = _fused_group(ws, stats, spec, cfg, warm_name)
    # one host sync for the whole group
    h_np = np.asarray(stats.h, np.float32)
    e_best = np.asarray(out.e_best, np.float32)
    lam = np.asarray(out.lam, np.float32)
    outer = np.asarray(out.k, np.int32)
    inner = np.asarray(out.inner, np.int32)
    warm_err = np.asarray(e0, np.float32)
    if trace is not None:   # (k, trace_len) leaves — transferred once
        trace = {k: np.asarray(v, np.float32) for k, v in trace.items()}
    return [_make_result(out.w_best[i], float(e_best[i]), float(lam[i]),
                         int(outer[i]), int(inner[i]), float(warm_err[i]),
                         float(h_np[i]),
                         trace=None if trace is None else
                         {k: v[i, :min(int(outer[i]), cfg.trace_len)]
                          for k, v in trace.items()})
            for i in range(ws.shape[0])]


# ---------------------------------------------------------------------------
# host-loop reference (the seed implementation, kept as the oracle)
# ---------------------------------------------------------------------------
def _prune_operator_host(w: jnp.ndarray, stats: GramStats, spec: SparsitySpec,
                         cfg: PrunerConfig,
                         warm: Optional[Union[str, jnp.ndarray]] = None,
                         inner_solve: Optional[callable] = None) -> PruneResult:
    """``inner_solve`` (same signature/return as ``fista_lib.solve``)
    swaps the per-lambda FISTA solve — the hook the row-sharded path
    (``MeshExecutor.row_fista_solve`` via ``distributed/rowfista``)
    plugs into while the Algorithm-1 outer loop stays on the host."""
    w = jnp.asarray(w, jnp.float32)
    B = gram_lib.target_correlation(stats, w)
    L = gram_lib.max_eigval(stats.G) * 1.01
    wx_norm = float(np.sqrt(max(float(stats.h), 1e-30)))

    w0 = _warm_start(cfg.warm_start if warm is None else warm, w, stats, spec)
    w0 = round_to(w0, spec)  # warm start must be a feasible candidate
    e_best = float(gram_lib.frob_error(stats, w0, B))
    warm_error = e_best
    w_best = w0

    lo, hi = cfg.lam_lo, cfg.lam_hi
    lam = cfg.lam_init
    t = 0
    e_stop = float("inf")
    total_inner = 0
    outer = 0
    # convergence trace matching the fused carry's write-at-min(k, tl-1)
    # semantics exactly: first tl-1 iterations keep their slot, every
    # later one overwrites the last slot
    tl = int(cfg.trace_len)
    trace_e: List[float] = []
    trace_lam: List[float] = []

    solve = fista_lib.solve if inner_solve is None else inner_solve
    for outer in range(1, cfg.max_outer + 1):
        w_k, iters = solve(
            stats.G, B, w_best, lam, L=L, max_iters=cfg.fista_iters,
            tol=cfg.fista_tol, momentum=cfg.momentum, step_impl=cfg.step_impl)
        total_inner += int(iters)
        w_k1 = round_to(w_k, spec)
        e_fista = float(gram_lib.frob_error(stats, w_k, B))
        e_total = float(gram_lib.frob_error(stats, w_k1, B))
        e_round = e_total - e_fista
        if tl > 0:
            if len(trace_e) < tl:
                trace_e.append(e_total)
                trace_lam.append(lam)
            else:
                trace_e[-1] = e_total
                trace_lam[-1] = lam

        if e_total < e_best:
            e_stop = (e_best - e_total) / max(e_best, 1e-30)
            w_best = w_k1
            e_best = e_total
            t = 0
        else:
            t += 1

        # bisection on lambda driven by the rounding-error share (Sec. 3.3):
        # high share => FISTA solution not sparse enough => raise lambda.
        ratio = e_round / max(e_total, 1e-30)
        if ratio > cfg.xi:
            lo = lam
        else:
            hi = lam
        lam = 0.5 * (lo + hi)

        if t >= cfg.patience or e_stop < cfg.eps:
            break

    return PruneResult(
        weight=w_best.astype(w.dtype), error=e_best,
        rel_error=e_best / max(wx_norm, 1e-30), lam=lam, outer_iters=outer,
        fista_iters=total_inner, warm_error=warm_error,
        trace=None if tl <= 0 else
        {"e_total": np.asarray(trace_e, np.float32),
         "lam": np.asarray(trace_lam, np.float32)})


def prune_with_method(method: str, w: jnp.ndarray, stats: GramStats,
                      spec: SparsitySpec, cfg: PrunerConfig = PrunerConfig()
                      ) -> tuple[jnp.ndarray, float]:
    """DEPRECATED string switch — use the solver registry instead:

        repro.core.solvers.get_solver(method).solve(w, stats, spec)

    Kept as a thin shim so pre-redesign callers keep working; delegates to
    the registered solver and returns the legacy (weight, error) pair.
    """
    import warnings

    warnings.warn(
        "prune_with_method is deprecated; use "
        "repro.core.solvers.get_solver(name).solve(...) or a PruneRecipe "
        "(repro.api)", DeprecationWarning, stacklevel=2)
    from repro.core import solvers as solvers_lib

    try:
        solver = solvers_lib.from_legacy(method, cfg)
    except KeyError as exc:
        raise ValueError(str(exc)) from None
    res = solver.solve(jnp.asarray(w, jnp.float32), stats, spec)
    return res.weight, res.error


METHODS = ("dense", "magnitude", "wanda", "sparsegpt", "fista", "admm")
