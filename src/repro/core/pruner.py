"""FISTAPruner Algorithm 1: outer loop with adaptive lambda bisection.

Per operator (paper Sec. 3.3/3.4):

    t=0; W_best = W_0; E_best = ||W_0 X* - W X||_F
    repeat:
        W_K  = FISTA(lam, warm start W_best, K iters)
        W_K1 = round(W_K, s% or n:m)                      # Eq. (8)
        E_total = ||W_K1 X* - W X||_F
        E_round = E_total - ||W_K X* - W X||_F
        if E_total < E_best: E_stop=(E_best-E_total)/E_best; keep W_K1; t=0
        else: t += 1
        bisect lam on [0, 1e6] by E_round/E_total vs xi=0.3
    until t >= T or E_stop < eps

The outer loop is host Python (a handful of iterations); the FISTA solve,
rounding, and error evaluations are jitted Gram-form computations, so the
inner work never leaves the device.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.core import baselines as baselines_lib
from repro.core import fista as fista_lib
from repro.core import gram as gram_lib
from repro.core.gram import GramStats
from repro.core.sparsity import SparsitySpec, round_to
from repro.utils import get_logger

log = get_logger("pruner")


@dataclasses.dataclass(frozen=True)
class PrunerConfig:
    """Paper Sec. 4.1 defaults: lam_init=1e-5, K=20, T=3, xi=0.3; eps is
    1e-6 for OPT-family and 1e-3 for LLaMA-family in the paper."""

    lam_init: float = 1e-5
    lam_lo: float = 0.0
    lam_hi: float = 1e6
    fista_iters: int = 20          # K
    fista_tol: float = fista_lib.DEFAULT_TOL
    patience: int = 3              # T
    eps: float = 1e-3              # relative-improvement stop
    xi: float = 0.3                # E_round/E_total threshold (Sec. 3.3)
    max_outer: int = 40            # safety bound on the bisection loop
    warm_start: str = "wanda"      # wanda | sparsegpt | magnitude | dense
    momentum: str = "fista"        # fista | paper  (see core/fista.py)
    step_impl: str = "jnp"         # jnp | pallas


@dataclasses.dataclass
class PruneResult:
    weight: jnp.ndarray            # W_best, satisfies the sparsity spec
    error: float                   # E_best = ||W_best X* - W X||_F
    rel_error: float               # E_best / ||W X||_F
    lam: float                     # final lambda
    outer_iters: int
    fista_iters: int               # total inner iterations across the loop
    warm_error: float              # error of the warm start (for ablation)


def _warm_start(name_or_w: Union[str, jnp.ndarray], w: jnp.ndarray,
                stats: GramStats, spec: SparsitySpec) -> jnp.ndarray:
    if not isinstance(name_or_w, str):
        return jnp.asarray(name_or_w, jnp.float32)
    if name_or_w == "wanda":
        return baselines_lib.wanda(w, stats, spec)
    if name_or_w == "sparsegpt":
        return baselines_lib.sparsegpt(w, stats, spec)
    if name_or_w == "magnitude":
        return baselines_lib.magnitude(w, spec)
    if name_or_w == "dense":
        return w.astype(jnp.float32)
    raise ValueError(f"unknown warm start {name_or_w!r}")


def prune_operator(w: jnp.ndarray, stats: GramStats, spec: SparsitySpec,
                   cfg: PrunerConfig = PrunerConfig(),
                   warm: Optional[Union[str, jnp.ndarray]] = None) -> PruneResult:
    """Prune one linear operator ``w`` (paper layout (out,in)) to ``spec``.

    ``stats`` must hold the Gram statistics accumulated with this operator's
    dense/pruned calibration activations (see core/gram.py).
    """
    w = jnp.asarray(w, jnp.float32)
    B = gram_lib.target_correlation(stats, w)
    L = gram_lib.max_eigval(stats.G) * 1.01
    wx_norm = float(np.sqrt(max(float(stats.h), 1e-30)))

    w0 = _warm_start(cfg.warm_start if warm is None else warm, w, stats, spec)
    w0 = round_to(w0, spec)  # warm start must be a feasible candidate
    e_best = float(gram_lib.frob_error(stats, w0, B))
    warm_error = e_best
    w_best = w0

    lo, hi = cfg.lam_lo, cfg.lam_hi
    lam = cfg.lam_init
    t = 0
    e_stop = float("inf")
    total_inner = 0
    outer = 0

    for outer in range(1, cfg.max_outer + 1):
        w_k, iters = fista_lib.solve(
            stats.G, B, w_best, lam, L=L, max_iters=cfg.fista_iters,
            tol=cfg.fista_tol, momentum=cfg.momentum, step_impl=cfg.step_impl)
        total_inner += int(iters)
        w_k1 = round_to(w_k, spec)
        e_fista = float(gram_lib.frob_error(stats, w_k, B))
        e_total = float(gram_lib.frob_error(stats, w_k1, B))
        e_round = e_total - e_fista

        if e_total < e_best:
            e_stop = (e_best - e_total) / max(e_best, 1e-30)
            w_best = w_k1
            e_best = e_total
            t = 0
        else:
            t += 1

        # bisection on lambda driven by the rounding-error share (Sec. 3.3):
        # high share => FISTA solution not sparse enough => raise lambda.
        ratio = e_round / max(e_total, 1e-30)
        if ratio > cfg.xi:
            lo = lam
        else:
            hi = lam
        lam = 0.5 * (lo + hi)

        if t >= cfg.patience or e_stop < cfg.eps:
            break

    return PruneResult(
        weight=w_best.astype(w.dtype), error=e_best,
        rel_error=e_best / max(wx_norm, 1e-30), lam=lam, outer_iters=outer,
        fista_iters=total_inner, warm_error=warm_error)


def prune_with_method(method: str, w: jnp.ndarray, stats: GramStats,
                      spec: SparsitySpec, cfg: PrunerConfig = PrunerConfig()
                      ) -> tuple[jnp.ndarray, float]:
    """Uniform entry point for benchmarks: returns (pruned weight, error)."""
    w = jnp.asarray(w, jnp.float32)
    if method == "fista":
        r = prune_operator(w, stats, spec, cfg)
        return r.weight, r.error
    if method == "wanda":
        y = baselines_lib.wanda(w, stats, spec)
    elif method == "sparsegpt":
        y = baselines_lib.sparsegpt(w, stats, spec)
    elif method == "magnitude":
        y = baselines_lib.magnitude(w, spec)
    elif method == "dense":
        y = w
    else:
        raise ValueError(f"unknown method {method!r}")
    B = gram_lib.target_correlation(stats, w)
    return y, float(gram_lib.frob_error(stats, y, B))


METHODS = ("dense", "magnitude", "wanda", "sparsegpt", "fista")
