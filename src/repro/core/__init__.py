"""FISTAPruner core: convex model, FISTA solver, Algorithm-1 pruner,
baselines, intra-layer error correction and the layer-unit scheduler."""
from repro.core.gram import GramStats, accumulate, init_stats, frob_error, target_correlation
from repro.core.sparsity import SparsitySpec, round_to
from repro.core.pruner import (PruneResult, PrunerConfig, prune_group,
                               prune_operator, prune_with_method)

__all__ = [
    "GramStats", "accumulate", "init_stats", "frob_error", "target_correlation",
    "SparsitySpec", "round_to",
    "PruneResult", "PrunerConfig", "prune_group", "prune_operator",
    "prune_with_method",
]
