"""FISTAPruner core: convex model, FISTA solver, Algorithm-1 pruner,
the LayerSolver registry (ADMM + baselines), intra-layer error
correction and the layer-unit scheduler."""
from repro.core.gram import GramStats, accumulate, init_stats, frob_error, target_correlation
from repro.core.sparsity import SparsitySpec, round_to
from repro.core.pruner import (PruneResult, PrunerConfig, prune_group,
                               prune_operator, prune_with_method)
from repro.core.admm import AdmmConfig
from repro.core.solvers import (LayerSolver, get_solver, register_solver,
                                registered_solvers)

__all__ = [
    "GramStats", "accumulate", "init_stats", "frob_error", "target_correlation",
    "SparsitySpec", "round_to",
    "PruneResult", "PrunerConfig", "prune_group", "prune_operator",
    "prune_with_method",
    "AdmmConfig",
    "LayerSolver", "get_solver", "register_solver", "registered_solvers",
]
