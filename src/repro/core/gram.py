"""Gram-statistic form of the FISTAPruner objective.

The paper's per-operator objective (Eq. 4)

    min_Y  1/2 ||Y X* - W X||_F^2 + lam * sum_i ||Y_i||_1

only touches the calibration data through three sufficient statistics
(all accumulated streaming over calibration batches, in fp32):

    G = X* X*^T          (n x n)   pruned-path Gram
    C = X  X*^T          (n x n)   cross Gram (dense path x pruned path)
    h = ||W X||_F^2      scalar    target energy

With B := W C (m x n) the smooth part and its gradient become

    f(Y)      = 1/2 ( <Y G, Y> - 2 <Y, B> + h )
    grad f(Y) = Y G - B

and the pruning error of any candidate Y is

    ||Y X* - W X||_F^2 = <Y G, Y> - 2 <Y, B> + h .

After calibration, the pruner never sees X again: memory per operator is
O(n^2 + m n) instead of O(n p), and every FISTA iteration is one dense
(m,n)x(n,n) matmul (MXU-friendly).  This is an exact restatement of the
paper's Appendix B math, not an approximation.

We additionally accumulate

    H    = X X^T   (n x n)   dense-path Gram   (SparseGPT baseline)
    hdiag = diag(H)          (Wanda's ||x_j||_2^2 metric)

so that every baseline + warm start runs off the same single calibration
sweep.

Weight-layout convention: the pruner works in the paper's (out=m, in=n)
layout.  Model code stores (in, out); the boundary transpose happens in
``core.sequential``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GramStats:
    """Streaming sufficient statistics for one linear operator.

    Shapes: ``G, C, H`` are (n, n) fp32, ``h`` scalar fp32, ``count`` the
    number of accumulated columns (tokens) — used for diagnostics only,
    the objective is scale-covariant.

    ``extras`` carries the accumulators of NOVEL registered statistics
    (core/solvers.py ``StatSpec.init``/``update``), keyed by stat name.
    It is part of the pytree, so extras shard, psum and stack exactly
    like the built-in Grams; empty for every built-in solver.
    """

    G: jnp.ndarray
    C: jnp.ndarray
    H: jnp.ndarray
    h: jnp.ndarray
    count: jnp.ndarray
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def tree_flatten(self):
        return (self.G, self.C, self.H, self.h, self.count, self.extras), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n(self) -> int:
        return self.G.shape[0]

    @property
    def hdiag(self) -> jnp.ndarray:
        """diag(X X^T) = per-input-feature squared activation norms (Wanda)."""
        return jnp.diag(self.H)


def init_stats(n: int, extras: Optional[Dict[str, Any]] = None) -> GramStats:
    z = jnp.zeros((n, n), jnp.float32)
    return GramStats(G=z, C=z, H=z, h=jnp.float32(0.0), count=jnp.float32(0.0),
                     extras=dict(extras or {}))


@jax.jit
def accumulate(stats: GramStats, x_dense: jnp.ndarray, x_pruned: jnp.ndarray,
               wx_dense: jnp.ndarray) -> GramStats:
    """Accumulate one calibration batch.

    ``x_dense``  : (..., n) activations of this operator in the DENSE net.
    ``x_pruned`` : (..., n) activations in the partially-PRUNED net (X*).
    ``wx_dense`` : (..., m) dense outputs W X (target) for the same batch.

    Any leading batch/seq dims are flattened to the token axis p.
    """
    xd = x_dense.reshape(-1, x_dense.shape[-1]).astype(jnp.float32)
    xp = x_pruned.reshape(-1, x_pruned.shape[-1]).astype(jnp.float32)
    wx = wx_dense.reshape(-1, wx_dense.shape[-1]).astype(jnp.float32)
    return GramStats(
        G=stats.G + xp.T @ xp,
        C=stats.C + xd.T @ xp,
        H=stats.H + xd.T @ xd,
        h=stats.h + jnp.sum(wx * wx),
        count=stats.count + jnp.float32(xd.shape[0]),
        extras=stats.extras,       # novel stats update via their own hooks
    )


def merge(a: GramStats, b: GramStats) -> GramStats:
    """Merge statistics accumulated on different shards (after psum this is
    what the all-reduce computes; kept for host-side tree-reduction).
    Extras merge additively — the contract every registered accumulator
    must satisfy to be shardable."""
    return GramStats(G=a.G + b.G, C=a.C + b.C, H=a.H + b.H, h=a.h + b.h,
                     count=a.count + b.count,
                     extras=jax.tree_util.tree_map(
                         lambda x, y: x + y, a.extras, b.extras))


@jax.jit
def target_correlation(stats: GramStats, w_dense: jnp.ndarray) -> jnp.ndarray:
    """B = W C  (m, n): correlation of the dense target with the pruned path."""
    return w_dense.astype(jnp.float32) @ stats.C


def frob_error_sq_gh(G: jnp.ndarray, h: jnp.ndarray, y: jnp.ndarray,
                     b: jnp.ndarray) -> jnp.ndarray:
    """Raw-array form of :func:`frob_error_sq` — usable inside fused loops
    (core/pruner.py's device-resident Algorithm 1) without a GramStats."""
    yf = y.astype(jnp.float32)
    quad = jnp.sum((yf @ G) * yf)
    cross = jnp.sum(yf * b)
    return jnp.maximum(quad - 2.0 * cross + h, 0.0)


def frob_error_gh(G: jnp.ndarray, h: jnp.ndarray, y: jnp.ndarray,
                  b: jnp.ndarray) -> jnp.ndarray:
    return jnp.sqrt(frob_error_sq_gh(G, h, y, b))


@jax.jit
def frob_error_sq(stats: GramStats, y: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """||Y X* - W X||_F^2 = <Y G, Y> - 2 <Y, B> + h  (clamped at 0)."""
    return frob_error_sq_gh(stats.G, stats.h, y, b)


def frob_error(stats: GramStats, y: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.sqrt(frob_error_sq(stats, y, b))


@partial(jax.jit, static_argnames=("iters",))
def max_eigval(G: jnp.ndarray, iters: int = 64, key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Largest eigenvalue of a PSD matrix by power iteration.

    Deterministic start (ones + diag seed) so results are reproducible;
    64 iterations is plenty for the step-size use here — FISTA only needs
    an UPPER bound on L to converge, so we inflate by 1.01 at the call
    site if desired.
    """
    n = G.shape[0]
    if key is None:
        v = jnp.ones((n,), jnp.float32) + jnp.diag(G) * 1e-3
    else:
        v = jax.random.normal(key, (n,), jnp.float32)
    v = v / (jnp.linalg.norm(v) + 1e-30)

    def body(_, v):
        w = G @ v
        return w / (jnp.linalg.norm(w) + 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v)
    return jnp.maximum(v @ (G @ v), 1e-12)


def dampen(G: jnp.ndarray, rel: float = 1e-6) -> jnp.ndarray:
    """Add relative ridge ``rel * mean(diag)`` — used by the SparseGPT
    baseline's Hessian inverse and as a safeguard for ill-conditioned
    calibration Grams."""
    d = jnp.mean(jnp.diag(G))
    return G + (rel * d + 1e-12) * jnp.eye(G.shape[0], dtype=G.dtype)
