"""Unified LayerSolver protocol + registry: one pluggable API for every
pruning method (DESIGN.md §7).

The paper's FISTAPruner is one member of a family of layer-wise pruners
that share everything except the per-operator solve: ALPS swaps FISTA for
ADMM (arXiv:2406.07831), Frank-Wolfe relaxes the same objective
(arXiv:2510.13713), and the one-shot baselines (magnitude / Wanda /
SparseGPT) are degenerate single-candidate members.  A ``LayerSolver``
owns exactly that per-operator solve:

    solve(w, stats, spec) -> PruneResult          # paper layout (out, in)
    solve_group(ws, stats, spec) -> [PruneResult] # same-shape batch

plus two capabilities the pipeline consults:

* ``supports_group_batch`` — the solver can batch all same-shape
  operators of a pruning group into one dispatch (core/sequential.py
  partitions groups by shape and calls ``solve_group``);
* ``stat_deps``            — the names of the registered calibration
  statistics the solver reads (see :func:`register_stat`).  The built-in
  stats are ``dense_gram`` (H = X X^T, always accumulated) and
  ``pruned_gram`` (G = X* X*^T / C = X X*^T, which requires the
  pruned-path forward).  core/sequential.py provisions exactly the
  declared stats: when no stat in play needs the pruned path, the
  group-stats scan skips the pruned-path forward entirely (the baselines
  only read the dense-path H / diag(H)).  A solver may register a novel
  stat (``StatSpec`` with ``init``/``update`` hooks) and declare it —
  the scan accumulates it into ``GramStats.extras`` with zero edits to
  the pipeline.  ``wants_pruned_gram`` remains as a derived read-only
  view (and legacy solvers that still declare it as a plain bool are
  honored by :meth:`LayerSolver.stats_required`).

Adding a method is one registered class — zero edits to
core/sequential.py, the driver, or the launchers:

    @register_solver("mymethod")
    class MySolver(LayerSolver):
        def solve(self, w, stats, spec): ...

and every entry point (`SequentialConfig`, `PruneRecipe`,
``--method mymethod``) picks it up by name.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import admm as admm_lib
from repro.core import baselines as baselines_lib
from repro.core import frankwolfe as fw_lib
from repro.core import gram as gram_lib
from repro.core import pruner as pruner_lib
from repro.core.admm import AdmmConfig
from repro.core.frankwolfe import FrankWolfeConfig
from repro.core.gram import GramStats
from repro.core.pruner import PruneResult, PrunerConfig, _make_result
from repro.core.sparsity import SparsitySpec


# ---------------------------------------------------------------------------
# calibration-statistic registry (the declared stats-dependency contract)
# ---------------------------------------------------------------------------
#: registry names of the two built-in statistics every GramStats carries
DENSE_GRAM = "dense_gram"    # H = X X^T (+ h, count): dense-path only
PRUNED_GRAM = "pruned_gram"  # G = X* X*^T / C = X X*^T: needs pruned forward


@dataclasses.dataclass(frozen=True)
class StatSpec:
    """One named calibration statistic the stats scan can provision.

    ``needs_pruned_path`` marks stats that read the pruned-path
    activations X*: the per-group scan only runs the (expensive)
    pruned-path forward when some declared stat needs it.

    Built-in stats live directly on :class:`~repro.core.gram.GramStats`
    and leave ``init``/``update`` as None.  A NOVEL stat provides both
    hooks and its accumulator is carried in ``GramStats.extras[name]``:

        init(n)                    -> initial accumulator for an operator
                                      with n input features
        update(acc, xd, xp, wx)    -> new accumulator given one batch's
                                      (p, n) dense / pruned activations
                                      and (p, m) dense targets (traced —
                                      must be jit-compatible)
    """

    name: str
    needs_pruned_path: bool = False
    init: Optional[Callable[[int], Any]] = None
    update: Optional[Callable[..., Any]] = None

    @property
    def is_extra(self) -> bool:
        """Novel stat (carried in GramStats.extras) vs a built-in field."""
        return self.init is not None


_STATS: Dict[str, StatSpec] = {}


def register_stat(spec: StatSpec) -> StatSpec:
    """Register a calibration statistic by name (idempotent overwrite)."""
    if spec.is_extra and spec.update is None:
        raise ValueError(f"stat {spec.name!r} declares init without update")
    _STATS[spec.name] = spec
    return spec


def unregister_stat(name: str) -> None:
    """Remove a registered stat (test helper for toy stats)."""
    if name in (DENSE_GRAM, PRUNED_GRAM):
        raise ValueError(f"cannot unregister built-in stat {name!r}")
    _STATS.pop(name, None)


def known_stats() -> Tuple[str, ...]:
    return tuple(sorted(_STATS))


def stat_spec(name: str) -> StatSpec:
    """Look up a registered stat; unknown names list the known stats."""
    try:
        return _STATS[name]
    except KeyError:
        raise KeyError(f"unknown stat {name!r}; known stats: "
                       f"{', '.join(known_stats())}") from None


register_stat(StatSpec(DENSE_GRAM, needs_pruned_path=False))
register_stat(StatSpec(PRUNED_GRAM, needs_pruned_path=True))


class LayerSolver(abc.ABC):
    """One pruning method, in the paper layout W (out=m, in=n).

    Subclasses are registered with :func:`register_solver` and constructed
    by name via :func:`get_solver` (kwargs are the solver's own knobs, so
    they serialize naturally into a ``PruneRecipe``).
    """

    name: str = "?"              # set by @register_solver
    #: names of the registered stats this solver reads (class or instance
    #: attribute).  None = legacy solver: fall back to its declared
    #: ``wants_pruned_gram`` bool, defaulting to both built-in Grams.
    stat_deps: Optional[Tuple[str, ...]] = None

    def stats_required(self) -> Tuple[str, ...]:
        """The validated stat names core/sequential.py must provision."""
        deps = self.stat_deps
        if deps is None:
            legacy = _declared_wants_pruned_gram(self)
            deps = (DENSE_GRAM,) if legacy is False \
                else (DENSE_GRAM, PRUNED_GRAM)
        for name in deps:
            stat_spec(name)        # raises KeyError listing known stats
        return tuple(deps)

    @property
    def wants_pruned_gram(self) -> bool:
        """Derived view of ``stat_deps`` kept for telemetry/benchmarks."""
        return any(stat_spec(s).needs_pruned_path
                   for s in self.stats_required())

    def bind_executor(self, executor: Any) -> None:
        """Attach a MeshExecutor (distributed/executor.py).  Solvers that
        can exploit the mesh (row-sharded FISTA) override/consume it; the
        default is a no-op so every solver is executor-bindable."""
        self._executor = executor

    @property
    def supports_group_batch(self) -> bool:
        return False

    @property
    def op_label(self) -> str:
        """OperatorReport.solver tag for a per-operator solve."""
        return self.name

    @property
    def group_label(self) -> str:
        """OperatorReport.solver tag for a batched group solve."""
        return f"{self.name}-group"

    @abc.abstractmethod
    def solve(self, w: jnp.ndarray, stats: GramStats,
              spec: SparsitySpec) -> PruneResult:
        ...

    def solve_group(self, ws: Sequence[jnp.ndarray],
                    stats: Sequence[GramStats],
                    spec: SparsitySpec) -> List[PruneResult]:
        """Batch solve; the fallback is a per-operator loop so every
        solver is group-callable regardless of ``supports_group_batch``."""
        return [self.solve(w, st, spec) for w, st in zip(ws, stats)]

    def describe(self) -> Dict[str, Any]:
        """Scheduler/driver telemetry payload."""
        return {"name": self.name, "group_batch": self.supports_group_batch}


def _declared_wants_pruned_gram(solver: LayerSolver) -> Optional[bool]:
    """A legacy solver's own ``wants_pruned_gram`` declaration, if any.

    Pre-stat_deps solvers declared the flag as a plain bool (instance or
    subclass attribute, shadowing the base-class property).  Looked up
    without touching the property to avoid recursing through
    :meth:`LayerSolver.stats_required`.
    """
    v = solver.__dict__.get("wants_pruned_gram")
    if isinstance(v, bool):
        return v
    for klass in type(solver).__mro__:
        if klass is LayerSolver:
            break
        v = klass.__dict__.get("wants_pruned_gram")
        if isinstance(v, bool):
            return v
    return None


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Type[LayerSolver]] = {}


def register_solver(name: str) -> Callable[[Type[LayerSolver]], Type[LayerSolver]]:
    """Class decorator: ``@register_solver("mymethod")``."""

    def deco(cls: Type[LayerSolver]) -> Type[LayerSolver]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def registered_solvers() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def unregister_solver(name: str) -> None:
    """Remove a registered solver (test helper for toy solvers)."""
    _REGISTRY.pop(name, None)


def get_solver(name: str, **kwargs: Any) -> LayerSolver:
    """Instantiate a registered solver by name with its own kwargs."""
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown solver {name!r}; registered solvers: "
            f"{', '.join(registered_solvers())}") from None
    return cls(**kwargs)


def from_legacy(method: str,
                pruner: Optional[PrunerConfig] = None) -> LayerSolver:
    """Map the pre-redesign (method, PrunerConfig) pair onto a solver.

    Only "fista" ever consumed the PrunerConfig; every other legacy method
    ignores it (exactly as the old string switch did).
    """
    if method == "fista":
        return FistaSolver(cfg=pruner)
    return get_solver(method)


# ---------------------------------------------------------------------------
# iterative solvers
# ---------------------------------------------------------------------------
@register_solver("fista")
class FistaSolver(LayerSolver):
    """The paper's Algorithm 1 (core/pruner.py): FISTA + lambda bisection."""

    stat_deps = (DENSE_GRAM, PRUNED_GRAM)

    def __init__(self, cfg: Optional[PrunerConfig] = None, **overrides: Any):
        self.cfg = dataclasses.replace(cfg or PrunerConfig(), **overrides)
        self._executor: Any = None

    def _row_sharded(self, rows: int) -> bool:
        """Row-shard this solve over the mesh "model" axis?  Requires the
        recipe to ask (``row_shard``), a bound executor with a model axis,
        and a row count the axis divides (no padding at CI scale)."""
        ex = self._executor
        return (self.cfg.row_shard and ex is not None
                and ex.can_row_shard(rows))

    @property
    def supports_group_batch(self) -> bool:
        return (self.cfg.outer_impl == "fused" and self.cfg.group_batch
                and not self.cfg.row_shard)

    @property
    def op_label(self) -> str:
        return self.cfg.outer_impl          # "fused" | "host"

    @property
    def group_label(self) -> str:
        return "fused-group"

    def solve(self, w, stats, spec):
        if self._row_sharded(int(w.shape[0])):
            # Algorithm-1 outer loop on the host, every inner FISTA solve
            # row-sharded over "model" (distributed/rowfista.py)
            return pruner_lib._prune_operator_host(
                w, stats, spec, self.cfg,
                inner_solve=self._executor.row_fista_solve)
        return pruner_lib.prune_operator(w, stats, spec, self.cfg)

    def solve_group(self, ws, stats, spec):
        if self.cfg.row_shard:
            return [self.solve(w, st, spec) for w, st in zip(ws, stats)]
        return pruner_lib.prune_group(list(ws), list(stats), spec, self.cfg)

    def describe(self):
        return {"name": self.name, "outer_impl": self.cfg.outer_impl,
                "group_batch": self.cfg.group_batch,
                "row_shard": self.cfg.row_shard}


@register_solver("admm")
class AdmmSolver(LayerSolver):
    """ALPS-style ADMM on the same objective (core/admm.py)."""

    stat_deps = (DENSE_GRAM, PRUNED_GRAM)

    def __init__(self, cfg: Optional[AdmmConfig] = None, **overrides: Any):
        self.cfg = dataclasses.replace(cfg or AdmmConfig(), **overrides)

    @property
    def supports_group_batch(self) -> bool:
        return True

    def solve(self, w, stats, spec):
        return admm_lib.prune_operator_admm(w, stats, spec, self.cfg)

    def solve_group(self, ws, stats, spec):
        return admm_lib.prune_group_admm(list(ws), list(stats), spec, self.cfg)

    def describe(self):
        return {"name": self.name, "rho_rel": self.cfg.rho_rel,
                "group_batch": True}


@register_solver("frankwolfe")
class FrankWolfeSolver(LayerSolver):
    """Projection-free Frank-Wolfe on the same objective (core/frankwolfe.py):
    LMO = top-k of the gradient, exact line search, rounding + polish."""

    stat_deps = (DENSE_GRAM, PRUNED_GRAM)

    def __init__(self, cfg: Optional[FrankWolfeConfig] = None,
                 **overrides: Any):
        self.cfg = dataclasses.replace(cfg or FrankWolfeConfig(), **overrides)

    @property
    def supports_group_batch(self) -> bool:
        return True

    def solve(self, w, stats, spec):
        return fw_lib.prune_operator_fw(w, stats, spec, self.cfg)

    def solve_group(self, ws, stats, spec):
        return fw_lib.prune_group_fw(list(ws), list(stats), spec, self.cfg)

    def describe(self):
        return {"name": self.name, "radius_rel": self.cfg.radius_rel,
                "max_iters": self.cfg.max_iters, "group_batch": True}


# ---------------------------------------------------------------------------
# one-shot solvers (the paper's baselines)
# ---------------------------------------------------------------------------
class OneShotSolver(LayerSolver):
    """Single-candidate methods: score/sweep once, report the exact
    Gram-form error of the candidate.  Group solves vmap the candidate
    construction + error evaluation into one dispatch."""

    stat_deps = (DENSE_GRAM,)

    @property
    def supports_group_batch(self) -> bool:
        return True

    def _candidate(self, w: jnp.ndarray, stats: GramStats,
                   spec: SparsitySpec) -> jnp.ndarray:
        raise NotImplementedError

    def _solve_traced(self, w, stats, spec):
        w = w.astype(jnp.float32)
        y = self._candidate(w, stats, spec)
        b = gram_lib.target_correlation(stats, w)
        return y, gram_lib.frob_error(stats, y, b)

    def solve(self, w, stats, spec):
        w = jnp.asarray(w, jnp.float32)
        y, e = self._solve_traced(w, stats, spec)
        return _make_result(y, float(e), 0.0, 0, 0, float(e), float(stats.h))

    def solve_group(self, ws, stats, spec):
        ws = jnp.stack([jnp.asarray(w, jnp.float32) for w in ws])
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *stats)
        ys, es = jax.vmap(
            lambda w, st: self._solve_traced(w, st, spec))(ws, stacked)
        e_np = np.asarray(es, np.float32)
        h_np = np.asarray(stacked.h, np.float32)
        return [_make_result(ys[i], float(e_np[i]), 0.0, 0, 0, float(e_np[i]),
                             float(h_np[i]))
                for i in range(ws.shape[0])]


@register_solver("magnitude")
class MagnitudeSolver(OneShotSolver):
    def _candidate(self, w, stats, spec):
        return baselines_lib.magnitude(w, spec)


@register_solver("wanda")
class WandaSolver(OneShotSolver):
    def _candidate(self, w, stats, spec):
        return baselines_lib.wanda(w, stats, spec)


@register_solver("sparsegpt")
class SparseGptSolver(OneShotSolver):
    def __init__(self, blocksize: int = 128, damp_rel: float = 0.01,
                 use_pruned_gram: bool = False):
        self.blocksize = blocksize
        self.damp_rel = damp_rel
        self.use_pruned_gram = use_pruned_gram
        # dependency follows the Gram the sweep actually reads
        self.stat_deps = (DENSE_GRAM, PRUNED_GRAM) if use_pruned_gram \
            else (DENSE_GRAM,)

    def _candidate(self, w, stats, spec):
        return baselines_lib.sparsegpt(
            w, stats, spec, blocksize=self.blocksize, damp_rel=self.damp_rel,
            use_pruned_gram=self.use_pruned_gram)

    def describe(self):
        return {"name": self.name, "blocksize": self.blocksize,
                "use_pruned_gram": self.use_pruned_gram,
                "group_batch": True}


@register_solver("dense")
class DenseSolver(OneShotSolver):
    """No-op solver (keeps the dense weights) — benchmark control row."""

    def _candidate(self, w, stats, spec):
        return w
