"""Sparsity specs, the rounding step (paper Eq. 8), and measurement utils.

``round(W, s% or n:m)`` corrects floating-point near-zeros from FISTA and
enforces the EXACT target pattern:

* unstructured s% : zero the s% entries with smallest |value| over the
  whole matrix (exact count, deterministic tie-break by flat index);
* n:m             : within every group of m consecutive entries of a row,
  keep the n largest |value| (per the paper, zero the m-n smallest).

All functions are jit-compatible and layout-agnostic (operate on the
paper's (out, in) matrices).
"""
from __future__ import annotations

import dataclasses
import re
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SparsitySpec:
    """Either unstructured (``ratio`` in [0,1)) or semi-structured n:m."""

    kind: str = "unstructured"      # "unstructured" | "nm"
    ratio: float = 0.5              # fraction ZEROED (unstructured)
    n: int = 2                      # kept per group (nm)
    m: int = 4                      # group size (nm)

    @staticmethod
    def parse(text: str) -> "SparsitySpec":
        """"50%" / "0.5" -> unstructured; "2:4" -> semi-structured."""
        text = text.strip()
        mt = re.fullmatch(r"(\d+)\s*:\s*(\d+)", text)
        if mt:
            return SparsitySpec(kind="nm", n=int(mt.group(1)), m=int(mt.group(2)))
        if text.endswith("%"):
            return SparsitySpec(kind="unstructured", ratio=float(text[:-1]) / 100.0)
        return SparsitySpec(kind="unstructured", ratio=float(text))

    @property
    def target_density(self) -> float:
        return (1.0 - self.ratio) if self.kind == "unstructured" else self.n / self.m

    def __str__(self) -> str:
        if self.kind == "nm":
            return f"{self.n}:{self.m}"
        return f"{self.ratio:.0%}"


# ---------------------------------------------------------------------------
# rounding (Eq. 8)
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("ratio",))
def round_unstructured(w: jnp.ndarray, ratio: float) -> jnp.ndarray:
    """Zero the ``ratio`` fraction of entries with smallest |w| (exact count)."""
    size = w.size
    k = int(round(ratio * size))
    if k <= 0:
        return w
    if k >= size:
        return jnp.zeros_like(w)
    flat = jnp.abs(w).reshape(-1)
    order = jnp.argsort(flat, stable=True)      # ties: lower flat index zeroed first
    keep = jnp.ones((size,), bool).at[order[:k]].set(False)
    return jnp.where(keep.reshape(w.shape), w, 0).astype(w.dtype)


def nm_rank(absw: jnp.ndarray, m: int) -> jnp.ndarray:
    """Within-group descending rank (0 = largest) with index tie-break.

    absw: (..., groups, m) -> int32 ranks, same shape.  rank_i counts the
    group members strictly larger, plus equal members with smaller index —
    a total order, so exactly n entries have rank < n.
    """
    a_i = absw[..., :, None]       # (..., g, m, 1)
    a_j = absw[..., None, :]       # (..., g, 1, m)
    idx = jnp.arange(m)
    tie = (a_j == a_i) & (idx[None, :] < idx[:, None])
    bigger = (a_j > a_i) | tie
    return jnp.sum(bigger, axis=-1).astype(jnp.int32)


@partial(jax.jit, static_argnames=("n", "m"))
def round_nm(w: jnp.ndarray, n: int, m: int) -> jnp.ndarray:
    """Keep the n largest-|value| entries of every length-m row group."""
    rows, cols = w.shape
    assert cols % m == 0, f"cols {cols} not divisible by group size {m}"
    g = w.reshape(rows, cols // m, m)
    rank = nm_rank(jnp.abs(g), m)
    return jnp.where(rank < n, g, 0).reshape(rows, cols).astype(w.dtype)


def round_to(w: jnp.ndarray, spec: SparsitySpec) -> jnp.ndarray:
    """Dispatch of paper Eq. (8)."""
    if spec.kind == "nm":
        return round_nm(w, spec.n, spec.m)
    return round_unstructured(w, spec.ratio)


# ---------------------------------------------------------------------------
# mask-constrained rounding (used by baselines that pick masks differently)
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("ratio",))
def mask_unstructured_by_score(score: jnp.ndarray, ratio: float) -> jnp.ndarray:
    """Boolean keep-mask zeroing the ``ratio`` fraction with smallest score."""
    size = score.size
    k = int(round(ratio * size))
    if k <= 0:
        return jnp.ones(score.shape, bool)
    order = jnp.argsort(score.reshape(-1), stable=True)
    return jnp.ones((size,), bool).at[order[:k]].set(False).reshape(score.shape)


@partial(jax.jit, static_argnames=("ratio",))
def mask_rowwise_by_score(score: jnp.ndarray, ratio: float) -> jnp.ndarray:
    """Per-ROW keep-mask (Wanda compares within each output row)."""
    rows, cols = score.shape
    k = int(round(ratio * cols))
    if k <= 0:
        return jnp.ones(score.shape, bool)
    order = jnp.argsort(score, axis=1, stable=True)
    mask = jnp.ones((rows, cols), bool)
    return mask.at[jnp.arange(rows)[:, None], order[:, :k]].set(False)


@partial(jax.jit, static_argnames=("n", "m"))
def mask_nm_by_score(score: jnp.ndarray, n: int, m: int) -> jnp.ndarray:
    rows, cols = score.shape
    g = score.reshape(rows, cols // m, m)
    return (nm_rank(g, m) < n).reshape(rows, cols)


def mask_by_score(score: jnp.ndarray, spec: SparsitySpec, rowwise: bool = False) -> jnp.ndarray:
    if spec.kind == "nm":
        return mask_nm_by_score(score, spec.n, spec.m)
    if rowwise:
        return mask_rowwise_by_score(score, spec.ratio)
    return mask_unstructured_by_score(score, spec.ratio)


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------
def density(w: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((w != 0).astype(jnp.float32))


def sparsity(w: jnp.ndarray) -> jnp.ndarray:
    return 1.0 - density(w)


def satisfies(w: jnp.ndarray, spec: SparsitySpec, tol: float = 1e-6) -> bool:
    """Check a matrix satisfies the sparsity pattern (host-side, for tests)."""
    import numpy as np

    wn = np.asarray(w)
    if spec.kind == "nm":
        g = wn.reshape(wn.shape[0], -1, spec.m)
        return bool(((g != 0).sum(axis=-1) <= spec.n).all())
    want = spec.ratio
    got = float((wn == 0).mean())
    return got >= want - tol


def round_tree_nm(params, n: int = 2, m: int = 4):
    """Round every eligible linear in a param tree to exact n:m (in paper
    layout, i.e. along each weight's input dim).

    Eligible: 2-D ``(in, out)`` weights and layer-stacked 3-D
    ``(L, in, out)`` weights with whole input groups and both dims >= 8;
    embeddings, norms and bias/scale vectors are left dense — the same
    eligibility rules ``serve/packed.pack_tree`` applies when packing.
    Used to build synthetic 2:4 checkpoints (serving benchmarks/tests)
    without running a pruner.
    """
    from repro.utils.tree import tree_map_with_path

    def visit(path, w):
        if "embed" in path or "norm" in path or "conv" in path \
                or path.endswith(("scale", "bias")):
            return w
        if getattr(w, "ndim", 0) == 2 and w.shape[0] % m == 0 \
                and min(w.shape) >= 8:
            return round_nm(w.T.astype(jnp.float32), n, m).T.astype(w.dtype)
        if getattr(w, "ndim", 0) == 3 and w.shape[1] % m == 0 \
                and min(w.shape[1:]) >= 8:
            sl = jax.vmap(lambda x: round_nm(x.T.astype(jnp.float32), n, m).T)(w)
            return sl.astype(w.dtype)
        return w

    return tree_map_with_path(visit, params)
