"""FISTA solver for the FISTAPruner convex model (paper Eq. 5a-5d).

Solves, in the Gram form of :mod:`repro.core.gram`,

    min_Y  1/2 <Y G, Y> - <Y, B> + h/2 + lam * ||Y||_1   (row-separable l1)

One iteration:

    (5a)  P = Y_k - (1/L) (Y_k G - B)          gradient step, L = lam_max(G)
    (5b)  X_k = SoftShrinkage_{lam/L}(P)       prox of the l1 term
    (5c)  t_{k+1} = (1 + sqrt(1 + 4 t_k^2)) / 2
    (5d)  Y_{k+1} = X_k + ((t_k - 1)/t_{k+1}) (X_k - X_{k-1})   Nesterov

``momentum="fista"`` (default) is the Beck-Teboulle recursion the paper
cites (difference of consecutive PROX points), which carries the
O(1/k^2) guarantee; ``momentum="paper"`` is the literal Eq. (5d) with
(X_k - Y_k).  Both are provided; they coincide at k=0 and differ only in
the extrapolation memory.  Stopping: ||X_k - X_{k-1}||_F < tol (Eq. 7)
or k == K.

Everything here is jit-compatible (lax.while_loop); the whole solve is
one fused XLA computation.  The per-iteration hot loop can optionally be
routed through the fused Pallas kernel (``step_impl="pallas"``) — same
math, one VMEM pass (see kernels/fista_step.py).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import gram as gram_lib

DEFAULT_TOL = 1e-6  # paper Eq. (7)


def soft_shrinkage(x: jnp.ndarray, rho) -> jnp.ndarray:
    """Elementwise SoftShrinkage_rho (paper Sec. 3.2)."""
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - rho, 0.0)


class FistaState(NamedTuple):
    y: jnp.ndarray        # extrapolated iterate (gradient point)
    x_prev: jnp.ndarray   # previous prox point X_{k-1}
    t: jnp.ndarray        # Nesterov scalar t_k
    k: jnp.ndarray        # iteration counter
    delta: jnp.ndarray    # ||X_k - X_{k-1}||_F of the last step


def _jnp_step(y: jnp.ndarray, G: jnp.ndarray, B: jnp.ndarray, inv_l: jnp.ndarray,
              thresh: jnp.ndarray) -> jnp.ndarray:
    """One gradient + shrink step in plain jnp (fp32)."""
    grad = y @ G - B
    return soft_shrinkage(y - inv_l * grad, thresh)


def _pallas_step(y, G, B, inv_l, thresh):
    from repro.kernels import ops as kops
    return kops.fista_prox_step(y, G, B, inv_l, thresh)


@partial(jax.jit, static_argnames=("max_iters", "momentum", "step_impl"))
def solve(G: jnp.ndarray, B: jnp.ndarray, y0: jnp.ndarray, lam,
          L: Optional[jnp.ndarray] = None, max_iters: int = 20,
          tol: float = DEFAULT_TOL, momentum: str = "fista",
          step_impl: str = "jnp") -> tuple[jnp.ndarray, jnp.ndarray]:
    """Run FISTA; returns (X_K, iterations_used).

    ``G`` (n,n) fp32, ``B`` (m,n) fp32, ``y0`` (m,n) warm start (the paper
    warm-starts from Wanda/SparseGPT solutions), ``lam`` scalar.
    """
    if L is None:
        L = gram_lib.max_eigval(G) * 1.01
    L = jnp.maximum(jnp.asarray(L, jnp.float32), 1e-12)
    inv_l = 1.0 / L
    thresh = jnp.asarray(lam, jnp.float32) * inv_l
    step = _pallas_step if step_impl == "pallas" else _jnp_step

    y0 = y0.astype(jnp.float32)
    # initial delta derives from y0 (0*sum) so it carries y0's sharding/vma
    # annotations under shard_map (while_loop carries must type-match)
    delta0 = jnp.float32(jnp.inf) + 0.0 * jnp.sum(y0)
    state = FistaState(y=y0, x_prev=y0, t=jnp.float32(1.0),
                       k=jnp.int32(0), delta=delta0)

    def cond(s: FistaState):
        return (s.k < max_iters) & (s.delta >= tol)

    def body(s: FistaState) -> FistaState:
        x = step(s.y, G, B, inv_l, thresh)                      # (5a)+(5b)
        t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * s.t * s.t))  # (5c)
        coef = (s.t - 1.0) / t_next
        anchor = s.x_prev if momentum == "fista" else s.y
        y_next = x + coef * (x - anchor)                        # (5d)
        delta = jnp.linalg.norm(x - s.x_prev)
        return FistaState(y=y_next, x_prev=x, t=t_next, k=s.k + 1, delta=delta)

    out = jax.lax.while_loop(cond, body, state)
    return out.x_prev, out.k


@jax.jit
def kkt_residual(G: jnp.ndarray, B: jnp.ndarray, y: jnp.ndarray, lam) -> jnp.ndarray:
    """Max KKT violation of the LASSO optimality conditions at Y.

        Y_ij != 0 :  (Y G - B)_ij + lam * sign(Y_ij) = 0
        Y_ij == 0 :  |(Y G - B)_ij| <= lam

    Returns the max absolute violation — 0 at the exact optimum.  This is
    the paper's "theoretical guarantee" made executable (property tests).
    """
    g = y.astype(jnp.float32) @ G - B
    lam = jnp.asarray(lam, jnp.float32)
    nz = jnp.abs(g + lam * jnp.sign(y))
    z = jnp.maximum(jnp.abs(g) - lam, 0.0)
    return jnp.max(jnp.where(y != 0, nz, z))


def objective(G: jnp.ndarray, B: jnp.ndarray, h: jnp.ndarray, y: jnp.ndarray,
              lam) -> jnp.ndarray:
    """Full objective value 1/2||YX*-WX||_F^2 + lam*sum_i ||Y_i||_1."""
    yf = y.astype(jnp.float32)
    smooth = 0.5 * (jnp.sum((yf @ G) * yf) - 2.0 * jnp.sum(yf * B) + h)
    return smooth + jnp.asarray(lam, jnp.float32) * jnp.sum(jnp.abs(yf))
