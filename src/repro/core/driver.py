"""Parallel pruning driver: sequential math x fault-tolerant scheduler.

Because pruning units are independent under the paper's intra-layer
scheme (their pruned stream restarts from the dense activation at the
unit boundary), the driver:

1. runs ONE dense relay pass, recording each unit's input states for
   every calibration micro-batch (host-side, layer-at-a-time memory);
2. hands the units to :class:`repro.core.scheduler.PruneScheduler` —
   any number of workers, retries, speculative duplicates, per-unit
   checkpoint/resume;
3. merges the per-unit pruned weights back into the model params.

``error_correction="full"`` and ``"cross"`` are inherently serial (unit
k+1 consumes unit k's pruned output) and fall back to the serial path in
sequential.py.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro import obs
from repro.checkpoint import store
from repro.core import sequential as seq_lib
from repro.core.scheduler import PruneScheduler, SchedulerConfig
from repro.core.sequential import OperatorReport, SequentialConfig
from repro.models.registry import ModelDef
from repro.utils import get_logger

log = get_logger("driver")


def _dense_unit_inputs(model: ModelDef, params: Any, calib_batches: Sequence[Dict],
                       units) -> Dict[str, List[Dict]]:
    """One dense relay pass; snapshot each unit's input states."""
    states = [model.embed(params, b) for b in calib_batches]
    inputs: Dict[str, List[Dict]] = {}
    for spec in units:
        inputs[spec.name] = [dict(s) for s in states]
        dense_unit = seq_lib._unit_params_of(params, spec)
        fwd = seq_lib._capture_forward(model, spec)
        states = [fwd(dense_unit, s)[0] for s in states]
        states = [model.post_unit(params, spec.layer_index, s) for s in states]
    return inputs


def parallel_prune(model: ModelDef, params: Any, calib_batches: Sequence[Dict],
                   cfg: SequentialConfig,
                   sched: SchedulerConfig = SchedulerConfig(),
                   executor: Optional[Any] = None
                   ) -> Tuple[Any, List[OperatorReport], Dict]:
    cfg = cfg.with_solver()   # resolve the legacy (method, pruner) pair once
    if executor is not None and cfg.executor is None:
        cfg = dataclasses.replace(cfg, executor=executor)
    executor = cfg.executor
    mesh_info = executor.describe() if executor is not None \
        else {"data": 1, "model": 1, "devices": 1}
    if cfg.error_correction in ("full", "cross"):
        new_params, reports = seq_lib.prune_model(model, params, calib_batches, cfg)
        return new_params, reports, {"mode": f"serial-{cfg.error_correction}",
                                     "mesh": mesh_info}

    units = {spec.name: spec for spec in model.units()}
    unit_inputs = _dense_unit_inputs(model, params, calib_batches,
                                     list(units.values()))

    def run_unit(name: str) -> Dict[str, Any]:
        spec = units[name]
        dense_unit = seq_lib._unit_params_of(params, spec)
        dense_states = unit_inputs[name]
        pruned_states = [dict(s) for s in dense_states]
        with obs.span("prune.unit", unit=name):
            pruned_unit, reports, _ = seq_lib.prune_unit(
                model, spec, dense_unit, dense_states, pruned_states, cfg)
        telemetry = dict(cfg.solver.describe(),
                         batched_ops=sum(1 for r in reports if r.group_size > 1))
        return {"unit_params": pruned_unit,
                "reports": [dataclasses.asdict(r) for r in reports],
                "solver": telemetry}

    def save_payload(name: str, payload: Dict,
                     meta: Optional[Dict] = None) -> None:
        # telemetry rides with the unit checkpoint: which worker pruned
        # this unit, on what mesh, and how long it took — multi-worker
        # runs stay attributable from the run dir alone
        store.save(sched.checkpoint_dir, f"unit_{name}",
                   {"unit_params": payload["unit_params"]},
                   extra={"reports": payload["reports"],
                          "solver": payload.get("solver", {}),
                          "telemetry": dict(meta or {}, mesh=mesh_info)})

    def load_payload(name: str) -> Dict:
        spec = units[name]
        like = {"unit_params": seq_lib._unit_params_of(params, spec)}
        tree, extra = store.load(sched.checkpoint_dir, f"unit_{name}", like)
        return {"unit_params": tree["unit_params"], "reports": extra["reports"],
                "solver": extra.get("solver", {})}

    has_store = sched.checkpoint_dir is not None
    scheduler = PruneScheduler(
        list(units.keys()), run_unit, sched,
        save_payload=save_payload if has_store else None,
        load_payload=load_payload if has_store else None)
    results = scheduler.run()
    if has_store:
        # run-level telemetry next to the unit checkpoints; consumed by
        # `python -m repro.obs report <ckpt_dir>`
        os.makedirs(sched.checkpoint_dir, exist_ok=True)
        with open(os.path.join(sched.checkpoint_dir, "run_summary.json"),
                  "w", encoding="utf-8") as f:
            json.dump(scheduler.run_summary, f, indent=1, default=float)

    new_params = params
    reports: List[OperatorReport] = []
    for name, res in results.items():
        spec = units[name]
        new_params = seq_lib._write_unit_params(new_params, spec,
                                                res.payload["unit_params"])
        reports.extend(OperatorReport(**r) for r in res.payload["reports"])
    return new_params, reports, dict(scheduler.stats, mesh=mesh_info)
