"""Layer-wise pruning with intra-layer cumulative error correction.

This module turns the per-operator pruner (core/pruner.py) into the
paper's full pipeline (Sec. 3.1, Fig. 2):

* each decoder layer is an independent **pruning unit** — its pruned
  stream starts from the DENSE activation at the unit input, which is
  exactly what makes units independent and layer-parallel (Sec. 3.4);
* inside a unit, operators are pruned **sequentially in groups**
  (peers like wq/wk/wv share an input); each group's Gram statistics
  use X (dense-path input) and X* (input produced by the already-pruned
  prefix of the unit), implementing Eq. (2);
* ``error_correction``:
    - "intra" (paper)   : X* relayed within the unit, dense across units
    - "none"  (ablation): X* = X everywhere (Fig. 4a baseline)
    - "full"  (beyond-paper): X* relayed ACROSS units too — potentially
      more accurate, but serializes layers (noted in DESIGN.md)
    - "cross" (beyond-paper): downstream units calibrate from the
      REALIZED pruned activations of upstream units — both X and X*
      start from the pruned relay at each unit input (the LLM-Surgeon
      view: minimize ||Y X~ - W X~|| at the input the pruned net really
      sees), with X* still relayed within the unit.  Serial, like "full".

Which calibration statistics a unit accumulates is driven by the
solver's DECLARED stat dependencies (core/solvers.py ``stat_deps``):
the pruned-path forward runs only when a declared stat needs it, and
novel registered stats are provisioned generically into
``GramStats.extras`` — zero per-solver edits here.

Memory: the relay keeps one unit's activations for the current
calibration set (the group-stats scan stacks the micro-batches of that
unit's captures); Gram statistics are O(n^2) per operator.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
import weakref
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import gram as gram_lib
from repro.core import solvers as solvers_lib
from repro.core.gram import GramStats
from repro.core.pruner import PrunerConfig
from repro.core.solvers import LayerSolver
from repro.core.sparsity import SparsitySpec
from repro.models.registry import ModelDef
from repro.models.transformer import UnitSpec
from repro.utils import get_logger
from repro.utils.tree import (flatten_with_paths, get_path, set_path,
                              tree_index, tree_stack)

log = get_logger("sequential")


def _record_solve_obs(unit: str, key: str, res: Any, seconds: float) -> None:
    """Prune-side observability (repro.obs): per-operator solver counters,
    iteration/rel-err histograms and — when the solver carried a
    ``trace_len``-bounded convergence history out of its while_loop — one
    series record per operator.  No-op while obs is disabled; everything
    recorded here is already on the host (PruneResult fields)."""
    if not obs.enabled():
        return
    reg = obs.registry()
    reg.counter("prune.operators").inc()
    reg.counter("prune.lambda_bisection_steps").inc(
        int(getattr(res, "outer_iters", 0)))
    reg.histogram("prune.outer_iters", obs.COUNT_BUCKETS).observe(
        getattr(res, "outer_iters", 0))
    reg.histogram("prune.fista_iters", obs.COUNT_BUCKETS).observe(
        getattr(res, "fista_iters", 0))
    reg.histogram("prune.rel_err", obs.FRACTION_BUCKETS).observe(res.rel_error)
    reg.histogram("prune.solve_s", obs.LATENCY_BUCKETS_S).observe(seconds)
    trace = getattr(res, "trace", None)
    if trace is not None:
        reg.series("prune.solver_trace").append({
            "unit": unit, "key": key,
            "rel_error": float(res.rel_error),
            "outer_iters": int(res.outer_iters),
            "e_total": [float(x) for x in trace["e_total"]],
            "lam": [float(x) for x in trace["lam"]]})


@dataclasses.dataclass(frozen=True)
class SequentialConfig:
    spec: SparsitySpec = SparsitySpec(ratio=0.5)
    pruner: PrunerConfig = PrunerConfig()    # legacy fista knobs (see below)
    method: str = "fista"            # registry name (core/solvers.py)
    error_correction: str = "intra"  # intra | none | full | cross
    # canonical solver handle; when None the legacy (method, pruner) pair is
    # resolved through the registry with a DeprecationWarning.  PruneRecipe
    # (repro/api.py) always sets this.
    solver: Optional[LayerSolver] = None
    # MeshExecutor (distributed/executor.py): when set, Gram accumulation
    # goes data-parallel over the calibration micro-batches and solvers
    # that can row-shard do so over "model".  Duck-typed (never imported
    # here) so core keeps zero dependencies on the distribution layer.
    executor: Optional[Any] = None

    def resolve_solver(self) -> LayerSolver:
        if self.solver is not None:
            return self.solver
        warnings.warn(
            "SequentialConfig(method=...) without an explicit solver is "
            "deprecated; build a PruneRecipe (repro.api) or pass "
            "solver=repro.core.solvers.get_solver(name, ...)",
            DeprecationWarning, stacklevel=3)
        return solvers_lib.from_legacy(self.method, self.pruner)

    def with_solver(self) -> "SequentialConfig":
        """Return a config whose ``solver`` field is materialized."""
        if self.solver is not None:
            return self
        return dataclasses.replace(self, solver=self.resolve_solver())


@dataclasses.dataclass
class OperatorReport:
    unit: str
    key: str
    shape: Tuple[int, int]
    error: float
    rel_error: float
    lam: float = 0.0
    outer_iters: int = 0
    fista_iters: int = 0
    seconds: float = 0.0
    solver: str = ""        # "host" | "fused" | "fused-group" | baseline name
    group_size: int = 1     # operators solved in the same batched dispatch


# ---------------------------------------------------------------------------
# capture-key -> param-leaf resolution (handles stacked MoE experts)
# ---------------------------------------------------------------------------
def resolve_param(unit_params: Any, key: str) -> Tuple[str, Optional[int]]:
    """Map a capture key to (param path within the unit, expert index)."""
    if "/expert" in key:
        prefix, rest = key.split("/expert", 1)
        e, op = rest.split("/")
        return f"{prefix}/w_{op}", int(e)
    return key, None


def get_weight(unit_params: Any, key: str) -> jnp.ndarray:
    path, e = resolve_param(unit_params, key)
    w = get_path(unit_params, path)
    return w[e] if e is not None else w


def set_weight(unit_params: Any, key: str, value: jnp.ndarray) -> Any:
    path, e = resolve_param(unit_params, key)
    if e is not None:
        stacked = get_path(unit_params, path)
        return set_path(unit_params, path, stacked.at[e].set(value.astype(stacked.dtype)))
    old = get_path(unit_params, path)
    return set_path(unit_params, path, value.astype(old.dtype))


# ---------------------------------------------------------------------------
# unit pruning
# ---------------------------------------------------------------------------
def _unit_params_of(params: Any, spec: UnitSpec) -> Any:
    node = get_path(params, spec.param_path)
    return tree_index(node, spec.layer_index) if spec.stacked else node


def _write_unit_params(params: Any, spec: UnitSpec, new_unit: Any) -> Any:
    if not spec.stacked:
        return set_path(params, spec.param_path, new_unit)
    stacked = get_path(params, spec.param_path)
    updated = jax.tree_util.tree_map(
        lambda s, n: s.at[spec.layer_index].set(n.astype(s.dtype)), stacked, new_unit)
    return set_path(params, spec.param_path, updated)


_CAPTURE_FWD_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _capture_forward(model: ModelDef, spec: UnitSpec):
    """jitted (unit_params, state) -> (next_state, captures).

    Cached per (model, layer) so repeated prune calls (scheduler retries,
    straggler duplicates, benchmarks) reuse the compiled forward instead of
    re-tracing a fresh closure every time.  Weak-keyed on the ModelDef so a
    discarded model's closures and compiled executables are not pinned."""
    per_model = _CAPTURE_FWD_CACHE.get(model)
    if per_model is None:
        per_model = {}
        _CAPTURE_FWD_CACHE[model] = per_model
    # param_path disambiguates units sharing a layer index (encdec enc/dec)
    cache_key = (spec.param_path, spec.layer_index)
    fwd = per_model.get(cache_key)
    if fwd is None:
        unit_apply, layer_index = model.unit_apply, spec.layer_index

        def fn(unit_params, state):
            cap: Dict[str, jnp.ndarray] = {}
            nxt = unit_apply(unit_params, layer_index, state, cap)
            return nxt, cap

        fwd = jax.jit(fn)
        per_model[cache_key] = fwd
    return fwd


@functools.partial(jax.jit, static_argnames=("unit_apply", "layer_index",
                                             "group_keys", "ec_none",
                                             "extra_specs"))
def _group_stats_scan(init: Dict[str, GramStats], current: Any,
                      ws: Dict[str, jnp.ndarray],
                      dense_caps: Dict[str, jnp.ndarray],
                      pruned_states: Dict[str, jnp.ndarray], *,
                      unit_apply, layer_index: int,
                      group_keys: Tuple[str, ...], ec_none: bool,
                      extra_specs: Tuple[Any, ...] = ()
                      ) -> Dict[str, GramStats]:
    """Accumulate a whole group's GramStats in ONE jitted scan over the
    calibration micro-batches, continuing from ``init``.

    ``dense_caps[key]`` / ``pruned_states`` leaves carry a leading
    micro-batch axis (stacked by the caller).  The pruned-path forward of
    ``current`` and every operator's G/C/H/h update run inside the scan
    body, so there is a single dispatch per same-shape run of batches
    instead of the seed's per-batch x per-key Python loops.  With
    ``ec_none`` the pruned path is skipped entirely (X* = X: the Fig. 4a
    ablation, and every solver whose declared stats are dense-path only).

    ``extra_specs`` (StatSpec tuple, core/solvers.py) are the NOVEL
    declared stats; their ``update`` hooks run in the same scan body and
    their accumulators live in ``GramStats.extras`` — statically keyed,
    so a re-registered hook re-traces instead of reusing a stale cache.
    """

    def body(acc, xs):
        cap_d, ps = xs
        if ec_none:
            cap_p = cap_d
        else:
            cap_p = {}
            unit_apply(current, layer_index, ps, cap_p)
        new = {}
        for key in group_keys:
            xd, xp = cap_d[key], cap_p[key]
            wx = xd @ ws[key]
            st = gram_lib.accumulate(acc[key], xd, xp, wx)
            if extra_specs:
                flat = lambda a: a.reshape(-1, a.shape[-1])
                extras = dict(st.extras)
                for sp in extra_specs:
                    extras[sp.name] = sp.update(extras[sp.name], flat(xd),
                                                flat(xp), flat(wx))
                st = dataclasses.replace(st, extras=extras)
            new[key] = st
        return new, None

    out, _ = jax.lax.scan(body, init, (dense_caps, pruned_states))
    return out


def _shape_buckets(states: Sequence[Dict]) -> List[List[int]]:
    """Partition micro-batch indices into same-shape buckets (a ragged
    final calibration batch must not be stacked with the full ones)."""
    buckets: Dict[Tuple, List[int]] = {}
    for i, s in enumerate(states):
        key = tuple((p, tuple(x.shape)) for p, x in flatten_with_paths(s))
        buckets.setdefault(key, []).append(i)
    return list(buckets.values())


def _shape_subgroups(group: Sequence[str], dense_unit: Any) -> List[List[str]]:
    """Partition a group's keys into maximal same-shape runs (order kept)."""
    by_shape: Dict[Tuple[int, ...], List[str]] = {}
    for key in group:
        shape = tuple(get_weight(dense_unit, key).shape)
        by_shape.setdefault(shape, []).append(key)
    return list(by_shape.values())


def prune_unit(model: ModelDef, spec: UnitSpec, dense_unit: Any,
               dense_states: Sequence[Dict], pruned_states: Sequence[Dict],
               cfg: SequentialConfig
               ) -> Tuple[Any, List[OperatorReport], List[Dict]]:
    """Prune one unit.  Returns (pruned unit params, reports, pruned next
    states) — dense next states are computed by the caller's relay.

    ``dense_states[b]`` / ``pruned_states[b]`` are the unit-input states of
    calibration micro-batch b on the dense / pruned paths.
    """
    cfg = cfg.with_solver()
    solver = cfg.solver
    executor = cfg.executor
    if executor is not None and hasattr(solver, "bind_executor"):
        solver.bind_executor(executor)   # row-sharded solves (rowfista path)
    fwd = _capture_forward(model, spec)
    current = dense_unit  # progressively replaced with pruned weights
    reports: List[OperatorReport] = []
    # dense-path captures don't change while the unit is pruned: one pass
    dense_caps = [fwd(dense_unit, s)[1] for s in dense_states]
    # provision exactly the solver's DECLARED stats (core/solvers.py):
    # the pruned-path forward is skipped in the "none" ablation AND when
    # no declared stat needs the pruned path.  In the latter case the
    # weights are unaffected, but the reported per-operator error becomes
    # the dense-path reconstruction error ||YX - WX|| (the standard metric
    # of the Wanda/SparseGPT literature) instead of the relay error
    # ||YX* - WX|| — cross-solver rel_error comparisons must account for
    # this (benchmarks tag each row with its error_stats mode).
    stat_specs = tuple(solvers_lib.stat_spec(s)
                       for s in solver.stats_required())
    extra_specs = tuple(sp for sp in stat_specs if sp.is_extra)
    ec_none = (cfg.error_correction == "none"
               or not any(sp.needs_pruned_path for sp in stat_specs))
    buckets = _shape_buckets(dense_states)
    # the scan body never reads the pruned states when ec_none —
    # pass cheap placeholders instead of stacking a copy of every state
    pruned_stacked = [jnp.zeros((len(idx),), jnp.float32) if ec_none
                      else tree_stack([dict(pruned_states[i]) for i in idx])
                      for idx in buckets]

    for group in spec.groups:
        # accumulate Gram statistics for every operator of the group in one
        # jitted scan per same-shape run of calibration batches (DESIGN.md §4)
        group_keys = tuple(group)
        ws = {k: get_weight(dense_unit, k) for k in group_keys}
        stats: Dict[str, GramStats] = {
            k: gram_lib.init_stats(
                ws[k].shape[0],
                extras={sp.name: sp.init(ws[k].shape[0])
                        for sp in extra_specs})
            for k in group_keys}
        t_gram = time.perf_counter()
        with obs.span("prune.gram", unit=spec.name, ops=len(group_keys)):
            for idx, pstacked in zip(buckets, pruned_stacked):
                caps_stacked = tree_stack(
                    [{k: dense_caps[i][k] for k in group_keys} for i in idx])
                static_kw = dict(unit_apply=model.unit_apply,
                                 layer_index=spec.layer_index,
                                 group_keys=group_keys, ec_none=ec_none,
                                 extra_specs=extra_specs)
                if executor is not None and executor.can_shard_batches(len(idx)):
                    # data-parallel accumulation: per-shard Gram scan + one
                    # psum over "data" (DESIGN.md §10)
                    stats = executor.sharded_group_stats(
                        _group_stats_scan, stats, current, ws, caps_stacked,
                        pstacked, **static_kw)
                else:
                    stats = _group_stats_scan(stats, current, ws, caps_stacked,
                                              pstacked, **static_kw)
        if obs.enabled():
            obs.registry().histogram(
                "prune.gram_scan_s", obs.LATENCY_BUCKETS_S).observe(
                time.perf_counter() - t_gram)

        # prune the group's operators against their statistics: same-shape
        # operators are solved in one batched dispatch when the solver can
        for sub in _shape_subgroups(group, dense_unit):
            if solver.supports_group_batch and len(sub) > 1:
                t0 = time.perf_counter()
                with obs.span("prune.solve_group", unit=spec.name,
                              ops=len(sub)):
                    results = solver.solve_group(
                        [jnp.asarray(ws[k], jnp.float32).T for k in sub],
                        [stats[k] for k in sub], cfg.spec)
                per_op = (time.perf_counter() - t0) / len(sub)
                for key, res in zip(sub, results):
                    rep = OperatorReport(
                        spec.name, key, tuple(res.weight.shape), res.error,
                        res.rel_error, res.lam, res.outer_iters,
                        res.fista_iters, per_op, solver.group_label, len(sub))
                    reports.append(rep)
                    current = set_weight(current, key, res.weight.T)
                    _record_solve_obs(spec.name, key, res, per_op)
                continue
            for key in sub:
                w_paper = jnp.asarray(ws[key], jnp.float32).T   # (out, in)
                t0 = time.perf_counter()
                with obs.span("prune.solve", unit=spec.name, op=key):
                    res = solver.solve(w_paper, stats[key], cfg.spec)
                rep = OperatorReport(spec.name, key, tuple(w_paper.shape),
                                     res.error, res.rel_error, res.lam,
                                     res.outer_iters, res.fista_iters,
                                     solver=solver.op_label)
                rep.seconds = time.perf_counter() - t0
                reports.append(rep)
                current = set_weight(current, key, res.weight.T)
                _record_solve_obs(spec.name, key, res, rep.seconds)

    # relay: pruned next states through the fully-pruned unit — only the
    # serial cross-unit modes consume them.  Under "intra"/"none" the
    # caller discards the relay, so skip the capture forwards entirely
    # (on grouped MoE units each one is a per-expert capture loop).
    if cfg.error_correction in ("full", "cross"):
        pruned_next = [fwd(current, s)[0] for s in pruned_states]
    else:
        pruned_next = []
    return current, reports, pruned_next


# ---------------------------------------------------------------------------
# whole-model pruning (the serial reference path; the scheduler distributes)
# ---------------------------------------------------------------------------
def prune_model(model: ModelDef, params: Any, calib_batches: Sequence[Dict],
                cfg: SequentialConfig,
                units: Optional[Sequence[UnitSpec]] = None,
                progress: Optional[Callable[[str], None]] = None
                ) -> Tuple[Any, List[OperatorReport]]:
    """Prune every unit of ``params`` using the calibration batches."""
    cfg = cfg.with_solver()   # resolve the legacy (method, pruner) pair once
    units = list(units if units is not None else model.units())
    dense_states = [model.embed(params, b) for b in calib_batches]
    pruned_states = [dict(s) for s in dense_states]
    new_params = params
    reports: List[OperatorReport] = []

    for spec in units:
        dense_unit = _unit_params_of(params, spec)
        if cfg.error_correction == "full":
            # beyond-paper: X stays dense-relayed, X* relays across units
            unit_in_dense, unit_in_pruned = dense_states, pruned_states
        elif cfg.error_correction == "cross":
            # cross-unit realized calibration: BOTH paths start from the
            # activations the pruned net actually produces at this unit's
            # input (targets become W X~, LLM-Surgeon style); X* still
            # relays within the unit through the pruned prefix
            unit_in_dense = pruned_states
            unit_in_pruned = [dict(s) for s in pruned_states]
        else:  # paper: units are independent — pruned stream restarts at
            unit_in_dense = dense_states                      # the dense input
            unit_in_pruned = [dict(s) for s in dense_states]
        pruned_unit, reps, pruned_next = prune_unit(
            model, spec, dense_unit, unit_in_dense, unit_in_pruned, cfg)
        reports.extend(reps)
        new_params = _write_unit_params(new_params, spec, pruned_unit)
        # advance the dense relay (and post-unit hooks, e.g. whisper enc_norm)
        fwd = _capture_forward(model, spec)
        if cfg.error_correction != "cross":   # cross never reads it again
            dense_states = [fwd(dense_unit, s)[0] for s in dense_states]
            dense_states = [model.post_unit(params, spec.layer_index, s)
                            for s in dense_states]
        if cfg.error_correction in ("full", "cross"):
            pruned_states = [model.post_unit(new_params, spec.layer_index, s)
                             for s in pruned_next]
        if progress is not None:
            err = float(np.mean([r.rel_error for r in reps])) if reps else 0.0
            progress(f"{spec.name}: mean rel err {err:.4f}")
        log.info("unit %s pruned (%d ops)", spec.name, len(reps))

    return new_params, reports


def unit_output_error(model: ModelDef, spec: UnitSpec, dense_unit: Any,
                      pruned_unit: Any, states: Sequence[Dict]) -> float:
    """||unit_pruned(x) - unit_dense(x)||_F / ||unit_dense(x)||_F over batches
    (used by the error-correction ablation, Fig. 4a analog)."""
    fwd = _capture_forward(model, spec)
    num, den = 0.0, 0.0
    for s in states:
        yd = fwd(dense_unit, s)[0]["x"]
        yp = fwd(pruned_unit, s)[0]["x"]
        num += float(jnp.sum((yp.astype(jnp.float32) - yd.astype(jnp.float32)) ** 2))
        den += float(jnp.sum(yd.astype(jnp.float32) ** 2))
    return float(np.sqrt(num / max(den, 1e-30)))
