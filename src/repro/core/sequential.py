"""Layer-wise pruning with intra-layer cumulative error correction.

This module turns the per-operator pruner (core/pruner.py) into the
paper's full pipeline (Sec. 3.1, Fig. 2):

* each decoder layer is an independent **pruning unit** — its pruned
  stream starts from the DENSE activation at the unit input, which is
  exactly what makes units independent and layer-parallel (Sec. 3.4);
* inside a unit, operators are pruned **sequentially in groups**
  (peers like wq/wk/wv share an input); each group's Gram statistics
  use X (dense-path input) and X* (input produced by the already-pruned
  prefix of the unit), implementing Eq. (2);
* ``error_correction``:
    - "intra" (paper)   : X* relayed within the unit, dense across units
    - "none"  (ablation): X* = X everywhere (Fig. 4a baseline)
    - "full"  (beyond-paper): X* relayed ACROSS units too — potentially
      more accurate, but serializes layers (noted in DESIGN.md)

Memory: the relay keeps one unit's activations for the current
calibration micro-batch only; Gram statistics are O(n^2) per operator.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gram as gram_lib
from repro.core import pruner as pruner_lib
from repro.core.gram import GramStats
from repro.core.pruner import PrunerConfig
from repro.core.sparsity import SparsitySpec
from repro.models.registry import ModelDef
from repro.models.transformer import UnitSpec
from repro.utils import get_logger
from repro.utils.tree import get_path, set_path, tree_index

log = get_logger("sequential")


@dataclasses.dataclass(frozen=True)
class SequentialConfig:
    spec: SparsitySpec = SparsitySpec(ratio=0.5)
    pruner: PrunerConfig = PrunerConfig()
    method: str = "fista"            # fista | wanda | sparsegpt | magnitude
    error_correction: str = "intra"  # intra | none | full


@dataclasses.dataclass
class OperatorReport:
    unit: str
    key: str
    shape: Tuple[int, int]
    error: float
    rel_error: float
    lam: float = 0.0
    outer_iters: int = 0
    fista_iters: int = 0
    seconds: float = 0.0


# ---------------------------------------------------------------------------
# capture-key -> param-leaf resolution (handles stacked MoE experts)
# ---------------------------------------------------------------------------
def resolve_param(unit_params: Any, key: str) -> Tuple[str, Optional[int]]:
    """Map a capture key to (param path within the unit, expert index)."""
    if "/expert" in key:
        prefix, rest = key.split("/expert", 1)
        e, op = rest.split("/")
        return f"{prefix}/w_{op}", int(e)
    return key, None


def get_weight(unit_params: Any, key: str) -> jnp.ndarray:
    path, e = resolve_param(unit_params, key)
    w = get_path(unit_params, path)
    return w[e] if e is not None else w


def set_weight(unit_params: Any, key: str, value: jnp.ndarray) -> Any:
    path, e = resolve_param(unit_params, key)
    if e is not None:
        stacked = get_path(unit_params, path)
        return set_path(unit_params, path, stacked.at[e].set(value.astype(stacked.dtype)))
    old = get_path(unit_params, path)
    return set_path(unit_params, path, value.astype(old.dtype))


# ---------------------------------------------------------------------------
# unit pruning
# ---------------------------------------------------------------------------
def _unit_params_of(params: Any, spec: UnitSpec) -> Any:
    node = get_path(params, spec.param_path)
    return tree_index(node, spec.layer_index) if spec.stacked else node


def _write_unit_params(params: Any, spec: UnitSpec, new_unit: Any) -> Any:
    if not spec.stacked:
        return set_path(params, spec.param_path, new_unit)
    stacked = get_path(params, spec.param_path)
    updated = jax.tree_util.tree_map(
        lambda s, n: s.at[spec.layer_index].set(n.astype(s.dtype)), stacked, new_unit)
    return set_path(params, spec.param_path, updated)


def _capture_forward(model: ModelDef, spec: UnitSpec):
    """jitted (unit_params, state) -> (next_state, captures)."""

    def fn(unit_params, state):
        cap: Dict[str, jnp.ndarray] = {}
        nxt = model.unit_apply(unit_params, spec.layer_index, state, cap)
        return nxt, cap

    return jax.jit(fn)


def prune_unit(model: ModelDef, spec: UnitSpec, dense_unit: Any,
               dense_states: Sequence[Dict], pruned_states: Sequence[Dict],
               cfg: SequentialConfig
               ) -> Tuple[Any, List[OperatorReport], List[Dict]]:
    """Prune one unit.  Returns (pruned unit params, reports, pruned next
    states) — dense next states are computed by the caller's relay.

    ``dense_states[b]`` / ``pruned_states[b]`` are the unit-input states of
    calibration micro-batch b on the dense / pruned paths.
    """
    fwd = _capture_forward(model, spec)
    current = dense_unit  # progressively replaced with pruned weights
    reports: List[OperatorReport] = []
    # dense-path captures don't change while the unit is pruned: one pass
    dense_caps = [fwd(dense_unit, s)[1] for s in dense_states]

    for group in spec.groups:
        # accumulate Gram statistics for every operator in the group
        stats: Dict[str, GramStats] = {}
        for b in range(len(dense_states)):
            cap_d = dense_caps[b]
            if cfg.error_correction == "none":
                cap_p = cap_d
            else:
                _, cap_p = fwd(current, pruned_states[b])
            for key in group:
                xd, xp = cap_d[key], cap_p[key]
                w = get_weight(dense_unit, key)          # (in, out) model layout
                n = w.shape[0]
                if key not in stats:
                    stats[key] = gram_lib.init_stats(n)
                wx = xd @ w                                # dense target W X
                stats[key] = gram_lib.accumulate(stats[key], xd, xp, wx)

        # prune each operator in the group against its statistics
        for key in group:
            w_model = get_weight(dense_unit, key)
            w_paper = jnp.asarray(w_model, jnp.float32).T   # (out, in)
            t0 = time.perf_counter()
            if cfg.method == "fista":
                res = pruner_lib.prune_operator(w_paper, stats[key], cfg.spec,
                                                cfg.pruner)
                new_w, err = res.weight, res.error
                rep = OperatorReport(spec.name, key, tuple(w_paper.shape), err,
                                     res.rel_error, res.lam, res.outer_iters,
                                     res.fista_iters)
            else:
                new_w, err = pruner_lib.prune_with_method(
                    cfg.method, w_paper, stats[key], cfg.spec, cfg.pruner)
                wx_norm = float(np.sqrt(max(float(stats[key].h), 1e-30)))
                rep = OperatorReport(spec.name, key, tuple(w_paper.shape), err,
                                     err / max(wx_norm, 1e-30))
            rep.seconds = time.perf_counter() - t0
            reports.append(rep)
            current = set_weight(current, key, new_w.T)

    # relay: pruned next states through the fully-pruned unit
    pruned_next = []
    for b in range(len(pruned_states)):
        nxt, _ = fwd(current, pruned_states[b])
        pruned_next.append(nxt)
    return current, reports, pruned_next


# ---------------------------------------------------------------------------
# whole-model pruning (the serial reference path; the scheduler distributes)
# ---------------------------------------------------------------------------
def prune_model(model: ModelDef, params: Any, calib_batches: Sequence[Dict],
                cfg: SequentialConfig,
                units: Optional[Sequence[UnitSpec]] = None,
                progress: Optional[Callable[[str], None]] = None
                ) -> Tuple[Any, List[OperatorReport]]:
    """Prune every unit of ``params`` using the calibration batches."""
    units = list(units if units is not None else model.units())
    dense_states = [model.embed(params, b) for b in calib_batches]
    pruned_states = [dict(s) for s in dense_states]
    new_params = params
    reports: List[OperatorReport] = []

    for spec in units:
        dense_unit = _unit_params_of(params, spec)
        if cfg.error_correction == "full":
            unit_in_pruned = pruned_states
        else:  # paper: units are independent — pruned stream restarts at
            unit_in_pruned = [dict(s) for s in dense_states]  # the dense input
        pruned_unit, reps, pruned_next = prune_unit(
            model, spec, dense_unit, dense_states, unit_in_pruned, cfg)
        reports.extend(reps)
        new_params = _write_unit_params(new_params, spec, pruned_unit)
        # advance the dense relay (and post-unit hooks, e.g. whisper enc_norm)
        fwd = _capture_forward(model, spec)
        dense_states = [fwd(dense_unit, s)[0] for s in dense_states]
        dense_states = [model.post_unit(params, spec.layer_index, s)
                        for s in dense_states]
        if cfg.error_correction == "full":
            pruned_states = [model.post_unit(new_params, spec.layer_index, s)
                             for s in pruned_next]
        if progress is not None:
            err = float(np.mean([r.rel_error for r in reps])) if reps else 0.0
            progress(f"{spec.name}: mean rel err {err:.4f}")
        log.info("unit %s pruned (%d ops)", spec.name, len(reps))

    return new_params, reports


def unit_output_error(model: ModelDef, spec: UnitSpec, dense_unit: Any,
                      pruned_unit: Any, states: Sequence[Dict]) -> float:
    """||unit_pruned(x) - unit_dense(x)||_F / ||unit_dense(x)||_F over batches
    (used by the error-correction ablation, Fig. 4a analog)."""
    fwd = _capture_forward(model, spec)
    num, den = 0.0, 0.0
    for s in states:
        yd = fwd(dense_unit, s)[0]["x"]
        yp = fwd(pruned_unit, s)[0]["x"]
        num += float(jnp.sum((yp.astype(jnp.float32) - yd.astype(jnp.float32)) ** 2))
        den += float(jnp.sum(yd.astype(jnp.float32) ** 2))
    return float(np.sqrt(num / max(den, 1e-30)))
