"""Batched serving engine: prefill + autoregressive decode.

Drives any ModelDef through its ``prefill``/``init_serve_state``/
``serve_step`` protocol; greedy or temperature sampling; the decode loop
is jitted once per (batch, cache) shape.

**Sampling determinism**: the PRNG is folded per *request id* and
generated-token index (``serve/sampling.py``), never per engine call —
a temperature-sampled request decodes identically regardless of batch
composition, which is what lets the continuous batcher
(``serve/batcher.py``) pin token identity against this engine.
``request_ids`` defaults to ``arange(B)``.

**Sparse fast path** (``ServeConfig.sparse``): a 2:4-pruned checkpoint
is detected at engine construction and its eligible weights are packed
into the compressed ``{"vals", "meta"}`` form, so every decode matmul of
those operators dispatches through the ``kernels/spmm24`` path (0.625x
weight traffic, the batch-1 decode roofline bound — DESIGN.md §2).
Packing preserves the weight dtype, so packed logits are bitwise-equal
to the dense matmul of the same masked weights.  ``sparse="dense"`` is
the fallback flag: packed checkpoints are unpacked and everything runs
through plain dense matmuls.

The packed tree is what the engine *accounts* with (``self.params``,
``sparse_stats``); what it *computes* with is ``packed.decode_view`` of
it — identity on TPU (spmm24 kernel path), the cached bitwise-lossless
dense view on CPU, where per-step unpacking made packed serving slower
than dense (see serve/packed.py).

``ServeConfig.decode_impl`` selects the decode fast path ("fused", the
default: block-table flash attention + fused packed epilogues in the
*paged* step) vs the reference gather path that anchors it bitwise.
The contiguous-cache engine here has no paged step, so it serves via
the reference path either way — the flag is validated and forwarded for
config symmetry with ``BatchConfig`` (DESIGN.md §11 fallback rules).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelDef
from repro.serve import packed as packed_lib
from repro.serve import sampling
from repro.utils import get_logger

log = get_logger("serve")

_SPARSE_MODES = ("auto", "packed", "dense")
DECODE_IMPLS = ("fused", "reference")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 => greedy
    cache_len: int = 256
    seed: int = 0
    sparse: str = "auto"           # auto | packed | dense (fallback flag)
    decode_impl: str = "fused"     # fused | reference (bitwise oracle)
    prefill_chunk: Optional[int] = None  # tokens per prefill chunk: route
                                         # the prefill through the same
                                         # fixed-width paged chunk
                                         # executable the batcher uses, so
                                         # solo outputs anchor the chunked
                                         # batcher bitwise (DESIGN.md §15)
    block_size: int = 16           # chunked-prefill block/table granularity
                                   # (must match BatchConfig.block_size for
                                   # the token-identity anchor)


def prepare_serving_params(params: Any, sparse: str
                           ) -> Tuple[Any, Dict[str, Any]]:
    """Route params onto the requested weight representation.

    auto   — pack when the checkpoint's weights satisfy 2:4 (lossless,
             weight dtype kept); otherwise serve dense.
    packed — require a 2:4 checkpoint (already packed or packable).
    dense  — force dense matmuls (unpacks a packed checkpoint).

    Shared by :class:`Engine` and the continuous batcher so both serving
    surfaces make identical packing decisions.
    """
    if sparse not in _SPARSE_MODES:
        raise ValueError(f"unknown sparse mode {sparse!r}; "
                         f"choices: {_SPARSE_MODES}")
    pre_packed = packed_lib.count_packed(params)
    if sparse == "dense":
        if pre_packed:
            log.info("sparse=dense: unpacking %d packed operators", pre_packed)
            params = packed_lib.unpack_tree(params)
        return params, {"mode": "dense", "packed_ops": 0}
    if pre_packed:      # caller packed explicitly (e.g. bf16 storage)
        return params, {"mode": "packed", "packed_ops": pre_packed}
    packed, stats = packed_lib.pack_tree(params, dtype=None)
    if stats["packed_ops"] == 0:
        if sparse == "packed":
            raise ValueError(
                "sparse='packed' but no operator satisfies 2:4 — prune "
                "the checkpoint to 2:4 first, or serve with sparse='auto'")
        return params, {"mode": "dense", "packed_ops": 0}
    log.info("2:4 checkpoint detected: packed %d operators "
             "(%.2f MB -> %.2f MB weight traffic)", stats["packed_ops"],
             stats["dense_bytes"] / 1e6, stats["packed_bytes"] / 1e6)
    return packed, {"mode": "packed", **stats}


class Engine:
    def __init__(self, model: ModelDef, params: Any, cfg: ServeConfig = ServeConfig(),
                 executor: Optional[Any] = None):
        """``executor`` (distributed/executor.py) places the serving
        params on its mesh per the Megatron column/row rules — decode
        runs tensor-parallel over "model" with one all-reduce per block
        (GSPMD inserts it), token-identical to the single-device path."""
        if cfg.decode_impl not in DECODE_IMPLS:
            raise ValueError(f"unknown decode_impl {cfg.decode_impl!r}; "
                             f"choices: {DECODE_IMPLS}")
        if cfg.prefill_chunk is not None:
            if cfg.prefill_chunk < 1:
                raise ValueError(f"prefill_chunk must be >= 1, got "
                                 f"{cfg.prefill_chunk}")
            if cfg.block_size < 1:
                raise ValueError(f"block_size must be >= 1, got "
                                 f"{cfg.block_size}")
            if model.paged_prefill_chunk is None:
                raise ValueError(
                    f"family {model.cfg.family!r} has no chunked prefill "
                    f"path (paged_prefill_chunk)")
        self.model, self.cfg = model, cfg
        self.executor = executor
        self.params, self.sparse_stats = prepare_serving_params(params, cfg.sparse)
        if cfg.decode_impl == "fused" and model.paged_step is None:
            log.debug("decode_impl='fused' on a family without a paged "
                      "step: serving via the reference decode path")
        # accounting tree (self.params, may stay packed) vs compute tree
        # (the decode view: identity on TPU, cached dense unpack on CPU)
        exec_params = packed_lib.decode_view(self.params)
        if executor is not None:
            same = exec_params is self.params
            self.params = executor.shard_params(self.params)
            exec_params = self.params if same else \
                executor.shard_params(exec_params)
        self._exec_params = exec_params
        self._decode_fn = jax.jit(self._decode_step)
        if cfg.prefill_chunk is not None:
            self._chunk_fn = jax.jit(self._chunk_step, donate_argnums=(1,))

    def _chunk_step(self, params, pool, table, tokens, pos0, n_valid):
        return self.model.paged_prefill_chunk(params, pool, table, tokens,
                                              pos0, n_valid,
                                              self.cfg.block_size)

    def _chunked_prefill(self, prompt: jnp.ndarray, cache_len: int,
                         req_keys: jnp.ndarray
                         ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        """Prefill via the fixed-width paged chunk executable, then fold
        the paged rows into the contiguous serve cache.

        This is the batcher's chunked-prefill machinery run solo: same
        ``paged_prefill_chunk`` function, same fixed context width
        (``cache_len``), so the resulting K/V rows and first-token logits
        are bitwise those of the batcher — which is what lets the
        chunked batcher anchor token identity against this engine.  The
        gather into the contiguous cache is a pure data movement (the
        pool and cache share a dtype), and the contiguous decode read is
        pinned bitwise-equal to the paged one (tests/test_kv_pool.py).
        """
        from repro.serve import kv_cache
        cfg = self.cfg
        B, P = prompt.shape
        bs, C = cfg.block_size, cfg.prefill_chunk
        MB = cache_len // bs
        table = jnp.arange(1, MB + 1, dtype=jnp.int32)
        state = self.model.init_serve_state(self._exec_params, B, cache_len,
                                            None)
        if self.executor is not None:
            state = self.executor.shard_serve_state(state)
        flat = kv_cache.flat_slots(list(range(1, MB + 1)), P, bs)
        prompt_np = np.asarray(prompt)
        firsts, rows = [], {k: [] for k in state}
        for b in range(B):
            pool = self.model.init_paged_state(MB + 1, bs)
            o, last = 0, None
            while o < P:
                n_valid = min(C, P - o)
                toks = np.zeros((1, C), np.int32)
                toks[0, :n_valid] = prompt_np[b, o:o + n_valid]
                last, pool = self._chunk_fn(self._exec_params, pool, table,
                                            jnp.asarray(toks), jnp.int32(o),
                                            jnp.int32(n_valid))
                o += n_valid
            firsts.append(last[:, -1, :])
            for k in state:
                rows[k].append(pool[k][:, flat])
        # cache_len >= P, so decode's non-ring slots are the absolute
        # positions: rows land at 0..P-1, the tail stays zero (masked)
        state = {k: state[k].at[:, :, :P].set(jnp.stack(rows[k], axis=1))
                 for k in state}
        first_logits = jnp.concatenate(firsts, axis=0).astype(jnp.float32)
        if self.executor is not None:
            first_logits = self.executor.replicate_logits(first_logits)
        token = sampling.sample(first_logits, sampling.step_keys(req_keys, 0),
                                cfg.temperature)[:, None]
        return token, state

    def _decode_step(self, params, state, token, pos, keys):
        logits, state = self.model.serve_step(params, state, token, pos)
        logits = logits[:, -1, :].astype(jnp.float32)
        if self.executor is not None:
            # sampling needs replicated logits (MeshExecutor.replicate_logits)
            logits = self.executor.replicate_logits(logits)
        nxt = sampling.sample(logits, keys, self.cfg.temperature)
        return nxt[:, None], state

    def _check_capacity(self, prompt_len: int, n_new: int) -> None:
        """Positions ``0..prompt_len+n_new-1`` must exist for the model.

        Without this check the engine silently wrapped or overran
        positions past the model's trained range (whisper's learned
        ``pos_embed`` lookup clamps out-of-range indices; RoPE models
        run past ``max_seq``) and decoded garbage.
        """
        if n_new < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {n_new}")
        if prompt_len < 1:
            raise ValueError("prompt must hold at least one token")
        total, limit = prompt_len + n_new, self.model.cfg.max_seq
        if total > limit:
            raise ValueError(
                f"prompt_len + max_new_tokens = {total} exceeds the model's "
                f"max_seq ({limit}): positions would silently wrap or "
                f"overrun the cache — shorten the prompt or lower "
                f"max_new_tokens")

    def generate(self, prompt: jnp.ndarray,
                 extras: Optional[Dict[str, jnp.ndarray]] = None,
                 max_new_tokens: Optional[int] = None,
                 request_ids: Optional[Any] = None) -> np.ndarray:
        """prompt (B, P) int32 -> generated tokens (B, new).

        ``request_ids`` (B,) int seeds the per-request sampling PRNG
        (default ``arange(B)``); pass each request's stable id to make
        temperature-sampled outputs independent of batch composition.
        """
        cfg = self.cfg
        B, P = prompt.shape
        n_new = cfg.max_new_tokens if max_new_tokens is None else max_new_tokens
        # VLM prefill prepends patch embeddings: they occupy positions too
        n_extra = 0
        if extras is not None and extras.get("patches") is not None:
            n_extra = extras["patches"].shape[1]
        p_eff = P + n_extra
        self._check_capacity(p_eff, n_new)
        cache_len = max(cfg.cache_len, p_eff + n_new)
        if request_ids is None:
            request_ids = np.arange(B)
        req_keys = sampling.request_keys(cfg.seed,
                                         jnp.asarray(request_ids, jnp.int32))

        if cfg.prefill_chunk is not None:
            if extras is not None:
                raise ValueError(
                    "chunked prefill takes token prompts only — serve "
                    "extras-carrying requests (VLM patches) with "
                    "prefill_chunk=None")
            # round the context up to whole blocks for the paged chunk path
            cache_len = -(-cache_len // cfg.block_size) * cfg.block_size
            token, state = self._chunked_prefill(prompt, cache_len, req_keys)
            pos0 = P
        elif self.model.prefill is not None:
            logits, state = self.model.prefill(self._exec_params, prompt,
                                               cache_len, extras)
            first_logits = logits[:, -1, :].astype(jnp.float32)
            if self.executor is not None:
                first_logits = self.executor.replicate_logits(first_logits)
            token = sampling.sample(first_logits,
                                    sampling.step_keys(req_keys, 0),
                                    cfg.temperature)[:, None]
            pos0 = p_eff
        else:
            # recurrent families: feed the prompt token-by-token (sampled
            # outputs are discarded until the last prompt token, whose
            # sample is generated-token 0 — hence the index-0 keys)
            state = self.model.init_serve_state(self._exec_params, B,
                                                cache_len, extras)
            if self.executor is not None:
                state = self.executor.shard_serve_state(state)
            keys0 = sampling.step_keys(req_keys, 0)
            for t in range(P):
                nxt, state = self._decode_fn(self._exec_params, state,
                                             prompt[:, t:t + 1], jnp.int32(t),
                                             keys0)
            token = nxt
            pos0 = P

        # tokens stay on device through the decode loop — a per-step
        # np.asarray would block the dispatch pipeline every token
        # (JAX003); one transfer after the loop
        out = [token]
        for t in range(n_new - 1):
            keys = sampling.step_keys(req_keys, t + 1)
            token, state = self._decode_fn(self._exec_params, state, token,
                                           jnp.int32(pos0 + t), keys)
            out.append(token)
        return np.asarray(jnp.concatenate(out, axis=1))
