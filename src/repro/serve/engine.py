"""Batched serving engine: prefill + autoregressive decode.

Drives any ModelDef through its ``prefill``/``init_serve_state``/
``serve_step`` protocol; greedy or temperature sampling; the decode loop
is jitted once per (batch, cache) shape.

**Sparse fast path** (``ServeConfig.sparse``): a 2:4-pruned checkpoint
is detected at engine construction and its eligible weights are packed
into the compressed ``{"vals", "meta"}`` form, so every decode matmul of
those operators dispatches through the ``kernels/spmm24`` path (0.625x
weight traffic, the batch-1 decode roofline bound — DESIGN.md §2).
Packing preserves the weight dtype, so packed logits are bitwise-equal
to the dense matmul of the same masked weights.  ``sparse="dense"`` is
the fallback flag: packed checkpoints are unpacked and everything runs
through plain dense matmuls.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelDef
from repro.serve import packed as packed_lib
from repro.utils import get_logger

log = get_logger("serve")

_SPARSE_MODES = ("auto", "packed", "dense")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 => greedy
    cache_len: int = 256
    seed: int = 0
    sparse: str = "auto"           # auto | packed | dense (fallback flag)


class Engine:
    def __init__(self, model: ModelDef, params: Any, cfg: ServeConfig = ServeConfig()):
        if cfg.sparse not in _SPARSE_MODES:
            raise ValueError(f"unknown sparse mode {cfg.sparse!r}; "
                             f"choices: {_SPARSE_MODES}")
        self.model, self.cfg = model, cfg
        self.params, self.sparse_stats = self._prepare_params(params)
        self._decode_fn = jax.jit(self._decode_step)

    def _prepare_params(self, params: Any) -> Tuple[Any, Dict[str, Any]]:
        """Route params onto the requested weight representation.

        auto   — pack when the checkpoint's weights satisfy 2:4 (lossless,
                 weight dtype kept); otherwise serve dense.
        packed — require a 2:4 checkpoint (already packed or packable).
        dense  — force dense matmuls (unpacks a packed checkpoint).
        """
        pre_packed = packed_lib.count_packed(params)
        if self.cfg.sparse == "dense":
            if pre_packed:
                log.info("sparse=dense: unpacking %d packed operators",
                         pre_packed)
                params = packed_lib.unpack_tree(params)
            return params, {"mode": "dense", "packed_ops": 0}
        if pre_packed:      # caller packed explicitly (e.g. bf16 storage)
            return params, {"mode": "packed", "packed_ops": pre_packed}
        packed, stats = packed_lib.pack_tree(params, dtype=None)
        if stats["packed_ops"] == 0:
            if self.cfg.sparse == "packed":
                raise ValueError(
                    "sparse='packed' but no operator satisfies 2:4 — prune "
                    "the checkpoint to 2:4 first, or serve with sparse='auto'")
            return params, {"mode": "dense", "packed_ops": 0}
        log.info("2:4 checkpoint detected: packed %d operators "
                 "(%.2f MB -> %.2f MB weight traffic)", stats["packed_ops"],
                 stats["dense_bytes"] / 1e6, stats["packed_bytes"] / 1e6)
        return packed, {"mode": "packed", **stats}

    def _decode_step(self, params, state, token, pos, key):
        logits, state = self.model.serve_step(params, state, token, pos)
        logits = logits[:, -1, :].astype(jnp.float32)
        if self.cfg.temperature > 0:
            nxt = jax.random.categorical(key, logits / self.cfg.temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32)[:, None], state

    def generate(self, prompt: jnp.ndarray,
                 extras: Optional[Dict[str, jnp.ndarray]] = None,
                 max_new_tokens: Optional[int] = None) -> np.ndarray:
        """prompt (B, P) int32 -> generated tokens (B, new)."""
        cfg = self.cfg
        B, P = prompt.shape
        n_new = max_new_tokens or cfg.max_new_tokens
        cache_len = max(cfg.cache_len, P + n_new)

        if self.model.prefill is not None:
            logits, state = self.model.prefill(self.params, prompt, cache_len, extras)
            last = jnp.argmax(logits[:, -1, :].astype(jnp.float32), axis=-1)
            token = last.astype(jnp.int32)[:, None]
            pos0 = P
        else:
            # recurrent families: feed the prompt token-by-token
            state = self.model.init_serve_state(self.params, B, cache_len, extras)
            token = prompt[:, :1]
            for t in range(P):
                key = jax.random.PRNGKey(cfg.seed + t)
                nxt, state = self._decode_fn(self.params, state,
                                             prompt[:, t:t + 1], jnp.int32(t), key)
            token = nxt
            pos0 = P

        out = [np.asarray(token)]
        for t in range(n_new - 1):
            key = jax.random.PRNGKey(cfg.seed + 10_000 + t)
            token, state = self._decode_fn(self.params, state, token,
                                           jnp.int32(pos0 + t), key)
            out.append(np.asarray(token))
        return np.concatenate(out, axis=1)
