"""Batched serving engine: prefill + autoregressive decode.

Drives any ModelDef through its ``prefill``/``init_serve_state``/
``serve_step`` protocol; greedy or temperature sampling; works with
dense or packed-2:4 params (models.common.dense dispatches).  The
decode loop is jitted once per (batch, cache) shape.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelDef
from repro.utils import get_logger

log = get_logger("serve")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 => greedy
    cache_len: int = 256
    seed: int = 0


class Engine:
    def __init__(self, model: ModelDef, params: Any, cfg: ServeConfig = ServeConfig()):
        self.model, self.params, self.cfg = model, params, cfg
        self._decode_fn = jax.jit(self._decode_step)

    def _decode_step(self, params, state, token, pos, key):
        logits, state = self.model.serve_step(params, state, token, pos)
        logits = logits[:, -1, :].astype(jnp.float32)
        if self.cfg.temperature > 0:
            nxt = jax.random.categorical(key, logits / self.cfg.temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32)[:, None], state

    def generate(self, prompt: jnp.ndarray,
                 extras: Optional[Dict[str, jnp.ndarray]] = None,
                 max_new_tokens: Optional[int] = None) -> np.ndarray:
        """prompt (B, P) int32 -> generated tokens (B, new)."""
        cfg = self.cfg
        B, P = prompt.shape
        n_new = max_new_tokens or cfg.max_new_tokens
        cache_len = max(cfg.cache_len, P + n_new)

        if self.model.prefill is not None:
            logits, state = self.model.prefill(self.params, prompt, cache_len, extras)
            last = jnp.argmax(logits[:, -1, :].astype(jnp.float32), axis=-1)
            token = last.astype(jnp.int32)[:, None]
            pos0 = P
        else:
            # recurrent families: feed the prompt token-by-token
            state = self.model.init_serve_state(self.params, B, cache_len, extras)
            token = prompt[:, :1]
            for t in range(P):
                key = jax.random.PRNGKey(cfg.seed + t)
                nxt, state = self._decode_fn(self.params, state,
                                             prompt[:, t:t + 1], jnp.int32(t), key)
            token = nxt
            pos0 = P

        out = [np.asarray(token)]
        for t in range(n_new - 1):
            key = jax.random.PRNGKey(cfg.seed + 10_000 + t)
            token, state = self._decode_fn(self.params, state, token,
                                           jnp.int32(pos0 + t), key)
            out.append(np.asarray(token))
        return np.concatenate(out, axis=1)
