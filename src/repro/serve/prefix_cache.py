"""Radix (trie) prefix cache over the paged KV block pool.

Identical prompt prefixes — system prompts, few-shot preambles — are the
dominant traffic shape at scale, and re-prefilling them per request is
pure waste.  This module caches *full prompt blocks* keyed by their
token content: after a request finishes prefilling, each full block of
its prompt becomes a node in a trie whose edges are the
``block_size``-token chunks of the prompt.  A later request walks the
trie with its own prompt and adopts every matched block into its block
table via :meth:`BlockPool.share` — those positions are never
recomputed.

Semantics (pinned in tests/test_prefix_cache.py and the serve stack
anchors):

* **Exact match only.**  An edge matches iff all ``block_size`` tokens
  are equal; partial blocks are never cached or matched.
* **Matches are capped at ``(P - 1) // block_size`` blocks** so at least
  one prompt token is always recomputed — the chunked prefill of that
  tail both produces the logits the first sampled token needs and
  writes the tail K/V into the request's *own* blocks.  Shared blocks
  are read-only by contract.
* **Bitwise identity.**  A cache hit replays the same fixed-width
  chunked-prefill executable over the same gathered context rows, so
  hit-path tokens are bitwise-identical to a cold prefill of the same
  prompt (the chunked path is bitwise self-consistent across chunk
  offsets/groupings; see DESIGN.md §15).
* **Refcount lifecycle.**  The cache holds one pool reference per node
  (:meth:`BlockPool.retain`); each sharer holds another.  Eviction is
  LRU over *leaf* nodes whose pool refcount is exactly 1 (only the
  cache still references them) — interior nodes and blocks shared with
  in-flight requests are never evicted.
* **Defrag-aware.**  :meth:`apply_defrag` renames node block ids after
  a pool compaction; contents move with the blocks, so shared-block
  bytes are preserved (pinned by property test).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import kv_cache


class _Node:
    __slots__ = ("key", "block", "children", "parent", "last_used")

    def __init__(self, key: Optional[bytes], block: Optional[int],
                 parent: Optional["_Node"]):
        self.key = key
        self.block = block
        self.children: Dict[bytes, "_Node"] = {}
        self.parent = parent
        self.last_used = 0


def _block_keys(prompt: Sequence[int], block_size: int) -> List[bytes]:
    toks = np.asarray(prompt, np.int32)
    n_full = len(toks) // block_size
    return [toks[i * block_size:(i + 1) * block_size].tobytes()
            for i in range(n_full)]


class PrefixCache:
    """Block-granular radix cache of prompt-prefix KV over a BlockPool.

    ``capacity`` bounds the number of cached blocks; inserts past it
    evict LRU refcount-1 leaves first and simply skip caching when
    nothing is evictable (in-flight sharers pin their blocks).
    """

    def __init__(self, pool: kv_cache.BlockPool,
                 capacity: Optional[int] = None):
        if capacity is not None and capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.pool = pool
        self.capacity = capacity
        self._root = _Node(None, None, None)
        self._size = 0      # cached blocks (nodes below root)
        self._clock = 0
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0
        self.inserted_blocks = 0
        self.evicted_blocks = 0

    @property
    def num_blocks(self) -> int:
        return self._size

    def _walk(self, prompt: Sequence[int]) -> List[_Node]:
        """Longest matched node path, capped to keep >= 1 token uncached."""
        bs = self.pool.block_size
        max_match = max(0, (len(prompt) - 1) // bs)
        path: List[_Node] = []
        node = self._root
        for key in _block_keys(prompt, bs)[:max_match]:
            child = node.children.get(key)
            if child is None:
                break
            path.append(child)
            node = child
        return path

    def match_tokens(self, prompt: Sequence[int]) -> int:
        """Tokens a cache hit would cover, without acquiring anything."""
        return len(self._walk(prompt)) * self.pool.block_size

    def acquire(self, request_id: int, prompt: Sequence[int]
                ) -> Tuple[List[int], int]:
        """Match ``prompt`` and share the matched blocks with the request.

        Returns ``(blocks, matched_tokens)``; the blocks are already in
        ``request_id``'s table order and counted against its ownership
        (released by the normal ``free_request`` path).
        """
        path = self._walk(prompt)
        self._clock += 1
        for node in path:
            node.last_used = self._clock
        blocks = [node.block for node in path]
        if blocks:
            self.pool.share(request_id, blocks)
            self.hits += 1
            self.hit_tokens += len(blocks) * self.pool.block_size
        else:
            self.misses += 1
        return blocks, len(blocks) * self.pool.block_size

    def insert(self, prompt: Sequence[int], blocks: Sequence[int]) -> int:
        """Cache the full prompt blocks of a completed prefill.

        ``blocks`` are the request's table blocks covering the prompt in
        logical order (shared prefix first, then its own).  Existing
        nodes are kept (first writer wins — contents are bitwise equal
        by construction); new nodes retain their block in the pool.
        Returns the number of newly cached blocks.
        """
        bs = self.pool.block_size
        keys = _block_keys(prompt, bs)
        if len(blocks) < len(keys):
            raise ValueError(
                f"{len(blocks)} blocks cannot cover {len(keys)} full "
                f"prompt blocks")
        self._clock += 1
        added = 0
        node = self._root
        for key, block in zip(keys, blocks):
            child = node.children.get(key)
            if child is None:
                if self.capacity is not None and self._size >= self.capacity:
                    if self.evict(self._size - self.capacity + 1) == 0:
                        break
                self.pool.retain([block])
                child = _Node(key, block, node)
                node.children[key] = child
                self._size += 1
                added += 1
            child.last_used = self._clock
            node = child
        self.inserted_blocks += added
        return added

    def _evictable_leaves(self) -> List[_Node]:
        leaves = []
        stack = list(self._root.children.values())
        while stack:
            node = stack.pop()
            if node.children:
                stack.extend(node.children.values())
            elif self.pool.refcount(node.block) == 1:
                leaves.append(node)
        leaves.sort(key=lambda n: n.last_used)
        return leaves

    def evict(self, n: int) -> int:
        """Evict up to ``n`` LRU leaf blocks held only by the cache.

        Evicting a leaf may expose its parent as the next candidate, so
        eviction cascades until ``n`` blocks are freed or nothing is
        evictable.  Returns the number of blocks freed.
        """
        freed = 0
        while freed < n:
            leaves = self._evictable_leaves()
            if not leaves:
                break
            for node in leaves[:n - freed]:
                del node.parent.children[node.key]
                self.pool.release([node.block])
                self._size -= 1
                freed += 1
        self.evicted_blocks += freed
        return freed

    def apply_defrag(self, remap: Dict[int, int]) -> None:
        """Rename node block ids after a :meth:`BlockPool.defrag`."""
        if not remap:
            return
        stack = list(self._root.children.values())
        while stack:
            node = stack.pop()
            node.block = remap.get(node.block, node.block)
            stack.extend(node.children.values())

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "hit_tokens": self.hit_tokens,
                "inserted_blocks": self.inserted_blocks,
                "evicted_blocks": self.evicted_blocks,
                "cached_blocks": self._size}
