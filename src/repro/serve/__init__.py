"""Serving: batched decode engine, continuous batcher + paged KV pool,
radix prompt-prefix cache, packed-2:4 weight store."""
from repro.serve.batcher import (BatchConfig, ContinuousBatcher, Request,
                                 RequestResult, synthetic_trace)
from repro.serve.engine import Engine, ServeConfig, prepare_serving_params
from repro.serve.kv_cache import BlockPool, PoolExhausted
from repro.serve.packed import pack_tree, unpack_tree
from repro.serve.prefix_cache import PrefixCache

__all__ = ["Engine", "ServeConfig", "prepare_serving_params", "pack_tree",
           "unpack_tree", "ContinuousBatcher", "BatchConfig", "Request",
           "RequestResult", "synthetic_trace", "BlockPool", "PoolExhausted",
           "PrefixCache"]
