"""Serving: batched decode engine + packed-2:4 weight store."""
from repro.serve.engine import Engine, ServeConfig
from repro.serve.packed import pack_tree, unpack_tree

__all__ = ["Engine", "ServeConfig", "pack_tree", "unpack_tree"]
