"""Per-request sampling state for serving.

The sampling PRNG is folded per **request id**, not per engine call or
batch slot:

    key(request, i) = fold_in(fold_in(PRNGKey(seed), request_id), i)

where ``i`` is the index of the generated token within the request.  A
temperature-sampled request therefore decodes identically no matter
which batch it shares, which slot of the continuous batcher it lands in,
or when it joins mid-flight — the property behind the serving stack's
token-identity anchor (tests/test_serve_stack.py): continuous-batched
output == solo static ``Engine.generate`` of the same prompt.

Every function is shape-polymorphic jnp and traceable, so the batcher
folds keys *inside* its jitted step while the engine folds them eagerly
— same ops, same keys, same tokens.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def request_keys(seed: int, request_ids: jnp.ndarray) -> jnp.ndarray:
    """(S,) int32 request ids -> (S, ...) per-request base PRNG keys."""
    base = jax.random.PRNGKey(seed)
    return jax.vmap(lambda r: jax.random.fold_in(base, r))(
        jnp.asarray(request_ids, jnp.int32))


def step_keys(req_keys: jnp.ndarray, index: jnp.ndarray) -> jnp.ndarray:
    """Fold per-request keys with the sample index (scalar or (S,))."""
    idx = jnp.broadcast_to(jnp.asarray(index, jnp.int32),
                           (req_keys.shape[0],))
    return jax.vmap(jax.random.fold_in)(req_keys, idx)


def sample(logits: jnp.ndarray, keys: jnp.ndarray,
           temperature) -> jnp.ndarray:
    """Per-row next-token sampling.  logits (S, V) float32; keys (S, ...);
    ``temperature`` scalar or (S,) — 0 means greedy argmax, otherwise a
    categorical draw at that temperature with the row's own key, so a
    row's token never depends on what else shares the batch."""
    temps = jnp.broadcast_to(jnp.asarray(temperature, jnp.float32),
                             (logits.shape[0],))
    greedy = jnp.argmax(logits, axis=-1)
    safe = jnp.where(temps > 0, temps, 1.0)
    drawn = jax.vmap(lambda k, row: jax.random.categorical(k, row))(
        keys, logits / safe[:, None])
    return jnp.where(temps > 0, drawn, greedy).astype(jnp.int32)
