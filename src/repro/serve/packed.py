"""Packed-2:4 weight store for memory-bound serving.

``pack_tree`` walks a param pytree and replaces every 2-D weight whose
paper-layout transpose satisfies the 2:4 pattern with the packed dict
``{"vals", "meta"}`` consumed transparently by ``models.common.dense``
(spmm24 kernel).  Decode-time weight traffic drops to 0.625x — the TPU
adaptation of the paper's 2:4 motivation (DESIGN.md §2).

Embeddings, norms, vectors, stacked expert tensors and anything not
actually 2:4-sparse are left dense.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.sparsity import SparsitySpec, satisfies
from repro.kernels import ops as kops
from repro.utils.tree import tree_map_with_path

_SPEC = SparsitySpec(kind="nm", n=2, m=4)


def _pattern_ok(w_paper: np.ndarray) -> bool:
    """w_paper (..., out, in): 2:4 along the input dim and mostly sparse."""
    groups = w_paper.reshape(w_paper.shape[:-1] + (w_paper.shape[-1] // 4, 4))
    return bool(((groups != 0).sum(axis=-1) <= 2).all()) and \
        float((w_paper == 0).mean()) >= 0.45


def _packable(path: str, w: Any) -> bool:
    if not hasattr(w, "ndim") or w.ndim not in (2, 3):
        return False
    if "embed" in path or "norm" in path or "conv" in path \
            or path.endswith(("scale", "bias")):
        return False
    if w.shape[-2] % 4 != 0:   # input dim (in, out layout) must be whole groups
        return False
    if min(w.shape[-2:]) < 8:  # layer-stacked bias vectors (L, d) are 2-D too
        return False
    wn = np.asarray(w, np.float32)
    if not wn.any():           # all-zero (fresh-init) tensors are not "2:4"
        return False
    w_paper = wn.T if w.ndim == 2 else wn.transpose(0, 2, 1)  # (L, out, in)
    return _pattern_ok(w_paper)


def pack_tree(params: Any, dtype: Any = jnp.bfloat16) -> Tuple[Any, dict]:
    """Returns (packed params, stats {packed_ops, dense_bytes, packed_bytes}).

    2-D weights (in, out) pack to {"vals" (out,in/2), "meta" (out,in/4)};
    layer-stacked 3-D weights (L, in, out) pack per-slice via vmap — the
    serving scan then slices the packed leaves exactly like dense ones.

    ``dtype`` is the packed-value storage dtype (bf16, the TPU serving
    default); ``dtype=None`` keeps each weight's own dtype, making the
    packing bitwise-lossless — the serve engine's fast path uses this so
    packed logits match the dense-matmul logits exactly.
    """
    stats = {"packed_ops": 0, "dense_bytes": 0, "packed_bytes": 0}

    def visit(path, w):
        if _packable(path, w):
            wt = jnp.asarray(w)
            wt = wt if dtype is None else wt.astype(dtype)
            if w.ndim == 2:
                vals, meta = kops.pack24(wt.T)
            else:
                import jax
                vals, meta = jax.vmap(kops.pack24)(wt.transpose(0, 2, 1))
            itemsize = jnp.dtype(vals.dtype).itemsize
            stats["packed_ops"] += 1 if w.ndim == 2 else w.shape[0]
            stats["dense_bytes"] += w.size * itemsize
            stats["packed_bytes"] += vals.size * itemsize + meta.size
            return {"vals": vals, "meta": meta}
        return w

    return tree_map_with_path(visit, params), stats


def is_packed_leaf(node: Any) -> bool:
    return (isinstance(node, dict) and len(node) == 2
            and "vals" in node and "meta" in node)


def count_packed(params: Any) -> int:
    """Number of packed-2:4 operator leaves in a param tree."""

    def rec(node) -> int:
        if is_packed_leaf(node):
            return node["vals"].shape[0] if node["vals"].ndim == 3 else 1
        if isinstance(node, dict):
            return sum(rec(v) for v in node.values())
        if isinstance(node, (list, tuple)):
            return sum(rec(v) for v in node)
        return 0

    return rec(params)


def decode_view(params: Any) -> Any:
    """The representation the decode step should *compute* with.

    On TPU: identity — packed leaves feed the spmm24 / fused-epilogue
    kernels, which is the whole point of packing (0.625x weight traffic).

    On CPU there is no packed-matmul hardware to win on, and unpacking
    inside the jitted per-token step (or interpreting the Pallas kernel)
    made packed serving ~2x *slower* than dense — the measured
    BENCH_serve regression.  So the unpack happens HERE, once, at
    construction: the returned tree is the bitwise-lossless dense view
    (pack_tree with ``dtype=None`` keeps values exactly), the caller
    keeps the packed tree for accounting (``packed_bytes`` in
    serve_bench's modeled roofline), and the hot loop runs plain dense
    matmuls.  Identity when nothing is packed.
    """
    import jax
    if jax.default_backend() == "tpu":
        return params
    n = count_packed(params)
    if n == 0:
        return params
    from repro.utils import get_logger
    get_logger("serve").info(
        "CPU backend: caching dense decode view of %d packed operators "
        "(packed tree kept for accounting)", n)
    return unpack_tree(params)


def unpack_tree(params: Any) -> Any:
    """Inverse of pack_tree (packed dicts -> dense (in, out))."""

    def rec(node):
        if isinstance(node, dict):
            if is_packed_leaf(node):
                n = node["vals"].shape[-1] * 2
                if node["vals"].ndim == 3:
                    import jax
                    dense = jax.vmap(lambda v, m: kops.unpack24(v, m, n))(
                        node["vals"], node["meta"])
                    return dense.transpose(0, 2, 1)
                return kops.unpack24(node["vals"], node["meta"], n).T
            return {k: rec(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            seq = [rec(v) for v in node]
            return type(node)(seq) if isinstance(node, tuple) else seq
        return node

    return rec(params)
