"""Continuous-batching request scheduler over the paged KV pool.

The batcher turns the single-shot ``Engine`` into a request-level
serving loop: an admission queue of :class:`Request`, a fixed number of
serving *slots*, and **one** jitted decode step
(``ModelDef.paged_step``) over those slots.  Requests join mid-flight —
their prompt K/V lands in freshly allocated blocks and their slot goes
active — and retire on EOS or length by flipping the active mask and
freeing their blocks.  The decode step never re-specializes: slot
count, block-table width, and pool shape are fixed at construction, so
joining/retiring costs zero recompilation (tests pin
``_step_fn._cache_size() == 1``).

Three serving features layer on top of that core (DESIGN.md §15):

* **Chunked prefill** (``BatchConfig.prefill_chunk``): prompts prefill
  through one fixed-width jitted chunk executable
  (``ModelDef.paged_prefill_chunk``), at most one chunk per scheduler
  tick, interleaved with decode — a long prompt no longer stalls every
  in-flight decode, bounding inter-token latency.  The chunk path is
  bitwise self-consistent across chunk sizes/offsets, and the solo
  ``Engine`` runs the same executable in its chunked mode, so the
  token-identity anchor holds end to end.
* **Prefix cache** (``BatchConfig.prefix_cache``, requires chunked
  prefill): full prompt blocks are cached in a radix trie
  (``serve/prefix_cache.py``) and shared block-refcounted across
  requests; a hit skips the matched chunks entirely and resumes the
  chunk executable mid-prompt — bitwise-identical to a cold prefill.
* **SLA-aware admission**: the queue orders by ``(priority, deadline,
  arrival, id)`` with strict head-of-line (no bypass — deterministic);
  admission charges a request its *actual* block need (prefix-cache
  hits are discounted) and, when the pool or slots are exhausted, a
  strictly-lower-priority active request is **preempted** — its
  written K/V swapped to the host, blocks freed, request re-queued —
  and later resumed bitwise-exactly via the ``scatter_prefill`` path.

Correctness anchor: every request's output is **token-identical** to a
solo ``Engine.generate(prompt, request_ids=[id])`` with
``cache_len == BatchConfig.context_len`` (and the same
``prefill_chunk`` when chunked) — on dense and 2:4-packed checkpoints,
greedy and temperature sampling (see DESIGN.md §9/§15 for why the paged
read, the fixed-width chunked prefill, and the per-request PRNG folding
make this exact).

Block accounting: blocks are allocated lazily as a request's context
grows, but admission *reserves* the request's worst-case block count
(``ceil((P + max_new) / block_size)`` minus prefix-cache-matched
blocks) against the pool, so an active request can never hit
``PoolExhausted`` mid-flight — pressure shows up as queueing delay or
preemption of lower-priority work, never as a mid-generation failure.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models.registry import ModelDef
from repro.serve import kv_cache, sampling
from repro.serve import packed as packed_lib
from repro.serve.engine import prepare_serving_params
from repro.serve.prefix_cache import PrefixCache
from repro.utils import get_logger

log = get_logger("serve.batcher")


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray                 # (P,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    eos_id: Optional[int] = None       # None: run to max_new_tokens
    arrival: float = 0.0               # seconds from trace start
    priority: int = 0                  # lower = more urgent
    deadline: Optional[float] = None   # seconds from trace start; tie-break


@dataclasses.dataclass
class RequestResult:
    id: int
    tokens: np.ndarray                 # generated tokens (includes EOS if hit)
    reason: str                        # "length" | "eos"
    prompt_len: int
    arrival: float                     # seconds from run start
    admitted: float
    first_token: float
    finished: float
    admitted_step: int                 # decode-step counter at admission
    finished_step: int
    priority: int = 0
    prefix_hit_tokens: int = 0         # prompt tokens served from the cache
    preemptions: int = 0               # times this request was preempted
    token_times: Optional[np.ndarray] = None  # per-token emission times (s)

    @property
    def latency(self) -> float:
        return self.finished - self.arrival


@dataclasses.dataclass(frozen=True)
class BatchConfig:
    slots: int = 4
    block_size: int = 16
    max_blocks_per_request: int = 4    # context width = block_size * this
    num_blocks: int = 64               # pool size incl. reserved trash block
    seed: int = 0                      # sampling PRNG seed (Engine's cfg.seed)
    sparse: str = "auto"               # auto | packed | dense
    max_prefills_per_tick: int = 1     # admission rate per scheduler tick
    decode_impl: str = "fused"         # fused (block-table flash kernel)
                                       # | reference (gather path, the
                                       #   bitwise oracle — DESIGN.md §11)
    prefill_chunk: Optional[int] = None  # tokens per prefill chunk; None =
                                         # eager one-shot prefill
    prefix_cache: bool = False         # radix prompt-prefix cache (requires
                                       # prefill_chunk — hits resume the
                                       # chunk executable mid-prompt)
    prefix_cache_blocks: Optional[int] = None  # cap on cached blocks

    @property
    def context_len(self) -> int:
        """Per-request context capacity (== the solo engine ``cache_len``
        that the token-identity anchor compares against)."""
        return self.block_size * self.max_blocks_per_request


class ContinuousBatcher:
    def __init__(self, model: ModelDef, params: Any,
                 cfg: BatchConfig = BatchConfig(),
                 executor: Optional[Any] = None):
        """``executor`` (distributed/executor.py) makes the batcher
        tensor-parallel: params place per the Megatron column/row rules
        and the paged KV pool takes its heads-sharded device layout (each
        "model" shard owns its attention heads' pages; the one all-reduce
        per block lands after wo/down — GSPMD inserts it from the
        shardings).  Host-side scheduling (admission, block tables,
        retirement) is unchanged, and the decoded tokens are pinned
        token-identical to the single-device batcher in
        tests/distributed_cases.py."""
        if model.paged_step is None or model.prefill is None:
            raise ValueError(
                f"family {model.cfg.family!r} has no paged serving path "
                f"(paged_step/prefill); the continuous batcher covers the "
                f"transformer families")
        if model.cfg.family == "vlm":
            raise ValueError(
                "vlm prefill needs per-request patch embeddings and Request "
                "carries none — serve VLMs through Engine.generate(extras=...)")
        if cfg.num_blocks < 2:
            raise ValueError("num_blocks must be >= 2 (block 0 is trash)")
        from repro.serve.engine import DECODE_IMPLS
        if cfg.decode_impl not in DECODE_IMPLS:
            raise ValueError(f"unknown decode_impl {cfg.decode_impl!r}; "
                             f"choices: {DECODE_IMPLS}")
        if cfg.prefill_chunk is not None:
            if cfg.prefill_chunk < 1:
                raise ValueError(f"prefill_chunk must be >= 1, got "
                                 f"{cfg.prefill_chunk}")
            if model.paged_prefill_chunk is None:
                raise ValueError(
                    f"family {model.cfg.family!r} has no chunked prefill "
                    f"path (paged_prefill_chunk)")
        if cfg.prefix_cache and cfg.prefill_chunk is None:
            raise ValueError(
                "prefix_cache requires prefill_chunk: cache hits resume the "
                "fixed-width chunk executable mid-prompt, and the eager "
                "prefill's numerics differ from the chunked path's")
        self.model, self.cfg = model, cfg
        self.executor = executor
        self.params, self.sparse_stats = prepare_serving_params(params, cfg.sparse)
        # accounting tree (self.params, may stay packed — serve_bench
        # meters its bytes) vs compute tree (packed.decode_view: identity
        # on TPU, cached dense unpack on CPU)
        exec_params = packed_lib.decode_view(self.params)
        self.pool = kv_cache.BlockPool(cfg.num_blocks, cfg.block_size)
        self.pool_state = model.init_paged_state(cfg.num_blocks, cfg.block_size)
        if executor is not None:
            same = exec_params is self.params
            self.params = executor.shard_params(self.params)
            exec_params = self.params if same else \
                executor.shard_params(exec_params)
            self.pool_state = executor.shard_paged_pool(self.pool_state)
        self._exec_params = exec_params
        self._cache: Optional[PrefixCache] = (
            PrefixCache(self.pool, cfg.prefix_cache_blocks)
            if cfg.prefix_cache else None)

        S = cfg.slots
        self._tables = np.zeros((S, cfg.max_blocks_per_request), np.int32)
        self._pos = np.zeros((S,), np.int32)       # next write position
        self._token = np.zeros((S, 1), np.int32)   # last sampled token
        self._req_ids = np.zeros((S,), np.int32)
        self._tok_idx = np.zeros((S,), np.int32)   # sample index of next token
        self._temps = np.zeros((S,), np.float32)
        self._active = np.zeros((S,), bool)
        self._slot_req: List[Optional[Request]] = [None] * S
        self._emitted: List[List[int]] = [[] for _ in range(S)]
        self._emit_times: List[List[float]] = [[] for _ in range(S)]
        self._meta: List[Dict[str, Any]] = [{} for _ in range(S)]
        # per-slot in-progress chunked prefill: {"table", "blocks", "done"}
        self._prefill: List[Optional[Dict[str, Any]]] = [None] * S
        self._reserved = 0                         # promised, unallocated blocks
        self._preempted: Dict[int, Dict[str, Any]] = {}  # rid -> saved state

        self.queue: Deque[Request] = deque()
        self.results: Dict[int, RequestResult] = {}
        self.stats = {"steps": 0, "prefills": 0, "prefill_tokens": 0,
                      "prefill_chunks": 0, "preemptions": 0, "resumes": 0,
                      "active_slot_steps": 0, "context_tokens": 0,
                      "step_walls": []}   # measured per-tick decode seconds

        # serve-side SLO metrics (repro.obs): instruments are fetched ONCE
        # here behind enabled(), so the per-tick cost while disabled is a
        # single attribute check; recording only touches values the loop
        # already holds on the host (no extra device syncs — OBS001)
        self._obs = obs.enabled()
        if self._obs:
            reg = obs.registry()
            self._m_ttft = reg.histogram("serve.ttft_s",
                                         obs.LATENCY_BUCKETS_S)
            self._m_itl = reg.histogram("serve.inter_token_s",
                                        obs.LATENCY_BUCKETS_S)
            self._m_wait = reg.histogram("serve.admission_wait_s",
                                         obs.LATENCY_BUCKETS_S)
            self._m_step = reg.histogram("serve.step_s",
                                         obs.LATENCY_BUCKETS_S)
            self._m_queue = reg.histogram("serve.queue_depth",
                                          obs.COUNT_BUCKETS)
            self._m_occ = reg.histogram("serve.pool_occupancy",
                                        obs.FRACTION_BUCKETS)
            self._m_active = reg.histogram("serve.active_slots",
                                           obs.COUNT_BUCKETS)
            self._m_prefill_pending = reg.histogram(
                "serve.prefill_pending_tokens", obs.COUNT_BUCKETS)
            self._c_decode_steps = reg.counter("serve.decode_steps")
            self._c_prefills = reg.counter("serve.prefills")
            self._c_prefill_tokens = reg.counter("serve.prefill_tokens")
            self._c_prefill_chunks = reg.counter("serve.prefill_chunks")
            self._c_decode_tokens = reg.counter("serve.decode_tokens")
            self._c_defrags = reg.counter("serve.defrags")
            self._c_defrag_blocks = reg.counter("serve.defrag_blocks_moved")
            self._c_preemptions = reg.counter("serve.preemptions")
            self._c_prefix_hits = reg.counter("serve.prefix_hits")
            self._c_prefix_misses = reg.counter("serve.prefix_misses")
            self._c_prefix_hit_tokens = reg.counter("serve.prefix_hit_tokens")
            self._c_prefix_evicted = reg.counter("serve.prefix_evicted_blocks")
            # per-priority admission-wait histograms bind lazily (one per
            # priority class ever seen) in _wait_hist; buffered waits are
            # flushed once per tick from _record_tick_obs
            self._m_wait_prio: Dict[int, Any] = {}
            self._obs_flushed = {"prefill_chunks": 0, "preemptions": 0,
                                 "hits": 0, "misses": 0, "hit_tokens": 0,
                                 "evicted": 0}
            self._pend_waits: List[Tuple[int, float]] = []

        def step(params, pool, tables, pos, token, req_ids, tok_idx, active,
                 temps):
            logits, pool = model.paged_step(params, pool, tables, token, pos,
                                            active, cfg.block_size,
                                            impl=cfg.decode_impl)
            logits = logits[:, -1, :].astype(jnp.float32)
            if executor is not None:
                # sampling must see replicated logits (see
                # MeshExecutor.replicate_logits) or TP temperature draws
                # diverge from the single-device path
                logits = executor.replicate_logits(logits)
            keys = sampling.step_keys(sampling.request_keys(cfg.seed, req_ids),
                                      tok_idx)
            return sampling.sample(logits, keys, temps)[:, None], pool

        self._step_fn = jax.jit(step, donate_argnums=(1,))

        if cfg.prefill_chunk is not None:
            def chunk_step(params, pool, table, tokens, pos0, n_valid):
                return model.paged_prefill_chunk(params, pool, table, tokens,
                                                 pos0, n_valid, cfg.block_size)

            # one executable for every chunk of every prompt: chunk width,
            # table width, and pool shape are fixed; offset/valid-count are
            # traced scalars (tests pin _chunk_fn._cache_size() == 1)
            self._chunk_fn = jax.jit(chunk_step, donate_argnums=(1,))

    # ------------------------------------------------------------------
    # submission / admission
    # ------------------------------------------------------------------
    def _blocks_needed(self, r: Request) -> int:
        return -(-(len(r.prompt) + r.max_new_tokens) // self.cfg.block_size)

    @staticmethod
    def _prio_key(r: Request) -> Tuple[float, float, float, int]:
        return (r.priority,
                r.deadline if r.deadline is not None else math.inf,
                r.arrival, r.id)

    def submit(self, request: Request) -> None:
        P, n = len(request.prompt), request.max_new_tokens
        if P < 1:
            raise ValueError(f"request {request.id}: empty prompt")
        if n < 1:
            raise ValueError(f"request {request.id}: max_new_tokens must be "
                             f">= 1, got {n}")
        limit = min(self.cfg.context_len, self.model.cfg.max_seq)
        if P + n > limit:
            raise ValueError(
                f"request {request.id}: prompt_len + max_new_tokens = {P + n} "
                f"exceeds the serving context ({self.cfg.context_len}) or the "
                f"model's max_seq ({self.model.cfg.max_seq})")
        if self._blocks_needed(request) > self.cfg.num_blocks - 1:
            raise kv_cache.PoolExhausted(
                f"request {request.id} needs {self._blocks_needed(request)} "
                f"blocks; the pool only has {self.cfg.num_blocks - 1}")
        if request.id in self.results or any(
                q.id == request.id for q in self.queue) or any(
                r is not None and r.id == request.id for r in self._slot_req):
            raise ValueError(f"duplicate request id {request.id}")
        self.queue.append(request)

    def _free_slot(self) -> Optional[int]:
        for s in range(self.cfg.slots):
            if self._slot_req[s] is None:
                return s
        return None

    def _head(self, now: float) -> Optional[Request]:
        """Most urgent arrived request: min (priority, deadline, arrival,
        id).  Strict head-of-line — nothing bypasses it."""
        best = None
        for r in self.queue:
            if r.arrival > now:
                continue
            if best is None or self._prio_key(r) < self._prio_key(best):
                best = r
        return best

    def _admit(self, now: float) -> int:
        """SLA-aware admission: prefill (or resume) the most urgent
        arrived request while a slot and its actual block need — the
        worst case minus prefix-cache-matched blocks — are available,
        evicting cache blocks and preempting strictly-lower-priority
        actives to make room."""
        admitted = 0
        while admitted < self.cfg.max_prefills_per_tick:
            r = self._head(now)
            if r is None:
                break
            need = self._blocks_needed(r)
            saved = self._preempted.get(r.id)
            # resume copies its saved K/V into fresh blocks, so it draws
            # its full need from the free list; a fresh request re-uses
            # matched prefix blocks in place
            matched_blocks = 0
            if saved is None and self._cache is not None:
                matched_blocks = (self._cache.match_tokens(r.prompt)
                                  // self.cfg.block_size)
            need_free = need - matched_blocks
            if not self._make_room(r, need_free, now):
                break                      # head-of-line waits for room
            slot = self._free_slot()
            self.queue.remove(r)
            if saved is not None:
                del self._preempted[r.id]
                self._resume_into(slot, r, saved, need, now)
            elif self.cfg.prefill_chunk is not None:
                self._begin_chunked_prefill(slot, r, need, now)
            else:
                self._prefill_into(slot, r, need, now)
            admitted += 1
        return admitted

    def _make_room(self, r: Request, need_free: int, now: float) -> bool:
        """Free a slot + ``need_free`` blocks for ``r``: LRU-evict
        cache-only blocks first, then preempt active requests of
        strictly lower priority (worst first).  Returns True iff ``r``
        can be admitted now."""
        while True:
            short = need_free - (self.pool.num_free - self._reserved)
            if short > 0 and self._cache is not None \
                    and self._cache.evict(short) > 0:
                continue
            if self._free_slot() is not None and \
                    self.pool.num_free - self._reserved >= need_free:
                return True
            victim = self._preemption_victim(r)
            if victim is None:
                return False
            self._preempt(victim, now)

    def _preemption_victim(self, r: Request) -> Optional[int]:
        """Least-urgent *active* slot whose priority is strictly worse
        than ``r``'s (prefilling slots finish; equal priority never
        preempts — no livelock)."""
        worst = None
        for s in range(self.cfg.slots):
            q = self._slot_req[s]
            if q is None or not self._active[s] or q.priority <= r.priority:
                continue
            if worst is None or \
                    self._prio_key(q) > self._prio_key(self._slot_req[worst]):
                worst = s
        return worst

    def _prefill_into(self, slot: int, r: Request, need: int, now: float) -> None:
        cfg, P = self.cfg, len(r.prompt)
        n0 = max(1, -(-P // cfg.block_size))
        blocks = self.pool.alloc(r.id, n0)
        self._reserved += need - n0
        prompt = jnp.asarray(np.asarray(r.prompt, np.int32)[None, :])
        # eager, exact-length prefill: identical values to the solo
        # engine's (prefill K/V and logits do not depend on cache width)
        with obs.span("serve.prefill", req=r.id, tokens=P):
            logits, kv = self.model.prefill(self._exec_params, prompt, P, None)
        flat = kv_cache.flat_slots(blocks, P, cfg.block_size)
        self.pool_state = kv_cache.scatter_prefill(
            self.pool_state, {k: v[:, 0] for k, v in kv.items()}, flat)
        first = self._sample_first(logits, r)
        self.stats["prefills"] += 1
        self.stats["prefill_tokens"] += P
        if self._obs:
            # first token is sampled at admission, so TTFT and admission
            # wait coincide unless the request queued before a free slot
            self._m_wait.observe(max(now - r.arrival, 0.0))
            self._m_ttft.observe(max(now - r.arrival, 0.0))
            self._pend_waits.append((r.priority, max(now - r.arrival, 0.0)))
            self._c_prefills.inc()
            self._c_prefill_tokens.inc(P)

        self._tables[slot] = kv_cache.table_row(blocks,
                                                cfg.max_blocks_per_request)
        self._pos[slot] = P
        self._token[slot, 0] = int(first[0])
        self._req_ids[slot] = r.id
        self._tok_idx[slot] = 1
        self._temps[slot] = r.temperature
        self._active[slot] = True
        self._slot_req[slot] = r
        self._emitted[slot] = [int(first[0])]
        self._emit_times[slot] = [now]
        self._meta[slot] = {"admitted": now, "first_token": now,
                            "admitted_step": self.stats["steps"],
                            "need": need, "hit_tokens": 0, "preemptions": 0}
        self._maybe_finish(slot, now)

    def _sample_first(self, logits: jnp.ndarray, r: Request) -> np.ndarray:
        """Sample a request's first token from its prefill logits with the
        same folded key the decode step would use at index 0."""
        keys0 = sampling.step_keys(
            sampling.request_keys(self.cfg.seed,
                                  jnp.asarray([r.id], jnp.int32)), 0)
        first_logits = logits[:, -1, :].astype(jnp.float32)
        if self.executor is not None:
            first_logits = self.executor.replicate_logits(first_logits)
        return np.asarray(sampling.sample(first_logits, keys0, r.temperature))

    # ------------------------------------------------------------------
    # chunked prefill
    # ------------------------------------------------------------------
    def _begin_chunked_prefill(self, slot: int, r: Request, need: int,
                               now: float) -> None:
        """Claim a slot and the prompt's blocks; prefix-cache hits adopt
        the matched blocks (read-only) and skip their chunks.  The slot
        stays decode-inactive until the last chunk lands."""
        cfg, P = self.cfg, len(r.prompt)
        hit_blocks, matched = [], 0
        if self._cache is not None:
            hit_blocks, matched = self._cache.acquire(r.id, r.prompt)
        n_own = max(1, -(-P // cfg.block_size)) - len(hit_blocks)
        own = self.pool.alloc(r.id, n_own)
        self._reserved += need - len(hit_blocks) - n_own
        blocks = hit_blocks + own
        self._req_ids[slot] = r.id
        self._temps[slot] = r.temperature
        self._slot_req[slot] = r
        self._emitted[slot] = []
        self._emit_times[slot] = []
        # the slot's live table row stays TRASH until activation — the
        # decode step writes unconditionally per slot, and only the trash
        # block may absorb writes for not-yet-active slots
        self._prefill[slot] = {
            "table": kv_cache.table_row(blocks, cfg.max_blocks_per_request),
            "blocks": blocks, "done": matched}
        self._meta[slot] = {"admitted": now, "first_token": now,
                            "admitted_step": self.stats["steps"],
                            "need": need, "hit_tokens": matched,
                            "preemptions": 0}
        if self._obs:
            self._m_wait.observe(max(now - r.arrival, 0.0))
            self._pend_waits.append((r.priority, max(now - r.arrival, 0.0)))

    def _prefill_tick(self, now: float) -> bool:
        """Run ONE prefill chunk for the most urgent prefilling slot.
        One chunk per scheduler tick is the ITL bound: decode ticks are
        never delayed by more than one chunk's latency."""
        best = None
        for s in range(self.cfg.slots):
            if self._prefill[s] is None:
                continue
            if best is None or self._prio_key(self._slot_req[s]) < \
                    self._prio_key(self._slot_req[best]):
                best = s
        if best is None:
            return False
        self._prefill_chunk_step(best, now)
        return True

    def _prefill_chunk_step(self, slot: int, now: float) -> None:
        cfg, st, r = self.cfg, self._prefill[slot], self._slot_req[slot]
        P, C = len(r.prompt), cfg.prefill_chunk
        o = st["done"]
        n_valid = min(C, P - o)
        toks = np.zeros((1, C), np.int32)
        toks[0, :n_valid] = np.asarray(r.prompt, np.int32)[o:o + n_valid]
        with obs.span("serve.prefill_chunk", req=r.id, offset=o,
                      tokens=n_valid):
            logits, self.pool_state = self._chunk_fn(
                self._exec_params, self.pool_state,
                jnp.asarray(st["table"]), jnp.asarray(toks),
                jnp.int32(o), jnp.int32(n_valid))
        st["done"] = o + n_valid
        self.stats["prefill_chunks"] += 1
        self.stats["prefill_tokens"] += n_valid
        if st["done"] >= P:
            self._activate_prefilled(slot, logits, now)

    def _activate_prefilled(self, slot: int, logits: jnp.ndarray,
                            now: float) -> None:
        """Last chunk landed: sample the first token, cache the prompt's
        full blocks, flip the slot decode-active."""
        cfg, st, r = self.cfg, self._prefill[slot], self._slot_req[slot]
        P = len(r.prompt)
        first = self._sample_first(logits, r)
        self.stats["prefills"] += 1
        if self._obs:
            self._m_ttft.observe(max(now - r.arrival, 0.0))
            self._c_prefills.inc()
            self._c_prefill_tokens.inc(P)
        if self._cache is not None:
            self._cache.insert(r.prompt, st["blocks"][:P // cfg.block_size])
        self._tables[slot] = st["table"]
        self._pos[slot] = P
        self._token[slot, 0] = int(first[0])
        self._tok_idx[slot] = 1
        self._active[slot] = True
        self._emitted[slot] = [int(first[0])]
        self._emit_times[slot] = [now]
        self._meta[slot]["first_token"] = now
        self._prefill[slot] = None
        self._maybe_finish(slot, now)

    # ------------------------------------------------------------------
    # preemption / resume
    # ------------------------------------------------------------------
    def _preempt(self, slot: int, now: float) -> None:
        """Evict an active request: copy its written K/V rows to the
        host, free its blocks, re-queue it.  Resume restores the rows
        via ``scatter_prefill`` (an identity cast for pool-dtype data),
        so the decode continues bitwise-exactly where it stopped."""
        r = self._slot_req[slot]
        pos = int(self._pos[slot])
        blocks = self.pool.blocks_of(r.id)
        flat = kv_cache.flat_slots(blocks, pos, self.cfg.block_size)
        with obs.span("serve.preempt", req=r.id, tokens=pos):
            kv = {name: np.asarray(self.pool_state[name][:, flat])
                  for name in self.pool_state}
        meta = dict(self._meta[slot])
        meta["preemptions"] = meta.get("preemptions", 0) + 1
        self._preempted[r.id] = {
            "pos": pos, "token": int(self._token[slot, 0]),
            "tok_idx": int(self._tok_idx[slot]),
            "emitted": list(self._emitted[slot]),
            "emit_times": list(self._emit_times[slot]),
            "kv": kv, "meta": meta}
        self._reserved -= meta["need"] - len(blocks)
        self.pool.free_request(r.id)
        self._active[slot] = False
        self._tables[slot] = kv_cache.TRASH_BLOCK
        self._pos[slot] = 0
        self._slot_req[slot] = None
        self._emitted[slot] = []
        self._emit_times[slot] = []
        self.queue.append(r)
        self.stats["preemptions"] += 1
        log.debug("preempted request %d at pos %d", r.id, pos)

    def _resume_into(self, slot: int, r: Request, saved: Dict[str, Any],
                     need: int, now: float) -> None:
        cfg = self.cfg
        pos = saved["pos"]
        n0 = max(1, -(-pos // cfg.block_size))
        blocks = self.pool.alloc(r.id, n0)
        self._reserved += need - n0
        flat = kv_cache.flat_slots(blocks, pos, cfg.block_size)
        self.pool_state = kv_cache.scatter_prefill(self.pool_state,
                                                   saved["kv"], flat)
        self._tables[slot] = kv_cache.table_row(blocks,
                                                cfg.max_blocks_per_request)
        self._pos[slot] = pos
        self._token[slot, 0] = saved["token"]
        self._req_ids[slot] = r.id
        self._tok_idx[slot] = saved["tok_idx"]
        self._temps[slot] = r.temperature
        self._active[slot] = True
        self._slot_req[slot] = r
        self._emitted[slot] = list(saved["emitted"])
        self._emit_times[slot] = list(saved["emit_times"])
        meta = dict(saved["meta"])
        meta["need"] = need
        self._meta[slot] = meta
        self.stats["resumes"] += 1
        log.debug("resumed request %d at pos %d", r.id, pos)

    # ------------------------------------------------------------------
    # decode loop
    # ------------------------------------------------------------------
    def _grow_blocks(self) -> None:
        """Lazy allocation: a slot about to write position ``pos`` needs
        block ``pos // block_size``; admission reserved it, so this alloc
        cannot fail."""
        for slot in range(self.cfg.slots):
            if not self._active[slot]:
                continue
            r = self._slot_req[slot]
            need_idx = int(self._pos[slot]) // self.cfg.block_size
            have = len(self.pool.blocks_of(r.id))
            if need_idx >= have:
                new = self.pool.alloc(r.id, need_idx - have + 1)
                self._reserved -= len(new)
                self._tables[slot, have:have + len(new)] = new

    def _tick(self, now: float) -> None:
        """One jitted decode step over all slots + host-side bookkeeping."""
        self._grow_blocks()
        t0 = time.perf_counter()
        token, self.pool_state = self._step_fn(
            self._exec_params, self.pool_state, jnp.asarray(self._tables),
            jnp.asarray(self._pos), jnp.asarray(self._token),
            jnp.asarray(self._req_ids), jnp.asarray(self._tok_idx),
            jnp.asarray(self._active), jnp.asarray(self._temps))
        token = np.asarray(token)   # device sync: the step really finished
        self.stats["step_walls"].append(time.perf_counter() - t0)
        self.stats["steps"] += 1
        n_active = int(self._active.sum())
        self.stats["active_slot_steps"] += n_active
        self.stats["context_tokens"] += int((self._pos[self._active] + 1).sum())
        if self._obs:
            self._record_tick_obs(n_active)
        for slot in range(self.cfg.slots):
            if not self._active[slot]:
                continue
            self._emitted[slot].append(int(token[slot, 0]))
            self._emit_times[slot].append(now)
            self._token[slot] = token[slot]
            self._pos[slot] += 1
            self._tok_idx[slot] += 1
            self._maybe_finish(slot, now)

    def _record_tick_obs(self, n_active: int) -> None:
        """Per-tick SLO recordings: everything here is host state the
        decode loop already computed (the token sync in ``_tick`` is the
        baseline sync, not one obs added).  Kept as ONE method so
        ``benchmarks/serve_bench.bench_obs_overhead`` can time the exact
        recording sequence the loop runs to derive its overhead gate.
        Scheduler-event counters (chunks, preemptions, cache traffic)
        flush as per-tick deltas against ``stats`` — one ``inc`` per
        instrument per tick regardless of event volume."""
        self._m_step.observe(self.stats["step_walls"][-1])
        self._m_queue.observe(len(self.queue))
        self._m_occ.observe(self.pool.num_live
                            / max(self.cfg.num_blocks - 1, 1))
        self._m_active.observe(n_active)
        self._c_decode_steps.inc()
        self._c_decode_tokens.inc(n_active)
        self._m_prefill_pending.observe(sum(
            len(self._slot_req[s].prompt) - p["done"]
            for s, p in enumerate(self._prefill) if p is not None))
        self._flush_delta(self._c_prefill_chunks, "prefill_chunks",
                          self.stats["prefill_chunks"])
        self._flush_delta(self._c_preemptions, "preemptions",
                          self.stats["preemptions"])
        if self._cache is not None:
            self._flush_delta(self._c_prefix_hits, "hits", self._cache.hits)
            self._flush_delta(self._c_prefix_misses, "misses",
                              self._cache.misses)
            self._flush_delta(self._c_prefix_hit_tokens, "hit_tokens",
                              self._cache.hit_tokens)
            self._flush_delta(self._c_prefix_evicted, "evicted",
                              self._cache.evicted_blocks)
        self._flush_waits()

    def _flush_delta(self, counter: Any, key: str, total: int) -> None:
        d = total - self._obs_flushed[key]
        if d:
            counter.inc(d)
            self._obs_flushed[key] = total

    def _wait_hist(self, priority: int) -> Any:
        """Per-priority admission-wait histogram, bound once per class."""
        h = self._m_wait_prio.get(priority)
        if h is None:
            h = obs.registry().histogram(
                f"serve.admission_wait_s.p{priority}", obs.LATENCY_BUCKETS_S)
            self._m_wait_prio[priority] = h
        return h
    def _flush_waits(self) -> None:
        # bounded by max_prefills_per_tick admissions per tick — this is
        # a per-tick flush of already-buffered host floats, not a
        # per-token recording
        for prio, wait in self._pend_waits:
            self._wait_hist(prio).observe(wait)
        self._pend_waits.clear()

    def _maybe_finish(self, slot: int, now: float) -> None:
        r = self._slot_req[slot]
        toks = self._emitted[slot]
        reason = None
        if r.eos_id is not None and toks and toks[-1] == r.eos_id:
            reason = "eos"
        elif len(toks) >= r.max_new_tokens:
            reason = "length"
        if reason is None:
            return
        meta = self._meta[slot]
        if self._obs and len(toks) > 1:
            self._m_itl.observe(max(now - meta["first_token"], 0.0)
                                / (len(toks) - 1))
        self._reserved -= meta["need"] - len(self.pool.blocks_of(r.id))
        self.pool.free_request(r.id)
        self._active[slot] = False
        self._tables[slot] = kv_cache.TRASH_BLOCK
        self._pos[slot] = 0
        self._slot_req[slot] = None
        self.results[r.id] = RequestResult(
            id=r.id, tokens=np.asarray(toks, np.int32), reason=reason,
            prompt_len=len(r.prompt), arrival=r.arrival,
            admitted=meta["admitted"], first_token=meta["first_token"],
            finished=now, admitted_step=meta["admitted_step"],
            finished_step=self.stats["steps"], priority=r.priority,
            prefix_hit_tokens=meta.get("hit_tokens", 0),
            preemptions=meta.get("preemptions", 0),
            token_times=np.asarray(self._emit_times[slot], np.float64))

    # ------------------------------------------------------------------
    # driver
    # ------------------------------------------------------------------
    def _busy(self) -> bool:
        return bool(self._active.any()) or \
            any(p is not None for p in self._prefill)

    def run(self, requests: Optional[List[Request]] = None
            ) -> List[RequestResult]:
        """Serve every submitted request to completion (trace-driven: a
        request with ``arrival > now`` waits).  Returns results by id."""
        for r in requests or ():
            self.submit(r)
        t0 = time.monotonic()
        while self.queue or self._busy():
            now = time.monotonic() - t0
            if not self._busy() and self.queue and \
                    all(r.arrival > now for r in self.queue):
                soonest = min(r.arrival for r in self.queue)
                time.sleep(min(soonest - now, 0.05))
                continue
            admitted = self._admit(now)
            prefilled = self._prefill_tick(time.monotonic() - t0)
            if self._active.any():
                self._tick(time.monotonic() - t0)
            elif not admitted and not prefilled:
                # nothing running and the head could not be admitted:
                # with no sharers left every cache block is evictable and
                # submit() bounds need to the pool size, so this is a
                # scheduler accounting bug — fail loudly, don't spin
                raise RuntimeError(
                    f"scheduler stall: {len(self.queue)} queued, "
                    f"{self.pool.num_free} free blocks, "
                    f"{self._reserved} reserved")
        return [self.results[i] for i in sorted(self.results)]

    def defrag(self) -> int:
        """Compact live blocks to the low end of the pool; returns the
        number of blocks moved.  Safe between ticks: tables of active
        and prefilling slots — and the prefix cache's node ids — are
        rewritten from the allocator's remapped state."""
        remap = self.pool.defrag()
        if self._obs:
            self._c_defrags.inc()
            self._c_defrag_blocks.inc(len(remap))
        if not remap:
            return 0
        self.pool_state = kv_cache.apply_defrag(
            self.pool_state, remap, self.cfg.num_blocks, self.cfg.block_size)
        if self._cache is not None:
            self._cache.apply_defrag(remap)
        for slot, r in enumerate(self._slot_req):
            if r is None:
                continue
            row = kv_cache.table_row(self.pool.blocks_of(r.id),
                                     self.cfg.max_blocks_per_request)
            if self._prefill[slot] is not None:
                self._prefill[slot]["table"] = row
                self._prefill[slot]["blocks"] = self.pool.blocks_of(r.id)
            else:
                self._tables[slot] = row
        return len(remap)


def synthetic_trace(num_requests: int, rate: float, vocab: int,
                    prompt_len: tuple = (8, 16), max_new_tokens: int = 16,
                    temperature: float = 0.0, eos_id: Optional[int] = None,
                    seed: int = 0, priorities: int = 1,
                    deadline_s: Optional[float] = None,
                    shared_prefix_len: int = 0) -> List[Request]:
    """Poisson(rate) arrival trace with uniform prompt lengths — the
    synthetic load for ``launch/serve.py`` and ``benchmarks/serve_bench``.
    ``rate <= 0`` means every request arrives at t=0 (closed-loop
    pressure).  ``priorities > 1`` assigns each request a uniform random
    priority class in ``[0, priorities)``; ``deadline_s`` gives every
    request ``arrival + deadline_s`` as its deadline.
    ``shared_prefix_len > 0`` prepends one common system-prompt prefix to
    every prompt (the prefix-cache traffic shape); ``prompt_len`` then
    sizes the per-request tail."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, size=shared_prefix_len).astype(np.int32)
    t, reqs = 0.0, []
    for i in range(num_requests):
        if rate > 0:
            t += float(rng.exponential(1.0 / rate))
        P = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        tail = rng.integers(0, vocab, size=P).astype(np.int32)
        prompt = np.concatenate([prefix, tail]) if shared_prefix_len else tail
        reqs.append(Request(
            id=i, prompt=prompt, max_new_tokens=max_new_tokens,
            temperature=temperature, eos_id=eos_id, arrival=t,
            priority=int(rng.integers(0, priorities)) if priorities > 1 else 0,
            deadline=None if deadline_s is None else t + deadline_s))
    return reqs
