"""Continuous-batching request scheduler over the paged KV pool.

The batcher turns the single-shot ``Engine`` into a request-level
serving loop: an admission queue of :class:`Request`, a fixed number of
serving *slots*, and **one** jitted decode step
(``ModelDef.paged_step``) over those slots.  Requests join mid-flight —
a solo eager prefill writes their K/V into freshly allocated blocks and
their slot goes active — and retire on EOS or length by flipping the
active mask and freeing their blocks.  The decode step never
re-specializes: slot count, block-table width, and pool shape are fixed
at construction, so joining/retiring costs zero recompilation
(tests pin ``_step_fn._cache_size() == 1``).

Correctness anchor: every request's output is **token-identical** to a
solo ``Engine.generate(prompt, request_ids=[id])`` with
``cache_len == BatchConfig.context_len`` — on dense and 2:4-packed
checkpoints, greedy and temperature sampling (see DESIGN.md §9 for why
the paged read and the per-request PRNG folding make this exact).

Block accounting: blocks are allocated lazily as a request's context
grows, but admission *reserves* the request's worst-case block count
(``ceil((P + max_new) / block_size)``) against the pool, so an active
request can never hit ``PoolExhausted`` mid-flight — pressure shows up
as queueing delay, never as a mid-generation failure.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models.registry import ModelDef
from repro.serve import kv_cache, sampling
from repro.serve import packed as packed_lib
from repro.serve.engine import prepare_serving_params
from repro.utils import get_logger

log = get_logger("serve.batcher")


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray                 # (P,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    eos_id: Optional[int] = None       # None: run to max_new_tokens
    arrival: float = 0.0               # seconds from trace start


@dataclasses.dataclass
class RequestResult:
    id: int
    tokens: np.ndarray                 # generated tokens (includes EOS if hit)
    reason: str                        # "length" | "eos"
    prompt_len: int
    arrival: float                     # seconds from run start
    admitted: float
    first_token: float
    finished: float
    admitted_step: int                 # decode-step counter at admission
    finished_step: int

    @property
    def latency(self) -> float:
        return self.finished - self.arrival


@dataclasses.dataclass(frozen=True)
class BatchConfig:
    slots: int = 4
    block_size: int = 16
    max_blocks_per_request: int = 4    # context width = block_size * this
    num_blocks: int = 64               # pool size incl. reserved trash block
    seed: int = 0                      # sampling PRNG seed (Engine's cfg.seed)
    sparse: str = "auto"               # auto | packed | dense
    max_prefills_per_tick: int = 1     # admission rate per scheduler tick
    decode_impl: str = "fused"         # fused (block-table flash kernel)
                                       # | reference (gather path, the
                                       #   bitwise oracle — DESIGN.md §11)

    @property
    def context_len(self) -> int:
        """Per-request context capacity (== the solo engine ``cache_len``
        that the token-identity anchor compares against)."""
        return self.block_size * self.max_blocks_per_request


class ContinuousBatcher:
    def __init__(self, model: ModelDef, params: Any,
                 cfg: BatchConfig = BatchConfig(),
                 executor: Optional[Any] = None):
        """``executor`` (distributed/executor.py) makes the batcher
        tensor-parallel: params place per the Megatron column/row rules
        and the paged KV pool takes its heads-sharded device layout (each
        "model" shard owns its attention heads' pages; the one all-reduce
        per block lands after wo/down — GSPMD inserts it from the
        shardings).  Host-side scheduling (admission, block tables,
        retirement) is unchanged, and the decoded tokens are pinned
        token-identical to the single-device batcher in
        tests/distributed_cases.py."""
        if model.paged_step is None or model.prefill is None:
            raise ValueError(
                f"family {model.cfg.family!r} has no paged serving path "
                f"(paged_step/prefill); the continuous batcher covers the "
                f"transformer families")
        if model.cfg.family == "vlm":
            raise ValueError(
                "vlm prefill needs per-request patch embeddings and Request "
                "carries none — serve VLMs through Engine.generate(extras=...)")
        if cfg.num_blocks < 2:
            raise ValueError("num_blocks must be >= 2 (block 0 is trash)")
        from repro.serve.engine import DECODE_IMPLS
        if cfg.decode_impl not in DECODE_IMPLS:
            raise ValueError(f"unknown decode_impl {cfg.decode_impl!r}; "
                             f"choices: {DECODE_IMPLS}")
        self.model, self.cfg = model, cfg
        self.executor = executor
        self.params, self.sparse_stats = prepare_serving_params(params, cfg.sparse)
        # accounting tree (self.params, may stay packed — serve_bench
        # meters its bytes) vs compute tree (packed.decode_view: identity
        # on TPU, cached dense unpack on CPU)
        exec_params = packed_lib.decode_view(self.params)
        self.pool = kv_cache.BlockPool(cfg.num_blocks, cfg.block_size)
        self.pool_state = model.init_paged_state(cfg.num_blocks, cfg.block_size)
        if executor is not None:
            same = exec_params is self.params
            self.params = executor.shard_params(self.params)
            exec_params = self.params if same else \
                executor.shard_params(exec_params)
            self.pool_state = executor.shard_paged_pool(self.pool_state)
        self._exec_params = exec_params

        S = cfg.slots
        self._tables = np.zeros((S, cfg.max_blocks_per_request), np.int32)
        self._pos = np.zeros((S,), np.int32)       # next write position
        self._token = np.zeros((S, 1), np.int32)   # last sampled token
        self._req_ids = np.zeros((S,), np.int32)
        self._tok_idx = np.zeros((S,), np.int32)   # sample index of next token
        self._temps = np.zeros((S,), np.float32)
        self._active = np.zeros((S,), bool)
        self._slot_req: List[Optional[Request]] = [None] * S
        self._emitted: List[List[int]] = [[] for _ in range(S)]
        self._meta: List[Dict[str, Any]] = [{} for _ in range(S)]
        self._reserved = 0                         # promised, unallocated blocks

        self.queue: Deque[Request] = deque()
        self.results: Dict[int, RequestResult] = {}
        self.stats = {"steps": 0, "prefills": 0, "prefill_tokens": 0,
                      "active_slot_steps": 0, "context_tokens": 0,
                      "step_walls": []}   # measured per-tick decode seconds

        # serve-side SLO metrics (repro.obs): instruments are fetched ONCE
        # here behind enabled(), so the per-tick cost while disabled is a
        # single attribute check; recording only touches values the loop
        # already holds on the host (no extra device syncs — OBS001)
        self._obs = obs.enabled()
        if self._obs:
            reg = obs.registry()
            self._m_ttft = reg.histogram("serve.ttft_s",
                                         obs.LATENCY_BUCKETS_S)
            self._m_itl = reg.histogram("serve.inter_token_s",
                                        obs.LATENCY_BUCKETS_S)
            self._m_wait = reg.histogram("serve.admission_wait_s",
                                         obs.LATENCY_BUCKETS_S)
            self._m_step = reg.histogram("serve.step_s",
                                         obs.LATENCY_BUCKETS_S)
            self._m_queue = reg.histogram("serve.queue_depth",
                                          obs.COUNT_BUCKETS)
            self._m_occ = reg.histogram("serve.pool_occupancy",
                                        obs.FRACTION_BUCKETS)
            self._m_active = reg.histogram("serve.active_slots",
                                           obs.COUNT_BUCKETS)
            self._c_decode_steps = reg.counter("serve.decode_steps")
            self._c_prefills = reg.counter("serve.prefills")
            self._c_prefill_tokens = reg.counter("serve.prefill_tokens")
            self._c_decode_tokens = reg.counter("serve.decode_tokens")
            self._c_defrags = reg.counter("serve.defrags")
            self._c_defrag_blocks = reg.counter("serve.defrag_blocks_moved")

        def step(params, pool, tables, pos, token, req_ids, tok_idx, active,
                 temps):
            logits, pool = model.paged_step(params, pool, tables, token, pos,
                                            active, cfg.block_size,
                                            impl=cfg.decode_impl)
            logits = logits[:, -1, :].astype(jnp.float32)
            if executor is not None:
                # sampling must see replicated logits (see
                # MeshExecutor.replicate_logits) or TP temperature draws
                # diverge from the single-device path
                logits = executor.replicate_logits(logits)
            keys = sampling.step_keys(sampling.request_keys(cfg.seed, req_ids),
                                      tok_idx)
            return sampling.sample(logits, keys, temps)[:, None], pool

        self._step_fn = jax.jit(step, donate_argnums=(1,))

    # ------------------------------------------------------------------
    # submission / admission
    # ------------------------------------------------------------------
    def _blocks_needed(self, r: Request) -> int:
        return -(-(len(r.prompt) + r.max_new_tokens) // self.cfg.block_size)

    def submit(self, request: Request) -> None:
        P, n = len(request.prompt), request.max_new_tokens
        if P < 1:
            raise ValueError(f"request {request.id}: empty prompt")
        if n < 1:
            raise ValueError(f"request {request.id}: max_new_tokens must be "
                             f">= 1, got {n}")
        limit = min(self.cfg.context_len, self.model.cfg.max_seq)
        if P + n > limit:
            raise ValueError(
                f"request {request.id}: prompt_len + max_new_tokens = {P + n} "
                f"exceeds the serving context ({self.cfg.context_len}) or the "
                f"model's max_seq ({self.model.cfg.max_seq})")
        if self._blocks_needed(request) > self.cfg.num_blocks - 1:
            raise kv_cache.PoolExhausted(
                f"request {request.id} needs {self._blocks_needed(request)} "
                f"blocks; the pool only has {self.cfg.num_blocks - 1}")
        if request.id in self.results or any(
                q.id == request.id for q in self.queue) or any(
                r is not None and r.id == request.id for r in self._slot_req):
            raise ValueError(f"duplicate request id {request.id}")
        self.queue.append(request)

    def _free_slot(self) -> Optional[int]:
        for s in range(self.cfg.slots):
            if not self._active[s]:
                return s
        return None

    def _admit(self, now: float) -> int:
        """FIFO admission: prefill queued+arrived requests into free slots
        while the pool can reserve their worst case."""
        admitted = 0
        while self.queue and admitted < self.cfg.max_prefills_per_tick:
            r = self.queue[0]
            if r.arrival > now:
                break
            slot = self._free_slot()
            if slot is None:
                break
            need = self._blocks_needed(r)
            if self.pool.num_free - self._reserved < need:
                break                      # head-of-line waits for blocks
            self.queue.popleft()
            self._prefill_into(slot, r, need, now)
            admitted += 1
        return admitted

    def _prefill_into(self, slot: int, r: Request, need: int, now: float) -> None:
        cfg, P = self.cfg, len(r.prompt)
        n0 = max(1, -(-P // cfg.block_size))
        blocks = self.pool.alloc(r.id, n0)
        self._reserved += need - n0
        prompt = jnp.asarray(np.asarray(r.prompt, np.int32)[None, :])
        # eager, exact-length prefill: identical values to the solo
        # engine's (prefill K/V and logits do not depend on cache width)
        with obs.span("serve.prefill", req=r.id, tokens=P):
            logits, kv = self.model.prefill(self._exec_params, prompt, P, None)
        flat = kv_cache.flat_slots(blocks, P, cfg.block_size)
        self.pool_state = kv_cache.scatter_prefill(
            self.pool_state, {k: v[:, 0] for k, v in kv.items()}, flat)
        keys0 = sampling.step_keys(
            sampling.request_keys(cfg.seed, jnp.asarray([r.id], jnp.int32)), 0)
        first_logits = logits[:, -1, :].astype(jnp.float32)
        if self.executor is not None:
            first_logits = self.executor.replicate_logits(first_logits)
        first = sampling.sample(first_logits, keys0, r.temperature)
        self.stats["prefills"] += 1
        self.stats["prefill_tokens"] += P
        if self._obs:
            # first token is sampled at admission, so TTFT and admission
            # wait coincide unless the request queued before a free slot
            self._m_wait.observe(max(now - r.arrival, 0.0))
            self._m_ttft.observe(max(now - r.arrival, 0.0))
            self._c_prefills.inc()
            self._c_prefill_tokens.inc(P)

        self._tables[slot] = kv_cache.table_row(blocks,
                                                cfg.max_blocks_per_request)
        self._pos[slot] = P
        self._token[slot, 0] = int(first[0])
        self._req_ids[slot] = r.id
        self._tok_idx[slot] = 1
        self._temps[slot] = r.temperature
        self._active[slot] = True
        self._slot_req[slot] = r
        self._emitted[slot] = [int(first[0])]
        self._meta[slot] = {"admitted": now, "first_token": now,
                            "admitted_step": self.stats["steps"],
                            "need": need}
        self._maybe_finish(slot, now)

    # ------------------------------------------------------------------
    # decode loop
    # ------------------------------------------------------------------
    def _grow_blocks(self) -> None:
        """Lazy allocation: a slot about to write position ``pos`` needs
        block ``pos // block_size``; admission reserved it, so this alloc
        cannot fail."""
        for slot in range(self.cfg.slots):
            if not self._active[slot]:
                continue
            r = self._slot_req[slot]
            need_idx = int(self._pos[slot]) // self.cfg.block_size
            have = len(self.pool.blocks_of(r.id))
            if need_idx >= have:
                new = self.pool.alloc(r.id, need_idx - have + 1)
                self._reserved -= len(new)
                self._tables[slot, have:have + len(new)] = new

    def _tick(self, now: float) -> None:
        """One jitted decode step over all slots + host-side bookkeeping."""
        self._grow_blocks()
        t0 = time.perf_counter()
        token, self.pool_state = self._step_fn(
            self._exec_params, self.pool_state, jnp.asarray(self._tables),
            jnp.asarray(self._pos), jnp.asarray(self._token),
            jnp.asarray(self._req_ids), jnp.asarray(self._tok_idx),
            jnp.asarray(self._active), jnp.asarray(self._temps))
        token = np.asarray(token)   # device sync: the step really finished
        self.stats["step_walls"].append(time.perf_counter() - t0)
        self.stats["steps"] += 1
        n_active = int(self._active.sum())
        self.stats["active_slot_steps"] += n_active
        self.stats["context_tokens"] += int((self._pos[self._active] + 1).sum())
        if self._obs:
            self._record_tick_obs(n_active)
        for slot in range(self.cfg.slots):
            if not self._active[slot]:
                continue
            self._emitted[slot].append(int(token[slot, 0]))
            self._token[slot] = token[slot]
            self._pos[slot] += 1
            self._tok_idx[slot] += 1
            self._maybe_finish(slot, now)

    def _record_tick_obs(self, n_active: int) -> None:
        """Per-tick SLO recordings: everything here is host state the
        decode loop already computed (the token sync in ``_tick`` is the
        baseline sync, not one obs added).  Kept as ONE method so
        ``benchmarks/serve_bench.bench_obs_overhead`` can time the exact
        recording sequence the loop runs to derive its overhead gate."""
        self._m_step.observe(self.stats["step_walls"][-1])
        self._m_queue.observe(len(self.queue))
        self._m_occ.observe(self.pool.num_live
                            / max(self.cfg.num_blocks - 1, 1))
        self._m_active.observe(n_active)
        self._c_decode_steps.inc()
        self._c_decode_tokens.inc(n_active)

    def _maybe_finish(self, slot: int, now: float) -> None:
        r = self._slot_req[slot]
        toks = self._emitted[slot]
        reason = None
        if r.eos_id is not None and toks and toks[-1] == r.eos_id:
            reason = "eos"
        elif len(toks) >= r.max_new_tokens:
            reason = "length"
        if reason is None:
            return
        meta = self._meta[slot]
        if self._obs and len(toks) > 1:
            self._m_itl.observe(max(now - meta["first_token"], 0.0)
                                / (len(toks) - 1))
        self._reserved -= meta["need"] - len(self.pool.blocks_of(r.id))
        self.pool.free_request(r.id)
        self._active[slot] = False
        self._tables[slot] = kv_cache.TRASH_BLOCK
        self._pos[slot] = 0
        self._slot_req[slot] = None
        self.results[r.id] = RequestResult(
            id=r.id, tokens=np.asarray(toks, np.int32), reason=reason,
            prompt_len=len(r.prompt), arrival=r.arrival,
            admitted=meta["admitted"], first_token=meta["first_token"],
            finished=now, admitted_step=meta["admitted_step"],
            finished_step=self.stats["steps"])

    # ------------------------------------------------------------------
    # driver
    # ------------------------------------------------------------------
    def run(self, requests: Optional[List[Request]] = None
            ) -> List[RequestResult]:
        """Serve every submitted request to completion (trace-driven: a
        request with ``arrival > now`` waits).  Returns results by id."""
        for r in requests or ():
            self.submit(r)
        t0 = time.monotonic()
        while self.queue or self._active.any():
            now = time.monotonic() - t0
            if not self._active.any() and self.queue and \
                    self.queue[0].arrival > now:
                time.sleep(min(self.queue[0].arrival - now, 0.05))
                continue
            self._admit(now)
            if self._active.any():
                self._tick(time.monotonic() - t0)
        return [self.results[i] for i in sorted(self.results)]

    def defrag(self) -> int:
        """Compact live blocks to the low end of the pool; returns the
        number of blocks moved.  Safe between ticks: tables of active
        slots are rewritten from the allocator's remapped state."""
        remap = self.pool.defrag()
        if self._obs:
            self._c_defrags.inc()
            self._c_defrag_blocks.inc(len(remap))
        if not remap:
            return 0
        self.pool_state = kv_cache.apply_defrag(
            self.pool_state, remap, self.cfg.num_blocks, self.cfg.block_size)
        for slot, r in enumerate(self._slot_req):
            if r is not None:
                self._tables[slot] = kv_cache.table_row(
                    self.pool.blocks_of(r.id), self.cfg.max_blocks_per_request)
        return len(remap)


def synthetic_trace(num_requests: int, rate: float, vocab: int,
                    prompt_len: tuple = (8, 16), max_new_tokens: int = 16,
                    temperature: float = 0.0, eos_id: Optional[int] = None,
                    seed: int = 0) -> List[Request]:
    """Poisson(rate) arrival trace with uniform prompt lengths — the
    synthetic load for ``launch/serve.py`` and ``benchmarks/serve_bench``.
    ``rate <= 0`` means every request arrives at t=0 (closed-loop
    pressure)."""
    rng = np.random.default_rng(seed)
    t, reqs = 0.0, []
    for i in range(num_requests):
        if rate > 0:
            t += float(rng.exponential(1.0 / rate))
        P = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        prompt = rng.integers(0, vocab, size=P).astype(np.int32)
        reqs.append(Request(id=i, prompt=prompt, max_new_tokens=max_new_tokens,
                            temperature=temperature, eos_id=eos_id, arrival=t))
    return reqs
