"""Paged KV-cache block pool for continuous batching.

The serving decode state is one flat *pool* per layer — tensors of shape
``(L, num_blocks * block_size, nkv, hd)`` created by
``models.transformer.init_paged_caches`` — plus per-request *block
tables* mapping logical token positions onto pool slots: position ``p``
of a request whose table is ``[b0, b1, ...]`` lives at flat slot
``b[p // block_size] * block_size + p % block_size``.

This module owns the host side of that contract:

* :class:`BlockPool` — the allocator.  Blocks are handed out lazily as a
  request's context grows and returned wholesale when it retires.  Block
  0 is the reserved **trash block**: unallocated block-table entries and
  inactive decode slots point there, so the jitted decode step writes
  unconditionally (masked slots land in trash) and never branches on
  occupancy.  The allocator therefore hands out blocks ``1..num_blocks-1``
  and guarantees no block is ever *writable* by two requests at once.

  Blocks are **refcounted** so the prefix cache (``serve/prefix_cache``)
  can share read-only prompt blocks across requests: :meth:`alloc` gives
  the owner the sole reference, :meth:`share` joins an existing live
  block to another request's table (read-only by contract — sharers
  write suffix/generated tokens into their own blocks), and
  :meth:`retain`/:meth:`release` carry the cache's own reference.  A
  block returns to the free list only when its last reference drops;
  :meth:`defrag` compacts every referenced block, owned or cache-held.
* Index helpers (:func:`flat_slots`, :func:`table_row`) shared by the
  batcher and the property tests.
* Device-side data movement (:func:`scatter_prefill`,
  :func:`apply_defrag`) — pure jnp, no model knowledge.

The device read/write side (gather to position order + masked attention)
lives in ``models/common.mha_decode_paged``; gathering the pages into
position order first is what makes the paged read bitwise-equal to a
contiguous cache (pinned in tests/test_kv_pool.py).
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

import numpy as np

TRASH_BLOCK = 0


class PoolExhausted(RuntimeError):
    """Raised when an allocation cannot be satisfied from the free list."""


class BlockPool:
    """Host-side block allocator over ``num_blocks`` fixed-size blocks.

    Block :data:`TRASH_BLOCK` is reserved; ``num_blocks - 1`` blocks are
    allocatable.  Per-request block lists keep allocation order, which is
    logical position order (the batcher allocates as the context grows),
    so ``blocks_of`` can be written straight into a block table.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the trash block)")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks, self.block_size = num_blocks, block_size
        # LIFO free list, lowest ids popped first (keeps the pool compact)
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._owned: Dict[int, List[int]] = {}
        self._ref: Dict[int, int] = {}  # block -> refcount (only > 0 entries)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_live(self) -> int:
        """Distinct blocks with at least one reference."""
        return len(self._ref)

    def blocks_of(self, request_id: int) -> List[int]:
        return list(self._owned.get(request_id, ()))

    def refcount(self, block: int) -> int:
        return self._ref.get(block, 0)

    def alloc(self, request_id: int, n: int = 1) -> List[int]:
        """Allocate ``n`` blocks for ``request_id`` (appended in order)."""
        if n > len(self._free):
            raise PoolExhausted(
                f"request {request_id} needs {n} block(s), only "
                f"{len(self._free)}/{self.num_blocks - 1} free")
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            self._ref[b] = 1
        self._owned.setdefault(request_id, []).extend(blocks)
        return blocks

    def share(self, request_id: int, blocks: Sequence[int]) -> None:
        """Join live blocks to ``request_id``'s table, read-only.

        Each block gains a reference; it appears in ``blocks_of`` so the
        request can address it via its block table, but by contract the
        sharer never writes into it (shared prefix blocks are fully
        written before they are shared).
        """
        for b in blocks:
            if self._ref.get(b, 0) < 1:
                raise ValueError(f"cannot share dead block {b}")
        for b in blocks:
            self._ref[b] += 1
        self._owned.setdefault(request_id, []).extend(blocks)

    def retain(self, blocks: Sequence[int]) -> None:
        """Add a bare reference (no owner) to each live block."""
        for b in blocks:
            if self._ref.get(b, 0) < 1:
                raise ValueError(f"cannot retain dead block {b}")
        for b in blocks:
            self._ref[b] += 1

    def release(self, blocks: Sequence[int]) -> List[int]:
        """Drop one reference per block; blocks reaching zero are freed.

        Returns the blocks actually returned to the free list.
        """
        freed = []
        for b in blocks:
            r = self._ref.get(b, 0)
            if r < 1:
                raise ValueError(f"releasing dead block {b}")
            if r == 1:
                del self._ref[b]
                freed.append(b)
            else:
                self._ref[b] = r - 1
        self._free.extend(sorted(freed, reverse=True))
        return freed

    def free_request(self, request_id: int) -> List[int]:
        """Drop ``request_id``'s reference on every block it holds.

        Blocks whose last reference this was return to the free list;
        blocks still referenced elsewhere (prefix-cache entries, other
        sharers) stay live.  Returns the request's full block list.
        """
        blocks = self._owned.pop(request_id, [])
        self.release(blocks)
        return blocks

    def defrag(self) -> Dict[int, int]:
        """Compact live blocks onto the lowest ids (trash stays put).

        Live means refcount > 0 — owned by a request *or* held by the
        prefix cache.  Returns the ``{old: new}`` remap (identity entries
        omitted) and rewrites the internal ownership/refcount maps.  The
        caller must apply the same remap to the device pool
        (:func:`apply_defrag`), to its block tables, and to the prefix
        cache (``PrefixCache.apply_defrag``) before the next decode step.
        """
        live = sorted(self._ref)
        remap = {old: new for new, old in enumerate(live, start=1)
                 if old != new}
        if remap:
            for rid, bl in self._owned.items():
                self._owned[rid] = [remap.get(b, b) for b in bl]
            self._ref = {remap.get(b, b): r for b, r in self._ref.items()}
            self._free = list(range(self.num_blocks - 1, len(live), -1))
        return remap


def flat_slots(blocks: Sequence[int], length: int, block_size: int) -> np.ndarray:
    """Flat pool slots of logical positions ``0..length-1``."""
    if length > len(blocks) * block_size:
        raise ValueError(f"{length} positions exceed {len(blocks)} block(s) "
                         f"x {block_size}")
    pos = np.arange(length)
    b = np.asarray(blocks, np.int32)
    return (b[pos // block_size] * block_size + pos % block_size).astype(np.int32)


def table_row(blocks: Sequence[int], max_blocks: int) -> np.ndarray:
    """Pad a request's block list into a fixed-width table row (trash-filled)."""
    if len(blocks) > max_blocks:
        raise ValueError(f"{len(blocks)} blocks exceed table width {max_blocks}")
    row = np.full((max_blocks,), TRASH_BLOCK, np.int32)
    row[:len(blocks)] = blocks
    return row


def scatter_prefill(pool: Dict[str, Any], kv: Dict[str, Any],
                    flat_idx: np.ndarray) -> Dict[str, Any]:
    """Write a prefill's K/V rows ``(L, P, nkv, hd)`` into pool slots
    ``flat_idx`` (P,).  Values are cast to the pool dtype — the same cast
    the contiguous serve cache applies, keeping the paged read bitwise
    equal to the contiguous one."""
    return {name: pool[name].at[:, flat_idx].set(kv[name].astype(pool[name].dtype))
            for name in pool}


def apply_defrag(pool: Dict[str, Any], remap: Dict[int, int],
                 num_blocks: int, block_size: int) -> Dict[str, Any]:
    """Permute pool contents per a :meth:`BlockPool.defrag` remap."""
    if not remap:
        return pool
    perm = np.arange(num_blocks)
    for old, new in remap.items():
        perm[new] = old

    def move(t):
        blocked = t.reshape((t.shape[0], num_blocks, block_size) + t.shape[2:])
        return blocked[:, perm].reshape(t.shape)

    return {name: move(t) for name, t in pool.items()}
