"""Public pruning API: a serializable ``PruneRecipe`` consumed by one
entry point, :func:`prune` (DESIGN.md §7).

A recipe is the complete, JSON-round-trippable description of a pruning
run — architecture, solver (registry name + its kwargs), sparsity,
error-correction mode, calibration sampling and scheduler settings:

    from repro import api

    recipe = api.PruneRecipe(arch="opt125m-proxy", method="admm",
                             sparsity="2:4",
                             solver={"rho_rel": 0.1},
                             calibration={"num_sequences": 32, "seq_len": 64},
                             scheduler={"workers": 4})
    pruned, reports, stats = api.prune(model, params, calib, recipe)

Every launcher (launch/prune.py, launch/dryrun.py, benchmarks) builds
recipes instead of hand-assembling SequentialConfig / PrunerConfig /
SchedulerConfig trees, so defaults live in exactly one place and a run
is reproducible from its serialized recipe alone.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ALL_ARCHS
from repro.core import solvers as solvers_lib
from repro.core.driver import parallel_prune
from repro.core.scheduler import SchedulerConfig
from repro.core.sequential import OperatorReport, SequentialConfig
from repro.core.solvers import LayerSolver
from repro.core.sparsity import SparsitySpec
from repro.data import CalibConfig, calibration_batches
from repro.distributed.executor import MeshConfig, MeshExecutor
from repro.eval.perplexity import EvalConfig
from repro.models.registry import ModelDef, load_arch

#: every `--arch` a launcher accepts (registry archs + the CI proxy)
ARCH_CHOICES: Tuple[str, ...] = tuple(ALL_ARCHS) + ("opt125m-proxy",)

#: checkpoint names a prune run leaves in its run dir (written by
#: launch/prune.py, consumed by launch/evaluate.py and the serve path)
DENSE_MODEL, PRUNED_MODEL = "dense_model", "pruned_model"

#: error-correction modes (core/sequential.py): "intra" is the paper's
#: layer-local correction; "full"/"cross" relay across units (serial)
_CORRECTIONS = ("intra", "none", "full", "cross")


def load_model(arch: str, smoke: bool = False) -> ModelDef:
    """The one arch -> ModelDef builder shared by all launchers."""
    if arch not in ARCH_CHOICES:
        raise ValueError(f"unknown arch {arch!r}; choices: "
                         f"{', '.join(ARCH_CHOICES)}")
    return load_arch(arch, smoke=smoke)


def _checked_kwargs(kwargs: Dict[str, Any], cls: type,
                    what: str) -> Dict[str, Any]:
    """Reject keys that are not fields of the target config dataclass —
    the recipe must fail loudly instead of silently dropping a knob."""
    fields = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(kwargs) - fields)
    if unknown:
        raise ValueError(f"unknown {what} keys {unknown}; "
                         f"valid: {sorted(fields)}")
    return dict(kwargs)


@dataclasses.dataclass
class PruneRecipe:
    """Serializable description of one pruning run.

    ``solver`` holds the registered solver's own kwargs (e.g. FISTA's
    ``fista_iters``/``outer_impl``, ADMM's ``rho_rel``, SparseGPT's
    ``blocksize``); ``calibration`` overrides :class:`CalibConfig` fields;
    ``scheduler`` overrides :class:`SchedulerConfig` fields; ``eval``
    overrides :class:`EvalConfig` fields (perplexity / KL / error-budget
    settings consumed by ``launch/evaluate.py`` and the quality bench).
    """

    arch: str = "opt125m-proxy"
    method: str = "fista"
    solver: Dict[str, Any] = dataclasses.field(default_factory=dict)
    sparsity: str = "50%"
    correction: str = "intra"
    calibration: Dict[str, Any] = dataclasses.field(default_factory=dict)
    scheduler: Dict[str, Any] = dataclasses.field(default_factory=dict)
    eval: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: mesh section ({devices, data_parallel, model_parallel} ->
    #: distributed.executor.MeshConfig): how every pipeline of this run
    #: places work on the device mesh.  Empty/1x1 = single device.
    mesh: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.correction not in _CORRECTIONS:
            raise ValueError(f"unknown correction {self.correction!r}; "
                             f"choices: {_CORRECTIONS}")
        SparsitySpec.parse(self.sparsity)          # fail early on bad specs
        self.scheduler_config()                    # ... bad kwargs
        self.calib_config()
        self.eval_config()
        self.mesh_config()
        self.build_solver()                        # ... and bad solvers —
        # a typo'd --recipe must die at load time, not after the dense
        # model has been trained

    # -- builders ------------------------------------------------------------
    def build_solver(self) -> LayerSolver:
        """Registry lookup; unknown names list the registered solvers."""
        try:
            return solvers_lib.get_solver(self.method, **self.solver)
        except TypeError as exc:
            raise ValueError(
                f"bad solver kwargs {sorted(self.solver)} for "
                f"{self.method!r}: {exc}") from None

    def sparsity_spec(self) -> SparsitySpec:
        return SparsitySpec.parse(self.sparsity)

    def sequential_config(self) -> SequentialConfig:
        solver = self.build_solver()
        # mirror a FISTA solver's config into the legacy field so anything
        # still reading cfg.pruner sees the recipe's knobs, not defaults
        pruner = solver.cfg if isinstance(solver, solvers_lib.FistaSolver) \
            else SequentialConfig().pruner
        return SequentialConfig(spec=self.sparsity_spec(), pruner=pruner,
                                method=self.method, solver=solver,
                                error_correction=self.correction)

    def calib_config(self) -> CalibConfig:
        return CalibConfig(**_checked_kwargs(self.calibration, CalibConfig,
                                             "calibration"))

    def scheduler_config(self) -> SchedulerConfig:
        return SchedulerConfig(**_checked_kwargs(self.scheduler,
                                                 SchedulerConfig, "scheduler"))

    def eval_config(self) -> EvalConfig:
        return EvalConfig(**_checked_kwargs(self.eval, EvalConfig, "eval"))

    def mesh_config(self) -> MeshConfig:
        return MeshConfig(**_checked_kwargs(self.mesh, MeshConfig, "mesh"))

    def build_executor(self) -> Optional[MeshExecutor]:
        """The run's MeshExecutor, or None for a single-device recipe.
        Device availability is checked HERE (not at recipe load), so a
        recipe authored for an 8-device pod still round-trips on a
        laptop — it just cannot execute there."""
        cfg = self.mesh_config()
        return None if cfg.is_single else MeshExecutor(cfg)

    def load_model(self, smoke: bool = False) -> ModelDef:
        return load_model(self.arch, smoke=smoke)

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PruneRecipe":
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - fields)
        if unknown:
            raise ValueError(f"unknown PruneRecipe keys {unknown}; "
                             f"valid: {sorted(fields)}")
        return cls(**d)

    def to_json(self, path: Optional[str] = None) -> str:
        text = json.dumps(self.to_dict(), indent=1, sort_keys=True)
        if path is not None:
            with open(path, "w") as f:
                f.write(text + "\n")
        return text

    @classmethod
    def from_json(cls, text_or_path: str) -> "PruneRecipe":
        if text_or_path.lstrip().startswith("{"):
            return cls.from_dict(json.loads(text_or_path))
        with open(text_or_path) as f:
            return cls.from_dict(json.load(f))


def prune(model: ModelDef, params: Any, calib: Sequence[Dict[str, Any]],
          recipe: PruneRecipe,
          sched: Optional[SchedulerConfig] = None,
          executor: Optional[MeshExecutor] = None
          ) -> Tuple[Any, List[OperatorReport], Dict[str, Any]]:
    """Prune ``params`` per the recipe.  Returns (pruned params, per-operator
    reports, scheduler stats) — the single entry point every launcher uses.

    ``executor`` overrides the recipe's ``mesh`` section (the launchers
    build one executor per process and thread it through every stage)."""
    if executor is None:
        executor = recipe.build_executor()
    seq_cfg = recipe.sequential_config()
    if executor is not None:
        seq_cfg = dataclasses.replace(seq_cfg, executor=executor)
    return parallel_prune(model, params, calib, seq_cfg,
                          sched if sched is not None
                          else recipe.scheduler_config(),
                          executor=executor)


def calibration_for(recipe: PruneRecipe, corpus: Any) -> List[Dict[str, Any]]:
    """Sample the recipe's calibration batches from a corpus."""
    return calibration_batches(corpus, recipe.calib_config())
