"""CLI: render or export a run's observability artifacts.

    python -m repro.obs report /tmp/prune_run            # text summary
    python -m repro.obs report /tmp/prune_run --json out.json
    python -m repro.obs trace  /tmp/prune_run -o trace.json   # Perfetto
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from repro.obs import OBS_SUBDIR, report as report_lib
from repro.obs import spans as spans_lib


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = ap.add_subparsers(dest="cmd", required=True)

    rp = sub.add_parser("report", help="text/JSON summary of a run dir")
    rp.add_argument("run_dir")
    rp.add_argument("--json", default=None, metavar="PATH",
                    help="also write the full summary as JSON here")

    tp = sub.add_parser("trace", help="export Chrome/Perfetto trace.json "
                                      "from a run dir's spans.jsonl")
    tp.add_argument("run_dir")
    tp.add_argument("-o", "--out", default=None,
                    help="output path (default <run_dir>/obs/trace.json)")
    args = ap.parse_args(argv)

    if not os.path.isdir(args.run_dir):
        print(f"error: not a directory: {args.run_dir}", file=sys.stderr)
        return 2

    if args.cmd == "report":
        summary = report_lib.summarize_run(args.run_dir)
        print(report_lib.render_text(summary))
        if args.json:
            with open(args.json, "w", encoding="utf-8") as f:
                json.dump(summary, f, indent=1, default=float)
            print(f"\nwrote {args.json}")
        return 0

    spath = os.path.join(args.run_dir, OBS_SUBDIR, "spans.jsonl")
    if not os.path.exists(spath):
        print(f"error: no spans at {spath}", file=sys.stderr)
        return 2
    out = args.out or os.path.join(args.run_dir, OBS_SUBDIR, "trace.json")
    spans_lib.export_perfetto(spans_lib.load_jsonl(spath), out)
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
