"""Counters, gauges, fixed-bucket histograms and series — pure Python.

Instruments are deliberately numpy-free: the serve hot loop records a
handful of floats per decode tick, and a ``bisect`` into a small tuple
of bucket edges plus two additions is cheaper than any array round-trip
(the obs-overhead gate in benchmarks/serve_bench.py holds instrumented
step latency within 2% of bare).

Histogram semantics are Prometheus-style upper edges: a histogram with
``buckets=(1, 2, 4)`` has four counts — values ``<= 1``, ``(1, 2]``,
``(2, 4]`` and the overflow ``> 4``.  ``bisect_left`` places a value
exactly on an edge into that edge's bucket.

``Series`` is the odd one out: an append-only list of small records for
data that isn't scalar — per-operator solver convergence traces
(``e_total``/``lam`` per outer iteration, bounded by
``PrunerConfig.trace_len``) ride in one series record per operator.

The registry's ``dump_jsonl``/``load_jsonl`` round-trip one JSON object
per metric, tagged with ``kind``.
"""
from __future__ import annotations

import json
import math
import os
import threading
from bisect import bisect_left
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

#: default edges for wall-time observations, seconds (100µs .. 10s)
LATENCY_BUCKETS_S: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

#: default edges for small nonnegative counts (queue depth, iterations)
COUNT_BUCKETS: Tuple[float, ...] = (0, 1, 2, 4, 8, 16, 32, 64, 128, 256)

#: default edges for ratios in [0, 1] (pool occupancy, error shares)
FRACTION_BUCKETS: Tuple[float, ...] = (
    0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 0.95, 1.0)


class Counter:
    kind = "counter"
    __slots__ = ("name", "value")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "name": self.name, "value": self.value}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Counter":
        c = cls(d["name"])
        c.value = d["value"]
        return c


class Gauge:
    """Last-write-wins scalar; tracks min/max over its lifetime."""

    kind = "gauge"
    __slots__ = ("name", "value", "vmin", "vmax", "n")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.n = 0

    def set(self, v: float) -> None:
        self.value = v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        self.n += 1

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "name": self.name, "value": self.value,
                "min": None if self.n == 0 else self.vmin,
                "max": None if self.n == 0 else self.vmax, "n": self.n}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Gauge":
        g = cls(d["name"])
        g.value, g.n = d["value"], d.get("n", 0)
        if g.n:
            g.vmin, g.vmax = d["min"], d["max"]
        return g


class Histogram:
    """Fixed ascending upper-edge buckets + one overflow bucket."""

    kind = "histogram"
    __slots__ = ("name", "buckets", "counts", "total", "sum", "vmin", "vmax")

    def __init__(self, name: str,
                 buckets: Sequence[float] = LATENCY_BUCKETS_S) -> None:
        edges = tuple(float(b) for b in buckets)
        if not edges or any(a >= b for a, b in zip(edges, edges[1:])):
            raise ValueError(
                f"histogram {name!r}: buckets must be strictly ascending "
                f"upper edges, got {buckets!r}")
        self.name = name
        self.buckets = edges
        self.counts = [0] * (len(edges) + 1)
        self.total = 0
        self.sum = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect_left(self.buckets, v)] += 1
        self.total += 1
        self.sum += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    @property
    def mean(self) -> Optional[float]:
        return self.sum / self.total if self.total else None

    def quantile(self, q: float) -> Optional[float]:
        """Upper-edge estimate of the q-quantile (the smallest bucket edge
        covering rank ceil(q * total); overflow resolves to the observed
        max).  Coarse by construction — SLO checks against fixed edges,
        not exact order statistics."""
        if self.total == 0:
            return None
        need = max(1, math.ceil(q * self.total))
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= need:
                return self.buckets[i] if i < len(self.buckets) else self.vmax
        return self.vmax

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "name": self.name,
                "buckets": list(self.buckets), "counts": list(self.counts),
                "total": self.total, "sum": self.sum,
                "min": None if self.total == 0 else self.vmin,
                "max": None if self.total == 0 else self.vmax}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Histogram":
        h = cls(d["name"], d["buckets"])
        h.counts = [int(c) for c in d["counts"]]
        h.total, h.sum = d["total"], d["sum"]
        if h.total:
            h.vmin, h.vmax = d["min"], d["max"]
        return h


class Series:
    """Append-only list of small JSON-able records (non-scalar data,
    e.g. per-operator solver convergence traces)."""

    kind = "series"
    __slots__ = ("name", "records")

    def __init__(self, name: str) -> None:
        self.name = name
        self.records: List[Dict[str, Any]] = []

    def append(self, record: Dict[str, Any]) -> None:
        self.records.append(record)

    def __len__(self) -> int:
        return len(self.records)

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        return iter(self.records)

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "name": self.name, "records": self.records}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Series":
        s = cls(d["name"])
        s.records = list(d["records"])
        return s


_KINDS = {cls.kind: cls for cls in (Counter, Gauge, Histogram, Series)}


class MetricsRegistry:
    """Get-or-create instrument registry; creation is lock-guarded, the
    instruments themselves are single-writer by convention (the batcher
    loop and each scheduler worker record into distinct instruments or
    tolerate the GIL-level interleaving of int/float adds)."""

    def __init__(self) -> None:
        self._metrics: Dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, *args):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, *args)
            elif not isinstance(m, cls):
                raise ValueError(f"metric {name!r} already registered as "
                                 f"{m.kind}, requested {cls.kind}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  buckets: Sequence[float] = LATENCY_BUCKETS_S) -> Histogram:
        return self._get(name, Histogram, buckets)

    def series(self, name: str) -> Series:
        return self._get(name, Series)

    def get(self, name: str) -> Optional[Any]:
        return self._metrics.get(name)

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            return {name: m.to_dict()
                    for name, m in sorted(self._metrics.items())}

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()

    def __len__(self) -> int:
        return len(self._metrics)

    def dump_jsonl(self, path: str) -> None:
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(path, "w", encoding="utf-8") as f:
            for payload in self.snapshot().values():
                f.write(json.dumps(payload, default=float) + "\n")

    @classmethod
    def load_jsonl(cls, path: str) -> "MetricsRegistry":
        reg = cls()
        with open(path, "r", encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                d = json.loads(line)
                reg._metrics[d["name"]] = _KINDS[d["kind"]].from_dict(d)
        return reg
